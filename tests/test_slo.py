"""Serving health observatory (serve/obs/slo.py + export surfaces): burn-rate
math, multi-window AND gating, the ok/warn/critical state machine, the
pressure signal, SLO-driven gateway backpressure, the zero-callback disabled
contract over the new paths, capped histogram retention, trace truncation,
the span-stream writer, and the OpenMetrics exposition."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve import obs
from repro.serve.gateway import frontend as fe
from repro.serve.gateway.gateway import (GatewayConfig, MicroBatchGateway,
                                         PromptGateway, drive_prompt_loop)
from repro.serve.gateway.sensors import Arrival
from repro.serve.gateway.slots import ContinuousBatcher, make_adapter
from repro.serve.gateway.telemetry import Telemetry

BS = 4

_SETUP_CACHE: dict = {}


def _setup(arch="stablelm_3b"):
    if arch not in _SETUP_CACHE:
        cfg = dataclasses.replace(configs.smoke_config(arch),
                                  param_dtype="float32")
        params, _ = lm.init(jax.random.key(0), cfg, {})
        _SETUP_CACHE[arch] = (cfg, params)
    return _SETUP_CACHE[arch]


def _prompt_arrivals(cfg, n, plen=8, seed=0, dt=0.001):
    rng = np.random.default_rng(seed)
    return [Arrival(t=i * dt, uid=i, endpoint=0, kind="prompt",
                    payload=rng.integers(0, cfg.vocab, plen)
                    .astype(np.int32)) for i in range(n)]


def _frame_arrivals(n, dt=0.001, seed=0):
    rng = np.random.default_rng(seed)
    return [Arrival(t=i * dt, uid=i, endpoint=0, kind="frame",
                    payload=rng.integers(0, 255, (28, 28, 1))
                    .astype(np.uint8)) for i in range(n)]


def _policy(objective="ttft", target=0.01, budget=0.01,
            warn_thr=2.0, crit_thr=8.0, long_s=0.05, short_s=0.01):
    """Two-tier ladder over one latency objective + drop_rate, same window
    pair for both tiers so severity order is purely the threshold order."""
    return obs.SLOPolicy(
        objectives=(obs.SLObjective(objective, target=target, budget=budget),
                    obs.SLObjective("drop_rate", budget=budget)),
        windows=(obs.BurnWindow(long_s, short_s, crit_thr, "critical"),
                 obs.BurnWindow(long_s, short_s, warn_thr, "warn")))


# ==========================================================================
# Burn-rate math.
# ==========================================================================

def test_burn_rate_is_bad_fraction_over_budget():
    mon = obs.SLOMonitor(_policy(budget=0.1, long_s=1.0))
    # 10 events in (0, 1]: 3 violations -> bad fraction 0.3, burn 3.0
    for i in range(10):
        mon.observe("ttft", 0.1 * (i + 1), 0.02 if i < 3 else 0.001)
    assert mon.burn_rate("ttft", 1.0, 1.0) == pytest.approx(3.0)
    # a shorter window sees only the good tail
    assert mon.burn_rate("ttft", 0.65, 1.0) == 0.0
    # no events in window / unknown objective -> 0, never a crash
    assert mon.burn_rate("ttft", 0.1, 99.0) == 0.0
    assert mon.burn_rate("nope", 1.0, 1.0) == 0.0


def test_burn_rate_window_is_half_open_and_horizon_bounded():
    mon = obs.SLOMonitor(_policy(budget=1.0, long_s=1.0, short_s=0.2))
    mon.observe("ttft", 0.0, 1.0)       # bad, exactly at t - window
    mon.observe("ttft", 0.5, 1.0)       # bad, inside
    # window (0, 1]: the event at exactly t - window is excluded
    assert mon.burn_rate("ttft", 1.0, 1.0) == pytest.approx(1.0)
    # events older than the policy horizon are evicted from the deque
    for t in np.linspace(5.0, 6.0, 20):
        mon.observe("ttft", float(t), 0.001)
    assert all(ts >= 5.0 for ts, _ in mon._events["ttft"])


def test_observe_ignores_unknown_objective():
    mon = obs.SLOMonitor(_policy())
    mon.observe("tpot", 0.1, 99.0)      # not in this policy
    mon.observe_event("tpot", 0.1, True)
    assert mon.evaluate(0.2) == "ok"


def test_default_policy_scales_sre_windows():
    pol = obs.SLOPolicy.default(period_s=30 * 24 * 3600.0, ttft_s=0.1)
    # at the SRE period the canonical pairs come back in hours
    assert pol.windows[0].long_s == pytest.approx(3600.0)
    assert pol.windows[0].short_s == pytest.approx(300.0)
    assert pol.windows[0].threshold == 14.4
    assert {o.name for o in pol.objectives} == {"ttft", "drop_rate"}
    small = obs.SLOPolicy.default(period_s=60.0, ttft_s=0.1)
    assert small.windows[0].long_s == pytest.approx(3600.0 / 43200)
    with pytest.raises(AssertionError):
        obs.SLOPolicy.default(period_s=1.0, drop_budget=None)  # no objectives


def test_policy_rejects_duplicate_objectives_and_bad_windows():
    with pytest.raises(AssertionError):
        obs.SLOPolicy(objectives=(obs.SLObjective("ttft", 0.1),
                                  obs.SLObjective("ttft", 0.2)),
                      windows=(obs.BurnWindow(1.0, 0.1, 2.0, "warn"),))
    with pytest.raises(AssertionError):
        obs.BurnWindow(0.1, 1.0, 2.0, "warn")       # short > long
    with pytest.raises(AssertionError):
        obs.BurnWindow(1.0, 0.1, 2.0, "fatal")      # unknown severity
    with pytest.raises(AssertionError):
        obs.SLObjective("ttft", budget=0.0)         # zero budget


# ==========================================================================
# Multi-window gating + the state machine.
# ==========================================================================

def test_alert_requires_both_windows_to_burn():
    pol = obs.SLOPolicy(
        objectives=(obs.SLObjective("ttft", target=0.01, budget=0.4),),
        windows=(obs.BurnWindow(1.0, 0.2, 1.5, "critical"),))
    mon = obs.SLOMonitor(pol)
    # long window burns (8 bad of 12), but the short window is all good:
    # the incident is over — no alert, no flapping
    for i in range(8):
        mon.observe("ttft", 0.1 * (i + 1), 1.0)
    for t in (0.85, 0.9, 0.95, 1.0):
        mon.observe("ttft", t, 0.001)
    assert mon.burn_rate("ttft", 1.0, 1.0) > 1.5
    assert mon.burn_rate("ttft", 0.2, 1.0) == 0.0
    assert mon.evaluate(1.0) == "ok"
    # make the short window burn too -> now it trips
    for t in (1.05, 1.1, 1.15):
        mon.observe("ttft", t, 1.0)
    assert mon.evaluate(1.15) == "critical"


def test_state_machine_walks_ok_warn_critical_and_recovers():
    mon = obs.SLOMonitor(_policy(budget=0.5, warn_thr=0.8, crit_thr=1.2,
                                 long_s=1.0, short_s=0.2))
    # ramp the violation fraction phase by phase (bad events at each
    # phase's tail so the short window sees them): burn crosses the warn
    # threshold before the critical one
    t = 0.0
    states = []
    for frac in (0.0, 0.25, 0.5, 1.0):
        for i in range(20):
            t += 0.05
            bad = i >= 20 * (1 - frac)
            mon.observe("ttft", t, 0.02 if bad else 0.001)
        states.append(mon.evaluate(t))
    assert states == ["ok", "ok", "warn", "critical"]
    # recovery: a quiet stretch drains both windows back to ok
    for _ in range(40):
        t += 0.05
        mon.observe("ttft", t, 0.001)
    states.append(mon.evaluate(t))
    assert states[-1] == "ok"
    assert [(a, b) for _, a, b, _ in mon.transitions] == \
        [("ok", "warn"), ("warn", "critical"), ("critical", "ok")]
    # transition log and report agree
    rep = mon.report()
    assert rep["state"] == "ok"
    assert [tr["to"] for tr in rep["transitions"]] == \
        ["warn", "critical", "ok"]
    assert rep["objectives"]["ttft"]["bad"] == 35


def test_transitions_emit_trace_instants_and_metric_gauges():
    tr, m = obs.Tracer(), obs.MetricsRegistry(interval_s=0.01)
    mon = obs.SLOMonitor(_policy(budget=0.5, warn_thr=0.4, crit_thr=1.2,
                                 long_s=1.0, short_s=0.2),
                         tracer=tr, metrics=m)
    t = 0.0
    for i in range(40):
        t += 0.05
        mon.observe("ttft", t, 0.02 if i >= 20 else 0.001)
        mon.evaluate(t)
        m.maybe_sample(t)
    inst = [e for e in tr.events if e["name"] == "slo_transition"]
    assert len(inst) == len(mon.transitions) >= 1
    assert inst[0]["args"]["from"] == "ok"
    assert inst[0]["args"]["to"] == "warn"
    assert inst[0]["args"]["objective"] == "ttft"
    assert "burn_ttft" in inst[0]["args"]
    # burn + state gauges landed as series columns
    ts, vs = m.series("burn_ttft")
    assert len(vs) > 0 and max(vs) > 0.4
    _, states = m.series("slo_state")
    assert max(states) >= 1


# ==========================================================================
# PressureSignal.
# ==========================================================================

def test_pressure_signal_subscribe_fire_unsubscribe():
    sig = obs.PressureSignal()
    got = []
    fn = got.append
    sig.subscribe(fn)
    ev = obs.PressureEvent(t=1.0, prev="ok", state="warn", worst="ttft",
                           burns={"ttft": 3.0})
    sig.fire(ev)
    assert got == [ev] and sig.last is ev and len(sig.events) == 1
    sig.unsubscribe(fn)
    sig.fire(dataclasses.replace(ev, t=2.0, state="critical"))
    assert len(got) == 1 and len(sig.events) == 2
    assert sig.last.state == "critical"


def test_pressure_fires_on_every_transition_with_worst_objective():
    mon = obs.SLOMonitor(_policy(budget=0.5, warn_thr=0.4, crit_thr=1.2,
                                 long_s=1.0, short_s=0.2))
    seen = []
    mon.pressure.subscribe(lambda e: seen.append((e.prev, e.state, e.worst)))
    t = 0.0
    for frac in (0.5, 1.0):
        for i in range(20):
            t += 0.05
            bad = i >= 20 * (1 - frac)
            mon.observe("ttft", t, 0.02 if bad else 0.001)
        mon.evaluate(t)
    assert seen == [("ok", "warn", "ttft"), ("warn", "critical", "ttft")]


# ==========================================================================
# Forced overload end-to-end (the acceptance scenario): burn engine walks
# ok -> warn -> critical and pressure fires before the first drop.
# ==========================================================================

def test_forced_overload_pressure_fires_before_first_drop():
    spec = fe.FrontendSpec(mode="sc", bits=4)
    # service 2x slower than arrivals: queue wait ramps ~1ms per frame, so
    # the burn engine sees the degradation long before the queue bound
    gw = MicroBatchGateway(GatewayConfig(bucket_sizes=(1,), max_queue=16,
                                         max_delay_s=0.0005,
                                         service_model="fixed",
                                         fixed_service_s=0.002), spec)
    gw.warmup()
    pol = _policy("queue_wait", target=0.006, budget=0.05,
                  warn_thr=2.0, crit_thr=8.0, long_s=0.05, short_s=0.01)
    tr, m = obs.Tracer(), obs.MetricsRegistry(interval_s=0.005)
    mon = obs.SLOMonitor(pol, tracer=tr, metrics=m)
    tel = gw.run(_frame_arrivals(60), tracer=tr, metrics=m, slo=mon)

    assert [(a, b) for _, a, b, _ in mon.transitions] == \
        [("ok", "warn"), ("warn", "critical")]
    drops = tel.dropped
    assert drops, "overload must eventually hit the queue bound"
    # the whole point of the signal: pressure fired while dropping was
    # still avoidable
    assert mon.pressure.events[0].t < drops[0][3]
    assert mon.pressure.events[0].state == "warn"
    # burn series columns rode into the metrics snapshots
    ts, vs = m.series("burn_queue_wait")
    assert len(vs) > 3 and max(vs) >= 8.0
    # drop_rate burn observed every rejection too
    assert mon.report()["objectives"]["drop_rate"]["bad"] == len(drops)
    # the instrumented overload run still keeps every PR 6 integrity pin
    tel.assert_conserved()
    tr.assert_nested()
    tr.assert_energy_conserved(tel)


# ==========================================================================
# SLO-driven backpressure at the gateway door.
# ==========================================================================

def test_prompt_gateway_backpressure_shrinks_admission_bound():
    cfg, params = _setup()
    ad = make_adapter(cfg, params, n_slots=2, max_len=16, paged=True,
                      block_size=BS)
    mon = obs.SLOMonitor(_policy())
    gw = PromptGateway(ContinuousBatcher(ad), max_queue=64, slo=mon,
                       shed_factor=4)
    assert gw._admit_bound() == 64
    mon.pressure.fire(obs.PressureEvent(0.1, "ok", "critical", "ttft", {}))
    assert gw._shedding and gw._admit_bound() == 16
    # recovery restores the configured bound
    mon.pressure.fire(obs.PressureEvent(0.2, "critical", "ok", None, {}))
    assert not gw._shedding and gw._admit_bound() == 64
    # the bound never collapses to zero, however aggressive the factor
    gw2 = PromptGateway(ContinuousBatcher(ad), max_queue=8,
                        slo=obs.SLOMonitor(_policy()), shed_factor=1000)
    gw2._on_pressure(obs.PressureEvent(0.1, "ok", "critical", "ttft", {}))
    assert gw2._admit_bound() == 1


def test_drive_loop_sheds_at_admission_under_critical_burn():
    # deterministic fake engine: one batch in service per tick, every
    # completion violates its queue-wait target, so the monitor goes
    # critical after the first completion and the (callable) admission
    # bound collapses — every later arrival is shed at the door
    mon = obs.SLOMonitor(_policy("queue_wait", target=0.001, budget=0.5,
                                 warn_thr=0.1, crit_thr=0.2,
                                 long_s=10.0, short_s=10.0))
    shed = {"on": False}
    mon.pressure.subscribe(
        lambda e: shed.update(on=(e.state == "critical")))
    tel = Telemetry()
    queue: list = []

    def step():
        done, queue[:] = list(queue), []
        return done

    drive_prompt_loop(
        _frame_arrivals(30), tel,
        busy=lambda: bool(queue),
        queue_depth=lambda: len(queue),
        max_queue=lambda: 0 if shed["on"] else 100,
        submit=queue.append,
        step=step,
        record=lambda a, now: mon.observe("queue_wait", now, 1.0),
        slo=mon)

    assert mon.state == "critical"
    t_crit, _, to, worst = mon.transitions[0]
    assert to == "critical" and worst == "queue_wait"
    # first arrival served; all 29 later ones shed by the pressure hook
    # (the nominal bound of 100 was never the limit)
    assert len(tel.dropped) == 29
    assert all(t > t_crit for _, _, _, t in tel.dropped)
    assert all(reason == "queue_full" for _, _, reason, _ in tel.dropped)
    assert mon.report()["objectives"]["drop_rate"]["bad"] == 29


# ==========================================================================
# Zero-callbacks-when-disabled covers the SLO paths.
# ==========================================================================

def test_disabled_slo_makes_zero_obs_callbacks():
    cfg, params = _setup()
    ad = make_adapter(cfg, params, n_slots=2, max_len=16, paged=True,
                      block_size=BS)
    gw = PromptGateway(ContinuousBatcher(ad), max_new_tokens=3)
    gw.warmup((8,))
    spec = fe.FrontendSpec(mode="sc", bits=4)
    fgw = MicroBatchGateway(GatewayConfig(bucket_sizes=(1, 2),
                                          service_model="fixed",
                                          fixed_service_s=0.001), spec)
    fgw.warmup()
    c0 = obs.callback_count()
    gw.run(_prompt_arrivals(cfg, 4))
    fgw.run(_frame_arrivals(6))
    assert obs.callback_count() == c0


def test_slo_entry_points_charge_the_callback_counter():
    mon = obs.SLOMonitor(_policy())
    c0 = obs.callback_count()
    mon.observe("ttft", 0.1, 0.001)
    mon.observe_event("drop_rate", 0.1, False)
    mon.evaluate(0.2)
    mon.pressure.subscribe(lambda e: None)
    mon.report()
    assert obs.callback_count() > c0


# ==========================================================================
# Capped histogram retention (MetricsRegistry).
# ==========================================================================

def test_hist_under_cap_is_exact_with_zero_dropped():
    m = obs.MetricsRegistry(hist_cap=64)
    vals = list(np.random.default_rng(1).normal(size=64))
    for v in vals:
        m.observe("lat", v)
    assert sorted(m.hists["lat"]) == sorted(float(v) for v in vals)
    p = m.percentiles("lat")
    assert p["n"] == 64 and p["n_dropped"] == 0
    assert p["p50"] == pytest.approx(float(np.percentile(vals, 50)))


def test_hist_over_cap_bounds_memory_and_reports_dropped():
    m = obs.MetricsRegistry(hist_cap=100)
    for i in range(10_000):
        m.observe("lat", float(i))
    assert len(m.hists["lat"]) == 100            # bounded retention
    assert m.hist_dropped("lat") == 9_900        # explicit, not silent
    p = m.percentiles("lat")
    assert p["n"] == 10_000 and p["n_dropped"] == 9_900
    # the reservoir is a uniform sample over the whole stream: its median
    # estimates the stream median, not the tail of whatever arrived last
    assert 2_000 < p["p50"] < 8_000
    assert all(0 <= v < 10_000 for v in m.hists["lat"])


def test_hist_reservoir_is_deterministic_per_seed():
    def fill(seed):
        m = obs.MetricsRegistry(hist_cap=32, seed=seed)
        for i in range(1000):
            m.observe("x", float(i))
        return m.hists["x"]
    assert fill(7) == fill(7)
    assert fill(7) != fill(8)


# ==========================================================================
# Trace export bounds + span streaming.
# ==========================================================================

def _small_trace():
    tr = obs.Tracer()
    for i in range(10):
        tr.clock.advance(float(i))
        tr.begin("work", tid=i)
        tr.clock.advance(i + 0.5)
        tr.end("work", tid=i)
    return tr


def test_chrome_trace_max_events_marks_truncation(tmp_path):
    tr = _small_trace()
    full = obs.chrome_trace(tr)
    cut = obs.chrome_trace(tr, max_events=4)
    names = [e["name"] for e in cut["traceEvents"]]
    assert names.count("work") == 4
    marker = next(e for e in cut["traceEvents"]
                  if e["name"] == "trace_truncated")
    assert marker["args"] == {"dropped_events": 6, "max_events": 4}
    assert obs.validate_chrome_trace(cut) == []
    # no cap -> every event, no marker
    full_names = [e["name"] for e in full["traceEvents"]]
    assert full_names.count("work") == 10
    assert "trace_truncated" not in full_names
    obs.write_chrome_trace(str(tmp_path / "t.json"), tr, max_events=4)


def test_span_stream_writer_streams_every_event(tmp_path):
    path = str(tmp_path / "spans.jsonl")
    with obs.SpanStreamWriter(path) as sink:
        tr = obs.Tracer(sink=sink)
        for i in range(5):
            tr.clock.advance(float(i))
            tr.begin("work", tid=i)
            tr.instant("mark", tid=i)
            tr.clock.advance(i + 0.5)
            tr.end("work", tid=i)
        assert sink.n_written == len(tr.events) == 10
    back = obs.read_span_stream(path)
    assert back == tr.events             # lossless, in record order


def test_span_stream_writer_validates_at_write_time(tmp_path):
    sink = obs.SpanStreamWriter(str(tmp_path / "bad.jsonl"))
    with pytest.raises(AssertionError, match="invalid event"):
        sink({"name": "x", "ph": "Z", "pid": 0, "tid": 0, "ts": 0.0})


# ==========================================================================
# OpenMetrics exposition.
# ==========================================================================

def test_openmetrics_round_trip_is_valid(tmp_path):
    m = obs.MetricsRegistry(hist_cap=8)
    m.inc("frames_completed", 5)
    m.set_gauge("queue_depth", 3)
    m.register("pool_blocks", lambda: 17)
    for v in range(20):
        m.observe("ttft_s", v * 0.001)
    mon = obs.SLOMonitor(_policy())
    mon.observe("ttft", 0.1, 0.001)
    mon.evaluate(0.1)
    text = obs.write_openmetrics(str(tmp_path / "m.txt"), m, mon)
    assert obs.validate_openmetrics(text) == []
    assert text.endswith("# EOF\n")
    assert "repro_frames_completed_total 5.0" in text
    assert "repro_queue_depth 3.0" in text
    assert "repro_pool_blocks 17.0" in text          # pulled at scrape time
    assert 'repro_ttft_s{quantile="0.5"}' in text
    assert "repro_ttft_s_count 20.0" in text
    assert "repro_ttft_s_dropped_total 12.0" in text  # cap surfaced
    assert "repro_slo_state 0.0" in text
    assert "repro_burn_ttft" in text


def test_openmetrics_validator_rejects_malformed():
    assert obs.validate_openmetrics("foo 1\n# EOF\n")       # no TYPE family
    assert obs.validate_openmetrics("# TYPE a gauge\na 1\n")  # no EOF
    assert obs.validate_openmetrics(
        "# TYPE a counter\na 1\n# EOF\n")               # counter w/o _total
    assert obs.validate_openmetrics(
        "# TYPE a gauge\n# TYPE a gauge\na 1\n# EOF\n")  # duplicate family
    assert obs.validate_openmetrics(
        "# TYPE a gauge\na one\n# EOF\n")               # non-numeric value
    assert obs.validate_openmetrics(42) == ["exposition is not a string"]
