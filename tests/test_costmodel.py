"""Roofline cost attribution (serve/obs/costmodel.py): XLA cost analysis via
AOT lowering, the degradation ladder when the backend offers none, stage-key
to serving-span mapping, roofline verdicts on the real serving geometries
(in-place decode memory-bound, chunked prefill fold compute-bound), and the
bitwise per-stage energy re-fold against the telemetry ledger."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro import configs
from repro.models import lm
from repro.serve import obs
from repro.serve.gateway import frontend as fe
from repro.serve.gateway.gateway import (GatewayConfig, MicroBatchGateway,
                                         PromptGateway)
from repro.serve.gateway.sensors import Arrival
from repro.serve.gateway.slots import ContinuousBatcher, make_adapter
from repro.serve.shard import ShardedPromptGateway, build_slices

_SETUP_CACHE: dict = {}


def _setup(arch="stablelm_3b"):
    if arch not in _SETUP_CACHE:
        cfg = dataclasses.replace(configs.smoke_config(arch),
                                  param_dtype="float32")
        params, _ = lm.init(jax.random.key(0), cfg, {})
        _SETUP_CACHE[arch] = (cfg, params)
    return _SETUP_CACHE[arch]


def _slice_mesh(i: int) -> Mesh:
    devs = jax.devices()
    return Mesh(np.asarray([devs[i % len(devs)]]), ("model",))


def _prompt_arrivals(cfg, n, plen=16, seed=0, dt=0.001):
    rng = np.random.default_rng(seed)
    return [Arrival(t=i * dt, uid=i, endpoint=0, kind="prompt",
                    payload=rng.integers(0, cfg.vocab, plen)
                    .astype(np.int32)) for i in range(n)]


def _fake_fn(result=None, exc=None):
    """A stand-in for a jitted fn whose ``.lower().compile()
    .cost_analysis()`` chain yields ``result`` (or raises ``exc``) — the
    shapes interpret mode / non-XLA backends actually produce."""
    class _Compiled:
        def cost_analysis(self):
            if exc is not None:
                raise exc
            return result

    class _Lowered:
        def compile(self):
            return _Compiled()

    class _Fn:
        def lower(self, *args):
            return _Lowered()

    return _Fn()


# ==========================================================================
# analyze(): real lowering + the per-version/per-backend shape drift.
# ==========================================================================

def test_analyze_counts_flops_and_bytes_of_real_jit():
    f = jax.jit(lambda a, b: a @ b)
    a = jnp.zeros((64, 64), jnp.float32)
    cost = obs.analyze(f, (a, a))
    assert cost is not None
    # a 64^3 matmul is 2*n^3 FLOPs; byte traffic covers the 3 arrays
    assert cost["flops"] == pytest.approx(2 * 64 ** 3, rel=0.25)
    assert cost["bytes"] >= 3 * 64 * 64 * 4
    # abstract args lower identically (nothing executes)
    spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    assert obs.analyze(f, (spec, spec)) == cost


def test_analyze_degrades_to_none_when_backend_offers_nothing():
    assert obs.analyze(_fake_fn(exc=RuntimeError("no analysis")), ()) is None
    assert obs.analyze(_fake_fn(result=None), ()) is None
    assert obs.analyze(_fake_fn(result=[]), ()) is None        # old-jax empty
    assert obs.analyze(_fake_fn(result={}), ()) is None
    assert obs.analyze(_fake_fn(result={"other": 1.0}), ()) is None
    assert obs.analyze(
        _fake_fn(result={"flops": 0.0, "bytes accessed": 0.0}), ()) is None


def test_analyze_normalizes_old_jax_list_shape_and_partial_dicts():
    full = {"flops": 5.0, "bytes accessed": 10.0}
    assert obs.analyze(_fake_fn(result=[full]), ()) == \
        obs.analyze(_fake_fn(result=full), ()) == \
        {"flops": 5.0, "bytes": 10.0}
    # bytes with no FLOP count is still useful (traffic-only verdict)
    assert obs.analyze(_fake_fn(result={"bytes accessed": 128.0}), ()) == \
        {"flops": 0.0, "bytes": 128.0}


# ==========================================================================
# Stage-key -> serving-span mapping.
# ==========================================================================

def test_span_for_strips_slice_prefixes_and_bucket_suffixes():
    assert obs.span_for("decode") == "tick"
    assert obs.span_for("slice0.decode") == "tick"
    assert obs.span_for("chunk_fold") == "prefill_chunk"
    assert obs.span_for("slice3.chunk_fold") == "prefill_chunk"
    assert obs.span_for("prefill") == "prefill"
    assert obs.span_for("copy") == "migrate"
    assert obs.span_for("sensor_b8") == "batch"
    assert obs.span_for("slice2.gateway_b4") == "batch"
    # static-only stages (no serving span measures them)
    assert obs.span_for("write_block") is None
    assert obs.span_for("scatter") is None


# ==========================================================================
# attribute(): degradation ladder, measured joins, verdicts.
# ==========================================================================

def test_attribute_degrades_per_stage_never_crashes():
    tr = obs.Tracer()
    tr.begin("tick", pid=obs.ENGINE_PID, tid=0, t=0.0)
    tr.end("tick", pid=obs.ENGINE_PID, tid=0, t=0.25)
    rep = obs.attribute(
        {"decode": (_fake_fn(exc=RuntimeError("interpret mode")), ()),
         "chunk_fold": (_fake_fn(result={"bytes accessed": 64.0}), ()),
         "prefill": (_fake_fn(result={"flops": 90.0,
                                      "bytes accessed": 100.0}), ())},
        tr)
    st = rep["stages"]
    # no analysis at all: measured timings still attributed
    assert st["decode"]["source"] == "measured-only"
    assert st["decode"]["verdict"] == "unknown"
    assert st["decode"]["flops"] is None
    assert st["decode"]["calls"] == 1
    assert st["decode"]["measured_s"] == pytest.approx(0.25)
    # bytes-only: pure traffic classifies memory-bound at intensity 0
    assert st["chunk_fold"]["source"] == "bytes-only"
    assert st["chunk_fold"]["verdict"] == "memory-bound"
    assert st["chunk_fold"]["intensity"] == 0.0
    # both terms: intensity vs the ridge
    assert st["prefill"]["source"] == "xla"
    assert st["prefill"]["intensity"] == pytest.approx(0.9)
    assert st["prefill"]["verdict"] == "compute-bound"
    assert rep["ridge_flops_per_byte"] == obs.DEFAULT_RIDGE


def test_attribute_without_tracer_is_static_only():
    rep = obs.attribute(
        {"decode": (_fake_fn(result={"flops": 1.0,
                                     "bytes accessed": 10.0}), ())})
    entry = rep["stages"]["decode"]
    assert entry["calls"] == 0 and entry["measured_s"] == 0.0
    assert entry["verdict"] == "memory-bound"
    assert "achieved_flops_per_s" not in entry    # no time base to rate over
    assert "energy" not in rep                    # no ledger attached


def test_attribute_respects_custom_ridge():
    stages = {"prefill": (_fake_fn(result={"flops": 90.0,
                                           "bytes accessed": 100.0}), ())}
    assert obs.attribute(stages, ridge=0.5)["stages"]["prefill"]["verdict"] \
        == "compute-bound"
    assert obs.attribute(stages, ridge=2.0)["stages"]["prefill"]["verdict"] \
        == "memory-bound"


# ==========================================================================
# The real serving geometries: decode streams the whole KV arena for one
# token of math (memory-bound); the chunked prefill fold amortizes weight
# traffic over a block of tokens (compute-bound).
# ==========================================================================

def test_roofline_classifies_decode_memory_bound_prefill_compute_bound():
    cfg, params = _setup()
    ad = make_adapter(cfg, params, n_slots=4, max_len=64, paged=True,
                      block_size=16)
    tr = obs.Tracer()
    mon = obs.SLOMonitor(obs.SLOPolicy.default(period_s=1.0, ttft_s=0.5))
    gw = PromptGateway(ContinuousBatcher(ad), max_new_tokens=8,
                       tracer=tr, slo=mon)
    gw.warmup((16,))
    tel = gw.run(_prompt_arrivals(cfg, 6, plen=16))
    assert len(tel.records) == 6

    rep = obs.attribute(gw.cost_args(), tr, telemetry=tel)
    st = rep["stages"]
    assert st["decode"]["source"] == "xla"
    assert st["decode"]["verdict"] == "memory-bound"
    assert st["decode"]["intensity"] < obs.DEFAULT_RIDGE
    assert st["chunk_fold"]["source"] == "xla"
    assert st["chunk_fold"]["verdict"] == "compute-bound"
    assert st["chunk_fold"]["intensity"] > obs.DEFAULT_RIDGE
    # measured spans joined: decode ticks ran and achieved rates follow
    assert st["decode"]["calls"] == len(tr.spans("tick"))
    assert st["decode"]["calls"] > 0
    assert st["decode"]["achieved_flops_per_s"] > 0
    assert st["chunk_fold"]["calls"] == len(tr.spans("prefill_chunk")) > 0

    # the energy cross-check rides along and re-folds bitwise
    en = rep["energy"]
    assert en["conserved"] is True
    assert en["n_requests"] == 6
    assert en["total_nj"] == tel.fleet_energy_nj
    assert set(en["stages_nj"]) == {"frontend_prefill_nj",
                                    "frontend_decode_nj", "link_nj"}
    assert all(v > 0 for v in en["stages_nj"].values())


def test_stage_energy_refolds_ledger_bitwise_with_migration():
    # reuse the sharded migration scenario: its ledger includes a
    # migration part, the hardest stage to keep conserved
    cfg, params = _setup()
    slices = build_slices(cfg, params, [_slice_mesh(0), _slice_mesh(1)],
                          n_slots=2, max_len=16, block_size=4)
    tr = obs.Tracer()
    gw = ShardedPromptGateway(slices, max_new_tokens=4, tracer=tr)
    gw.warmup((8,))
    tel = gw.run(_prompt_arrivals(cfg, 6, plen=8))
    en = obs.stage_energy(tr, tel)
    assert en["conserved"] is True
    assert en["fleet_energy_nj"] == tel.fleet_energy_nj
    assert en["n_requests"] == len(tel.records)
    # and the sharded registry exposes slice-prefixed stages that all map
    # to real serving spans or are static-only
    stages = gw.cost_args()
    assert any(k.startswith("slice0.") for k in stages)
    assert any(k.startswith("slice1.") for k in stages)
    rep = obs.attribute(stages, tr)
    assert rep["stages"]["slice0.decode"]["verdict"] in (
        "memory-bound", "unknown")


def test_frame_gateway_cost_args_lower_and_classify():
    spec = fe.FrontendSpec(mode="sc", bits=4)
    gw = MicroBatchGateway(GatewayConfig(bucket_sizes=(1, 2),
                                         service_model="fixed",
                                         fixed_service_s=0.001), spec)
    rep = obs.attribute(gw.cost_args())
    st = rep["stages"]
    assert set(st) == {"sensor_b1", "gateway_b1", "sensor_b2", "gateway_b2"}
    for entry in st.values():
        assert entry["source"] == "xla"
        assert entry["span"] == "batch"
        assert entry["flops"] > 0 and entry["bytes"] > 0


def test_costmodel_entry_points_charge_the_callback_counter():
    c0 = obs.callback_count()
    obs.attribute({})
    obs.stage_energy(obs.Tracer())
    assert obs.callback_count() > c0
