"""Paged KV cache: pool bookkeeping, dense-vs-paged decode parity across all
four attention families, prefix sharing, copy-on-write isolation, admission
control, and the budget claim (paged > dense concurrency at equal bytes)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import sequential_decode_reference

from repro import configs
from repro.models import lm
from repro.serve.gateway.gateway import PromptGateway
from repro.serve.gateway.sensors import Arrival
from repro.serve.gateway.slots import (ContinuousBatcher, Request,
                                       make_adapter)
from repro.serve.kvcache import BlockPool, PoolExhausted, chain_keys

FAMILY_ARCH = {                      # one arch per attention family
    "decoder": "stablelm_3b",
    "moe": "deepseek_moe_16b",
    "hybrid": "hymba_1_5b",
    "encdec": "whisper_medium",
}


def _setup(arch, seed=0):
    cfg = dataclasses.replace(configs.smoke_config(arch),
                              param_dtype="float32")
    params, _ = lm.init(jax.random.key(0), cfg, {})
    extras = None
    if cfg.family == "encdec":
        rng = np.random.default_rng(99)
        enc = jnp.asarray(rng.normal(0, 1, (1, cfg.enc_len, cfg.d_model)),
                          jnp.float32)
        extras = lambda: {"enc_embed": enc}
    return cfg, params, extras


# ==========================================================================
# Pool bookkeeping (no device arrays involved).
# ==========================================================================

def test_pool_alloc_refcount_and_free():
    pool = BlockPool(num_blocks=5, block_size=4)
    assert pool.capacity == 4 and pool.available() == 4
    a, b = pool.alloc(), pool.alloc()
    assert pool.blocks_in_use() == 2
    pool.acquire(a)                       # refcount 2
    pool.release(a)
    assert pool.blocks_in_use() == 2      # still held once
    pool.release(a)
    pool.release(b)
    assert pool.blocks_in_use() == 0 and pool.available() == 4
    with pytest.raises(AssertionError):
        pool.release(b)                   # double free is a bug, not a no-op


def test_pool_lru_eviction_unindexes_cold_blocks():
    pool = BlockPool(num_blocks=4, block_size=4)
    keys, _ = chain_keys(np.arange(8, dtype=np.int32), 4)
    a = pool.alloc()
    pool.register(keys[0], a)
    b = pool.alloc()
    pool.register(keys[1], b)
    pool.release(a)                       # both parked in the LRU, a colder
    pool.release(b)
    assert pool.available() == 3 and len(pool.lru) == 2
    c = pool.alloc()                      # free list first: no eviction
    d = pool.alloc()                      # evicts a (cold end)
    assert pool.evictions == 1
    assert pool.index.get(keys[0]) is None          # a unindexed
    assert pool.index.get(keys[1]) == b             # b survives
    e = pool.alloc()                      # evicts b
    assert pool.evictions == 2
    with pytest.raises(PoolExhausted):
        pool.alloc()


def test_pool_prefix_revival_from_lru():
    pool = BlockPool(num_blocks=4, block_size=4)
    toks = np.arange(8, dtype=np.int32)
    keys, _ = chain_keys(toks, 4)
    bids = [pool.alloc() for _ in keys]
    for key, bid in zip(keys, bids):
        pool.register(key, bid)
    for bid in bids:
        pool.release(bid)                 # request retired; blocks cached
    hits, partial, _, _ = pool.match_prefix(toks)
    assert hits == bids and partial is None
    revived = pool.acquire(hits[0])
    assert revived == bids[0] and pool.blocks_in_use() == 1


def test_chain_keys_prefix_property():
    """Chain keys agree exactly on the shared prefix and nowhere past the
    first divergence (radix-descent semantics)."""
    a = np.arange(16, dtype=np.int32)
    b = a.copy()
    b[9] = 77                             # diverge inside block 2
    ka, pa = chain_keys(a, 4)
    kb, pb = chain_keys(b, 4)
    assert ka[:2] == kb[:2] and ka[2] != kb[2] and ka[3] != kb[3]
    ka2, pa2 = chain_keys(a[:10], 4)      # partial chunk key exists + chains
    assert ka2 == ka[:2] and pa2 is not None
    assert chain_keys(a[:8], 4)[1] is None


# ==========================================================================
# Dense-vs-paged decode parity (tentpole acceptance: all four families).
# ==========================================================================

@pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
def test_dense_paged_decode_parity(family):
    """Block-table slots must produce token-for-token what the dense
    reference oracle produces, for every attention-cache family."""
    cfg, params, extras = _setup(FAMILY_ARCH[family])
    assert cfg.family == family
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (5, 9, 7)]
    n_new, max_len = 4, 32
    batcher = ContinuousBatcher(make_adapter(
        cfg, params, n_slots=2, max_len=max_len, extras=extras,
        paged=True, block_size=4))
    for i, p in enumerate(prompts):       # 3 requests > 2 slots
        batcher.submit(Request(uid=i, prompt=p, max_new_tokens=n_new))
    got = {r.uid: r.generated for r in batcher.run()}
    assert len(got) == len(prompts)
    for i, p in enumerate(prompts):
        want = sequential_decode_reference(cfg, params, p, n_new, max_len,
                                           extras=extras)
        assert got[i] == want, (family, i, got[i], want)


# ==========================================================================
# Prefix sharing + copy-on-write (satellite acceptance).
# ==========================================================================

def test_prefix_sharing_uses_fewer_blocks_than_dense():
    """Two requests with a common prompt prefix must share blocks: the pool
    holds strictly fewer blocks than the two chains laid out densely, and
    both requests still decode exactly like isolated dense runs."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(2)
    bs, n_new, max_len = 4, 4, 32
    common = rng.integers(0, cfg.vocab, size=8, dtype=np.int32)  # 2 blocks
    pa = np.concatenate([common, rng.integers(0, cfg.vocab, size=3,
                                              dtype=np.int32)])
    pb = np.concatenate([common, rng.integers(0, cfg.vocab, size=2,
                                              dtype=np.int32)])
    ad = make_adapter(cfg, params, n_slots=2, max_len=max_len,
                      paged=True, block_size=bs)
    ad.insert(0, pa, max_new=n_new)
    ad.insert(1, pb, max_new=n_new)
    dense_total = (-(-(len(pa) + n_new) // bs)) + (-(-(len(pb) + n_new) // bs))
    assert ad.pool.blocks_in_use() < dense_total
    assert ad.slot_stats(1)["prefix_hit_blocks"] == 2
    st = ad.pool_stats()
    assert st["prefix_hit_rate"] > 0 and st["bytes_saved_vs_dense"] > 0

    # shared-prefix requests still match the isolated oracle token-for-token
    batcher = ContinuousBatcher(make_adapter(
        cfg, params, n_slots=2, max_len=max_len, paged=True, block_size=bs))
    for i, p in enumerate((pa, pb)):
        batcher.submit(Request(uid=i, prompt=p, max_new_tokens=n_new))
    got = {r.uid: r.generated for r in batcher.run()}
    for i, p in enumerate((pa, pb)):
        want = sequential_decode_reference(cfg, params, p, n_new, max_len)
        assert got[i] == want, (i, got[i], want)


def test_cow_divergence_preserves_sibling_bitwise():
    """Two requests sharing a partial prompt block are forced to write
    *different* tokens into it.  Copy-on-write must give each its own copy:
    every decode step's logits match a 2-slot dense adapter running the same
    isolated requests, bit for bit.  (``chunked=False``: the legacy
    one-shot path is what shares the partial block read-only and copies
    lazily; the chunk fold recomputes it into a private block instead —
    its isolation is covered in tests/test_chunked_prefill.py.)"""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(3)
    bs, max_len = 4, 32
    prompt = rng.integers(0, cfg.vocab, size=6, dtype=np.int32)  # partial blk
    paged = make_adapter(cfg, params, n_slots=2, max_len=max_len,
                         paged=True, block_size=bs, chunked=False)
    dense = make_adapter(cfg, params, n_slots=2, max_len=max_len)
    paged.insert(0, prompt, max_new=8)
    paged.insert(1, prompt, max_new=8)
    assert paged.slot_stats(1)["prefix_hit_blocks"] == 2   # 1 full + partial
    dense.insert(0, prompt)
    dense.insert(1, prompt)
    active = np.asarray([True, True])
    # divergent forced tokens -> both writers must CoW off the shared block
    steps = [np.asarray([3, 7], np.int32), np.asarray([11, 2], np.int32),
             np.asarray([5, 5], np.int32), np.asarray([1, 9], np.int32)]
    for toks in steps:
        paged.decode(toks % cfg.vocab, active)
        dense.decode(toks % cfg.vocab, active)
        np.testing.assert_array_equal(np.asarray(paged.last_logits),
                                      np.asarray(dense.last_logits))
    assert paged.pool.cow_copies >= 1


# ==========================================================================
# Admission control + the fixed-budget concurrency claim.
# ==========================================================================

def test_admission_queues_when_pool_cannot_cover_demand():
    """With a pool too small for two concurrent worst-case requests, the
    batcher must queue (not crash, not over-admit) and still finish all."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(4)
    bs, n_new = 4, 4
    # each request: ceil((9+4)/4) = 4 blocks; pool holds 6 usable
    ad = make_adapter(cfg, params, n_slots=2, max_len=16,
                      paged=True, block_size=bs, num_blocks=7)
    batcher = ContinuousBatcher(ad)
    prompts = [rng.integers(0, cfg.vocab, size=9).astype(np.int32)
               for _ in range(3)]
    for i, p in enumerate(prompts):
        batcher.submit(Request(uid=i, prompt=p, max_new_tokens=n_new))
    done = batcher.run()
    assert len(done) == 3
    assert batcher.peak_active == 1        # never two concurrent worst-cases
    assert ad.pool.blocks_in_use() == 0    # everything released
    # a request whose worst case exceeds the whole pool is rejected at
    # submit (validate_request), before it could deadlock the queue
    tiny = make_adapter(cfg, params, n_slots=1, max_len=16,
                        paged=True, block_size=bs, num_blocks=3)
    with pytest.raises(ValueError):
        ContinuousBatcher(tiny).submit(
            Request(uid=9, prompt=prompts[0], max_new_tokens=n_new))


def test_can_admit_counts_lru_revivals_as_demand():
    """A prefix hit parked in the LRU consumes supply when revived (it
    leaves the evictable pool without an allocation), so admission must
    price it in — or the prefix-cache-warm steady state overcommits."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(7)
    bs = 4
    ad = make_adapter(cfg, params, n_slots=2, max_len=16,
                      paged=True, block_size=bs, num_blocks=7)  # capacity 6
    pa = rng.integers(0, cfg.vocab, size=8, dtype=np.int32)
    ad.insert(0, pa, max_new=4)            # 3 blocks (2 full + 1 gen)
    ad.clear(0)                            # 2 indexed blocks park in LRU
    pb = rng.integers(0, cfg.vocab, size=9, dtype=np.int32)
    ad.insert(0, pb, max_new=7)            # 4 blocks: free supply now 0
    assert ad.pool.available() == 2        # only the 2 LRU blocks remain
    # pa again: 3 blocks total, 2 hits — but both hits are LRU revivals, so
    # true consumption is 1 alloc + 2 revivals = 3 > 2 available
    assert not ad.can_admit(pa, 4)
    # forcing the insert anyway exhausts the pool and must roll back fully
    with pytest.raises(PoolExhausted):
        ad.insert(1, pa, max_new=4)
    assert ad.pool.blocks_in_use() == 4 and ad.pool.available() == 2


def test_paged_outlives_dense_at_fixed_budget():
    """Same simulated HBM budget: short requests let the block pool run
    strictly more concurrent slots than same-budget dense max_len slots."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(5)
    bs, max_len, n_new = 4, 32, 2
    nb_per_dense_slot = max_len // bs
    budget_blocks = 2 * nb_per_dense_slot          # budget == 2 dense slots
    dense = ContinuousBatcher(make_adapter(cfg, params, n_slots=2,
                                           max_len=max_len))
    paged = ContinuousBatcher(make_adapter(
        cfg, params, n_slots=6, max_len=max_len, paged=True, block_size=bs,
        num_blocks=budget_blocks + 1))             # +1 = the trash block
    prompts = [rng.integers(0, cfg.vocab, size=6).astype(np.int32)
               for _ in range(6)]                  # 2 blocks each
    for b in (dense, paged):
        for i, p in enumerate(prompts):
            b.submit(Request(uid=i, prompt=p, max_new_tokens=n_new))
        assert len(b.run()) == 6
    assert dense.peak_active == 2                  # capped by slot count
    assert paged.peak_active > dense.peak_active


# ==========================================================================
# Telemetry integration (pool counters + LM-path energy).
# ==========================================================================

def test_gateway_pool_telemetry_and_lm_energy():
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(6)
    common = rng.integers(0, cfg.vocab, size=8, dtype=np.int32)
    arrivals = [Arrival(uid=i, t=0.01 * i, endpoint=i, kind="prompt",
                        payload=np.concatenate(
                            [common, rng.integers(0, cfg.vocab, size=2 + i,
                                                  dtype=np.int32)]))
                for i in range(4)]
    batcher = ContinuousBatcher(make_adapter(
        cfg, params, n_slots=2, max_len=32, paged=True, block_size=4))
    pgw = PromptGateway(batcher, max_new_tokens=4)
    tel = pgw.run(arrivals)
    tel.assert_conserved()
    assert len(tel.records) == 4
    # satellite: every LM request now carries a J/inference figure
    assert all(r.energy_nj > 0 for r in tel.records)
    assert all(r.kv_blocks > 0 for r in tel.records)
    assert any(r.prefix_hit_blocks > 0 for r in tel.records)
    rep = tel.report(1.0, kind="prompt")
    assert rep["j_per_inference"] > 0
    assert rep["kv_blocks_per_req"] > 0
    pool = rep["pool"]
    for key in ("blocks_in_use", "prefix_hit_rate", "evictions",
                "bytes_saved_vs_dense", "cow_copies"):
        assert key in pool, key
    assert pool["prefix_hit_rate"] > 0
    # the drained snapshot reads 0 in use; the peaks must hold the evidence
    assert pool["blocks_in_use"] == 0
    assert pool["peak_blocks_in_use"] > 0
    assert pool["peak_bytes_saved_vs_dense"] > 0
