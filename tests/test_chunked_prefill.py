"""Prefix-hit chunked prefill: the block-size prefill fold, bitwise resume
parity for all four attention families (engine + adapter + arena blocks),
no-recompile steady state, exact admission pricing, the PoolExhausted
rollback disarm, and the at-capacity trash-block routing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve import engine
from repro.serve.gateway.slots import (ContinuousBatcher, Request,
                                       make_adapter)
from repro.serve.kvcache import PoolExhausted, TRASH_BLOCK

FAMILY_ARCH = {
    "decoder": "stablelm_3b",
    "moe": "deepseek_moe_16b",
    "hybrid": "hymba_1_5b",
    "encdec": "whisper_medium",
}

BS = 4


def _setup(arch, seed=0):
    cfg = dataclasses.replace(configs.smoke_config(arch),
                              param_dtype="float32")
    params, _ = lm.init(jax.random.key(0), cfg, {})
    extras = None
    if cfg.family == "encdec":
        rng = np.random.default_rng(99)
        enc = jnp.asarray(rng.normal(0, 1, (1, cfg.enc_len, cfg.d_model)),
                          jnp.float32)
        extras = lambda: {"enc_embed": enc}
    return cfg, params, extras


# ==========================================================================
# Engine-level fold parity (tentpole acceptance: all four families).
# ==========================================================================

def _empty_prefix(cfg, params, extras):
    empty = engine.init_cache(cfg, 1, 0, abstract=True)
    cache = {key: jnp.zeros(empty[key].shape, empty[key].dtype)
             for key in ("k", "v") if key in empty}
    cache["len"] = jnp.int32(0)
    if cfg.family == "hybrid":
        cache["conv"] = jnp.zeros((cfg.n_layers, 1, cfg.conv_k - 1,
                                   cfg.inner), cfg.dtype)
        cache["ssm"] = jnp.zeros((cfg.n_layers, 1, cfg.inner,
                                  cfg.ssm_state), jnp.float32)
    if cfg.family == "encdec":
        cache["xk"], cache["xv"] = engine.encode_cross(
            cfg, params, extras()["enc_embed"])
    return cache


def _fold(cfg, params, prompt, cache, start):
    q, logits = start, None
    while q < len(prompt):
        c = min(BS, len(prompt) - q)
        cache, logits = engine.prefill_chunked(
            cfg, params, {"tokens": jnp.asarray(prompt[None, q:q + c])},
            cache, q)
        q += c
    return cache, logits


@pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
def test_engine_fold_resume_bitwise(family):
    """Resuming the prefill fold at an H-block prefix must reproduce the
    cold fold's logits and K/V bit-for-bit (assert_array_equal): chunk j's
    compiled graph is shape-identical in both folds.  H=0 degenerates to
    the cold fold itself."""
    cfg, params, extras = _setup(FAMILY_ARCH[family])
    rng = np.random.default_rng(1)
    P = 11                                       # 2 full blocks + partial
    prompt = rng.integers(0, cfg.vocab, size=P, dtype=np.int32)
    cold_cache, cold_logits = _fold(cfg, params, prompt,
                                    _empty_prefix(cfg, params, extras), 0)
    for H in (0, 1, 2):
        q0 = H * BS
        warm = {"len": jnp.int32(q0),
                "k": cold_cache["k"][..., :q0, :, :],
                "v": cold_cache["v"][..., :q0, :, :]}
        if family == "hybrid":
            # the recurrent boundary state comes from folding the prefix —
            # exactly what the adapter snapshots during a cold admission
            pc, _ = _fold(cfg, params, prompt[:q0],
                          _empty_prefix(cfg, params, extras), 0)
            warm["conv"], warm["ssm"] = pc["conv"], pc["ssm"]
        if family == "encdec":
            warm["xk"], warm["xv"] = engine.encode_cross(
                cfg, params, extras()["enc_embed"])
        warm_cache, warm_logits = _fold(cfg, params, prompt, warm, q0)
        np.testing.assert_array_equal(np.asarray(cold_logits),
                                      np.asarray(warm_logits), err_msg=family)
        for key in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(cold_cache[key]),
                                          np.asarray(warm_cache[key]),
                                          err_msg=(family, key, H))


def test_engine_fold_resume_bitwise_sliced_window():
    """When the window is smaller than the prefix, windowed layers attend
    only the trailing ``window`` gathered keys (the O(S·window) bound).
    The slice must preserve both the fold's bitwise resume property and
    agreement with the one-shot prefill's sliding-window math."""
    cfg = dataclasses.replace(configs.smoke_config("hymba_1_5b"),
                              param_dtype="float32", window=2)
    params, _ = lm.init(jax.random.key(0), cfg, {})
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, size=11, dtype=np.int32)
    cold_cache, cold_logits = _fold(cfg, params, prompt,
                                    _empty_prefix(cfg, params, None), 0)
    pc, _ = _fold(cfg, params, prompt[:2 * BS],
                  _empty_prefix(cfg, params, None), 0)
    warm = {"len": jnp.int32(2 * BS),
            "k": cold_cache["k"][..., :2 * BS, :, :],
            "v": cold_cache["v"][..., :2 * BS, :, :],
            "conv": pc["conv"], "ssm": pc["ssm"]}
    warm_cache, warm_logits = _fold(cfg, params, prompt, warm, 2 * BS)
    np.testing.assert_array_equal(np.asarray(cold_logits),
                                  np.asarray(warm_logits))
    np.testing.assert_array_equal(np.asarray(cold_cache["k"]),
                                  np.asarray(warm_cache["k"]))
    # and the slice is semantically exact: the fold agrees with the
    # one-shot attend_sliding prefill up to graph-shape ulps
    _, oneshot_logits = engine.prefill(cfg, params,
                                       {"tokens": jnp.asarray(prompt[None])})
    np.testing.assert_allclose(np.asarray(cold_logits),
                               np.asarray(oneshot_logits),
                               rtol=1e-4, atol=1e-4)


# ==========================================================================
# Adapter-level parity: warm insert == cold insert, blocks and logits.
# ==========================================================================

def _slot_blocks(ad, slot):
    """Arena contents of a slot's chain, keyed (key, logical block idx)."""
    out = {}
    for j, bid in enumerate(ad.slot_bids[slot]):
        for key in ad.seq_keys:
            out[key, j] = np.asarray(ad.arena_block(key, bid))
    return out


@pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
def test_adapter_resume_matches_cold_insert(family):
    """A prefix-hit insert must scatter bit-identical arena blocks and pick
    the same next token as the identical prompt admitted cold — including a
    shared prefix that ends mid-block (partial hit) — while actually
    skipping the shared blocks' prefill."""
    cfg, params, extras = _setup(FAMILY_ARCH[family])
    rng = np.random.default_rng(2)
    prefix = rng.integers(0, cfg.vocab, size=2 * BS, dtype=np.int32)
    tail_a = rng.integers(0, cfg.vocab, size=3, dtype=np.int32)
    tail_b = rng.integers(0, cfg.vocab, size=3, dtype=np.int32)
    pa = np.concatenate([prefix, tail_a])
    pb = np.concatenate([prefix, tail_b])

    cold = make_adapter(cfg, params, n_slots=2, max_len=32, extras=extras,
                        paged=True, block_size=BS)
    tok_cold = cold.insert(0, pb, max_new=4)
    blocks_cold = _slot_blocks(cold, 0)

    warm = make_adapter(cfg, params, n_slots=2, max_len=32, extras=extras,
                        paged=True, block_size=BS)
    warm.insert(0, pa, max_new=4)                # seeds the radix prefix
    tok_warm = warm.insert(1, pb, max_new=4)
    assert warm.slot_stats(1)["prefill_tokens_skipped"] == 2 * BS
    assert warm.slot_stats(1)["prefix_hit_blocks"] == 2
    assert tok_warm == tok_cold, family
    blocks_warm = _slot_blocks(warm, 1)
    assert blocks_cold.keys() == blocks_warm.keys()
    for where, a in blocks_cold.items():
        np.testing.assert_array_equal(a, blocks_warm[where],
                                      err_msg=(family,) + where)

    # a hit that ends mid-block: identical prompt, full chain + partial hit;
    # the fold recomputes the boundary chunk into a private block
    warm.clear(1)
    tok_mid = warm.insert(1, pa, max_new=4)
    st = warm.slot_stats(1)
    assert st["prefix_hit_blocks"] == 3          # 2 full + the partial
    assert st["prefill_tokens_skipped"] == 2 * BS
    oracle = make_adapter(cfg, params, n_slots=1, max_len=32, extras=extras,
                          paged=True, block_size=BS)
    assert tok_mid == oracle.insert(0, pa, max_new=4)
    mid_blocks = _slot_blocks(warm, 1)
    for where, a in _slot_blocks(oracle, 0).items():
        np.testing.assert_array_equal(a, mid_blocks[where],
                                      err_msg=(family,) + where)


def test_adapter_divergent_writers_stay_isolated():
    """Chunked-path replacement for lazy copy-on-write: two slots admitted
    from the same prompt (full-coverage partial hit) decode into *private*
    boundary blocks, so one slot's divergent writes must leave the sibling's
    blocks and logits untouched, bit for bit."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=6, dtype=np.int32)

    def mk():
        ad = make_adapter(cfg, params, n_slots=2, max_len=32,
                          paged=True, block_size=BS)
        ad.insert(0, prompt, max_new=8)
        ad.insert(1, prompt, max_new=8)
        return ad

    a, b = mk(), mk()
    blocks0 = _slot_blocks(a, 0)
    # slot 1 diverges for four steps; slot 0 idle
    for tok in (3, 11, 5, 1):
        a.decode(np.asarray([0, tok], np.int32),
                 np.asarray([False, True]))
    for where, arr in blocks0.items():           # sibling blocks untouched
        np.testing.assert_array_equal(arr, _slot_blocks(a, 0)[where])
    # slot 0 now decodes exactly as if slot 1 had never moved (oracle b)
    for tok in (7, 2, 5, 9):
        a.decode(np.asarray([tok, 0], np.int32),
                 np.asarray([True, False]))
        b.decode(np.asarray([tok, 0], np.int32),
                 np.asarray([True, False]))
        np.testing.assert_array_equal(np.asarray(a.last_logits[0]),
                                      np.asarray(b.last_logits[0]))


# ==========================================================================
# Recompile-free steady state.
# ==========================================================================

def test_fold_steady_state_never_recompiles():
    """Once a (prefix blocks, chunk shape) bucket is compiled, further
    inserts of the same shape — cold or resumed — reuse it."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(4)
    prefix = rng.integers(0, cfg.vocab, size=2 * BS, dtype=np.int32)
    ad = make_adapter(cfg, params, n_slots=2, max_len=32,
                      paged=True, block_size=BS)
    mk = lambda: np.concatenate(
        [prefix, rng.integers(0, cfg.vocab, size=3, dtype=np.int32)])
    ad.insert(0, mk(), max_new=4)                # cold: compiles the fold
    ad.insert(1, mk(), max_new=4)                # warm: compiles the resume
    n_chunk = ad._chunk_fn._cache_size()
    n_gather = ad._gather_prefix._cache_size()
    ad.clear(1)
    for _ in range(3):                           # same-bucket warm inserts
        ad.insert(1, mk(), max_new=4)
        ad.clear(1)
    assert ad._chunk_fn._cache_size() == n_chunk
    assert ad._gather_prefix._cache_size() == n_gather


def test_fold_buckets_shared_process_wide():
    """The chunk fold's jit buckets are keyed by config, not by adapter
    instance: a second adapter of the same config shares the first one's
    executables and its steady-state admissions compile nothing new."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(14)
    prefix = rng.integers(0, cfg.vocab, size=2 * BS, dtype=np.int32)
    mk = lambda: np.concatenate(
        [prefix, rng.integers(0, cfg.vocab, size=3, dtype=np.int32)])
    ad1 = make_adapter(cfg, params, n_slots=2, max_len=32,
                       paged=True, block_size=BS)
    ad2 = make_adapter(cfg, params, n_slots=2, max_len=32,
                       paged=True, block_size=BS)
    assert ad1._chunk_fn is ad2._chunk_fn        # one cache per config
    ad1.insert(0, mk(), max_new=4)               # cold fold buckets
    ad1.insert(1, mk(), max_new=4)               # resume bucket
    n_chunk = ad1._chunk_fn._cache_size()
    # the second adapter admits the same shapes (its own pool starts cold,
    # so this is a cold fold + a resumed fold there) — zero new buckets
    ad2.insert(0, mk(), max_new=4)
    ad2.insert(1, mk(), max_new=4)
    assert ad2._chunk_fn._cache_size() == n_chunk
    # a *different* config gets its own fold cache, not a collision
    cfg2 = dataclasses.replace(cfg, q_chunk=max(cfg.q_chunk // 2, 1))
    params2, _ = lm.init(jax.random.key(1), cfg2, {})
    ad3 = make_adapter(cfg2, params2, n_slots=1, max_len=32,
                       paged=True, block_size=BS)
    assert ad3._chunk_fn is not ad1._chunk_fn


# ==========================================================================
# Admission pricing is exact (satellite: hit-aware demand).
# ==========================================================================

def _consumed(ad, prompt, max_new, slot):
    before = ad.pool.available()
    ad.insert(slot, prompt, max_new=max_new)
    return before - ad.pool.available()


def test_admission_demand_matches_actual_allocations():
    """``_admission_demand`` must equal the supply insert() actually
    consumes — cold, warm with a live holder (the mid-block boundary block
    must be priced once, not double-counted via arming/revival the chunked
    fold never performs), and warm from the LRU."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(5)
    p = np.concatenate([rng.integers(0, cfg.vocab, size=2 * BS,
                                     dtype=np.int32),
                        rng.integers(0, cfg.vocab, size=2, dtype=np.int32)])
    ad = make_adapter(cfg, params, n_slots=3, max_len=16,
                      paged=True, block_size=BS, num_blocks=32)
    # cold: whole chain allocated
    d = ad._admission_demand(p, 4)
    assert d == 4 and _consumed(ad, p, 4, 0) == d
    # warm, holder live: 2 full hits referenced; the boundary block is the
    # slot's own fresh block — demand is 2, not 3 (no arming, no revival)
    d = ad._admission_demand(p, 4)
    assert d == 2 and _consumed(ad, p, 4, 1) == d
    # warm from the LRU: revivals consume evictable supply 1-for-1
    ad.clear(0)
    ad.clear(1)
    d = ad._admission_demand(p, 4)
    assert d == 4                                # 4-2 hits + 2 revivals
    assert _consumed(ad, p, 4, 2) == d


def test_admission_demand_matches_legacy_path():
    """The legacy one-shot path holds the shared partial and arms existing
    holders — its demand includes exactly those units."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(6)
    p = np.concatenate([rng.integers(0, cfg.vocab, size=BS, dtype=np.int32),
                        rng.integers(0, cfg.vocab, size=2, dtype=np.int32)])
    ad = make_adapter(cfg, params, n_slots=2, max_len=16,
                      paged=True, block_size=BS, num_blocks=32,
                      chunked=False)
    assert ad._admission_demand(p, 4) == 3 == _consumed(ad, p, 4, 0)
    # holder live + unarmed: the hit block is referenced (free), and the
    # shared partial costs one arming spare + this slot's own spare + one
    # generation block = 3
    d = ad._admission_demand(p, 4)
    assert d == 3 == _consumed(ad, p, 4, 1)
    assert ad.cow_spare[0] is not None           # holder armed


# ==========================================================================
# PoolExhausted rollback disarms armed holders (satellite bugfix).
# ==========================================================================

def test_failed_insert_disarms_armed_holders():
    """A failed legacy admission must release the copy-on-write spares it
    armed sibling holders with and restore their partial registrations —
    one leaked spare per failed retry would bleed the pool dry."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(7)
    p = np.concatenate([rng.integers(0, cfg.vocab, size=BS, dtype=np.int32),
                        rng.integers(0, cfg.vocab, size=2, dtype=np.int32)])
    ad = make_adapter(cfg, params, n_slots=2, max_len=20,
                      paged=True, block_size=BS, num_blocks=7,
                      chunked=False)
    ad.insert(0, p, max_new=2)                   # full + partial: 2 blocks
    reg_before = ad.partial_reg[0]
    assert reg_before is not None and ad.cow_spare[0] is None
    avail = ad.pool.available()
    assert not ad.can_admit(p, 12)               # worst case cannot fit
    with pytest.raises(PoolExhausted):
        ad.insert(1, p, max_new=12)
    # the holder is disarmed: spare released, registration restored
    assert ad.cow_spare[0] is None and ad.cow_blk[0] is None
    assert ad.partial_reg[0] == reg_before
    assert ad.pool.available() == avail
    assert ad.pool.blocks_in_use() == 2
    # and the request still completes once supply frees up
    ad.clear(0)
    ad.insert(1, p, max_new=12)


# ==========================================================================
# At-capacity slots route to the trash block (satellite bugfix).
# ==========================================================================

def test_at_capacity_slot_writes_trash_and_finishes():
    """A slot whose len reached max_len must not scatter into its final
    block (which may be a *shared* prefix block): the lane is masked to the
    trash block, its state freezes, and the batcher retires the request."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(8)
    p = rng.integers(0, cfg.vocab, size=6, dtype=np.int32)
    ad = make_adapter(cfg, params, n_slots=1, max_len=8,
                      paged=True, block_size=BS)
    ad.insert(0, p, max_new=2)
    # force the out-of-contract state the pre-fix clamp silently corrupted
    ad.lens[0] = ad.max_len
    ad.cache["len"] = ad.cache["len"].at[0].set(ad.max_len)
    assert ad.at_capacity(0)
    final_bid = int(ad.tables[0, ad.nb_max - 1])
    before = {key: np.asarray(ad.arena_block(key, final_bid))
              for key in ad.seq_keys}
    ad.decode(np.asarray([3], np.int32), np.asarray([True]))
    assert ad.lens[0] == ad.max_len              # state frozen, no advance
    for key in ad.seq_keys:                      # final block untouched
        np.testing.assert_array_equal(
            before[key], np.asarray(ad.arena_block(key, final_bid)))

    # batcher integration: the request is surfaced as finished
    ad2 = make_adapter(cfg, params, n_slots=1, max_len=8,
                       paged=True, block_size=BS)
    batcher = ContinuousBatcher(ad2)
    batcher.submit(Request(uid=0, prompt=p[:4], max_new_tokens=4))
    batcher.step()                               # insert + 1 decode tick
    assert batcher.active[0] is not None
    ad2.lens[0] = ad2.max_len
    done = batcher.step()
    assert [r.uid for r in done] == [0]
    assert batcher.active[0] is None and not batcher.busy
