"""Energy/power/area model vs the paper's Table 3."""
import numpy as np
import pytest

from repro.core import energy


@pytest.mark.parametrize("bits", range(2, 9))
def test_energy_rows_match_paper(bits):
    bp, sp, be, se, ba, sa = energy.PAPER_TABLE3[bits]
    r = energy.report(bits)
    assert r.sc_energy_nj == pytest.approx(se, rel=0.02), "SC nJ/frame"
    assert r.bin_energy_nj == pytest.approx(be, rel=0.03), "binary nJ/frame"
    assert r.sc_power_mw == pytest.approx(sp, rel=0.02), "SC mW"
    assert r.bin_power_mw == pytest.approx(bp, rel=0.05), "binary mW"
    assert r.sc_area_mm2 == pytest.approx(sa, rel=0.03), "SC mm^2"
    assert r.bin_area_mm2 == pytest.approx(ba, rel=0.02), "binary mm^2"


def test_headline_claims():
    """9.8x energy efficiency at 4-bit; break-even (>=1x) at 8-bit."""
    assert energy.report(4).efficiency_gain == pytest.approx(9.8, abs=0.3)
    assert 1.0 <= energy.report(8).efficiency_gain < 1.5


def test_exponential_sc_scaling():
    """SC energy halves per bit removed (stream length halves)."""
    for b in range(3, 9):
        ratio = energy.sc_energy_nj(b) / energy.sc_energy_nj(b - 1)
        assert 1.7 < ratio < 2.4


def test_binary_scaling_near_linear():
    """Binary energy grows ~linearly in datapath width (small quadratic
    multiplier-array term)."""
    es = [energy.bin_energy_nj(b) for b in range(2, 9)]
    diffs = np.diff(es)
    assert np.std(diffs) / np.mean(diffs) < 0.10
    assert all(d > 0 for d in diffs)


def test_component_shares_sum_to_one():
    s = energy.component_shares(4)
    assert sum(s.values()) == pytest.approx(1.0)
    assert s["tff_adders"] > s["counters"]      # adder tree dominates counters


def test_scaled_projection():
    """Beyond-paper projection: doubling units doubles power, same per-frame
    time; efficiency gain ratio is preserved."""
    base = energy.report(4)
    big = energy.scaled_report(4, energy.K_WINDOW, 2 * energy.N_UNITS,
                               energy.N_KERNELS)
    assert big.sc_power_mw == pytest.approx(2 * base.sc_power_mw)
    assert big.efficiency_gain == pytest.approx(base.efficiency_gain)
