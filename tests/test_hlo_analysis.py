"""Loop-aware HLO cost analysis: trip-count multiplication correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as ha


def _compile(f, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        return jax.lax.scan(body, x, None, length=10)[0]
    res = ha.analyze(_compile(f, (256, 256), (256, 256)))
    assert res["flops"] == 10 * 2 * 256 ** 3


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            return jax.lax.scan(inner, c, None, length=5)[0], None
        return jax.lax.scan(outer, x, None, length=4)[0]
    res = ha.analyze(_compile(f, (128, 128), (128, 128)))
    assert res["flops"] == 20 * 2 * 128 ** 3


def test_no_loop_plain_dot():
    def f(a, b):
        return a @ b
    res = ha.analyze(_compile(f, (64, 32), (32, 16)))
    assert res["flops"] == 2 * 64 * 32 * 16


def test_checkpoint_remat_counted():
    """jax.checkpoint adds forward recompute dots to the backward pass."""
    def loss(ck):
        def inner(x, w):
            h = jnp.tanh(x @ w)
            return jnp.sum(jnp.tanh(h @ w))
        body = jax.checkpoint(inner) if ck else inner

        def f(x, w):
            return jax.grad(body)(x, w)
        return ha.analyze(_compile(f, (64, 64), (64, 64)))["flops"]

    plain, remat = loss(False), loss(True)
    assert remat >= plain                       # recompute adds dots
    assert plain >= 4 * 2 * 64 ** 3             # fwd 2 + bwd >= 2


def test_collective_factors():
    st = {"count": 1, "bytes": 100, "traffic_bytes": 0.0}
    # factor math spot-checks via a synthetic line walk
    txt = """
ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  ROOT %ar = f32[16,16]{1,0} all-reduce(%p), replica_groups=[2,4]<=[8], to_apply=%add
}
"""
    res = ha.analyze(txt)
    ar = res["collectives"]["all-reduce"]
    assert ar["count"] == 1
    assert ar["bytes"] == 16 * 16 * 4
    assert abs(ar["traffic_bytes"] - ar["bytes"] * 2 * 3 / 4) < 1e-6


def test_bytes_counts_fusion_boundaries():
    def f(a, b):
        return jnp.sum(a * b + 1.0)
    res = ha.analyze(_compile(f, (1024,), (1024,)))
    # reads a+b (8KiB) + small outputs; must be within a loose band
    assert 8 * 1024 <= res["hbm_bytes"] <= 64 * 1024
