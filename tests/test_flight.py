"""Flight recorder (serve/obs/flight.py) + critical-path attribution
(serve/obs/critpath.py): seeded-reservoir determinism, exact-tail streams,
retain=False ring-only retention, the zero-callback disabled pin, the
float-equality segment re-fold (including nested migrate carving), per-role
aggregation, and shrink semantics."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve import obs
from repro.serve.gateway import frontend as fe
from repro.serve.gateway.gateway import (GatewayConfig, MicroBatchGateway,
                                         PromptGateway)
from repro.serve.gateway.sensors import Arrival
from repro.serve.gateway.slots import ContinuousBatcher, make_adapter
from repro.serve.obs import critpath
from repro.serve.obs.tracer import REQUESTS_PID

_SETUP_CACHE: dict = {}


def _setup(arch="stablelm_3b"):
    if arch not in _SETUP_CACHE:
        cfg = dataclasses.replace(configs.smoke_config(arch),
                                  param_dtype="float32")
        params, _ = lm.init(jax.random.key(0), cfg, {})
        _SETUP_CACHE[arch] = (cfg, params)
    return _SETUP_CACHE[arch]


def _prompt_arrivals(cfg, n, plen=8, seed=0, dt=0.001):
    rng = np.random.default_rng(seed)
    return [Arrival(t=i * dt, uid=i, endpoint=0, kind="prompt",
                    payload=rng.integers(0, cfg.vocab, plen)
                    .astype(np.int32)) for i in range(n)]


def _frame_arrivals(n, dt=0.001, seed=0):
    rng = np.random.default_rng(seed)
    return [Arrival(t=i * dt, uid=i, endpoint=0, kind="frame",
                    payload=rng.integers(0, 255, (28, 28, 1))
                    .astype(np.uint8)) for i in range(n)]


def _span(name, ts, dur, *, tid=0, pid=REQUESTS_PID, args=None):
    return {"name": name, "ph": "X", "pid": pid, "tid": tid, "ts": ts,
            "dur": dur, "args": args or {}}


# ==========================================================================
# Ring-buffer semantics.
# ==========================================================================

def test_reservoir_is_seeded_deterministic_and_bounded():
    a = obs.FlightRecorder(span_cap=32, seed=7)
    b = obs.FlightRecorder(span_cap=32, seed=7)
    c = obs.FlightRecorder(span_cap=32, seed=8)
    events = [_span("decode", i * 1e-3, 1e-4, tid=i % 5)
              for i in range(2000)]
    for e in events:
        a(e), b(e), c(e)
    sa, sb, sc = a.snapshot(), b.snapshot(), c.snapshot()
    # same seed, same stream -> the exact same surviving spans, sorted
    assert sa["spans"] == sb["spans"] and len(sa["spans"]) == 32
    assert sa["spans"] != sc["spans"]          # different seed, different keep
    acct = sa["accounting"]
    assert acct["spans_seen"] == 2000 and acct["spans_kept"] == 32
    assert acct["spans_dropped"] == 1968
    # the reservoir is uniform over the run, not a tail: a plain tail would
    # only hold the last 32 events
    assert min(e["ts"] for e in sa["spans"]) < events[-32]["ts"]


def test_instants_and_counters_keep_exact_tail():
    fl = obs.FlightRecorder(instant_cap=4, counter_cap=3)
    for i in range(10):
        fl({"name": "drop", "ph": "i", "pid": 0, "tid": i, "ts": float(i),
            "args": {}})
        fl({"name": "queue", "ph": "C", "pid": 1, "tid": 0, "ts": float(i),
            "args": {"depth": i}})
    snap = fl.snapshot()
    assert [e["tid"] for e in snap["instants"]] == [6, 7, 8, 9]
    assert [e["args"]["depth"] for e in snap["counters"]] == [7, 8, 9]
    assert snap["accounting"]["instants_seen"] == 10
    assert snap["accounting"]["instants_kept"] == 4


def test_metrics_sink_feeds_sample_tail():
    fl = obs.FlightRecorder(sample_cap=2)
    m = obs.MetricsRegistry(interval_s=0.01, sink=fl.observe_sample)
    for i in range(5):
        m.observe("ttft", 1e-3)
        m.snapshot(i * 0.011)
    snap = fl.snapshot()
    assert snap["samples"] and len(snap["samples"]) <= 2
    assert snap["accounting"]["samples_seen"] >= len(snap["samples"])


def test_retain_false_makes_the_ring_the_only_retention():
    fl = obs.FlightRecorder()
    tr = obs.Tracer(retain=False, sink=fl)
    tr.begin("request", tid=3)
    tr.clock.advance(0.5)
    tr.end("request", tid=3)
    tr.instant("drop", tid=4)
    assert tr.events == []                     # always-on mode: no growth
    snap = fl.snapshot()
    assert [e["name"] for e in snap["spans"]] == ["request"]
    assert [e["name"] for e in snap["instants"]] == ["drop"]


def test_flight_disabled_run_charges_zero_callbacks():
    gw = MicroBatchGateway(GatewayConfig(bucket_sizes=(1, 4)),
                           fe.FrontendSpec(mode="sc", bits=4))
    gw.warmup()
    c0 = obs.callback_count()
    gw.run(_frame_arrivals(8))
    assert obs.callback_count() == c0
    fl = obs.FlightRecorder()
    gw.run(_frame_arrivals(8), flight=fl)
    assert obs.callback_count() > c0 and fl.spans_seen > 0


def test_shrink_halves_content_and_recomputes_accounting():
    fl = obs.FlightRecorder(span_cap=16, instant_cap=8)
    for i in range(40):
        fl(_span("decode", i * 1e-3, 1e-4))
        fl({"name": "drop", "ph": "i", "pid": 0, "tid": i, "ts": float(i),
            "args": {}})
    snap = fl.snapshot()
    half = obs.FlightRecorder.shrink(snap)
    assert len(half["spans"]) == 8 and len(half["instants"]) == 4
    acct = half["accounting"]
    assert acct["spans_seen"] == 40 and acct["spans_kept"] == 8
    assert acct["spans_dropped"] == 32         # recomputed, not stale
    # shrink bottoms out at one entry per non-empty stream, never zero
    for _ in range(10):
        half = obs.FlightRecorder.shrink(half)
    assert len(half["spans"]) == 1 and len(half["instants"]) == 1


def test_capacities_must_be_positive():
    with pytest.raises(ValueError):
        obs.FlightRecorder(span_cap=0)


# ==========================================================================
# Critical-path attribution: the float-equality re-fold contract.
# ==========================================================================

def test_attribution_refolds_with_float_equality_and_carves_nesting():
    # awkward IEEE durations on purpose: 0.1 + 0.2 != 0.3 territory
    req = _span("request", 0.1, 0.7000000000000003, tid=9)
    children = [
        _span("queue_wait", 0.1, 0.10000000000000014, tid=9),
        _span("prefill", 0.2, 0.15000000000000002, tid=9),
        _span("decode", 0.4, 0.30000000000000004, tid=9),
        _span("migrate", 0.45, 0.1, tid=9),        # nested inside decode
        _span("prefill_chunk", 0.21, 0.01, tid=9),  # stays inside prefill
    ]
    cps = critpath.analyze([req] + children)
    assert len(cps) == 1
    cp = cps[0]
    assert critpath.verify(cp)                 # bitwise, not approx
    assert critpath.fold([d for _, d in cp["segments"]]) == req["dur"]
    # the migrate span was carved out of its decode parent, charged once
    assert cp["by_stage"]["migrate"] == pytest.approx(0.1)
    assert cp["by_stage"]["decode"] == \
        pytest.approx(0.30000000000000004 - 0.1)
    assert "prefill_chunk" not in cp["by_stage"]
    assert cp["segments"][-1][0] == "unattributed"
    assert cp["dominant"] == "decode"


def test_aggregate_ranks_stages_and_p_tail():
    fast = [critpath.attribute_request(
        _span("request", i * 1.0, 0.01, tid=i),
        [_span("queue_wait", i * 1.0, 0.008, tid=i)]) for i in range(9)]
    slow = [critpath.attribute_request(
        _span("request", 100.0, 1.0, tid=99),
        [_span("decode", 100.0, 0.9, tid=99)])]
    agg = critpath.aggregate(fast + slow, p=0.9)
    assert agg["exact"] and agg["requests"] == 10
    assert agg["ranking"][0] == "decode"       # 0.9s beats 9 * 8ms
    # the slow request IS the tail: fixing decode moves the p-quantile
    assert agg["p_dominant"] == "decode" and agg["p_dur"] == 1.0
    assert agg["stages"]["queue_wait"]["requests_dominated"] == 9
    shares = sum(rec["share"] for rec in agg["stages"].values())
    assert shares == pytest.approx(1.0)


def test_aggregate_by_role_maps_stages_to_tiers():
    cp = critpath.attribute_request(
        _span("request", 0.0, 1.0, tid=0),
        [_span("queue_wait", 0.0, 0.2, tid=0),
         _span("prefill", 0.2, 0.3, tid=0),
         _span("handoff", 0.5, 0.1, tid=0),
         _span("decode", 0.6, 0.3, tid=0)])
    agg = critpath.aggregate([cp], roles=True)
    roles = agg["by_role"]
    assert roles["prefill"]["stages"] == ["prefill", "queue_wait"]
    assert roles["boundary"]["stages"] == ["handoff"]
    assert roles["decode"]["total_s"] == pytest.approx(0.3)
    assert sum(r["share"] for r in roles.values()) == pytest.approx(1.0)


def test_empty_and_childless_requests_stay_exact():
    assert critpath.aggregate([])["requests"] == 0
    cp = critpath.attribute_request(_span("request", 0.0, 0.25, tid=1), [])
    assert critpath.verify(cp)
    assert cp["segments"] == [("unattributed", 0.25)]
    assert cp["dominant"] == "unattributed"


# ==========================================================================
# End-to-end: live gateway -> flight ring -> critical paths.
# ==========================================================================

def test_prompt_gateway_flight_ring_supports_exact_critpath():
    cfg, params = _setup()
    ad = make_adapter(cfg, params, n_slots=2, max_len=32, paged=True,
                      block_size=4)
    fl = obs.FlightRecorder(seed=3)
    m = obs.MetricsRegistry(interval_s=0.005)
    gw = PromptGateway(ContinuousBatcher(ad), max_new_tokens=4,
                       flight=fl, metrics=m)
    tel = gw.run(_prompt_arrivals(cfg, 5))
    assert len(tel.records) == 5
    snap = fl.snapshot()
    assert snap["spans"] and snap["samples"]   # ring + metrics both fed
    cps = critpath.analyze(snap["spans"])
    agg = critpath.aggregate(cps)
    assert agg["requests"] >= 1 and agg["exact"]
    # package-level aliases resolve to the same functions
    assert obs.analyze_critical_paths is critpath.analyze
    assert obs.aggregate_critical_paths is critpath.aggregate


def test_frame_gateway_traced_run_attributes_every_request():
    gw = MicroBatchGateway(GatewayConfig(bucket_sizes=(1, 4)),
                           fe.FrontendSpec(mode="sc", bits=4))
    gw.warmup()
    tr = obs.Tracer()
    tel = gw.run(_frame_arrivals(12), tracer=tr)
    agg = critpath.aggregate(critpath.analyze(tr.events))
    assert agg["requests"] == len(tel.records) and agg["exact"]
    assert set(agg["ranking"]) <= set(critpath.STAGES)
