import numpy as np

from repro.data import mnist_synth, tokens


def test_token_batches_deterministic():
    a = tokens.batch_at(7, 42, 4, 16, 100)
    b = tokens.batch_at(7, 42, 4, 16, 100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = tokens.batch_at(7, 43, 4, 16, 100)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_token_labels_are_shifted():
    b = tokens.batch_at(0, 0, 2, 8, 50)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 50


def test_pipeline_resume():
    p1 = tokens.TokenPipeline(0, 2, 8, 50)
    for _ in range(3):
        p1.next()
    b_at_3 = p1.next()
    p2 = tokens.TokenPipeline(0, 2, 8, 50, start_step=3)
    np.testing.assert_array_equal(p2.next()["tokens"], b_at_3["tokens"])


def test_mnist_synth_contract():
    xtr, ytr, xte, yte = mnist_synth.dataset(200, 50, seed=1)
    assert xtr.shape == (200, 28, 28, 1) and xtr.dtype == np.uint8
    assert set(np.unique(ytr)) <= set(range(10))
    # images are non-trivial (ink present, not saturated)
    assert 5 < xtr.mean() < 128
    # deterministic
    xtr2, *_ = mnist_synth.dataset(200, 50, seed=1)
    np.testing.assert_array_equal(xtr, xtr2)


def test_mnist_classes_distinguishable():
    """Mean images of distinct digits differ substantially."""
    xtr, ytr, *_ = mnist_synth.dataset(400, 10, seed=0)
    means = [xtr[ytr == d].mean(0) for d in range(10) if (ytr == d).sum() > 3]
    dists = []
    for i in range(len(means)):
        for j in range(i + 1, len(means)):
            dists.append(np.abs(means[i] - means[j]).mean())
    assert min(dists) > 2.0


def test_mnist_batches_deterministic():
    xtr, ytr, *_ = mnist_synth.dataset(100, 10)
    b1 = list(mnist_synth.batches(xtr, ytr, 8, seed=5, steps=2))
    b2 = list(mnist_synth.batches(xtr, ytr, 8, seed=5, steps=2))
    np.testing.assert_array_equal(b1[0][0], b2[0][0])
