"""Per-architecture smoke tests: reduced same-family config, one forward /
train-grad step on CPU, asserting output shapes and no NaNs; plus one
prefill+decode round per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve import engine


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["enc_embed"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.enc_len, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["vision_embed"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.n_vision_tokens, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_forward_and_grad(arch):
    cfg = configs.smoke_config(arch)
    params, specs = lm.init(jax.random.key(0), cfg, {})
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: not isinstance(x, dict))
    batch = _batch(cfg)

    def loss_fn(p):
        return lm.forward(cfg, p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_prefill_decode(arch):
    cfg = configs.smoke_config(arch)
    params, _ = lm.init(jax.random.key(0), cfg, {})
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    cache, logits = jax.jit(lambda p, b: engine.prefill(cfg, p, b))(
        params, batch)
    assert logits.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    # grow attention caches so decode has a free slot
    grown = dict(cache)
    for k in ("k", "v", "kx_self", "vx_self"):
        if k in grown:
            pad = [(0, 0)] * grown[k].ndim
            pad[-3] = (0, 8)
            grown[k] = jnp.pad(grown[k], pad)
    nc, lg = jax.jit(lambda p, c, t: engine.decode_step(cfg, p, c, t))(
        params, grown, batch["tokens"][:, :1])
    assert lg.shape == (B, cfg.vocab_padded)
    assert np.isfinite(np.asarray(lg, np.float32)).all(), arch
    assert int(nc["len"]) == S + 1


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_full_config_abstract(arch):
    """The FULL published config builds abstractly (shapes only) and its
    parameter count matches the published scale."""
    cfg = configs.config(arch)
    params, specs = lm.init(None, cfg, {"data": 16, "model": 16},
                            abstract=True)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    expected = {
        "llama3_405b": 405e9, "starcoder2_15b": 15e9, "deepseek_67b": 67e9,
        "stablelm_3b": 2.8e9, "whisper_medium": 0.8e9,
        "llama32_vision_90b": 90e9, "rwkv6_7b": 7.5e9, "hymba_1_5b": 1.6e9,
        "deepseek_moe_16b": 16e9, "moonshot_v1_16b_a3b": 28e9,
    }[arch]
    assert 0.8 * expected < n < 1.25 * expected, (arch, n)


def test_decode_matches_forward_logits():
    """Incremental decode reproduces the teacher-forced forward logits
    (f32 params for a tight tolerance)."""
    cfg = configs.smoke_config("stablelm_3b")
    cfg = type(cfg)(**{**cfg.__dict__, "param_dtype": "float32"})
    params, _ = lm.init(jax.random.key(1), cfg, {})
    rng = np.random.default_rng(0)
    B, S = 1, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    # forward logits at position t for all t: prefill of t+1 tokens
    cache, logits_prefill = engine.prefill(cfg, params, {"tokens": toks})
    # decode path: prefill S-1 then decode token S-1
    cache2, _ = engine.prefill(cfg, params, {"tokens": toks[:, :-1]})
    grown = dict(cache2)
    for k in ("k", "v"):
        pad = [(0, 0)] * grown[k].ndim
        pad[-3] = (0, 4)
        grown[k] = jnp.pad(grown[k], pad)
    _, logits_decode = engine.decode_step(cfg, params, grown, toks[:, -1:])
    np.testing.assert_allclose(np.asarray(logits_decode),
                               np.asarray(logits_prefill),
                               rtol=2e-4, atol=2e-4)
