import numpy as np
import pytest

from repro.core import sng


@pytest.mark.parametrize("bits", [2, 4, 6, 8, 10])
@pytest.mark.parametrize("which", [0, 1])
def test_lfsr_maximal_period(bits, which):
    seq = sng.lfsr_sequence(bits, which=which, length=(1 << bits) - 1)
    assert len(set(seq.tolist())) == (1 << bits) - 1   # visits all but 0
    assert 0 not in seq


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_deterministic_sequences_are_permutations(bits):
    N = 1 << bits
    for fn in (sng.vdc_sequence, sng.ramp_sequence, sng.revgray_sequence):
        seq = fn(bits)
        assert sorted(seq.tolist()) == list(range(N)), fn.__name__


def test_vdc_is_bit_reversal():
    assert sng.vdc_sequence(3).tolist() == [0, 4, 2, 6, 1, 5, 3, 7]


@pytest.mark.parametrize("scheme", sng.SCHEMES)
def test_scheme_registry(scheme):
    ca, cb = sng.codes_for_scheme(scheme, 4)
    assert len(ca) == len(cb) == 16


def test_ramp_stream_is_thermometer():
    import jax.numpy as jnp
    from repro.core import bitstream as bs
    s = sng.ramp_stream(jnp.asarray(5), 32)
    bits = np.asarray(bs.unpack_bits(s, 32)).astype(int)
    assert bits.tolist() == [1] * 5 + [0] * 27
