"""GPipe pipeline parallelism: schedule correctness on a 4-stage virtual
mesh (subprocess keeps the main process single-device)."""
import subprocess
import sys
import textwrap

import pytest

_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist.pipeline import gpipe_apply
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("stage",))
    n_stages, n_micro, B, d = 4, 6, 2, 8
    rng = np.random.default_rng(0)
    params = {
        "w": jnp.asarray(rng.normal(0, 0.5, (n_stages, d, d)), jnp.float32),
        "b": jnp.asarray(rng.normal(0, 0.1, (n_stages, d)), jnp.float32),
    }
    xs = jnp.asarray(rng.normal(0, 1, (n_micro, B, d)), jnp.float32)

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    got = gpipe_apply(stage_fn, params, xs, mesh)

    # sequential reference
    def seq(x):
        for s in range(n_stages):
            x = jnp.tanh(x @ params["w"][s] + params["b"][s])
        return x
    want = jnp.stack([seq(xs[m]) for m in range(n_micro)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # the compiled module must use point-to-point transfers
    txt = jax.jit(lambda p, x: gpipe_apply(stage_fn, p, x, mesh)).lower(
        params, xs).compile().as_text()
    assert "collective-permute" in txt
    print("PIPELINE_OK")
""")


@pytest.mark.slow
def test_gpipe_matches_sequential():
    r = subprocess.run([sys.executable, "-c", _PROG], capture_output=True,
                       text=True, timeout=600)
    assert "PIPELINE_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-1500:]
