import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # bare env: deterministic sweep fallback
    from _hypothesis_compat import given, settings, st

from repro.core import bitstream as bs


@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_pack_unpack_roundtrip(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(3, n)).astype(bool)
    packed = bs.pack_bits(jnp.asarray(bits))
    assert packed.shape == (3, bs.n_words(n))
    out = np.asarray(bs.unpack_bits(packed, n))
    assert (out == bits).all()
    assert (np.asarray(bs.popcount(packed)) == bits.sum(-1)).all()


@given(st.integers(2, 8))
@settings(max_examples=7, deadline=None)
def test_comparator_exact_counts(bits):
    """A permutation code sequence yields exactly `level` ones."""
    N = 1 << bits
    codes = jnp.asarray(np.random.default_rng(0).permutation(N), jnp.int32)
    lv = jnp.arange(N + 1)
    packed = bs.encode_comparator(lv, codes, N)
    assert (np.asarray(bs.popcount(packed)) == np.arange(N + 1)).all()


def test_tail_masking():
    n = 45  # non-multiple of 32
    ones = bs.ones((2,), n)
    assert bs.popcount(ones).tolist() == [n, n]
    z = bs.zeros((2,), n)
    assert bs.popcount(z).tolist() == [0, 0]


def test_value():
    bits = jnp.asarray([[1, 0, 1, 0, 1, 0, 0, 0]], dtype=bool)
    v = bs.value(bs.pack_bits(bits), 8)
    assert float(v[0]) == 3 / 8
