"""Chunked recurrences vs naive step-by-step references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # bare env: deterministic sweep fallback
    from _hypothesis_compat import given, settings, st

from repro.nn import ssm


def _wkv_naive(r, k, v, w, u):
    B, S, H, D = r.shape
    Sm = np.zeros((B, H, D, D), np.float64)
    out = np.zeros((B, S, H, D), np.float64)
    r, k, v, w = (np.asarray(t, np.float64) for t in (r, k, v, w))
    u = np.asarray(u, np.float64)
    for t in range(S):
        kv = k[:, t, :, :, None] * v[:, t, :, None, :]
        out[:, t] = np.einsum("bhd,bhde->bhe", r[:, t], Sm + u[..., None] * kv)
        Sm = w[:, t, :, :, None] * Sm + kv
    return out, Sm


@given(st.integers(1, 2), st.sampled_from([4, 8, 16, 32]), st.integers(1, 3),
       st.sampled_from([4, 8]), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_wkv6_chunked_matches_naive(B, S, H, D, seed):
    rng = np.random.default_rng(seed)
    r, k, v = (jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(np.exp(-np.exp(rng.normal(-2, 1, (B, S, H, D)))),
                    jnp.float32)
    u = jnp.asarray(rng.normal(0, 0.3, (H, D)), jnp.float32)
    chunk = min(4, S)
    out, state = ssm.wkv6_chunked(r, k, v, w, u, chunk=chunk)
    want, want_state = _wkv_naive(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), want_state,
                               rtol=2e-4, atol=2e-4)


def test_wkv6_step_consistent_with_chunked():
    rng = np.random.default_rng(0)
    B, S, H, D = 2, 8, 2, 4
    r, k, v = (jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(np.exp(-np.exp(rng.normal(-2, 1, (B, S, H, D)))),
                    jnp.float32)
    u = jnp.asarray(rng.normal(0, 0.3, (H, D)), jnp.float32)
    full, state_c = ssm.wkv6_chunked(r, k, v, w, u, chunk=4)
    state = jnp.zeros((B, H, D, D), jnp.float32)
    outs = []
    for t in range(S):
        o, state = ssm.wkv6_step(r[:, t], k[:, t], v[:, t], w[:, t], u, state)
        outs.append(o)
    step_out = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step_out), np.asarray(full),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_c),
                               rtol=2e-4, atol=2e-4)


def _selective_naive(x, dt, A_log, Bm, Cm, D_skip):
    x, dt, Bm, Cm = (np.asarray(t, np.float64) for t in (x, dt, Bm, Cm))
    A = -np.exp(np.asarray(A_log, np.float64))
    D_ = np.asarray(D_skip, np.float64)
    B_, S, d = x.shape
    N = A.shape[-1]
    h = np.zeros((B_, d, N))
    ys = np.zeros((B_, S, d))
    for t in range(S):
        a = np.exp(dt[:, t, :, None] * A)
        h = a * h + (dt[:, t] * x[:, t])[..., None] * Bm[:, t, None, :]
        ys[:, t] = np.einsum("bdn,bn->bd", h, Cm[:, t]) + D_ * x[:, t]
    return ys, h


@given(st.integers(1, 2), st.sampled_from([4, 8, 32]), st.integers(2, 6),
       st.integers(1, 4), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_selective_scan_matches_naive(B, S, d, N, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, (B, S, d)), jnp.float32)
    dt = jnp.asarray(rng.random((B, S, d)) * 0.2 + 0.01, jnp.float32)
    A_log = jnp.asarray(rng.normal(0, 0.5, (d, N)), jnp.float32)
    Bm = jnp.asarray(rng.normal(0, 1, (B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(0, 1, (B, S, N)), jnp.float32)
    D_skip = jnp.asarray(rng.normal(0, 1, (d,)), jnp.float32)
    chunk = min(4, S)
    y, h = ssm.selective_scan(x, dt, A_log, Bm, Cm, D_skip, chunk=chunk)
    yw, hw = _selective_naive(x, dt, A_log, Bm, Cm, D_skip)
    np.testing.assert_allclose(np.asarray(y), yw, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), hw, rtol=2e-4, atol=2e-4)


def test_selective_step_consistent():
    rng = np.random.default_rng(1)
    B, S, d, N = 2, 6, 3, 4
    x = jnp.asarray(rng.normal(0, 1, (B, S, d)), jnp.float32)
    dt = jnp.asarray(rng.random((B, S, d)) * 0.2 + 0.01, jnp.float32)
    A_log = jnp.asarray(rng.normal(0, 0.5, (d, N)), jnp.float32)
    Bm = jnp.asarray(rng.normal(0, 1, (B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(0, 1, (B, S, N)), jnp.float32)
    D_skip = jnp.asarray(rng.normal(0, 1, (d,)), jnp.float32)
    y_full, h_full = ssm.selective_scan(x, dt, A_log, Bm, Cm, D_skip, chunk=2)
    h = jnp.zeros((B, d, N), jnp.float32)
    for t in range(S):
        y, h = ssm.selective_step(x[:, t], dt[:, t], A_log, Bm[:, t],
                                  Cm[:, t], D_skip, h)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_full[:, -1]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)
