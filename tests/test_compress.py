import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # bare env: deterministic sweep fallback
    from _hypothesis_compat import given, settings, st

from repro.dist import compress


@given(st.integers(1, 5000), st.integers(0, 2**31 - 1),
       st.sampled_from([64, 256, 2048]))
@settings(max_examples=30, deadline=None)
def test_int8_roundtrip_error_bound(n, seed, chunk):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, 1, (n,)), jnp.float32)
    out = compress.int8_roundtrip(g, chunk)
    err = np.abs(np.asarray(out) - np.asarray(g))
    # max error <= half an int8 LSB of the per-chunk scale
    gmax = np.abs(np.asarray(g)).reshape(-1)
    scale_bound = np.abs(np.asarray(g)).max() / 127.0
    assert err.max() <= scale_bound * 0.5 + 1e-7


def test_zero_tensor():
    g = jnp.zeros((100,), jnp.float32)
    out = compress.int8_roundtrip(g)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_error_feedback_reduces_bias():
    """EF compensates quantization bias: the running compressed sum tracks
    the true sum much closer than without feedback."""
    rng = np.random.default_rng(0)
    g = rng.normal(0, 1, (50, 257)).astype(np.float32) * \
        np.geomspace(0.01, 1.0, 257)[None, :].astype(np.float32)
    res = jnp.zeros((257,), jnp.float32)
    sum_ef = np.zeros(257)
    sum_plain = np.zeros(257)
    for t in range(50):
        out_ef, res = compress.int8_roundtrip_ef(jnp.asarray(g[t]), res, 64)
        sum_ef += np.asarray(out_ef)
        sum_plain += np.asarray(compress.int8_roundtrip(jnp.asarray(g[t]), 64))
    true = g.sum(0)
    assert np.abs(sum_ef - true).mean() <= np.abs(sum_plain - true).mean()


def test_shapes_preserved():
    g = jnp.ones((3, 5, 7), jnp.bfloat16)
    out = compress.int8_roundtrip(g)
    assert out.shape == g.shape and out.dtype == g.dtype
