"""int8 KV-cache quantization (beyond-paper): round-trip bounds + decode
logit fidelity vs the bf16 cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # bare env: deterministic sweep fallback
    from _hypothesis_compat import given, settings, st

from repro import configs
from repro.models import lm
from repro.serve import engine, kvquant


@given(st.integers(1, 64), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_roundtrip_error_bound(d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 2, (3, 5, d)), jnp.float32)
    q, s = kvquant.quantize(x)
    back = kvquant.dequantize(q, s, jnp.float32)
    maxerr = np.abs(np.asarray(back) - np.asarray(x)).max(-1)
    bound = np.abs(np.asarray(x)).max(-1) / 127.0
    assert (maxerr <= bound * 0.5001 + 1e-7).all()


@pytest.mark.parametrize("arch", ["stablelm_3b", "hymba_1_5b",
                                  "deepseek_moe_16b"])
def test_decode_logits_close_to_bf16_cache(arch):
    base = configs.smoke_config(arch)
    base = dataclasses.replace(base, param_dtype="float32")
    qcfg = dataclasses.replace(base, kv_quant=True)
    params, _ = lm.init(jax.random.key(0), base, {})
    rng = np.random.default_rng(0)
    B, S = 2, 12
    toks = jnp.asarray(rng.integers(0, base.vocab, (B, S)), jnp.int32)

    outs = {}
    for name, cfg in (("bf16", base), ("int8", qcfg)):
        cache, _ = engine.prefill(cfg, params, {"tokens": toks})
        grown = dict(cache)
        for k in ("k", "v", "k_scale", "v_scale"):
            if k in grown:
                pad = [(0, 0)] * grown[k].ndim
                pad[-3] = (0, 4)
                grown[k] = jnp.pad(grown[k], pad)
        _, logits = engine.decode_step(cfg, params, grown, toks[:, :1])
        outs[name] = np.asarray(logits, np.float32)
    # logits track closely; rankings preserved
    denom = np.abs(outs["bf16"]).max()
    assert np.abs(outs["int8"] - outs["bf16"]).max() / denom < 0.05
    assert (outs["int8"].argmax(-1) == outs["bf16"].argmax(-1)).all()


def test_cache_size_halves():
    cfg = configs.smoke_config("stablelm_3b")
    qcfg = dataclasses.replace(cfg, kv_quant=True)
    c16 = engine.init_cache(cfg, 4, 128, abstract=True)
    c8 = engine.init_cache(qcfg, 4, 128, abstract=True)

    def nbytes(c):
        return sum(np.prod(v.shape) * v.dtype.itemsize
                   for v in jax.tree.leaves(c))
    assert nbytes(c8) < 0.6 * nbytes(c16)
