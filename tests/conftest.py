import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def sequential_decode_reference(cfg, params, prompt, n_new, max_len=None,
                                extras=None):
    """Single-request greedy decode oracle: prefill then n_new-1 decode
    steps, argmax at each.  ``max_len`` pads attention k/v caches so decode
    can write past the prompt (None for O(1)-state families).  ``extras``
    supplies family prefill inputs (enc_embed / vision_embed)."""
    import jax.numpy as jnp
    from repro.serve import engine

    batch = {"tokens": jnp.asarray(prompt[None])}
    if extras is not None:
        batch.update(extras() if callable(extras) else extras)
    cache, logits = engine.prefill(cfg, params, batch)
    if max_len is not None:
        for k in ("k", "v"):
            if k in cache:
                pad = [(0, 0)] * cache[k].ndim
                pad[-3] = (0, max_len - cache[k].shape[-3])
                cache[k] = jnp.pad(cache[k], pad)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        cache, logits = engine.decode_step(
            cfg, params, cache, jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
    return toks
