"""End-to-end behaviour of the paper's system: pretrain -> quantize/SC ->
retrain -> the hybrid recovers accuracy (paper §V.B), on the synthetic digit
set (offline MNIST stand-in; relative claims only — see DESIGN.md)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hybrid
from repro.core.sc_layer import SCConfig
from repro.data import mnist_synth
from repro.models import lenet
from repro.train import optim


@pytest.fixture(scope="module")
def trained():
    """A small float LeNet trained briefly on synthetic digits."""
    cfg = lenet.LeNetConfig(conv1_filters=8, conv2_filters=16, dense=64)
    xtr, ytr, xte, yte = mnist_synth.dataset(2000, 500)
    params = lenet.init(jax.random.key(0), cfg)
    opt_cfg = optim.AdamWConfig(lr=1e-3)
    opt = optim.init(params, opt_cfg)
    key = jax.random.key(1)
    for xb, yb in mnist_synth.batches(xtr, ytr, 64, 0, 150):
        key, sub = jax.random.split(key)
        params, opt, _ = hybrid.float_train_step(
            params, opt, jnp.asarray(xb), jnp.asarray(yb), sub, cfg, opt_cfg)
    return cfg, params, (xtr, ytr, xte, yte)


def test_float_baseline_learns(trained):
    cfg, params, (xtr, ytr, xte, yte) = trained
    acc = hybrid.evaluate(params, xte, yte, cfg,
                          hybrid.HybridConfig(mode="float"))
    assert acc > 0.8, acc


def test_hybrid_sc_retraining_recovers(trained):
    """The paper's central system claim: SC first layer + retrained binary
    tail ~= float accuracy; retraining recovers most of the quantization
    drop."""
    cfg, params, (xtr, ytr, xte, yte) = trained
    hcfg = hybrid.HybridConfig(mode="sc", sc=SCConfig(bits=4))
    feats_tr = hybrid.cache_first_layer(params, xtr[:1500], hcfg)
    feats_te = hybrid.cache_first_layer(params, xte, hcfg)
    before = hybrid.evaluate_cached(params, feats_te, yte, cfg)
    retrained = hybrid.retrain_tail(params, feats_tr, ytr[:1500], cfg,
                                    steps=150, batch=64)
    after = hybrid.evaluate_cached(retrained, feats_te, yte, cfg)
    float_acc = hybrid.evaluate(params, xte, yte, cfg,
                                hybrid.HybridConfig(mode="float"))
    assert after >= before - 0.02            # retraining never hurts much
    assert after > 0.75, (before, after)
    assert float_acc - after < 0.15, (float_acc, after)


def test_binary_design_equivalence(trained):
    """The all-binary quantized baseline flows through the same pipeline —
    and, as in the paper, it too needs the tail retrained (sign activation
    replaces ReLU, so unretrained accuracy drops several points)."""
    cfg, params, (xtr, ytr, xte, yte) = trained
    hcfg = hybrid.HybridConfig(mode="binary", bits=4)
    feats_tr = hybrid.cache_first_layer(params, xtr[:1200], hcfg)
    feats_te = hybrid.cache_first_layer(params, xte[:400], hcfg)
    before = hybrid.evaluate_cached(params, feats_te, yte[:400], cfg)
    retrained = hybrid.retrain_tail(params, feats_tr, ytr[:1200], cfg,
                                    steps=120, batch=64)
    after = hybrid.evaluate_cached(retrained, feats_te, yte[:400], cfg)
    assert after > 0.6, (before, after)
    assert after >= before - 0.02


def test_sc_2bit_collapse(trained):
    """Paper Table 3: at 2-bit the SC design collapses (43.8% error) while
    4-bit stays close — verify the cliff's direction."""
    cfg, params, (xtr, ytr, xte, yte) = trained
    accs = {}
    for bits in (2, 4):
        hcfg = hybrid.HybridConfig(mode="sc", sc=SCConfig(bits=bits))
        feats = hybrid.cache_first_layer(params, xte[:300], hcfg)
        accs[bits] = hybrid.evaluate_cached(params, feats, yte[:300], cfg)
    assert accs[4] > accs[2], accs


def test_ste_sign_gradient():
    x = jnp.asarray([-2.0, -0.5, 0.0, 0.5, 2.0])
    g = jax.grad(lambda x: jnp.sum(hybrid.ste_sign(x)))(x)
    np.testing.assert_array_equal(np.asarray(g), [0, 1, 1, 1, 0])
