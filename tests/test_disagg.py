"""Disaggregated prefill/decode serving (serve/shard/ under a RolePlan):
role-partitioned admission, prefill->decode handoff parity against the
stay-put oracle per attention family, handoff energy conservation,
affinity-aware eviction protection, migration rollback, per-role shedding,
and the head-of-line acceptance bar on a forced multi-device CPU mesh.

Single-device runs exercise everything but true multi-device placement
(slices then share the one device); the ``disagg`` CI job re-runs this file
under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the
@multi head-of-line test activates.
"""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro import configs
from repro.launch.mesh import make_disagg_meshes
from repro.models import lm
from repro.serve.gateway.sensors import Arrival
from repro.serve.gateway.slots import ContinuousBatcher, Request, make_adapter
from repro.serve.kvcache.pool import BlockPool, PoolExhausted
from repro.serve.shard import (RolePlan, ShardedPromptGateway, build_slices,
                               migrate_slot)

FAMILY_ARCH = {                      # one arch per attention family
    "decoder": "stablelm_3b",
    "moe": "deepseek_moe_16b",
    "hybrid": "hymba_1_5b",
    "encdec": "whisper_medium",
}
BS = 4

multi = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

_SETUP_CACHE: dict = {}


def _setup(arch):
    if arch not in _SETUP_CACHE:
        cfg = dataclasses.replace(configs.smoke_config(arch),
                                  param_dtype="float32")
        params, _ = lm.init(jax.random.key(0), cfg, {})
        extras = None
        if cfg.family == "encdec":
            rng = np.random.default_rng(99)
            enc = jnp.asarray(rng.normal(0, 1, (1, cfg.enc_len, cfg.d_model)),
                              jnp.float32)
            extras = (lambda e=enc: {"enc_embed": e})
        _SETUP_CACHE[arch] = (cfg, params, extras)
    return _SETUP_CACHE[arch]


def _slice_mesh(i: int) -> Mesh:
    devs = jax.devices()
    return Mesh(np.asarray([devs[i % len(devs)]]), ("model",))


def _mk_gateway(cfg, params, extras, n_slices, *, roles=None, n_slots=2,
                num_blocks=None, max_new=4, max_len=16, max_queue=128):
    slices = build_slices(cfg, params,
                          [_slice_mesh(i) for i in range(n_slices)],
                          n_slots=n_slots, max_len=max_len, block_size=BS,
                          num_blocks=num_blocks, extras=extras)
    return ShardedPromptGateway(slices, max_new_tokens=max_new,
                                max_queue=max_queue, roles=roles)


def _run_capture(gw, prompts):
    """Run prompts through the gateway, returning the Request objects."""
    arrivals = [Arrival(uid=i, t=0.0, endpoint=0, kind="prompt", payload=p)
                for i, p in enumerate(prompts)]
    reqs = {}
    orig = gw.submit

    def submit(req):
        reqs[req.uid] = req
        return orig(req)

    gw.submit = submit
    tel = gw.run(arrivals)
    gw.submit = orig
    return reqs, tel


def _oracle_tokens(cfg, params, extras, prompts, max_new):
    ad = make_adapter(cfg, params, n_slots=2, max_len=16, extras=extras,
                      paged=True, block_size=BS)
    out = []
    for i, p in enumerate(prompts):
        ob = ContinuousBatcher(ad)
        o = Request(uid=1000 + i, prompt=p, max_new_tokens=max_new)
        ob.submit(o)
        ob.run()
        out.append(o.generated)
    return out


# ==========================================================================
# RolePlan + mesh factoring.
# ==========================================================================

def test_roleplan_validation():
    plan = RolePlan.split(1, 2)
    assert plan.prefill == (0,) and plan.decode == (1, 2)
    assert plan.role_of(0) == "prefill" and plan.role_of(2) == "decode"
    with pytest.raises(AssertionError):
        RolePlan(prefill=(0, 1), decode=(1, 2))     # overlap
    with pytest.raises(AssertionError):
        RolePlan(prefill=(0,), decode=())           # empty role
    with pytest.raises(AssertionError):
        plan.role_of(3)                             # not in the plan
    cfg, params, extras = _setup("stablelm_3b")
    with pytest.raises(AssertionError):             # plan must cover slices
        _mk_gateway(cfg, params, extras, 2, roles=RolePlan.split(1, 2))


def test_disagg_meshes_partition_devices():
    if jax.device_count() >= 2:
        pre, dec = make_disagg_meshes(1, jax.device_count() - 1)
        assert len(pre) == 1
        ids = [d.id for m in pre + dec for d in m.devices.flat]
        assert len(ids) == len(set(ids))            # disjoint device groups
    with pytest.raises(AssertionError):
        make_disagg_meshes(0, 1)
    with pytest.raises(AssertionError):
        make_disagg_meshes(jax.device_count(), 1)   # over budget


# ==========================================================================
# Tentpole parity: the disaggregated gateway's tokens are the stay-put
# oracle's, per attention family; handoff energy re-folds conserved.
# ==========================================================================

@pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
def test_disagg_tokens_match_oracle(family):
    """1 prefill + 2 decode slices: every request is admitted on the
    prefill slice, handed off mid-lifecycle, and must still generate the
    solo oracle's tokens exactly (the migration path's bitwise contract,
    exercised through the role scheduler for all four families)."""
    cfg, params, extras = _setup(FAMILY_ARCH[family])
    rng = np.random.default_rng(17)
    prompts = [rng.integers(0, cfg.vocab, size=int(s)).astype(np.int32)
               for s in (5, 9, 6, 7)]
    gw = _mk_gateway(cfg, params, extras, 3, roles=RolePlan.split(1, 2))
    reqs, tel = _run_capture(gw, prompts)
    tel.assert_conserved()
    rep = tel.report(1.0, kind="prompt")
    assert rep["completed"] == len(prompts)
    # every request decoded somewhere else than it prefilled
    assert gw.handoffs == len(prompts)
    assert rep["routing"]["handoffs"] == gw.handoffs
    assert rep["routing"]["handoff_bytes"] == gw.handoff_bytes > 0
    assert gw.migrations == 0            # no rebalancing in role mode
    for i, want in enumerate(_oracle_tokens(cfg, params, extras, prompts,
                                            gw.max_new_tokens)):
        assert reqs[i].generated == want, i


def test_handoff_energy_rides_the_conserved_ledger():
    """Handoff bytes are charged per request through the same
    migration-energy pricing as rebalancing moves — the ledger stays
    conserved and the per-record bytes sum to the router's total."""
    cfg, params, extras = _setup("stablelm_3b")
    rng = np.random.default_rng(23)
    prompts = [rng.integers(0, cfg.vocab, size=int(s)).astype(np.int32)
               for s in (5, 9, 6)]
    gw = _mk_gateway(cfg, params, extras, 3, roles=RolePlan.split(1, 2))
    reqs, tel = _run_capture(gw, prompts)
    tel.assert_conserved()
    rep = tel.report(1.0, kind="prompt")
    moved = [r for r in tel.records if r.migration_bytes > 0]
    assert moved and sum(r.migration_bytes for r in moved) == \
        gw.handoff_bytes > 0
    assert rep["migration_bytes_total"] == gw.handoff_bytes
    assert all(reqs[i].migrations == 1 for i in range(len(prompts)))


def test_colocated_roles_none_matches_disagg_tokens():
    """roles=None is the PR 5 gateway: same prompts produce the same
    tokens through both scheduling modes (and the colocated run reports
    zero handoffs)."""
    cfg, params, extras = _setup("stablelm_3b")
    rng = np.random.default_rng(29)
    prompts = [rng.integers(0, cfg.vocab, size=int(s)).astype(np.int32)
               for s in (5, 9, 6, 7)]
    colo = _mk_gateway(cfg, params, extras, 3)
    creqs, ctel = _run_capture(colo, prompts)
    assert colo.handoffs == 0
    assert ctel.report(1.0, kind="prompt")["routing"]["handoffs"] == 0
    disagg = _mk_gateway(cfg, params, extras, 3, roles=RolePlan.split(1, 2))
    dreqs, _ = _run_capture(disagg, prompts)
    for i in range(len(prompts)):
        assert creqs[i].generated == dreqs[i].generated, i


# ==========================================================================
# Satellite: affinity-aware eviction — handoff protects the prompt chain
# on its owning decode slice; the pool prefers evicting unprotected blocks.
# ==========================================================================

def test_handoff_protects_chain_on_owning_decode_slice():
    """Two requests sharing a full-block prefix hand off to the same
    decode slice (radix affinity beats occupancy), and the chain's keys
    are protected on that slice's pool."""
    cfg, params, extras = _setup("stablelm_3b")
    rng = np.random.default_rng(31)
    prefix = rng.integers(0, cfg.vocab, size=2 * BS).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(0, cfg.vocab, size=3,
                                                    dtype=np.int32)]),
               np.concatenate([prefix, rng.integers(0, cfg.vocab, size=5,
                                                    dtype=np.int32)])]
    gw = _mk_gateway(cfg, params, extras, 3, roles=RolePlan.split(1, 2),
                     max_len=24)
    # serialize: first request completes before the second arrives, so the
    # second's handoff sees the first's chain parked on its decode slice
    arrivals = [Arrival(uid=0, t=0.0, endpoint=0, kind="prompt",
                        payload=prompts[0])]
    gw.run(arrivals)
    owners = [i for i in gw.roles.decode
              if gw.slices[i].adapter.pool.protected]
    assert len(owners) == 1                     # exactly one owning slice
    gw.run([Arrival(uid=1, t=0.0, endpoint=0, kind="prompt",
                    payload=prompts[1])])
    assert gw.handoffs == 2
    own = gw.slices[owners[0]].adapter.pool
    # both chains live on the owner, prefix keys protected there
    from repro.serve.kvcache.pool import chain_keys
    keys, _ = chain_keys(prefix, BS)
    assert set(keys) <= own.protected
    assert all(not gw.slices[i].adapter.pool.protected
               for i in gw.roles.decode if i != owners[0])


def test_pool_protected_eviction_preference():
    """Eviction takes the coldest *unprotected* block first; with every
    parked block protected it falls back to the cold end (liveness) and
    counts the forced eviction."""
    pool = BlockPool(num_blocks=4, block_size=BS)
    bids = [pool.alloc() for _ in range(3)]
    keys = [bytes([i]) * 20 for i in range(3)]
    for k, b in zip(keys, bids):
        pool.register(k, b)
    for b in bids:
        pool.release(b)                         # LRU cold->hot: bids order
    pool.protect([keys[0]])
    got = pool.alloc()                          # coldest unprotected
    assert got == bids[1]
    assert keys[0] in pool.index and keys[1] not in pool.index
    assert pool.protected_evictions == 0
    pool.protect([keys[2]])                     # everything parked protected
    got2 = pool.alloc()
    assert got2 == bids[0]                      # cold-end fallback
    assert pool.protected_evictions == 1
    assert keys[0] not in pool.protected        # unindex clears protection
    pool.unprotect(keys)
    assert not pool.protected
    # protecting an unindexed key is a no-op, not a leak
    pool.protect([b"missing" * 3])
    assert not pool.protected


# ==========================================================================
# Satellite: migration rollback — a failed handoff leaves both slices
# exactly as they were (dst blocks released, src radix untouched).
# ==========================================================================

def _two_adapters(cfg, params, extras, *, dst_blocks=None):
    mk = lambda mesh, nb: make_adapter(
        cfg, params, n_slots=2, max_len=24, extras=extras, paged=True,
        block_size=BS, num_blocks=nb, mesh=mesh)
    return mk(_slice_mesh(0), None), mk(_slice_mesh(1), dst_blocks)


def test_migrate_rollback_on_pool_exhausted():
    """Destination too small for the chain: allocation fails partway and
    every destination block is released; the source keeps decoding the
    oracle's bits as if nothing happened."""
    cfg, params, extras = _setup("stablelm_3b")
    rng = np.random.default_rng(41)
    prompt = rng.integers(0, cfg.vocab, size=9).astype(np.int32)
    src, dst = _two_adapters(cfg, params, extras, dst_blocks=3)
    oracle, _ = _two_adapters(cfg, params, extras)
    assert oracle.insert(0, prompt, max_new=8) == \
        src.insert(0, prompt, max_new=8)
    free0, idx0 = len(dst.pool.free), dict(dst.pool.index)
    with pytest.raises(PoolExhausted):
        migrate_slot(src, 0, dst, 0, prompt)
    assert len(dst.pool.free) == free0 and dst.pool.index == idx0
    assert not dst.slot_bids[0]
    assert src.slot_bids[0]                     # source untouched
    lane0 = np.asarray([True, False])
    for _ in range(3):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        np.testing.assert_array_equal(oracle.decode(forced, lane0),
                                      src.decode(forced, lane0))
        np.testing.assert_array_equal(np.asarray(oracle.last_logits)[0],
                                      np.asarray(src.last_logits)[0])


def test_migrate_rollback_mid_copy_releases_and_unregisters():
    """A failure *after* some blocks copied and registered (the cross-host
    hop is the fallible part) must unregister exactly this migration's
    index entries, release every destination block, leave pre-existing
    destination chains untouched, and keep the source decodable — and a
    retry must then succeed."""
    cfg, params, extras = _setup("stablelm_3b")
    rng = np.random.default_rng(43)
    # prompt shares exactly ONE full block with dst's pre-existing chain:
    # its second full block is fresh, so the failing copy sequence is
    # [register-worthy fresh block, fresh partial block] — the fault on
    # call 2 lands after a registration happened
    prefix = rng.integers(0, cfg.vocab, size=BS).astype(np.int32)
    prompt = np.concatenate([prefix, rng.integers(0, cfg.vocab, size=7,
                                                  dtype=np.int32)])
    other = np.concatenate([prefix, rng.integers(0, cfg.vocab, size=5,
                                                 dtype=np.int32)])
    src, dst = _two_adapters(cfg, params, extras)
    oracle, _ = _two_adapters(cfg, params, extras)
    assert oracle.insert(0, prompt, max_new=8) == \
        src.insert(0, prompt, max_new=8)
    # a pre-existing chain on dst: shared-prefix hits must survive rollback
    dst.insert(0, other, max_new=4)
    idx0 = dict(dst.pool.index)
    ref0 = dst.pool.refcount.copy()
    free0 = len(dst.pool.free)
    real_write, calls = dst._write_block, []

    def flaky(arena, bid, contents):
        calls.append(int(bid))
        if len(calls) >= 2:
            raise RuntimeError("wire dropped mid-copy")
        return real_write(arena, bid, contents)

    dst._write_block = flaky
    with pytest.raises(RuntimeError, match="mid-copy"):
        migrate_slot(src, 0, dst, 1, prompt)
    dst._write_block = real_write
    assert len(calls) == 2                      # it really failed partway
    assert dst.pool.index == idx0               # registrations undone,
    np.testing.assert_array_equal(dst.pool.refcount, ref0)  # refs restored
    assert len(dst.pool.free) == free0
    assert not dst.slot_bids[1]
    assert src.slot_bids[0]                     # src radix/blocks untouched
    # retry succeeds and the moved lane continues the oracle bitwise
    receipt = migrate_slot(src, 0, dst, 1, prompt)
    assert receipt.bytes_moved > 0
    lane0 = np.asarray([True, False])
    lane1 = np.asarray([False, True])
    for _ in range(3):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        to = oracle.decode(forced, lane0)
        td = dst.decode(forced[::-1], lane1)
        assert to[0] == td[1]
        np.testing.assert_array_equal(np.asarray(oracle.last_logits)[0],
                                      np.asarray(dst.last_logits)[1])


# ==========================================================================
# Per-role admission control: which scheduler sheds under which burn.
# ==========================================================================

def test_per_role_shedding_mapping():
    """TPOT burn (a decode symptom) tightens the handoff scheduler and
    leaves admission alone; every other objective sheds at the door.
    Colocated keeps the PR 7 behaviour: one bound, no role split."""
    cfg, params, extras = _setup("stablelm_3b")
    gw = _mk_gateway(cfg, params, extras, 3, roles=RolePlan.split(1, 2),
                     max_queue=64)
    ev = lambda worst, state="critical": types.SimpleNamespace(
        state=state, worst=worst, prev="ok", burns={}, t=0.0)
    gw._on_pressure(ev("ttft"))
    assert gw._shed_role == "prefill"
    assert gw._admit_bound() == 64 // gw.shed_factor
    gw._on_pressure(ev("tpot"))
    assert gw._shed_role == "decode"
    assert gw._admit_bound() == 64              # admission unaffected
    gw._on_pressure(ev("tpot", state="ok"))
    assert gw._shed_role is None and gw._admit_bound() == 64
    colo = _mk_gateway(cfg, params, extras, 2, max_queue=64)
    colo._on_pressure(ev("tpot"))
    assert colo._shed_role is None              # no role split colocated
    assert colo._admit_bound() == 64 // colo.shed_factor


def test_decode_shed_tightens_handoff_headroom():
    """Under decode-side shedding a handoff needs shed_factor x block
    headroom on the target — a slice that could just fit the chain stops
    being a candidate until pressure clears."""
    cfg, params, extras = _setup("stablelm_3b")
    gw = _mk_gateway(cfg, params, extras, 3, roles=RolePlan.split(1, 2),
                     num_blocks=9)
    rng = np.random.default_rng(47)
    prompt = rng.integers(0, cfg.vocab, size=9).astype(np.int32)
    req = Request(uid=0, prompt=prompt, max_new_tokens=4)
    gw.submit(req)
    gw.slices[0].batcher.step(decode=False)     # prefilled, awaiting handoff
    assert gw.route_handoff(req) in gw.roles.decode
    gw._shedding, gw._shed_role = True, "decode"
    assert gw.route_handoff(req) is None        # headroom x4 not available
    gw._shedding, gw._shed_role = False, None
    assert gw.route_handoff(req) in gw.roles.decode


# ==========================================================================
# Per-role observability: gauge series + OpenMetrics exposition.
# ==========================================================================

def test_role_metrics_series_and_openmetrics(tmp_path):
    from repro.serve.obs import MetricsRegistry
    from repro.serve.obs.export import (openmetrics_text,
                                        validate_openmetrics,
                                        write_openmetrics)
    cfg, params, extras = _setup("stablelm_3b")
    rng = np.random.default_rng(53)
    prompts = [rng.integers(0, cfg.vocab, size=int(s)).astype(np.int32)
               for s in (5, 9, 6)]
    slices = build_slices(cfg, params,
                          [_slice_mesh(i) for i in range(3)],
                          n_slots=2, max_len=16, block_size=BS)
    metrics = MetricsRegistry(interval_s=1e-9)
    gw = ShardedPromptGateway(slices, max_new_tokens=4, max_queue=128,
                              roles=RolePlan.split(1, 2), metrics=metrics)
    arrivals = [Arrival(uid=i, t=0.0, endpoint=0, kind="prompt", payload=p)
                for i, p in enumerate(prompts)]
    tel = gw.run(arrivals)
    rep = tel.report(1.0, kind="prompt")
    names = set().union(*(s.keys() for s in rep["series"])) - {"t"}
    for want in ("prefill_queue", "decode_queue", "prefill_occupancy",
                 "decode_occupancy", "handoffs", "handoff_bytes"):
        assert want in names, (want, names)
    last = rep["series"][-1]
    assert last["handoffs"] == gw.handoffs == len(prompts)
    assert last["prefill_occupancy"] == 0.0     # drained at run end
    text = openmetrics_text(metrics)
    required = ["repro_handoffs", "repro_handoff_bytes",
                "repro_prefill_occupancy", "repro_decode_occupancy",
                "repro_prefill_queue", "repro_decode_queue"]
    assert validate_openmetrics(text, require=required) == []
    assert validate_openmetrics(text, require=["repro_nope"]) \
        == ["required family 'repro_nope' not declared"]
    out = write_openmetrics(str(tmp_path / "m.txt"), metrics=metrics,
                            require=required)
    assert "repro_handoffs" in out
    with pytest.raises(AssertionError, match="repro_nope"):
        write_openmetrics(str(tmp_path / "m2.txt"), metrics=metrics,
                         require=["repro_nope"])


# ==========================================================================
# Forced 8-device mesh: the head-of-line acceptance bar.
# ==========================================================================

@multi
def test_disagg_relieves_decode_head_of_line():
    """Under a forced prefill burst at equal device budget, the decode
    slices' p99 tick latency (between-token time; ticks never contain
    prefill folds) must beat the colocated gateway's all-slice p99 tick
    latency (ticks absorb admission's chunked folds).  This is the
    JetStream-style argument for disaggregation, and the trend the
    ``--disagg`` bench gate enforces."""
    cfg, params, extras = _setup("stablelm_3b")
    rng = np.random.default_rng(61)
    short = [rng.integers(0, cfg.vocab, size=5, dtype=np.int32)
             for _ in range(12)]
    burst = [rng.integers(0, cfg.vocab, size=28, dtype=np.int32)
             for _ in range(8)]
    arrivals = [Arrival(uid=i, t=0.0, endpoint=0, kind="prompt", payload=p)
                for i, p in enumerate(short)]
    arrivals += [Arrival(uid=100 + i, t=0.0, endpoint=0, kind="prompt",
                         payload=p) for i, p in enumerate(burst)]

    def build(roles):
        slices = build_slices(cfg, params,
                              [_slice_mesh(i) for i in range(8)],
                              n_slots=2, max_len=36, block_size=BS)
        gw = ShardedPromptGateway(slices, max_new_tokens=6, max_queue=128,
                                  roles=roles, auto_rebalance=False)
        gw.warmup((4, 8))
        return gw

    colo = build(None)
    ctel = colo.run(list(arrivals))
    disagg = build(RolePlan.split(2, 6))
    dtel = disagg.run(list(arrivals))
    assert ctel.report(1.0, kind="prompt")["completed"] == \
        dtel.report(1.0, kind="prompt")["completed"] == len(arrivals)
    assert disagg.handoffs > 0
    c_p99 = colo.tick_latency_ms("all")
    d_p99 = disagg.tick_latency_ms("decode")
    assert d_p99 > 0 and c_p99 > 0
    assert d_p99 < c_p99, (d_p99, c_p99)
