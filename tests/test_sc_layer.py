import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # bare env: deterministic sweep fallback
    from _hypothesis_compat import given, settings, st

from repro.core import sc_layer
from repro.core.sc_layer import SCConfig


@given(st.integers(2, 8), st.integers(1, 30), st.integers(1, 8),
       st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_table_equals_streams(bits, K, O, seed):
    """The (N+1)^2 product-count table path is bit-identical to materializing
    the packed streams — for every bit width including N<32."""
    cfg = SCConfig(bits=bits, adder="tff")
    rng = np.random.default_rng(seed)
    N = 1 << bits
    xl = jnp.asarray(rng.integers(0, N + 1, (3, K)), jnp.int32)
    wl = jnp.asarray(rng.integers(0, N + 1, (K, O)), jnp.int32)
    a = sc_layer.counts_via_table(xl, wl, cfg)
    b = sc_layer.counts_via_streams(xl, wl, cfg)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quantize_weights_split():
    w = jnp.asarray([[0.5, -0.25], [1.0, 0.75], [-0.1, 0.0]])
    pos, neg, scale = sc_layer.quantize_weights(w, 4, scale=True)
    assert pos.shape == w.shape and neg.shape == w.shape
    # pos and neg never both nonzero
    assert not np.any((np.asarray(pos) > 0) & (np.asarray(neg) > 0))
    back = sc_layer.dequantize_weights(pos, neg, scale, 4)
    np.testing.assert_allclose(np.asarray(back), np.asarray(w), atol=1.1 / 16)


def test_weight_scaling_uses_full_range():
    w = jnp.asarray([[0.1, -0.05], [0.2, 0.01]])  # tiny weights
    pos, neg, scale = sc_layer.quantize_weights(w, 4, scale=True)
    m = np.maximum(np.asarray(pos), np.asarray(neg)).max(0)
    assert (m == 16).all()    # each kernel normalized to full range


def test_sign_activation_and_soft_threshold():
    cfg = SCConfig(bits=6, soft_threshold=0.0)
    x = jnp.asarray([[1.0, 1.0, 0.0, 0.0]])
    w = jnp.asarray([[1.0], [1.0], [-1.0], [-1.0]])      # x.w = 2 > 0
    out = sc_layer.sc_dot_sign(x, w, cfg)
    assert float(out[0, 0]) == 1.0
    wneg = -w
    assert float(sc_layer.sc_dot_sign(x, wneg, cfg)[0, 0]) == -1.0
    # a large threshold forces 0
    cfg_t = SCConfig(bits=6, soft_threshold=10.0)
    assert float(sc_layer.sc_dot_sign(x, w, cfg_t)[0, 0]) == 0.0


def test_sc_conv_output_domain():
    cfg = SCConfig(bits=4)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.random((2, 12, 12, 1)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.3, (5, 5, 1, 8)), jnp.float32)
    out = sc_layer.sc_conv2d_sign(x, w, cfg)
    assert out.shape == (2, 12, 12, 8)
    assert set(np.unique(np.asarray(out))) <= {-1.0, 0.0, 1.0}


def test_binary_baseline_matches_float_sign_at_high_precision():
    """8-bit binary quantized conv ~= sign of the float conv."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.random((1, 8, 8, 1)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.5, (3, 3, 1, 4)), jnp.float32)
    out = sc_layer.binary_conv2d_sign(x, w, bits=8)
    patches = sc_layer.extract_patches(x, 3)
    ref = jnp.sign(jnp.einsum("bhwk,ko->bhwo", patches, w.reshape(9, 4)))
    agree = (np.asarray(out) == np.asarray(ref)).mean()
    assert agree > 0.9


def test_sc_accuracy_improves_with_bits():
    """Monte-Carlo: SC dot-product error shrinks ~2x per extra bit."""
    rng = np.random.default_rng(2)
    K = 25
    x = jnp.asarray(rng.random((64, K)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.4, (K, 4)), jnp.float32)
    exact = np.asarray(jnp.einsum("mk,ko->mo", x, w))
    errs = {}
    for bits in (3, 5, 7):
        cfg = SCConfig(bits=bits, adder="tff")
        xl = sc_layer.quantize_levels(x, bits)
        pos, neg, scale = sc_layer.quantize_weights(w, bits)
        cp = sc_layer.counts_via_table(xl, pos, cfg)
        cn = sc_layer.counts_via_table(xl, neg, cfg)
        d = (np.asarray(cp) - np.asarray(cn)) * 2.0 ** sc_layer.tree_depth(K) \
            / (1 << bits)
        errs[bits] = np.abs(d * np.asarray(scale)[None] - exact).mean()
    assert errs[3] > errs[5] > errs[7]
