"""Pallas flash-attention kernel vs naive oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attn, ref


@pytest.mark.parametrize("BH,S,D,qc,kc,causal,dtype", [
    (4, 256, 64, 128, 128, True, jnp.float32),
    (2, 256, 128, 64, 128, False, jnp.float32),
    (8, 512, 64, 128, 64, True, jnp.bfloat16),
    (1, 128, 64, 64, 64, True, jnp.float32),
    (3, 384, 128, 128, 128, True, jnp.bfloat16),
])
def test_flash_kernel_matches_oracle(BH, S, D, qc, kc, causal, dtype):
    rng = np.random.default_rng(BH * S)
    q = jnp.asarray(rng.normal(0, 1, (BH, S, D)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (BH, S, D)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (BH, S, D)), dtype)
    out = flash_attn.flash_attention(q, k, v, causal=causal, qc=qc, kc=kc)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_kernel_matches_xla_flash_path():
    """The Pallas kernel and the XLA custom-VJP path agree (same math)."""
    from repro.nn import attention
    rng = np.random.default_rng(7)
    B, S, H, D = 2, 256, 2, 64
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
    xla = attention.attend_chunked(q, k, v, causal=True, q_chunk=64,
                                   kv_chunk=64)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    pal = flash_attn.flash_attention(qf, kf, vf, causal=True, qc=64, kc=64)
    pal = pal.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(xla),
                               rtol=2e-4, atol=2e-4)
