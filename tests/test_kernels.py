"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU), with
shape/bit-width sweeps and hypothesis-random inputs."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # bare env: deterministic sweep fallback
    from _hypothesis_compat import given, settings, st

from repro.core import sc_layer, sng
from repro.kernels import ops, ref


def _pad_pow2(x, w, K):
    Kp = 1 << max(1, int(np.ceil(np.log2(max(K, 2)))))
    return (jnp.pad(x, ((0, 0), (0, Kp - K), (0, 0))),
            jnp.pad(w, ((0, Kp - K), (0, 0), (0, 0))))


@pytest.mark.parametrize("M,K,O,bits", [
    (37, 25, 11, 5), (100, 25, 64, 8), (7, 9, 3, 6),
    (256, 32, 128, 5), (128, 64, 16, 7), (1, 2, 1, 5),
])
@pytest.mark.parametrize("adder", ["tff", "ideal"])
def test_sc_dot_kernel_matches_oracle(M, K, O, bits, adder):
    N = 1 << bits
    rng = np.random.default_rng(M * 31 + K)
    x = jnp.asarray(rng.integers(0, 2**32, (M, K, N // 32), dtype=np.uint32))
    w = jnp.asarray(rng.integers(0, 2**32, (K, O, N // 32), dtype=np.uint32))
    got = ops.sc_dot(x, w, adder=adder, s0_mode="alt")
    xp, wp = _pad_pow2(x, w, K)
    want = ref.sc_dot(xp, wp, s0_mode="alt", adder=adder)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(5, 8), st.integers(1, 40), st.integers(1, 30),
       st.integers(1, 12), st.sampled_from(["zero", "one", "alt"]),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_sc_dot_kernel_hypothesis(bits, M, K, O, s0_mode, seed):
    N = 1 << bits
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 2**32, (M, K, N // 32), dtype=np.uint32))
    w = jnp.asarray(rng.integers(0, 2**32, (K, O, N // 32), dtype=np.uint32))
    got = ops.sc_dot(x, w, s0_mode=s0_mode)
    xp, wp = _pad_pow2(x, w, K)
    want = ref.sc_dot(xp, wp, s0_mode=s0_mode)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bits", [5, 6, 7, 8])
def test_sng_pack_kernel_matches_oracle(bits):
    N = 1 << bits
    rng = np.random.default_rng(bits)
    lv = jnp.asarray(rng.integers(0, N + 1, (57,)), jnp.int32)
    for codes_fn in (sng.vdc_sequence, sng.ramp_sequence,
                     sng.revgray_sequence):
        codes = jnp.asarray(codes_fn(bits), jnp.int32)
        got = ops.sng_pack(lv, codes, N)
        want = ref.sng_pack(lv, codes, N)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_end_to_end_equals_table_path():
    """SNG kernel + dot kernel == the functional table path == gate truth."""
    bits = 5
    N = 1 << bits
    cfg = sc_layer.SCConfig(bits=bits, adder="tff", s0_mode="alt")
    rng = np.random.default_rng(3)
    xl = jnp.asarray(rng.integers(0, N + 1, (53, 25)), jnp.int32)
    wl = jnp.asarray(rng.integers(0, N + 1, (25, 16)), jnp.int32)
    kern = ops.sc_dot_from_levels(xl, wl, bits)
    table = sc_layer.counts_via_table(xl, wl, cfg)
    streams = sc_layer.counts_via_streams(xl, wl, cfg)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(table))
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(streams))


def test_kernel_block_shapes():
    """Different BlockSpec tilings give identical results."""
    bits, M, K, O = 5, 64, 25, 32
    N = 1 << bits
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 2**32, (M, K, N // 32), dtype=np.uint32))
    w = jnp.asarray(rng.integers(0, 2**32, (K, O, N // 32), dtype=np.uint32))
    outs = [np.asarray(ops.sc_dot(x, w, bm=bm, bo=bo))
            for bm, bo in ((16, 8), (32, 32), (64, 16), (128, 128))]
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)
