import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import manager as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(0, 1, (4, 8)), jnp.float32),
        "b": {"w": jnp.asarray(rng.normal(0, 1, (3, 3)), jnp.bfloat16),
              "step": jnp.int32(7)},
    }


def test_roundtrip_including_bf16(tmp_path):
    tree = _tree()
    ckpt.save(tmp_path, 5, tree)
    restored, manifest = ckpt.restore(tmp_path, jax.tree.map(jnp.zeros_like,
                                                             tree))
    assert manifest["step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_crc_detects_corruption(tmp_path):
    tree = _tree()
    path = ckpt.save(tmp_path, 1, tree)
    victim = sorted(path.glob("leaf_*.npy"))[0]
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="CRC"):
        ckpt.restore(tmp_path, jax.tree.map(jnp.zeros_like, tree))


def test_structure_mismatch_rejected(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    with pytest.raises(ValueError, match="structure"):
        ckpt.restore(tmp_path, {"only": jnp.zeros((2,))})


def test_latest_pointer_and_fallback(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    ckpt.save(tmp_path, 9, _tree(1))
    assert ckpt.latest_step(tmp_path) == 9
    (tmp_path / "LATEST").unlink()          # simulate lost pointer
    assert ckpt.latest_step(tmp_path) == 9  # recovered by scan


def test_atomicity_tmp_dirs_ignored(tmp_path):
    ckpt.save(tmp_path, 3, _tree())
    # a crashed half-save leaves a tmp dir — must be invisible
    (tmp_path / ".tmp_step_0000000099_123").mkdir()
    assert ckpt.latest_step(tmp_path) == 3
    ckpt.gc_tmp(tmp_path)
    assert not list(tmp_path.glob(".tmp_*"))


def test_manager_retention_and_async(tmp_path):
    mgr = ckpt.CheckpointManager(tmp_path, keep=2, save_interval=10)
    for step in (10, 20, 30):
        mgr.save_async(step, _tree(step))
    mgr.wait()
    steps = sorted(int(p.name.split("_")[-1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [20, 30]
    restored, manifest = mgr.restore_latest(
        jax.tree.map(jnp.zeros_like, _tree()))
    assert manifest["step"] == 30


def test_should_save_interval(tmp_path):
    mgr = ckpt.CheckpointManager(tmp_path, save_interval=100)
    assert not mgr.should_save(0)
    assert mgr.should_save(100)
    assert not mgr.should_save(101)
