"""Cascade decode attention over shared prefixes + the unified attention
backend API: log-sum-exp merge numerics, cascade-vs-flat parity at the
attention op, the Pallas cascade kernels (interpret), adapter-level parity
for all four attention families, the bitwise degrade rule, steady-state
no-recompile, shared-chain eligibility (mid-CoW / protected-for-handoff
exclusion), backend alias<->enum equivalence, and ServeSpec/make_gateway
construction including the sharded and disaggregated gateways."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro import configs
from repro.models import lm
from repro.nn import attention
from repro.serve.backend import (BACKENDS, auto_backend, resolve_backend)
from repro.serve.gateway.sensors import Arrival
from repro.serve.gateway.slots import ContinuousBatcher, Request, make_adapter
from repro.serve.kvcache import PagedKVSlotAdapter
from repro.serve.shard import RolePlan
from repro.serve.spec import ServeSpec, make_gateway

FAMILY_ARCH = {                      # one arch per attention family
    "decoder": "stablelm_3b",
    "moe": "deepseek_moe_16b",       # windowed layers + GQA
    "hybrid": "hymba_1_5b",
    "encdec": "whisper_medium",
}
BS = 4

_SETUP_CACHE: dict = {}


def _setup(arch):
    if arch not in _SETUP_CACHE:
        cfg = dataclasses.replace(configs.smoke_config(arch),
                                  param_dtype="float32")
        params, _ = lm.init(jax.random.key(0), cfg, {})
        extras = None
        if cfg.family == "encdec":
            rng = np.random.default_rng(99)
            enc = jnp.asarray(rng.normal(0, 1, (1, cfg.enc_len, cfg.d_model)),
                              jnp.float32)
            extras = (lambda e=enc: {"enc_embed": e})
        _SETUP_CACHE[arch] = (cfg, params, extras)
    return _SETUP_CACHE[arch]


def _slice_mesh(i: int) -> Mesh:
    devs = jax.devices()
    return Mesh(np.asarray([devs[i % len(devs)]]), ("model",))


# ==========================================================================
# LSE merge numerics (op level).
# ==========================================================================

def _state(s, v):
    """Unnormalized softmax state of scores s (..., S) over values
    v (..., S, D) — the oracle both merge implementations must compose to."""
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    return jnp.einsum("...s,...sd->...d", p, v), m, jnp.sum(p, -1)


def test_merge_recomposes_concatenated_softmax():
    """Splitting a key set in two, taking each half's online-softmax state,
    and LSE-merging must reproduce the whole set's softmax attention."""
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.normal(0, 3, (2, 4, 12)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (2, 4, 12, 8)), jnp.float32)
    whole = jnp.einsum("bhs,bhsd->bhd", jax.nn.softmax(s, -1), v)
    for cut in (1, 5, 11):
        a1, m1, l1 = _state(s[..., :cut], v[..., :cut, :])
        a2, m2, l2 = _state(s[..., cut:], v[..., cut:, :])
        acc, _, l = attention.merge_softmax_states(a1, m1, l1, a2, m2, l2)
        np.testing.assert_allclose(np.asarray(acc / l[..., None]),
                                   np.asarray(whole), rtol=1e-5, atol=1e-6)


def test_merge_empty_side_is_identity_bitwise():
    """The empty state (m = NEG_INF, l = 0, acc = 0) must drop out of the
    merge EXACTLY — an ungrouped lane's suffix-only state passes through
    bit for bit, which is what makes the adapter's flat degrade safe."""
    rng = np.random.default_rng(1)
    s = jnp.asarray(rng.normal(0, 2, (3, 4, 9)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (3, 4, 9, 8)), jnp.float32)
    acc, m, l = _state(s, v)
    empty_a = jnp.zeros_like(acc)
    empty_m = jnp.full_like(m, attention.NEG_INF)
    empty_l = jnp.zeros_like(l)
    for args in ((empty_a, empty_m, empty_l, acc, m, l),
                 (acc, m, l, empty_a, empty_m, empty_l)):
        ma, mm, ml = attention.merge_softmax_states(*args)
        np.testing.assert_array_equal(np.asarray(ma), np.asarray(acc))
        np.testing.assert_array_equal(np.asarray(mm), np.asarray(m))
        np.testing.assert_array_equal(np.asarray(ml), np.asarray(l))
    # both sides empty: zeros, not NaN (NEG_INF is finite)
    ma, _, ml = attention.merge_softmax_states(
        empty_a, empty_m, empty_l, empty_a, empty_m, empty_l)
    assert not np.any(np.isnan(np.asarray(ma)))
    np.testing.assert_array_equal(np.asarray(ml), np.zeros_like(ml))


# ==========================================================================
# attend_decode_cascade vs the flat reference (the dense fp32 oracle).
# ==========================================================================

def _cascade_fixture(seed=0, Hq=4, Hkv=2):
    """Lanes 0-2 share a 3-block prefix; lane 3 is ungrouped.  Lane
    lengths end mid-block; group padding exercises the mask scatter."""
    rng = np.random.default_rng(seed)
    D, bs, nb = 8, 4, 6
    k_arena = jnp.asarray(rng.normal(size=(25, bs, Hkv, D)), jnp.float32)
    v_arena = jnp.asarray(rng.normal(size=(25, bs, Hkv, D)), jnp.float32)
    tables = np.zeros((4, nb), np.int32)
    tables[0] = [1, 2, 3, 10, 11, 0]
    tables[1] = [1, 2, 3, 12, 0, 0]
    tables[2] = [1, 2, 3, 13, 14, 15]
    tables[3] = [4, 5, 6, 7, 0, 0]
    cache_len = jnp.asarray([18, 15, 23, 14], jnp.int32)
    q = jnp.asarray(rng.normal(size=(4, 1, Hq, D)), jnp.float32)
    new_kv = (jnp.asarray(rng.normal(size=(4, Hkv, D)), jnp.float32),
              jnp.asarray(rng.normal(size=(4, Hkv, D)), jnp.float32))
    meta = {
        "group_tables": jnp.asarray([[1, 2, 3, 0]], jnp.int32),
        "group_len": jnp.asarray([12], jnp.int32),
        "group_lanes": jnp.asarray([[0, 1, 2, 0]], jnp.int32),
        "group_mask": jnp.asarray([[True, True, True, False]]),
        "lane_q0": jnp.asarray([12, 12, 12, 0], jnp.int32),
        "suffix_tables": jnp.asarray(
            [[10, 11, 0, 0], [12, 0, 0, 0], [13, 14, 15, 0], [4, 5, 6, 7]],
            jnp.int32),
    }
    return q, k_arena, v_arena, tables, cache_len, new_kv, meta


# window=8 clips into the shared prefix for lane 1 (len 15, q0 12); window=2
# lies entirely inside every suffix, emptying the prefix states (the merge
# must drop them exactly); Hq=Hkv=4 is MHA, Hq=4/Hkv=2 is GQA.
@pytest.mark.parametrize("window", [0, 8, 2])
@pytest.mark.parametrize("heads", [(4, 2), (4, 4)])
def test_cascade_matches_flat_reference(window, heads):
    Hq, Hkv = heads
    q, ka, va, tables, cl, nk, meta = _cascade_fixture(Hq=Hq, Hkv=Hkv)
    flat = attention.attend_decode_paged(q, ka, va, jnp.asarray(tables), cl,
                                         window=window, new_kv=nk)
    casc = attention.attend_decode_cascade(q, ka, va, meta, cl,
                                           window=window, new_kv=nk)
    np.testing.assert_allclose(np.asarray(casc), np.asarray(flat),
                               rtol=2e-6, atol=2e-6)


def test_cascade_empty_suffix_is_prefix_only():
    """A lane whose length equals its group prefix has an all-masked
    suffix pass (l2 = 0): the merged output must equal flat attention over
    the prefix alone — no NaN, no phantom probability mass."""
    q, ka, va, tables, _, _, meta = _cascade_fixture()
    cl = jnp.asarray([12, 15, 23, 14], jnp.int32)   # lane 0: len == q0
    flat = attention.attend_decode_paged(q, ka, va, jnp.asarray(tables), cl)
    casc = attention.attend_decode_cascade(q, ka, va, meta, cl)
    assert not np.any(np.isnan(np.asarray(casc)))
    np.testing.assert_allclose(np.asarray(casc), np.asarray(flat),
                               rtol=2e-6, atol=2e-6)


@pytest.mark.parametrize("window", [0, 8, 2])
def test_cascade_pallas_kernels_match_flat(window):
    """kernel=True routes the prefix pass, the offset suffix sweep, and
    the merge through kernels/paged_attn.py (interpret off-TPU): same
    key-set selection, same tolerance against the flat reference."""
    q, ka, va, tables, cl, nk, meta = _cascade_fixture(seed=3)
    flat = attention.attend_decode_paged(q, ka, va, jnp.asarray(tables), cl,
                                         window=window, new_kv=nk)
    casc = attention.attend_decode_cascade(q, ka, va, meta, cl,
                                           window=window, new_kv=nk,
                                           kernel=True, interpret=True)
    np.testing.assert_allclose(np.asarray(casc), np.asarray(flat),
                               rtol=2e-6, atol=2e-6)


def test_state_kernel_empty_sweep_returns_empty_state():
    """An all-masked sweep (window entirely below the sweep's positions, or
    zero length) must come back as the EMPTY state (m = NEG_INF, l = 0),
    not a phantom uniform distribution — the flat kernel's exp(0) == 1
    failure mode this kernel explicitly zeroes out."""
    from repro.kernels import paged_attn as pk
    rng = np.random.default_rng(4)
    ka = jnp.asarray(rng.normal(size=(5, 4, 2, 8)), jnp.float32)
    va = jnp.asarray(rng.normal(size=(5, 4, 2, 8)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(1, 4, 8)), jnp.float32)
    acc, m, l = pk.paged_decode_attention_with_state(
        q, ka, va, jnp.asarray([[1, 2]], jnp.int32),
        jnp.asarray([0], jnp.int32), interpret=True)
    np.testing.assert_array_equal(np.asarray(l), np.zeros_like(l))
    np.testing.assert_array_equal(np.asarray(acc), np.zeros_like(acc))
    assert np.all(np.asarray(m) <= attention.NEG_INF)


# ==========================================================================
# Adapter-level parity: backend="cascade" vs backend="xla", all four
# attention families, tokens exact, logits to fp32 tolerance.
# ==========================================================================

def _shared_adapters(cfg, extras, params, backend, *, n_lanes=3,
                     shared_len=5 * BS, tail=3, seed=11, max_len=48):
    """n_lanes lanes sharing a shared_len-token prompt prefix (block
    aligned) plus one lane with a disjoint prompt."""
    ad = PagedKVSlotAdapter(cfg, params, n_lanes + 1, max_len,
                            block_size=BS, extras=extras, backend=backend)
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, cfg.vocab, size=shared_len).tolist()
    for s in range(n_lanes):
        toks = shared + rng.integers(1, cfg.vocab, size=tail + s).tolist()
        ad.insert(s, np.asarray(toks, np.int32), max_new=8)
    ad.insert(n_lanes, rng.integers(1, cfg.vocab, size=shared_len // 2,
                                    dtype=np.int32), max_new=8)
    return ad


@pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
def test_cascade_adapter_matches_flat_tick(family):
    """Same inserts, same forced tokens: the cascade tick must emit the
    flat in-place tick's argmax tokens exactly, logits to fp32 merge
    tolerance, and actually form a group (moe exercises windowed layers
    through the same metadata)."""
    cfg, params, extras = _setup(FAMILY_ARCH[family])
    a_x = _shared_adapters(cfg, extras, params, "xla")
    a_c = _shared_adapters(cfg, extras, params, "cascade")
    assert a_c.backend == "cascade" and a_c.inplace and not a_c.kernel
    rng = np.random.default_rng(21)
    active = np.ones(4, bool)
    for step in range(4):
        forced = rng.integers(0, cfg.vocab, size=4).astype(np.int32)
        tx = a_x.decode(forced, active)
        tc = a_c.decode(forced, active)
        assert a_c.last_groups == 1
        np.testing.assert_array_equal(tx, tc)
        np.testing.assert_allclose(np.asarray(a_c.last_logits),
                                   np.asarray(a_x.last_logits),
                                   rtol=2e-4, atol=2e-4)
    st = a_c.cascade_stats()
    assert st["groups"] == 1 and st["grouped_lanes"] == 3
    assert st["prefix_rows_flat"] == 3 * st["prefix_rows"]
    proxy = a_c.tick_bytes_proxy()
    assert proxy["cascade"] < proxy["inplace"] < proxy["gather"]


def test_cascade_degrades_to_flat_tick_bitwise():
    """No chain shared by >= 2 lanes: the cascade adapter must run the
    SAME flat jitted executable — logits bitwise, zero groups.  Also
    covers the single-lane-group rule: min_lanes=2 means a lone lane
    never forms a group."""
    cfg, params, extras = _setup("stablelm_3b")
    rng = np.random.default_rng(31)
    prompts = [rng.integers(1, cfg.vocab, size=s, dtype=np.int32)
               for s in (9, 13)]                       # disjoint prompts
    mk = lambda backend: PagedKVSlotAdapter(
        cfg, params, 2, 24, block_size=BS, extras=extras, backend=backend)
    a_x, a_c = mk("xla"), mk("cascade")
    for slot, p in enumerate(prompts):
        assert a_x.insert(slot, p, max_new=6) == \
            a_c.insert(slot, p, max_new=6)
    active = np.ones(2, bool)
    for step in range(4):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        tx = a_x.decode(forced, active)
        tc = a_c.decode(forced, active)
        assert a_c.last_groups == 0
        np.testing.assert_array_equal(tx, tc)
        np.testing.assert_array_equal(np.asarray(a_x.last_logits),
                                      np.asarray(a_c.last_logits))


def test_cascade_steady_state_never_recompiles():
    """The pow2-padded metadata buckets hold across steady-state ticks:
    after the first cascade tick compiles its bucket, further ticks with
    the same group topology must not grow the jit cache."""
    cfg, params, extras = _setup("stablelm_3b")
    a_c = _shared_adapters(cfg, extras, params, "cascade")
    rng = np.random.default_rng(41)
    active = np.ones(4, bool)
    forced = rng.integers(0, cfg.vocab, size=4).astype(np.int32)
    a_c.decode(forced, active)
    assert a_c.last_groups == 1
    size1 = a_c._decode_cascade._cache_size()
    assert size1 == 1
    for step in range(4):
        a_c.decode(forced, active)
    assert a_c.last_groups == 1
    assert a_c._decode_cascade._cache_size() == size1
    assert "decode_cascade" in a_c.jit_fns()


def test_cascade_meta_bucket_crossing_is_a_detectable_leak():
    """The recompile detector must see the cascade tick's full jit
    surface: jit_fns() exposes the outer cascade executable plus the three
    module-level kernel jits (grouped-prefix pass, per-lane suffix pass,
    softmax-state merge).  Decoding past a pow2 suffix-table bucket
    boundary forces a recompile — the detector must flag it, attributed to
    the cascade executable, while steady-state ticks inside one bucket
    stay clean."""
    from repro.serve import obs
    cfg, params, extras = _setup("stablelm_3b")
    a_c = _shared_adapters(cfg, extras, params, "cascade", max_len=64)
    rng = np.random.default_rng(51)
    active = np.ones(4, bool)
    forced = rng.integers(0, cfg.vocab, size=4).astype(np.int32)
    a_c.decode(forced, active)                  # compile the first bucket
    fns = a_c.jit_fns()
    for key in ("decode_cascade", "cascade_prefix", "cascade_suffix",
                "cascade_merge"):
        assert key in fns, f"jit_fns() must expose {key}"
    det = obs.RecompileDetector()
    det.track("cascade", fns)                   # asserts all are jitted
    det.snapshot()
    for _ in range(3):                          # same bucket: steady state
        a_c.decode(forced, active)
    assert det.steady_state_recompiles() == 0, det.report()
    # the ungrouped lane's suffix grows one block per tick; enough ticks
    # cross the pow2 suffix-table bucket and recompile the cascade tick
    for _ in range(8):
        a_c.decode(forced, active)
    assert a_c.last_groups == 1                 # topology never changed
    assert det.steady_state_recompiles() >= 1, det.report()
    leaks = {k for k, v in det.deltas().items() if v > 0}
    assert "cascade.decode_cascade" in leaks, det.report()


def test_cascade_stats_ride_metrics_series_and_openmetrics(tmp_path):
    """A cascade-backed gateway run with metrics attached must publish the
    grouping stats as pull-gauges: cascade_* columns in report()["series"]
    and repro_cascade_* OpenMetrics families (the require= list the obs CI
    job pins)."""
    from repro.serve.obs import MetricsRegistry
    from repro.serve.obs.export import openmetrics_text, write_openmetrics
    cfg, params, extras = _setup("stablelm_3b")
    rng = np.random.default_rng(71)
    shared = rng.integers(1, cfg.vocab, size=5 * BS).tolist()
    prompts = [np.asarray(shared + rng.integers(
        1, cfg.vocab, size=3 + i).tolist(), np.int32) for i in range(3)]
    arrivals = [Arrival(uid=i, t=0.0, endpoint=0, kind="prompt", payload=p)
                for i, p in enumerate(prompts)]
    metrics = MetricsRegistry(interval_s=1e-9)
    gw = make_gateway(cfg, params, ServeSpec(
        n_slots=4, max_len=64, paged=True, block_size=BS,
        backend="cascade", max_new_tokens=4, metrics=metrics))
    tel = gw.run(arrivals)
    rep = tel.report(1.0, kind="prompt")
    names = set().union(*(s.keys() for s in rep["series"])) - {"t"}
    keys = ("groups", "grouped_lanes", "prefix_rows", "prefix_rows_flat")
    for key in keys:
        assert f"cascade_{key}" in names, (key, names)
    # mid-run snapshots saw the shared-prefix group live
    assert max(s["cascade_grouped_lanes"] for s in rep["series"]
               if "cascade_grouped_lanes" in s) >= 2
    required = [f"repro_cascade_{k}" for k in keys]
    assert all(f"# TYPE repro_cascade_{k} gauge" in
               openmetrics_text(metrics) for k in keys)
    out = write_openmetrics(str(tmp_path / "m.txt"), metrics=metrics,
                            require=required)
    assert "repro_cascade_groups" in out


# ==========================================================================
# shared_chains eligibility: partial / unshared / protected / mid-CoW
# blocks break the chain (tentpole bugfix + satellite regression).
# ==========================================================================

def test_shared_chains_eligibility_rules():
    cfg, params, extras = _setup("stablelm_3b")
    ad = _shared_adapters(cfg, extras, params, "cascade")
    pool = ad.pool
    chains = {s: [int(b) for b in
                  ad.tables[s, :int(ad.lens[s]) // ad.bs]]
              for s in range(4)}
    groups = pool.shared_chains(chains)
    assert len(groups) == 1
    chain, lanes = groups[0]
    assert sorted(lanes) == [0, 1, 2] and len(chain) == 5
    # min_lanes above the group size: no group
    assert pool.shared_chains(chains, min_lanes=4) == []
    # a skipped block (armed for CoW this tick) truncates the chain there
    short = pool.shared_chains(chains, skip={chain[2]})
    assert short and short[0][0] == chain[:2]
    # skipping the chain head kills the whole group
    assert pool.shared_chains(chains, skip={chain[0]}) == []


def test_protected_for_handoff_chain_never_grouped():
    """Satellite bugfix: a chain protected for a disagg prefill->decode
    handoff (PR 8 ``protect``) must not enter a group — the handoff owns
    those blocks' lifecycle mid-flight.  A forced mid-handoff tick must
    degrade to the flat executable bitwise."""
    cfg, params, extras = _setup("stablelm_3b")
    a_x = _shared_adapters(cfg, extras, params, "xla")
    a_c = _shared_adapters(cfg, extras, params, "cascade")
    keys = [a_c.pool.block_key[int(b)]
            for b in a_c.tables[0, :int(a_c.lens[0]) // a_c.bs]
            if a_c.pool.block_key.get(int(b))]
    assert keys
    a_c.pool.protect(keys)                 # what the handoff path does
    rng = np.random.default_rng(51)
    active = np.ones(4, bool)
    forced = rng.integers(0, cfg.vocab, size=4).astype(np.int32)
    tc = a_c.decode(forced, active)
    assert a_c.last_groups == 0            # nothing grouped mid-handoff
    tx = a_x.decode(forced, active)
    np.testing.assert_array_equal(tx, tc)
    np.testing.assert_array_equal(np.asarray(a_x.last_logits),
                                  np.asarray(a_c.last_logits))
    # handoff completes -> unprotect -> grouping resumes
    a_c.pool.unprotect(keys)
    a_c.decode(forced, active)
    assert a_c.last_groups == 1


# ==========================================================================
# Backend enum + deprecated alias equivalence (api_redesign satellite).
# ==========================================================================

def test_resolve_backend_alias_equivalence():
    assert resolve_backend("xla") == "xla"
    assert resolve_backend(inplace=False) == "gather"
    assert resolve_backend(kernel=True) == "pallas"
    assert resolve_backend(kernel=False) == "xla"
    assert resolve_backend(inplace=True, kernel=None) == auto_backend()
    assert resolve_backend() == auto_backend()
    assert auto_backend() in ("xla", "pallas")
    with pytest.raises(ValueError, match="one of"):
        resolve_backend("mosaic")
    with pytest.raises(ValueError, match="alone"):
        resolve_backend("xla", kernel=True)
    with pytest.raises(ValueError, match="no kernel path"):
        resolve_backend(inplace=False, kernel=True)


def test_adapter_boolean_aliases_warn_and_match_enum():
    """Every legacy boolean spelling must build the adapter the enum
    spelling builds — and warn about its own deprecation."""
    cfg, params, _ = _setup("stablelm_3b")
    mk = lambda **kw: make_adapter(cfg, params, n_slots=1, max_len=8,
                                   paged=True, block_size=BS, **kw)
    for legacy, enum in ((dict(inplace=False), "gather"),
                         (dict(kernel=False), "xla"),
                         (dict(kernel=True), "pallas")):
        with pytest.warns(DeprecationWarning, match="deprecated"):
            ad = mk(**legacy)
        assert ad.backend == enum
        assert ad.backend == mk(backend=enum).backend
    with warnings.catch_warnings():
        warnings.simplefilter("error")     # enum spelling must NOT warn
        ad = mk(backend="cascade")
    assert ad.backend == "cascade" and ad.inplace and not ad.kernel
    with pytest.raises(ValueError, match="alone"):
        mk(backend="xla", kernel=True)
    assert set(BACKENDS) == {"gather", "xla", "pallas", "cascade"}


def test_unsupported_layouts_reject_explicit_cascade():
    """kv_quant / vlm layouts: an explicit cascade or pallas request must
    fail loudly; the auto probe quietly falls back to the XLA tick."""
    cfg, params, _ = _setup("stablelm_3b")
    qcfg = dataclasses.replace(cfg, kv_quant=True)
    with pytest.raises(ValueError, match="kv_quant"):
        PagedKVSlotAdapter(qcfg, params, 1, 8, block_size=BS,
                           backend="cascade")
    ad = PagedKVSlotAdapter(qcfg, params, 1, 8, block_size=BS)
    assert ad.backend == "xla"


# ==========================================================================
# ServeSpec / make_gateway (api_redesign satellite): colocated, sharded,
# and disaggregated construction from one declarative spec.
# ==========================================================================

def _arrivals(prompts):
    return [Arrival(uid=i, t=0.0, endpoint=0, kind="prompt", payload=p)
            for i, p in enumerate(prompts)]


def _run_tokens(gw, prompts, max_new):
    reqs = [Request(uid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    submitted = {}
    orig = gw.submit

    def submit(req):
        submitted[req.uid] = req
        return orig(req)

    gw.submit = submit
    gw.run(_arrivals(prompts))
    gw.submit = orig
    del reqs
    return [submitted[i].generated for i in sorted(submitted)]


def test_make_gateway_validates_spec():
    cfg, params, _ = _setup("stablelm_3b")
    with pytest.raises(ValueError, match="paged"):
        make_gateway(cfg, params, ServeSpec(backend="cascade"))
    with pytest.raises(ValueError, match="mesh"):
        make_gateway(cfg, params,
                     ServeSpec(paged=True, roles=RolePlan.split(1, 1)))
    with pytest.raises(ValueError, match="paged"):
        make_gateway(cfg, params, ServeSpec(mesh=[_slice_mesh(0)]))
    spec = ServeSpec()
    assert spec.replace(backend="xla").backend == "xla"
    assert spec.backend is None        # frozen: replace returns a copy


def test_make_gateway_colocated_cascade_matches_xla():
    """One ServeSpec field flips the whole gateway's tick dataflow; the
    generated tokens must not change."""
    cfg, params, extras = _setup("stablelm_3b")
    rng = np.random.default_rng(61)
    shared = rng.integers(1, cfg.vocab, size=2 * BS).tolist()
    prompts = [np.asarray(shared + rng.integers(
        1, cfg.vocab, size=2 + i).tolist(), np.int32) for i in range(3)]
    spec = ServeSpec(n_slots=3, max_len=24, paged=True, block_size=BS,
                     max_new_tokens=4)
    outs = {}
    for backend in ("xla", "cascade"):
        gw = make_gateway(cfg, params, spec.replace(backend=backend),
                          extras=extras)
        assert gw.batcher.adapter.backend == backend
        reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            gw.batcher.submit(r)
        gw.batcher.run()
        outs[backend] = [r.generated for r in reqs]
    assert outs["xla"] == outs["cascade"]


def test_make_gateway_sharded_and_disagg_cascade_parity():
    """spec.mesh builds the sharded gateway, spec.roles disaggregates it;
    backend="cascade" must generate the same tokens as "xla" through
    both topologies (prefix-sharing prompts land on one slice by
    affinity, so its decode ticks actually group)."""
    cfg, params, extras = _setup("stablelm_3b")
    rng = np.random.default_rng(71)
    shared = rng.integers(1, cfg.vocab, size=2 * BS).tolist()
    prompts = [np.asarray(shared + rng.integers(
        1, cfg.vocab, size=2 + i).tolist(), np.int32) for i in range(3)]
    base = ServeSpec(n_slots=3, max_len=24, paged=True, block_size=BS,
                     max_new_tokens=4, auto_rebalance=False)
    for roles in (None, RolePlan.split(1, 1)):
        outs = {}
        for backend in ("xla", "cascade"):
            spec = base.replace(
                mesh=[_slice_mesh(i) for i in range(2)],
                roles=roles, backend=backend)
            gw = make_gateway(cfg, params, spec, extras=extras)
            assert all(sl.adapter.backend == backend for sl in gw.slices)
            outs[backend] = _run_tokens(gw, prompts, 4)
        assert outs["xla"] == outs["cascade"], f"roles={roles}"
