"""In-place (gather-free) paged decode: bitwise parity against the gather
tick and the dense adapter for all four attention families, decode at block
boundaries, out-of-range lane routing, the full-chain-gather-is-gone jaxpr
pin, and the Pallas-kernel tick."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve.gateway.slots import make_adapter

FAMILY_ARCH = {                      # one arch per attention family
    "decoder": "stablelm_3b",        # causal MHA
    "moe": "deepseek_moe_16b",       # causal + routed FFN
    "hybrid": "hymba_1_5b",          # sliding windows + GQA + SSM state
    "encdec": "whisper_medium",      # causal self + cross attention
}
BS = 4


def _setup(arch):
    cfg = dataclasses.replace(configs.smoke_config(arch),
                              param_dtype="float32")
    params, _ = lm.init(jax.random.key(0), cfg, {})
    extras = None
    if cfg.family == "encdec":
        rng = np.random.default_rng(99)
        enc = jnp.asarray(rng.normal(0, 1, (1, cfg.enc_len, cfg.d_model)),
                          jnp.float32)
        extras = lambda: {"enc_embed": enc}
    elif cfg.family == "vlm":
        rng = np.random.default_rng(98)
        vis = jnp.asarray(
            rng.normal(0, 1, (1, cfg.n_vision_tokens, cfg.d_model)),
            jnp.float32)
        extras = lambda: {"vision_embed": vis}
    return cfg, params, extras


def _chain_blocks(ad, slot):
    return {(key, j): np.asarray(ad.arena_block(key, bid))
            for j, bid in enumerate(ad.slot_bids[slot])
            for key in ad.seq_keys}


# ==========================================================================
# Tentpole acceptance: the in-place tick is bitwise-identical to both
# oracles — the PR 2 gather tick and the dense adapter — per family.
# ==========================================================================

@pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
def test_inplace_matches_gather_tick_bitwise(family):
    """Same inserts, same forced tokens: the gather-free tick must produce
    the gather tick's logits, arena blocks, and non-sequence state bit for
    bit, every step."""
    cfg, params, extras = _setup(FAMILY_ARCH[family])
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (5, 9)]
    adapters = [make_adapter(cfg, params, n_slots=2, max_len=24,
                             extras=extras, paged=True, block_size=BS,
                             inplace=ip) for ip in (True, False)]
    assert adapters[0].inplace and not adapters[1].inplace
    for slot, p in enumerate(prompts):
        toks = [ad.insert(slot, p, max_new=8) for ad in adapters]
        assert toks[0] == toks[1]
    active = np.asarray([True, True])
    for step in range(6):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        outs = [ad.decode(forced, active) for ad in adapters]
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(np.asarray(adapters[0].last_logits),
                                      np.asarray(adapters[1].last_logits))
    inp, gat = adapters
    assert inp.slot_bids == gat.slot_bids
    for slot in range(2):
        a, b = _chain_blocks(inp, slot), _chain_blocks(gat, slot)
        for key in a:
            np.testing.assert_array_equal(a[key], b[key], err_msg=str(key))
    for key in inp.cache:
        np.testing.assert_array_equal(np.asarray(inp.cache[key]),
                                      np.asarray(gat.cache[key]))


@pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
def test_inplace_matches_dense_adapter_bitwise(family):
    """The in-place tick against the *dense* oracle: one-shot admission
    (``chunked=False`` shares the dense adapter's prefill executable), then
    every decode step's logits must match bit for bit — causal, windowed
    (hybrid respects the trailing-``window`` bound), GQA, and encdec cross
    attention all ride through the block tables."""
    cfg, params, extras = _setup(FAMILY_ARCH[family])
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (6, 9)]
    paged = make_adapter(cfg, params, n_slots=2, max_len=24, extras=extras,
                         paged=True, block_size=BS, chunked=False)
    dense = make_adapter(cfg, params, n_slots=2, max_len=24, extras=extras)
    assert paged.inplace
    for slot, p in enumerate(prompts):
        assert paged.insert(slot, p, max_new=8) == dense.insert(slot, p)
    active = np.asarray([True, True])
    for step in range(6):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        tp = paged.decode(forced, active)
        td = dense.decode(forced, active)
        np.testing.assert_array_equal(tp, td)
        np.testing.assert_array_equal(np.asarray(paged.last_logits),
                                      np.asarray(dense.last_logits))


# ==========================================================================
# int8 kv_quant rides the in-place tick (quantized one-row write +
# dequantize inside the attention read) — bitwise against the gather tick,
# which vmaps the dense quant decode_step.
# ==========================================================================

@pytest.mark.parametrize("family", ["decoder", "hybrid"])
def test_kvquant_inplace_matches_gather_tick_bitwise(family):
    """cfg.kv_quant=True: the in-place tick quantizes the new K/V row
    post-RoPE, writes int8 rows + f32 scale rows, and dequantizes the
    gathered view in the read — the gather tick's bits, every step, for
    the quantized arenas too."""
    cfg, params, extras = _setup(FAMILY_ARCH[family])
    cfg = dataclasses.replace(cfg, kv_quant=True)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (5, 9)]
    adapters = [make_adapter(cfg, params, n_slots=2, max_len=24,
                             extras=extras, paged=True, block_size=BS,
                             inplace=ip) for ip in (True, False)]
    assert adapters[0].inplace and not adapters[1].inplace
    assert not adapters[0].kernel            # quant: XLA reference only
    assert {"k_scale", "v_scale"} <= set(adapters[0].seq_keys)
    for slot, p in enumerate(prompts):
        toks = [ad.insert(slot, p, max_new=8) for ad in adapters]
        assert toks[0] == toks[1]
    active = np.asarray([True, True])
    for step in range(5):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        outs = [ad.decode(forced, active) for ad in adapters]
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(np.asarray(adapters[0].last_logits),
                                      np.asarray(adapters[1].last_logits))
    inp, gat = adapters
    assert inp.slot_bids == gat.slot_bids
    for slot in range(2):
        a, b = _chain_blocks(inp, slot), _chain_blocks(gat, slot)
        for key in a:
            np.testing.assert_array_equal(a[key], b[key], err_msg=str(key))


# ==========================================================================
# vlm's grouped cache rides the in-place tick (PR 8: the last gather-tick
# fallback is gone) — bitwise against the kept gather oracle.
# ==========================================================================

def test_vlm_inplace_matches_gather_tick_bitwise():
    """The grouped layout (two leading layer axes on self k/v, one on the
    cross-layer self k/v) decodes through the generalized in-place row
    write: the gather tick's logits, arena blocks, and slot state bit for
    bit, every step."""
    cfg, params, extras = _setup("llama32_vision_90b")
    assert cfg.family == "vlm"
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (5, 9)]
    adapters = [make_adapter(cfg, params, n_slots=2, max_len=24,
                             extras=extras, paged=True, block_size=BS,
                             inplace=ip) for ip in (True, False)]
    assert adapters[0].inplace and not adapters[1].inplace
    assert not adapters[0].kernel          # grouped layout: XLA reference
    assert {"kx_self", "vx_self"} <= set(adapters[0].seq_keys)
    for slot, p in enumerate(prompts):
        toks = [ad.insert(slot, p, max_new=8) for ad in adapters]
        assert toks[0] == toks[1]
    active = np.asarray([True, True])
    for step in range(5):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        outs = [ad.decode(forced, active) for ad in adapters]
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(np.asarray(adapters[0].last_logits),
                                      np.asarray(adapters[1].last_logits))
    inp, gat = adapters
    assert inp.slot_bids == gat.slot_bids
    for slot in range(2):
        a, b = _chain_blocks(inp, slot), _chain_blocks(gat, slot)
        for key in a:
            np.testing.assert_array_equal(a[key], b[key], err_msg=str(key))
    for key in inp.cache:
        np.testing.assert_array_equal(np.asarray(inp.cache[key]),
                                      np.asarray(gat.cache[key]))


def test_vlm_explicit_kernel_rejected():
    """kernel=True is a contract; the grouped layout must refuse it loudly
    instead of silently measuring the XLA path."""
    cfg, params, extras = _setup("llama32_vision_90b")
    with pytest.raises(ValueError, match="vlm"):
        make_adapter(cfg, params, n_slots=1, max_len=8, extras=extras,
                     paged=True, block_size=BS, kernel=True)


# ==========================================================================
# Block-boundary cases (satellite): aligned crossing, last writable
# position, trash-padded short chains.
# ==========================================================================

@pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
def test_decode_block_boundary_cases(family):
    """Three lanes decoding together against the dense oracle, bitwise:
    a block-aligned prompt (len % bs == 0, first decode crosses into a
    freshly inserted block), a prompt at max_len - 1 (the last writable
    position), and a short trash-padded chain."""
    cfg, params, extras = _setup(FAMILY_ARCH[family])
    rng = np.random.default_rng(3)
    max_len = 16
    lens = (8, 15, 3)       # aligned | last writable | trash-padded short
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in lens]
    paged = make_adapter(cfg, params, n_slots=3, max_len=max_len,
                         extras=extras, paged=True, block_size=BS,
                         chunked=False)
    dense = make_adapter(cfg, params, n_slots=3, max_len=max_len,
                         extras=extras)
    for slot, p in enumerate(prompts):
        # reserve enough generation blocks to actually decode (slot 1 can
        # only ever take one more token: 15 + 1 == max_len)
        max_new = min(8, max_len - len(p))
        assert paged.insert(slot, p, max_new=max_new) == dense.insert(slot, p)
    # step 1: slot 1 writes position 15 — the last position its final
    # block holds; slot 0 writes position 8, the first row of the fresh
    # generation block its table got at admission
    active = np.asarray([True, True, True])
    forced = rng.integers(0, cfg.vocab, size=3).astype(np.int32)
    np.testing.assert_array_equal(paged.decode(forced, active),
                                  dense.decode(forced, active))
    np.testing.assert_array_equal(np.asarray(paged.last_logits),
                                  np.asarray(dense.last_logits))
    assert paged.at_capacity(1)
    # steps 2-3: slot 1 is retired (at capacity) — the oracle must mask it
    # too, since its dense cache would clamp the out-of-range write; slots
    # 0 and 2 keep decoding across their block boundaries
    active = np.asarray([True, False, True])
    for step in range(3):
        forced = rng.integers(0, cfg.vocab, size=3).astype(np.int32)
        tp = paged.decode(forced, active)
        td = dense.decode(forced, active)
        np.testing.assert_array_equal(tp[active], td[active])
        np.testing.assert_array_equal(
            np.asarray(paged.last_logits)[active],
            np.asarray(dense.last_logits)[active])
    assert int(paged.lens[0]) == 12 and int(paged.lens[2]) == 7


# ==========================================================================
# Out-of-range lanes route to the trash block *inside* the jitted tick
# (satellite bugfix: the old clamp aliased them onto the final block).
# ==========================================================================

@pytest.mark.parametrize("inplace", [True, False])
def test_oor_lane_routes_to_trash_in_jit(inplace):
    """Bypass the host-side at_capacity masking and hand the jitted tick an
    out-of-range length with a *real* write-block id: the write must land
    in the trash block, leaving the final (possibly shared) block intact.
    The pre-fix gather tick clamped the extraction slice instead, silently
    overwriting the final block."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab, size=7).astype(np.int32)
    ad = make_adapter(cfg, params, n_slots=1, max_len=8, paged=True,
                      block_size=BS, inplace=inplace)
    ad.insert(0, prompt, max_new=1)
    final_bid = int(ad.tables[0, ad.nb_max - 1])
    assert final_bid != 0
    dense = dict(ad.cache)
    dense["len"] = dense["len"].at[0].set(ad.max_len)      # out of range
    before = {key: np.asarray(ad.arena_block(key, final_bid))
              for key in ad.seq_keys}
    arena2, _, _ = ad._decode(
        ad.params, ad.arena, dense, jnp.asarray(ad.tables),
        jnp.asarray([[5]], jnp.int32), jnp.asarray([True]),
        jnp.asarray([final_bid], jnp.int32))               # a REAL target
    for key in ad.seq_keys:
        np.testing.assert_array_equal(
            before[key],
            np.asarray(jnp.take(arena2[key], final_bid,
                                axis=ad._bax[key])))


# ==========================================================================
# The full-chain gather is gone from the steady-state tick (jaxpr pin).
# ==========================================================================

def _gather_out_sizes(jaxpr, out):
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "gather":
            out.extend(int(np.prod(v.aval.shape)) for v in eqn.outvars)
        for p in eqn.params.values():
            vals = p if isinstance(p, (list, tuple)) else (p,)
            for v in vals:
                if isinstance(v, jax.core.ClosedJaxpr):
                    _gather_out_sizes(v.jaxpr, out)
                elif isinstance(v, jax.core.Jaxpr):
                    _gather_out_sizes(v, out)
    return out


def test_full_chain_gather_gone_from_inplace_tick():
    """The old tick materialized each key's whole (slots, L, nb_max*bs)
    dense cache through one giant gather; the in-place tick must never
    produce a gather that large — its reads are per-layer (XLA reference)
    or per-block (kernel DMA)."""
    cfg, params, _ = _setup("stablelm_3b")
    ad = make_adapter(cfg, params, n_slots=2, max_len=32, paged=True,
                      block_size=BS)
    args = (ad.params, ad.arena, ad.cache, jnp.asarray(ad.tables),
            jnp.zeros((2, 1), jnp.int32), jnp.ones((2,), bool),
            jnp.zeros((2,), jnp.int32))
    full_chain = (2 * cfg.n_layers * ad.nb_max * ad.bs
                  * cfg.n_kv_heads * cfg.d_head)
    new = _gather_out_sizes(jax.make_jaxpr(ad._tick_inplace_impl)(*args)
                            .jaxpr, [])
    assert new and max(new) < full_chain
    # guard the pin itself: the legacy tick DOES contain that gather
    old = _gather_out_sizes(jax.make_jaxpr(ad._tick_impl)(*args).jaxpr, [])
    assert max(old) >= full_chain


# ==========================================================================
# The Pallas kernel tick (forced interpret off-TPU; the CI
# kernels-interpret leg runs this deliberately).
# ==========================================================================

@pytest.mark.parametrize("arch", ["stablelm_3b", "hymba_1_5b"])
def test_kernel_tick_matches_reference(arch):
    """kernel=True routes every self-attention layer through
    kernels/paged_attn.py inside the serving tick.  The kernel's online
    softmax is not bitwise against the single-shot reference, but tokens
    must agree and logits must be close — including hymba's traced
    per-layer sliding/global window selection."""
    cfg, params, extras = _setup(arch)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (5, 9)]
    ref = make_adapter(cfg, params, n_slots=2, max_len=16, extras=extras,
                       paged=True, block_size=BS, kernel=False)
    ker = make_adapter(cfg, params, n_slots=2, max_len=16, extras=extras,
                       paged=True, block_size=BS, kernel=True)
    assert ker.kernel and ker.inplace
    for slot, p in enumerate(prompts):
        assert ref.insert(slot, p, max_new=4) == ker.insert(slot, p,
                                                            max_new=4)
    active = np.asarray([True, True])
    for step in range(3):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        tr = ref.decode(forced, active)
        tk = ker.decode(forced, active)
        np.testing.assert_array_equal(tr, tk)
        np.testing.assert_allclose(np.asarray(ker.last_logits),
                                   np.asarray(ref.last_logits),
                                   rtol=2e-4, atol=2e-4)
