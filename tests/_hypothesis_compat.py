"""Deterministic stand-in for ``hypothesis`` on bare environments.

The tier-1 suite must collect and run green without any packages beyond
jax + pytest (the container contract).  When ``hypothesis`` is installed the
test files use it unchanged; when it is missing they fall back to this shim,
which turns each ``@given`` property into a fixed parameter sweep:

  - the boundary combination (every strategy at its minimum) and the
    opposite corner (every strategy at its maximum) always run;
  - the remaining ``settings(max_examples=N)`` budget is filled with draws
    from a fixed-seed generator, so failures reproduce exactly.

No shrinking, ``assume``, or stateful testing — none of the suite's
properties need them.
"""
from __future__ import annotations

import functools
import inspect
import types

import numpy as np

_SEED = 0x5EED


class _Strategy:
    def __init__(self, boundary, sample):
        self.boundary = boundary      # (lo_example, hi_example)
        self.sample = sample          # rng -> value


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy((min_value, max_value),
                     lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy((elements[0], elements[-1]),
                     lambda rng: elements[int(rng.integers(len(elements)))])


def _booleans() -> _Strategy:
    return _Strategy((False, True), lambda rng: bool(rng.integers(2)))


def _floats(min_value=0.0, max_value=1.0, **_kw) -> _Strategy:
    return _Strategy((min_value, max_value),
                     lambda rng: float(rng.uniform(min_value, max_value)))


st = types.SimpleNamespace(integers=_integers, sampled_from=_sampled_from,
                           booleans=_booleans, floats=_floats)
strategies = st


def settings(max_examples: int = 20, deadline=None, **_ignored):
    """Record the example budget; accepted in either decorator order."""
    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn
    return deco


def _examples(strats, n):
    combos = [tuple(s.boundary[0] for s in strats),
              tuple(s.boundary[1] for s in strats)]
    rng = np.random.default_rng(_SEED)
    while len(combos) < n:
        combos.append(tuple(s.sample(rng) for s in strats))
    return combos[:max(n, 1)]


def given(*strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_compat_max_examples", 20)
            for ex in _examples(strats, n):
                fn(*args, *ex, **kwargs)

        # Hide the strategy-supplied parameters from pytest's fixture
        # resolution (hypothesis does the same via its own wrapper).
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        if strats:
            params = params[:-len(strats)]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper
    return deco
