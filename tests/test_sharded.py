"""Sharded paged serving (serve/shard/): slice-placement bitwise parity,
cross-slice migration mid-decode, prefix-affinity routing (including a hit
routed to a non-owning slice), and the aggregate-concurrency acceptance bar
on a forced multi-device CPU mesh.

Single-device runs exercise everything but true multi-device placement
(slices then share the one device — the policy layer is device-agnostic);
the ``sharded`` CI job re-runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so every slice owns
a real (virtual) device and the @multi tests activate.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro import configs
from repro.dist.sharding import mesh_shape_dict, slice_meshes
from repro.launch.mesh import make_serving_mesh
from repro.models import lm
from repro.serve import engine
from repro.serve.gateway.sensors import Arrival
from repro.serve.gateway.slots import ContinuousBatcher, Request, make_adapter
from repro.serve.shard import (ShardedPromptGateway, build_slices,
                               migrate_slot)

FAMILY_ARCH = {                      # one arch per attention family
    "decoder": "stablelm_3b",
    "moe": "deepseek_moe_16b",
    "hybrid": "hymba_1_5b",
    "encdec": "whisper_medium",
}
BS = 4

multi = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")

_SETUP_CACHE: dict = {}


def _setup(arch):
    if arch not in _SETUP_CACHE:
        cfg = dataclasses.replace(configs.smoke_config(arch),
                                  param_dtype="float32")
        params, _ = lm.init(jax.random.key(0), cfg, {})
        extras = None
        if cfg.family == "encdec":
            rng = np.random.default_rng(99)
            enc = jnp.asarray(rng.normal(0, 1, (1, cfg.enc_len, cfg.d_model)),
                              jnp.float32)
            extras = (lambda e=enc: {"enc_embed": e})
        _SETUP_CACHE[arch] = (cfg, params, extras)
    return _SETUP_CACHE[arch]


def _slice_mesh(i: int) -> Mesh:
    """Single-device slice mesh i (devices reused when there are fewer)."""
    devs = jax.devices()
    return Mesh(np.asarray([devs[i % len(devs)]]), ("model",))


def _chain_blocks(ad, slot):
    return {(key, j): np.asarray(ad.arena_block(key, bid))
            for j, bid in enumerate(ad.slot_bids[slot])
            for key in ad.seq_keys}


# ==========================================================================
# Tentpole acceptance: a sharded (mesh-placed) slice runs the unsharded
# tick bit for bit — per family, on whatever device the slice owns.
# ==========================================================================

@pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
def test_slice_placement_bitwise(family):
    """The same adapter committed to a 1-slice mesh (the *last* device, so
    the 8-device CI job really crosses devices) must reproduce the
    unsharded adapter's logits, arena blocks, and slot state bitwise."""
    cfg, params, extras = _setup(FAMILY_ARCH[family])
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (5, 9)]
    un = make_adapter(cfg, params, n_slots=2, max_len=24, extras=extras,
                      paged=True, block_size=BS)
    sh = make_adapter(cfg, params, n_slots=2, max_len=24, extras=extras,
                      paged=True, block_size=BS,
                      mesh=_slice_mesh(jax.device_count() - 1))
    assert sh.mesh is not None
    for slot, p in enumerate(prompts):
        assert un.insert(slot, p, max_new=8) == sh.insert(slot, p, max_new=8)
    active = np.asarray([True, True])
    for step in range(4):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        np.testing.assert_array_equal(un.decode(forced, active),
                                      sh.decode(forced, active))
        np.testing.assert_array_equal(np.asarray(un.last_logits),
                                      np.asarray(sh.last_logits))
    assert un.slot_bids == sh.slot_bids
    for slot in range(2):
        a, b = _chain_blocks(un, slot), _chain_blocks(sh, slot)
        for key in a:
            np.testing.assert_array_equal(a[key], b[key], err_msg=str(key))
    for key in un.cache:
        np.testing.assert_array_equal(np.asarray(un.cache[key]),
                                      np.asarray(sh.cache[key]))


def test_arena_specs_match_layout():
    """engine.arena_specs must produce one spec per paged key with the
    arena's exact rank, for every family layout (incl. vlm's grouped axes
    and the int8 quant scales), and shard KV heads over "model" exactly
    when cache_specs would."""
    ms = {"data": 2, "model": 2}
    for arch, quant in [("stablelm_3b", False), ("stablelm_3b", True),
                        ("hymba_1_5b", False), ("whisper_medium", False),
                        ("llama32_vision_90b", False)]:
        cfg = configs.smoke_config(arch)
        if quant:
            cfg = dataclasses.replace(cfg, kv_quant=True)
        arena = engine.init_paged_arena(cfg, 4, BS, abstract=True)
        specs = engine.arena_specs(cfg, ms)
        assert set(specs) == set(arena), arch
        for key, a in arena.items():
            sp = tuple(specs[key])
            assert len(sp) == a.ndim, (arch, key, sp, a.shape)
            assert sp[engine.arena_block_axis(a)] is None, \
                "the block axis never shards"
            want = "model" if cfg.n_kv_heads % ms["model"] == 0 else None
            if key in ("k", "v"):
                assert sp[-2] == want, (arch, key, sp)


# ==========================================================================
# Cross-slice migration: a live request moves mid-decode and keeps
# producing the oracle's bits; sharing re-establishes on the destination.
# ==========================================================================

@pytest.mark.parametrize("family", ["decoder", "hybrid", "encdec"])
def test_migration_mid_decode_bitwise(family):
    """Decode 3 steps on slice A, migrate the request to slice B, decode 3
    more: B's lane must continue the oracle's logits bit for bit (covers
    plain KV, hybrid conv/SSM state rows, and encdec cross-K/V)."""
    cfg, params, extras = _setup(FAMILY_ARCH[family])
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (5, 9)]
    mk = lambda mesh=None: make_adapter(
        cfg, params, n_slots=2, max_len=24, extras=extras, paged=True,
        block_size=BS, mesh=mesh)
    oracle = mk()
    A, B = mk(_slice_mesh(0)), mk(_slice_mesh(1))
    active = np.asarray([True, True])
    for slot, p in enumerate(prompts):
        assert oracle.insert(slot, p, max_new=8) == \
            A.insert(slot, p, max_new=8)
    for step in range(3):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        np.testing.assert_array_equal(oracle.decode(forced, active),
                                      A.decode(forced, active))
    live = -(-int(A.lens[1]) // BS)
    receipt = migrate_slot(A, 1, B, 1, prompts[1])
    # only blocks holding written rows cross the host; the pre-allocated
    # generation tail is re-created empty on the destination
    assert receipt.blocks_moved == live > 0
    assert receipt.blocks_total == len(B.slot_bids[1]) > live
    assert not A.slot_bids[1]                     # source slot released
    # the prompt's full blocks are now hit-able on the destination
    n_full = len(prompts[1]) // BS
    hits, _, _, _ = B.pool.match_prefix(prompts[1], count=False)
    assert len(hits) == n_full
    lane1 = np.asarray([False, True])
    for step in range(3):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        to = oracle.decode(forced, active)
        tb = B.decode(forced, lane1)
        np.testing.assert_array_equal(to[1:], tb[1:])
        np.testing.assert_array_equal(np.asarray(oracle.last_logits)[1],
                                      np.asarray(B.last_logits)[1])


def test_migration_preserves_sharing_and_cow():
    """Two requests sharing a full-block prefix: migrating one must leave
    the sibling's shared blocks bit-identical on the source, register the
    chain on the destination, and a second migration of the sibling must
    re-share those blocks there (referenced, not copied)."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(31)
    prefix = rng.integers(0, cfg.vocab, size=2 * BS).astype(np.int32)
    p0 = np.concatenate([prefix, rng.integers(0, cfg.vocab, size=3,
                                              dtype=np.int32)])
    p1 = np.concatenate([prefix, rng.integers(0, cfg.vocab, size=5,
                                              dtype=np.int32)])
    mk = lambda mesh=None: make_adapter(cfg, params, n_slots=2, max_len=24,
                                        paged=True, block_size=BS, mesh=mesh)
    oracle, A, B = mk(), mk(_slice_mesh(0)), mk(_slice_mesh(1))
    for slot, p in enumerate((p0, p1)):
        assert oracle.insert(slot, p, max_new=8) == \
            A.insert(slot, p, max_new=8)
    shared_bids = A.slot_bids[0][:2]
    assert shared_bids == A.slot_bids[1][:2]      # prefix blocks shared
    assert all(A.pool.refcount[b] == 2 for b in shared_bids)
    before = {(key, b): np.asarray(A.arena_block(key, b))
              for b in shared_bids for key in A.seq_keys}
    live1 = -(-int(A.lens[1]) // BS)
    r1 = migrate_slot(A, 1, B, 1, p1)
    assert r1.blocks_shared == 0 and r1.blocks_moved == live1
    # source sibling untouched: refcounts dropped, bytes identical
    assert all(A.pool.refcount[b] == 1 for b in shared_bids)
    for (key, b), val in before.items():
        np.testing.assert_array_equal(val, np.asarray(A.arena_block(key, b)))
    # sibling keeps decoding the oracle's bits on the source
    active = np.asarray([True, True])
    lane0 = np.asarray([True, False])
    for step in range(3):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        to = oracle.decode(forced, active)
        ta = A.decode(forced, lane0)
        np.testing.assert_array_equal(to[:1], ta[:1])
    # second migration: the destination now owns the chain — shared blocks
    # are referenced there, not copied again
    live0 = -(-int(A.lens[0]) // BS)
    r0 = migrate_slot(A, 0, B, 0, p0)
    assert r0.blocks_shared == 2
    assert r0.blocks_moved == live0 - 2 < r1.blocks_moved
    assert all(B.pool.refcount[b] == 2
               for b in B.slot_bids[0][:2])


# ==========================================================================
# The router: affinity routing, spill to a non-owning slice, rebalancing
# migration inside the serving loop, telemetry.
# ==========================================================================

def _mk_gateway(cfg, params, n_slices, *, n_slots=2, num_blocks=None,
                max_new=4, auto_rebalance=True, max_queue=128):
    slices = build_slices(cfg, params,
                          [_slice_mesh(i) for i in range(n_slices)],
                          n_slots=n_slots, max_len=16, block_size=BS,
                          num_blocks=num_blocks)
    return ShardedPromptGateway(slices, max_new_tokens=max_new,
                                max_queue=max_queue,
                                auto_rebalance=auto_rebalance)


def test_router_affinity_then_spill_to_non_owning_slice():
    """Request 1 seeds a prefix on its slice; request 2 (same prefix, idle
    gateway) must route there by affinity; request 3 (same prefix, owning
    slice saturated) must spill to a non-owning slice and still complete
    with the oracle's tokens — the hit is an optimization, never a
    correctness dependency."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(41)
    prefix = rng.integers(0, cfg.vocab, size=2 * BS).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab, size=3, dtype=np.int32)
             for _ in range(3)]
    prompts = [np.concatenate([prefix, t]) for t in tails]
    gw = _mk_gateway(cfg, params, 2, n_slots=1, auto_rebalance=False)

    i0 = gw.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=4))
    gw.slices[i0].batcher.run()
    assert gw.routing["load"] == 1
    # idle owning slice -> affinity
    i1, reason = gw.route(prompts[1], 4)
    assert (i1, reason) == (i0, "affinity")
    gw.submit(Request(uid=1, prompt=prompts[1], max_new_tokens=4))
    # saturate the owning slice: its one slot is busy and a request queues
    busy = Request(uid=2, prompt=prompts[2], max_new_tokens=5)
    gw.slices[i0].batcher.submit(busy)
    gw.slices[i0].batcher.step()
    gw.slices[i0].batcher.submit(
        Request(uid=3, prompt=rng.integers(0, cfg.vocab, size=5,
                                           dtype=np.int32),
                max_new_tokens=4))
    i2, reason = gw.route(prompts[1], 4)
    assert reason == "affinity_spill" and i2 != i0
    req = Request(uid=4, prompt=prompts[1], max_new_tokens=4)
    assert gw.submit(req) != i0
    gw.slices[i2].batcher.run()
    # spilled request produced the oracle's tokens despite the cold slice
    oracle_ad = make_adapter(cfg, params, n_slots=1, max_len=16,
                             paged=True, block_size=BS)
    ob = ContinuousBatcher(oracle_ad)
    oreq = Request(uid=99, prompt=prompts[1], max_new_tokens=4)
    ob.submit(oreq)
    ob.run()
    assert req.generated == oreq.generated


def test_router_run_rebalances_and_conserves_energy():
    """A long-running request (A) blocks its slice while an affinity-routed
    sibling (C) queues behind it; the other slice drains and goes idle.
    The serving loop's rebalancer must migrate A onto the idle slice
    (unblocking C's admission onto the warm prefix), complete everything,
    charge the migration bytes into the (conserved) energy ledger, and
    report per-slice pool snapshots + routing counters."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(51)
    gw = _mk_gateway(cfg, params, 2, n_slots=1, num_blocks=9, max_new=4)
    prefix = rng.integers(0, cfg.vocab, size=2 * BS).astype(np.int32)
    a = Request(uid=0, prompt=prefix, max_new_tokens=8)
    assert gw.submit(a) == 0               # empty gateway: least-loaded
    gw.slices[0].batcher.step()            # admit A (indexes the prefix)
    b = Request(uid=1, prompt=rng.integers(0, cfg.vocab, size=6,
                                           dtype=np.int32),
                max_new_tokens=2)
    assert gw.submit(b) == 1               # load routing avoids slice 0
    c = Request(uid=2, prompt=np.concatenate(
        [prefix, rng.integers(0, cfg.vocab, size=3, dtype=np.int32)]),
        max_new_tokens=2)
    assert gw.submit(c) == 0               # affinity: A's slice owns it
    assert len(gw.slices[0].batcher.pending) == 1   # queued behind A
    tel = gw.run([])                       # drain under auto-rebalance
    tel.assert_conserved()
    rep = tel.report(1.0, kind="prompt")
    assert rep["completed"] == 3
    # B drained slice 1 and went idle while C queued behind A -> the
    # rebalancer moved A over, and C admitted onto the warm prefix
    assert gw.migrations >= 1
    assert a.migrations >= 1 and a.migration_bytes > 0
    assert c.prefill_tokens_skipped > 0
    assert rep["routing"]["migrations"] == gw.migrations
    assert rep["routing"]["migration_bytes"] == gw.migration_bytes > 0
    assert rep["migration_bytes_total"] == gw.migration_bytes
    assert set(rep["pools"]) == {0, 1}
    assert rep["pool"]["n_slices"] == 2
    migrated = [r for r in tel.records if r.migration_bytes > 0]
    assert migrated and sum(r.migration_bytes for r in migrated) == \
        gw.migration_bytes


# ==========================================================================
# Forced 8-device mesh: real multi-device slices (the sharded CI job).
# ==========================================================================

@multi
def test_serving_mesh_factors_into_slices():
    mesh = make_serving_mesh(8, model=1)
    subs = slice_meshes(mesh)
    assert len(subs) == 8
    assert len({list(m.devices.flat)[0].id for m in subs}) == 8
    assert mesh_shape_dict(mesh) == {"data": 8, "model": 1}
    mesh2 = make_serving_mesh(4, model=2)
    subs2 = slice_meshes(mesh2)
    assert len(subs2) == 4 and all(m.devices.size == 2 for m in subs2)


@multi
def test_router_multi_device_parity():
    """8 one-device slices, one request per slice (distinct prompts route
    by load): every request's generated tokens must equal a solo run on an
    unsharded adapter — per-lane bitwise independence carried across the
    whole mesh."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(61)
    mesh = make_serving_mesh(8, model=1)
    slices = build_slices(cfg, params, mesh, n_slots=2, max_len=16,
                          block_size=BS)
    gw = ShardedPromptGateway(slices, max_new_tokens=3,
                              auto_rebalance=False)
    prompts = [rng.integers(0, cfg.vocab, size=int(s), dtype=np.int32)
               for s in rng.integers(4, 10, size=8)]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=3)
            for i, p in enumerate(prompts)]
    used = {gw.submit(r) for r in reqs}
    assert len(used) == 8                  # load routing spread the fleet
    while gw.busy:
        gw.step()
    oracle_ad = make_adapter(cfg, params, n_slots=2, max_len=16,
                             paged=True, block_size=BS)
    for i, p in enumerate(prompts):
        ob = ContinuousBatcher(oracle_ad)
        oreq = Request(uid=100 + i, prompt=p, max_new_tokens=3)
        ob.submit(oreq)
        ob.run()
        assert reqs[i].generated == oreq.generated, i


@multi
def test_aggregate_slots_exceed_single_device():
    """Acceptance: at a fixed per-device block budget, 8 slices sustain
    more concurrent slots than one device with the same budget."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(71)
    budget = 9                            # 8 usable blocks per device
    prompts = [rng.integers(0, cfg.vocab, size=6, dtype=np.int32)
               for _ in range(16)]
    arrivals = [Arrival(uid=i, t=0.0, endpoint=0, kind="prompt", payload=p)
                for i, p in enumerate(prompts)]
    single = make_adapter(cfg, params, n_slots=8, max_len=16, paged=True,
                          block_size=BS, num_blocks=budget)
    sb = ContinuousBatcher(single)
    for i, p in enumerate(prompts):
        sb.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    sb.run()
    mesh = make_serving_mesh(8, model=1)
    slices = build_slices(cfg, params, mesh, n_slots=8, max_len=16,
                          block_size=BS, num_blocks=budget)
    gw = ShardedPromptGateway(slices, max_new_tokens=4, max_queue=128)
    gw.run(arrivals)
    assert gw.peak_active_total() > sb.peak_active


@multi
def test_model_axis_sharded_slice_decodes():
    """A 2-device tensor-parallel slice (KV heads sharded over "model"
    when divisible) must produce the unsharded tokens; logits agree to
    float tolerance (cross-device reductions may reorder sums, so this is
    deliberately NOT a bitwise pin — docs/sharding.md spells out the
    parity boundary)."""
    cfg, params, _ = _setup("stablelm_3b")
    rng = np.random.default_rng(81)
    mesh2 = make_serving_mesh(1, model=2)
    sm = slice_meshes(mesh2)[0]
    assert sm.devices.size == 2
    un = make_adapter(cfg, params, n_slots=2, max_len=16, paged=True,
                      block_size=BS)
    sh = make_adapter(cfg, params, n_slots=2, max_len=16, paged=True,
                      block_size=BS, mesh=sm)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (5, 7)]
    for slot, p in enumerate(prompts):
        assert un.insert(slot, p, max_new=4) == sh.insert(slot, p, max_new=4)
    active = np.asarray([True, True])
    for step in range(3):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        tu = un.decode(forced, active)
        ts = sh.decode(forced, active)
        np.testing.assert_array_equal(tu, ts)
        np.testing.assert_allclose(np.asarray(sh.last_logits),
                                   np.asarray(un.last_logits),
                                   rtol=1e-5, atol=1e-5)
