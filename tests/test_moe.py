import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import moe as moe_lib
from repro.nn.moe import MoEConfig


def _params(rng, E, d, f, n_shared=0):
    p = {
        "w_router": jnp.asarray(rng.normal(0, 0.5, (d, E)), jnp.float32),
        "w_gate": jnp.asarray(rng.normal(0, 0.1, (E, d, f)), jnp.float32),
        "w_in": jnp.asarray(rng.normal(0, 0.1, (E, d, f)), jnp.float32),
        "w_out": jnp.asarray(rng.normal(0, 0.1, (E, f, d)), jnp.float32),
    }
    if n_shared:
        sf = n_shared * f
        p.update(
            shared_gate=jnp.asarray(rng.normal(0, 0.1, (d, sf)), jnp.float32),
            shared_in=jnp.asarray(rng.normal(0, 0.1, (d, sf)), jnp.float32),
            shared_out=jnp.asarray(rng.normal(0, 0.1, (sf, d)), jnp.float32))
    return p


def _dense_reference(x, p, cfg):
    """Route each token through its top-k experts directly (no capacity)."""
    B, S, d = x.shape
    xt = np.asarray(x, np.float64).reshape(-1, d)
    logits = xt @ np.asarray(p["w_router"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[: cfg.top_k]
        wsum = probs[t, top].sum()
        for e in top:
            g = xt[t] @ np.asarray(p["w_gate"][e], np.float64)
            h = xt[t] @ np.asarray(p["w_in"][e], np.float64)
            a = (g / (1 + np.exp(-g))) * h
            out[t] += (probs[t, e] / wsum) * \
                (a @ np.asarray(p["w_out"][e], np.float64))
    return out.reshape(B, S, d)


@pytest.mark.parametrize("impl", ["einsum", "sort"])
def test_moe_matches_dense_reference(impl):
    """With generous capacity (no drops) both dispatch impls equal the dense
    per-token routing computation."""
    rng = np.random.default_rng(0)
    B, S, d, E, f, k = 2, 16, 8, 4, 16, 2
    cfg = MoEConfig(n_experts=E, top_k=k, d_expert=f, capacity_factor=4.0,
                    group_size=16, impl=impl)
    x = jnp.asarray(rng.normal(0, 1, (B, S, d)), jnp.float32)
    p = _params(rng, E, d, f)
    out, aux = moe_lib.moe_ffn(x, p, cfg)
    want = _dense_reference(x, p, cfg)
    np.testing.assert_allclose(np.asarray(out, np.float64), want,
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0.5   # load-balance loss ~ O(1)


def test_impls_agree():
    rng = np.random.default_rng(1)
    B, S, d, E, f, k = 2, 32, 8, 8, 8, 2
    x = jnp.asarray(rng.normal(0, 1, (B, S, d)), jnp.float32)
    p = _params(rng, E, d, f)
    outs = []
    for impl in ("einsum", "sort"):
        cfg = MoEConfig(E, k, f, capacity_factor=8.0, group_size=32,
                        impl=impl)
        outs.append(np.asarray(moe_lib.moe_ffn(x, p, cfg)[0]))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-3, atol=2e-3)


def test_capacity_drops_tokens():
    """With capacity_factor -> tiny, some tokens get zero routed output."""
    rng = np.random.default_rng(2)
    B, S, d, E, f = 1, 64, 8, 4, 8
    cfg = MoEConfig(E, 2, f, capacity_factor=0.1, group_size=64)
    x = jnp.asarray(rng.normal(0, 1, (B, S, d)), jnp.float32)
    p = _params(rng, E, d, f)
    out, _ = moe_lib.moe_ffn(x, p, cfg)
    norms = np.linalg.norm(np.asarray(out), axis=-1)
    assert (norms < 1e-6).any()          # dropped tokens exist
    assert (norms > 1e-6).any()          # but not all dropped


def test_shared_experts_added():
    rng = np.random.default_rng(3)
    B, S, d, E, f = 1, 16, 8, 4, 8
    x = jnp.asarray(rng.normal(0, 1, (B, S, d)), jnp.float32)
    p = _params(rng, E, d, f, n_shared=2)
    cfg0 = MoEConfig(E, 2, f, n_shared=0, capacity_factor=4.0, group_size=16)
    cfg2 = MoEConfig(E, 2, f, n_shared=2, capacity_factor=4.0, group_size=16)
    out0, _ = moe_lib.moe_ffn(x, p, cfg0)
    out2, _ = moe_lib.moe_ffn(x, p, cfg2)
    assert not np.allclose(np.asarray(out0), np.asarray(out2))
