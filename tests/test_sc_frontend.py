"""The paper's technique as a first-class LM feature: first_layer_mode="sc"
(DESIGN §Arch-applicability) — forward exact SC sim, backward STE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm


@pytest.mark.parametrize("arch", ["stablelm_3b", "whisper_medium", "rwkv6_7b"])
def test_sc_frontend_trains(arch):
    cfg = dataclasses.replace(configs.smoke_config(arch),
                              first_layer_mode="sc", sc_bits=4)
    params, specs = lm.init(jax.random.key(0), cfg, {})
    assert "sc_frontend" in params
    rng = np.random.default_rng(0)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["enc_embed"] = jnp.zeros((B, cfg.enc_len, cfg.d_model),
                                       jnp.bfloat16)

    def loss_fn(p):
        return lm.forward(cfg, p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    # STE: gradient reaches the SC frontend weights (retraining can adapt it)
    gw = np.asarray(grads["sc_frontend"]["w"], np.float32)
    assert np.isfinite(gw).all() and np.abs(gw).sum() > 0


def test_sc_frontend_output_is_ternary_scaled():
    cfg = dataclasses.replace(configs.smoke_config("stablelm_3b"),
                              first_layer_mode="sc", sc_bits=4)
    params, _ = lm.init(jax.random.key(1), cfg, {})
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (1, 8, cfg.d_model)),
                    jnp.float32)
    out = lm.sc_frontend(cfg, params["sc_frontend"], x)
    vals = np.unique(np.round(np.asarray(out, np.float32)
                              / np.asarray(params["sc_frontend"]["gamma"],
                                           np.float32), 5))
    assert set(vals) <= {-1.0, 0.0, 1.0}
