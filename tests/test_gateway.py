"""Near-sensor serving gateway: bucket-shape stability (no recompiles),
backpressure under oversubscription, telemetry conservation, and
slot-batcher parity across model families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import sequential_decode_reference

from repro import configs
from repro.models import lm
from repro.serve.gateway import frontend as fe
from repro.serve.gateway.gateway import (GatewayConfig, MicroBatchGateway,
                                         PromptGateway)
from repro.serve.gateway.sensors import Arrival, FleetConfig, SensorFleet
from repro.serve.gateway.slots import (ContinuousBatcher, Request,
                                       make_adapter)
from repro.serve.gateway.telemetry import Telemetry


def _frame_trace(n, dt=0.001, start=0.0):
    """Synthetic arrivals with a fixed inter-arrival time."""
    rng = np.random.default_rng(0)
    frames = rng.integers(0, 255, size=(n, 28, 28, 1), dtype=np.uint8)
    return [Arrival(uid=i, t=start + i * dt, endpoint=i % 4, kind="frame",
                    payload=frames[i]) for i in range(n)]


# ==========================================================================
# Micro-batching gateway (frame path).
# ==========================================================================

def test_bucket_shapes_never_recompile():
    """After warmup, arbitrary traffic reuses the per-bucket executables —
    the jit caches must stay at exactly one entry per stage per bucket."""
    spec = fe.FrontendSpec(mode="sc", bits=2)
    gw = MicroBatchGateway(GatewayConfig(bucket_sizes=(1, 2, 4),
                                         service_model="fixed",
                                         fixed_service_s=1e-4), spec)
    gw.warmup()
    baseline = gw.compile_counts()
    assert all(v == 2 for v in baseline.values()), baseline  # sensor+gateway
    for trace in (_frame_trace(1), _frame_trace(7), _frame_trace(23),
                  _frame_trace(5, dt=0.1)):    # ragged + sparse arrivals
        gw.run(trace)
    assert gw.compile_counts() == baseline


def test_backpressure_rejects_beyond_queue_bound():
    """Oversubscription (service slower than offered load) must shed load
    through admission control, not grow the queue without bound."""
    spec = fe.FrontendSpec(mode="binary", bits=4)
    cfg = GatewayConfig(bucket_sizes=(1, 2), max_queue=4,
                        max_delay_s=0.001, service_model="fixed",
                        fixed_service_s=0.05)      # 2/0.05 = 40 Hz capacity
    gw = MicroBatchGateway(cfg, spec)
    gw.warmup()
    trace = _frame_trace(200, dt=0.001)            # 1000 Hz offered
    tel = gw.run(trace)
    assert len(tel.dropped) > 0
    assert len(tel.records) + len(tel.dropped) == len(trace)
    # every admitted request completed and was charged
    tel.assert_conserved()


def test_deadline_flush_bounds_latency_when_idle():
    """A lone request must not wait for a full bucket: the deadline flushes
    it after max_delay_s (plus service + link/sensor offsets)."""
    spec = fe.FrontendSpec(mode="sc", bits=2)
    cfg = GatewayConfig(bucket_sizes=(1, 2, 4, 8), max_delay_s=0.005,
                        service_model="fixed", fixed_service_s=1e-4)
    gw = MicroBatchGateway(cfg, spec)
    gw.warmup()
    tel = gw.run(_frame_trace(1))
    assert len(tel.records) == 1
    lat = tel.records[0].latency_s
    assert lat < 0.05, lat


def test_telemetry_energy_conservation_and_link_bytes():
    """Sum of per-request energy equals the fleet total exactly, and the sc
    partition moves strictly fewer bytes/frame than the binary one."""
    trace = _frame_trace(40)
    per_frontend = {}
    for mode in ("sc", "binary"):
        spec = fe.FrontendSpec(mode=mode, bits=4)
        gw = MicroBatchGateway(GatewayConfig(service_model="fixed",
                                             fixed_service_s=1e-4), spec)
        gw.warmup()
        tel = gw.run(trace)
        tel.assert_conserved()
        assert len(tel.records) == len(trace)
        per_req = sum(r.energy_nj for r in tel.records)
        assert per_req == pytest.approx(tel.fleet_energy_nj, abs=1e-9)
        per_frontend[mode] = (fe.link_bytes_per_frame(spec),
                              tel.report(1.0)["mean_energy_nj"])
    assert per_frontend["sc"][0] < per_frontend["binary"][0]
    assert per_frontend["sc"][1] < per_frontend["binary"][1]


def test_ternary_wire_format_roundtrip_matches_accounting():
    """The packed payload IS the accounted wire format: nbytes equals
    link_bytes_per_frame, and unpack inverts pack exactly."""
    spec = fe.FrontendSpec(mode="sc", bits=2)
    c = spec.lenet
    shape = (c.image_size // 2, c.image_size // 2, c.conv1_filters)
    rng = np.random.default_rng(0)
    h = rng.integers(-1, 2, (3,) + shape).astype(np.float32)
    packed = fe.pack_ternary(jnp.asarray(h))
    assert packed.dtype == jnp.uint8
    assert packed[0].nbytes == fe.link_bytes_per_frame(spec)
    out = np.asarray(fe.unpack_ternary(packed, shape))
    np.testing.assert_array_equal(out, h)


def test_fleet_trace_deterministic():
    f1 = SensorFleet(FleetConfig(n_endpoints=4, frame_rate_hz=8.0,
                                 image_pool=16, seed=3))
    f2 = SensorFleet(FleetConfig(n_endpoints=4, frame_rate_hz=8.0,
                                 image_pool=16, seed=3))
    e1, e2 = f1.events(2.0), f2.events(2.0)
    assert [a.t for a in e1] == [a.t for a in e2]
    assert all(np.array_equal(a.payload, b.payload)
               for a, b in zip(e1, e2))


# ==========================================================================
# Family-generic slot batcher.
# ==========================================================================

@pytest.mark.parametrize("arch", ["stablelm_3b", "hymba_1_5b",
                                  "deepseek_moe_16b"])
def test_decoder_family_slot_batcher_parity(arch):
    """Attention-cache families (decoder / hybrid / moe) serve through the
    same slot batcher API as rwkv, with token-level parity vs sequential
    decode_step."""
    cfg = dataclasses.replace(configs.smoke_config(arch),
                              param_dtype="float32")
    params, _ = lm.init(jax.random.key(0), cfg, {})
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (5, 9, 7)]
    n_new, max_len = 4, 32
    batcher = ContinuousBatcher(
        make_adapter(cfg, params, n_slots=2, max_len=max_len))
    for i, p in enumerate(prompts):           # 3 requests > 2 slots
        batcher.submit(Request(uid=i, prompt=p, max_new_tokens=n_new))
    got = {r.uid: r.generated for r in batcher.run()}
    assert len(got) == len(prompts)
    for i, p in enumerate(prompts):
        want = sequential_decode_reference(cfg, params, p, n_new, max_len)
        assert got[i] == want, (i, got[i], want)


def test_freed_slots_do_not_decode_stale_state():
    """After draining, every slot's state is exactly the cleared value —
    freed slots must not keep evolving stale context between admissions."""
    cfg = dataclasses.replace(configs.smoke_config("rwkv6_7b"),
                              param_dtype="float32")
    params, _ = lm.init(jax.random.key(0), cfg, {})
    rng = np.random.default_rng(1)
    batcher = ContinuousBatcher(make_adapter(cfg, params, n_slots=2))
    batcher.submit(Request(uid=0,
                           prompt=rng.integers(0, cfg.vocab, size=6,
                                               dtype=np.int32),
                           max_new_tokens=4))
    batcher.run()
    for key in ("wkv", "shift1", "shift2"):
        a = np.asarray(batcher.adapter.state[key], np.float32)
        assert np.abs(a).max() == 0.0, key


def test_eos_honored_on_prefill_token():
    cfg = dataclasses.replace(configs.smoke_config("rwkv6_7b"),
                              param_dtype="float32")
    params, _ = lm.init(jax.random.key(0), cfg, {})
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=6, dtype=np.int32)
    probe = ContinuousBatcher(make_adapter(cfg, params, n_slots=1))
    probe.submit(Request(uid=0, prompt=prompt, max_new_tokens=1))
    first_tok = probe.run()[0].generated[0]

    batcher = ContinuousBatcher(make_adapter(cfg, params, n_slots=1))
    batcher.submit(Request(uid=1, prompt=prompt, max_new_tokens=8,
                           eos_id=first_tok))
    done = batcher.run()
    assert done[0].generated == [first_tok]


def test_prompt_gateway_serves_lm_path():
    cfg = dataclasses.replace(configs.smoke_config("rwkv6_7b"),
                              param_dtype="float32")
    params, _ = lm.init(jax.random.key(0), cfg, {})
    rng = np.random.default_rng(3)
    arrivals = [Arrival(uid=i, t=0.01 * i, endpoint=i, kind="prompt",
                        payload=rng.integers(0, cfg.vocab, size=8,
                                             dtype=np.int32))
                for i in range(5)]
    batcher = ContinuousBatcher(make_adapter(cfg, params, n_slots=2))
    pgw = PromptGateway(batcher, max_new_tokens=4)
    pgw.warmup((8,), cfg.vocab)     # compile outside the virtual clock
    tel = pgw.run(arrivals)
    tel.assert_conserved()
    assert len(tel.records) == 5
    assert all(r.t_done >= r.t_arrival for r in tel.records)
    rep = tel.report(1.0, kind="prompt")
    assert rep["completed"] == 5 and rep["p99_latency_ms"] > 0
    # drop accounting is kind-scoped: frame drops never leak into the
    # prompt report
    tel.drop(99, "frame")
    assert tel.report(1.0, kind="prompt")["dropped"] == 0
    assert tel.report(1.0, kind="frame")["dropped"] == 1
