"""Serving observability (serve/obs/): span tracer correctness, the
zero-callback disabled contract, bitwise span-energy conservation against
the telemetry ledger for both frontends and both serving paths, metrics
time-series, SLO stats in report(), drop reasons, the recompile detector,
and Chrome trace-event export validity."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro import configs
from repro.models import lm
from repro.serve import obs
from repro.serve.gateway import frontend as fe
from repro.serve.gateway.gateway import (GatewayConfig, MicroBatchGateway,
                                         PromptGateway)
from repro.serve.gateway.sensors import Arrival
from repro.serve.gateway.slots import (ContinuousBatcher, Request,
                                       make_adapter)
from repro.serve.gateway.telemetry import Telemetry
from repro.serve.shard import ShardedPromptGateway, build_slices

BS = 4

_SETUP_CACHE: dict = {}


def _setup(arch="stablelm_3b"):
    if arch not in _SETUP_CACHE:
        cfg = dataclasses.replace(configs.smoke_config(arch),
                                  param_dtype="float32")
        params, _ = lm.init(jax.random.key(0), cfg, {})
        _SETUP_CACHE[arch] = (cfg, params)
    return _SETUP_CACHE[arch]


def _slice_mesh(i: int) -> Mesh:
    devs = jax.devices()
    return Mesh(np.asarray([devs[i % len(devs)]]), ("model",))


def _prompt_arrivals(cfg, n, plen=8, seed=0, dt=0.001):
    rng = np.random.default_rng(seed)
    return [Arrival(t=i * dt, uid=i, endpoint=0, kind="prompt",
                    payload=rng.integers(0, cfg.vocab, plen)
                    .astype(np.int32)) for i in range(n)]


def _frame_arrivals(n, seed=0, dt=0.0005):
    rng = np.random.default_rng(seed)
    return [Arrival(t=i * dt, uid=i, endpoint=0, kind="frame",
                    payload=rng.integers(0, 255, (28, 28, 1))
                    .astype(np.uint8)) for i in range(n)]


# ==========================================================================
# Tracer unit behavior.
# ==========================================================================

def test_tracer_strict_nesting_enforced_at_record_time():
    tr = obs.Tracer()
    tr.clock.advance(1.0)
    tr.begin("a", tid=7)
    tr.clock.advance(2.0)
    tr.begin("b", tid=7)
    with pytest.raises(AssertionError):
        tr.end("a", tid=7)              # b is innermost: a may not close
    tr.clock.advance(3.0)
    tr.end("b", tid=7)
    tr.end("a", tid=7)
    with pytest.raises(AssertionError):
        tr.end("a", tid=7)              # nothing open
    tr.assert_nested()
    spans = {s["name"]: s for s in tr.spans()}
    assert spans["a"]["ts"] == 1.0 and spans["a"]["dur"] == 2.0
    assert spans["b"]["ts"] == 2.0 and spans["b"]["dur"] == 1.0


def test_tracer_open_span_fails_nesting_check():
    tr = obs.Tracer()
    tr.begin("left_open", tid=1)
    with pytest.raises(AssertionError, match="open spans"):
        tr.assert_nested()


def test_sim_clock_is_monotone():
    c = obs.SimClock()
    c.advance(2.0)
    c.advance(1.0)                      # going backwards is a no-op
    assert c.t == 2.0


# ==========================================================================
# Zero-cost-when-disabled: no tracer attached -> zero obs callbacks.
# ==========================================================================

def test_disabled_tracing_makes_zero_callbacks():
    cfg, params = _setup()
    ad = make_adapter(cfg, params, n_slots=2, max_len=16, paged=True,
                      block_size=BS)
    gw = PromptGateway(ContinuousBatcher(ad), max_new_tokens=3)
    gw.warmup((4, 8))
    c0 = obs.callback_count()
    tel = gw.run(_prompt_arrivals(cfg, 4))
    assert tel.report(1.0, "prompt")["completed"] == 4
    # SLO stamps still work without a tracer (bare SimClock path) ...
    assert all(r.t_admit >= 0 for r in tel.records)
    # ... and not one Python-level tracer callback was made
    assert obs.callback_count() == c0


def test_disabled_tracing_frame_path_zero_callbacks():
    spec = fe.FrontendSpec(mode="sc", bits=4)
    gw = MicroBatchGateway(GatewayConfig(bucket_sizes=(1, 2, 4),
                                         service_model="fixed",
                                         fixed_service_s=0.001), spec)
    gw.warmup()
    c0 = obs.callback_count()
    tel = gw.run(_frame_arrivals(8))
    assert tel.report(1.0, "frame")["completed"] == 8
    assert obs.callback_count() == c0


# ==========================================================================
# Span energy attribution sums bitwise to the conserved ledger.
# ==========================================================================

@pytest.mark.parametrize("mode", ["sc", "binary"])
def test_frame_span_energy_conserved_bitwise(mode):
    spec = fe.FrontendSpec(mode=mode, bits=4)
    gw = MicroBatchGateway(GatewayConfig(bucket_sizes=(1, 2, 4),
                                         service_model="fixed",
                                         fixed_service_s=0.001), spec)
    gw.warmup()
    tracer = obs.Tracer()
    tel = gw.run(_frame_arrivals(10), tracer=tracer)
    tel.assert_conserved()
    tracer.assert_nested()
    tracer.assert_energy_conserved(tel)     # float equality, not isclose
    spans = tracer.request_spans()
    assert set(spans) == {r.uid for r in tel.records}
    # every lifecycle stage is present and the span covers arrival -> done
    for r in tel.records:
        s = spans[r.uid]
        assert s["ts"] == r.t_arrival
        assert s["ts"] + s["dur"] == pytest.approx(r.t_done, abs=1e-12)
    for name in ("sensor_link", "queue_wait", "serve", "batch"):
        assert tracer.spans(name)


def test_prompt_span_energy_conserved_bitwise_and_slo_stats():
    cfg, params = _setup()
    ad = make_adapter(cfg, params, n_slots=2, max_len=16, paged=True,
                      block_size=BS)
    tracer = obs.Tracer()
    metrics = obs.MetricsRegistry(interval_s=1e-4)
    gw = PromptGateway(ContinuousBatcher(ad), max_new_tokens=3,
                       tracer=tracer, metrics=metrics)
    c0 = obs.callback_count()
    gw.warmup((4, 8))
    assert obs.callback_count() == c0       # warmup is never traced
    tel = gw.run(_prompt_arrivals(cfg, 5))
    tel.assert_conserved()
    tracer.assert_nested()
    tracer.assert_energy_conserved(tel)
    assert set(tracer.request_spans()) == {r.uid for r in tel.records}
    assert tracer.spans("prefill") and tracer.spans("decode")
    assert tracer.spans("prefill_chunk")    # paged fold chunks traced
    assert tracer.spans("tick")             # engine track
    rep = tel.report(1.0, "prompt")
    assert rep["n_samples"] == 5 and rep["slo_n_samples"] == 5
    for k in ("ttft_p50_ms", "ttft_p99_ms", "tpot_p50_ms", "tpot_p99_ms",
              "queue_wait_p50_ms", "queue_wait_p99_ms"):
        assert rep[k] >= 0.0
    # interval time-series rode into the report (pool occupancy + queue)
    series = rep["series"]
    assert len(series) >= 2
    assert all("pool_blocks_in_use" in s and "queue_depth" in s
               for s in series)


def test_prefix_hit_chunks_marked_in_trace():
    cfg, params = _setup()
    ad = make_adapter(cfg, params, n_slots=1, max_len=16, paged=True,
                      block_size=BS)
    tracer = obs.Tracer()
    gw = PromptGateway(ContinuousBatcher(ad), max_new_tokens=2,
                       tracer=tracer)
    gw.warmup((4,))
    rng = np.random.default_rng(3)
    prefix = rng.integers(0, cfg.vocab, 2 * BS).astype(np.int32)
    arrs = [
        Arrival(t=0.0, uid=0, endpoint=0, kind="prompt",
                payload=np.concatenate([prefix, [1, 2]]).astype(np.int32)),
        Arrival(t=10.0, uid=1, endpoint=0, kind="prompt",
                payload=np.concatenate([prefix, [3, 4]]).astype(np.int32)),
    ]
    tel = gw.run(arrs)
    tracer.assert_energy_conserved(tel)
    resumes = [e for e in tracer.events if e["name"] == "prefix_resume"]
    assert len(resumes) == 1                # only the warm request resumed
    assert resumes[0]["args"]["blocks"] == 2
    assert resumes[0]["args"]["tokens_skipped"] == 2 * BS
    assert resumes[0]["tid"] == 1           # on the warm request's track
    # the warm request folded fewer chunks than the cold one
    chunks = tracer.spans("prefill_chunk")
    cold = [c for c in chunks if c["tid"] == 0]
    warm = [c for c in chunks if c["tid"] == 1]
    assert len(warm) < len(cold)
    assert all(c["args"]["prefix_hit"] is False for c in chunks)


def test_sharded_trace_covers_migration_and_conserves_energy():
    cfg, params = _setup()
    slices = build_slices(cfg, params, [_slice_mesh(0), _slice_mesh(1)],
                          n_slots=1, max_len=16, block_size=BS,
                          num_blocks=9)
    tracer = obs.Tracer()
    gw = ShardedPromptGateway(slices, max_new_tokens=8, max_queue=128,
                              tracer=tracer)
    gw.warmup((4, 8))
    rng = np.random.default_rng(51)
    prefix = rng.integers(0, cfg.vocab, size=2 * BS).astype(np.int32)
    a = Request(uid=0, prompt=prefix, max_new_tokens=8)
    gw.submit(a)
    gw.slices[0].batcher.step()             # admit A, untraced
    b = Request(uid=1, prompt=rng.integers(0, cfg.vocab, size=6,
                                           dtype=np.int32),
                max_new_tokens=2)
    gw.submit(b)
    c = Request(uid=2, prompt=np.concatenate(
        [prefix, rng.integers(0, cfg.vocab, size=3, dtype=np.int32)]),
        max_new_tokens=2)
    gw.submit(c)
    tel = gw.run([])                        # drain under auto-rebalance
    tel.assert_conserved()
    tracer.assert_nested()
    # every completed uid has a request span (A's opened late) and the
    # span energies — incl. A's migration part — reproduce the ledger
    tracer.assert_energy_conserved(tel)
    assert gw.migrations >= 1
    mig = tracer.spans("migrate")
    assert len(mig) == gw.migrations
    assert mig[0]["tid"] == 0 and mig[0]["args"]["bytes"] > 0
    moved = tracer.request_spans()[0]["args"]["energy_parts"]
    assert moved["migration_nj"] > 0.0
    # each slice ticks on its own engine track (pid 1 + slice_idx)
    tick_pids = {e["pid"] for e in tracer.spans("tick")}
    assert tick_pids <= {1, 2} and 1 in tick_pids


# ==========================================================================
# Telemetry satellites: drop reasons, report guards, series passthrough.
# ==========================================================================

def test_drop_reasons_and_legacy_tuple_shape():
    tel = Telemetry()
    tel.drop(7, "frame")                    # legacy 2-arg call still works
    tel.drop(8, "prompt", "queue_full", 1.5)
    assert [d[:2] for d in tel.dropped] == [(7, "frame"), (8, "prompt")]
    rep = tel.report(1.0)
    assert rep["dropped"] == 2
    assert rep["dropped_by_reason"] == {"unspecified": 1, "queue_full": 1}
    assert tel.report(1.0, "prompt")["dropped_by_reason"] == \
        {"queue_full": 1}


def test_gateway_drop_carries_reason_and_time():
    spec = fe.FrontendSpec(mode="sc", bits=4)
    gw = MicroBatchGateway(GatewayConfig(bucket_sizes=(1, 2),
                                         max_queue=2,
                                         service_model="fixed",
                                         fixed_service_s=1.0), spec)
    gw.warmup()
    tel = gw.run(_frame_arrivals(16, dt=1e-5))
    rep = tel.report(1.0, "frame")
    assert rep["dropped"] > 0
    assert rep["dropped_by_reason"] == {"queue_full": rep["dropped"]}
    assert all(d[2] == "queue_full" and d[3] > 0 for d in tel.dropped)


def test_report_zero_duration_and_tiny_samples_guarded():
    tel = Telemetry()
    rep = tel.report(0.0)                   # must not divide by zero
    assert rep["throughput_hz"] == 0.0 and rep["n_samples"] == 0
    assert "p99_latency_ms" not in rep      # no percentile claims on n=0
    rep = tel.report(-1.0)
    assert rep["throughput_hz"] == 0.0


def test_report_series_passthrough():
    tel = Telemetry()
    tel.record_series([{"t": 0.0, "q": 1}, {"t": 0.1, "q": 2}])
    assert tel.report(1.0)["series"] == [{"t": 0.0, "q": 1},
                                         {"t": 0.1, "q": 2}]


# ==========================================================================
# Metrics registry.
# ==========================================================================

def test_metrics_counters_gauges_sources_and_interval():
    m = obs.MetricsRegistry(interval_s=0.1)
    depth = {"v": 3}
    m.register("queue_depth", lambda: depth["v"])
    m.inc("completed")
    m.inc("completed", 2)
    m.set_gauge("load", 0.5)
    assert m.maybe_sample(0.0)              # first call always samples
    assert not m.maybe_sample(0.05)         # inside the interval
    depth["v"] = 9
    assert m.maybe_sample(0.2)
    assert len(m.samples) == 2
    assert m.samples[0] == {"t": 0.0, "completed": 3.0, "load": 0.5,
                            "queue_depth": 3}
    assert m.samples[1]["queue_depth"] == 9
    ts, vs = m.series("queue_depth")
    assert ts == [0.0, 0.2] and vs == [3, 9]


def test_metrics_percentiles_carry_sample_count():
    m = obs.MetricsRegistry()
    assert m.percentiles("lat") == {"n": 0, "n_dropped": 0}
    for v in (1.0, 2.0, 3.0):
        m.observe("lat", v)
    p = m.percentiles("lat")
    assert p["n"] == 3 and p["p50"] == 2.0
    assert p["n_dropped"] == 0      # under the cap: summary is exact


# ==========================================================================
# Recompile detector.
# ==========================================================================

def test_recompile_detector_steady_state_and_leak():
    f = jax.jit(lambda x: x + 1)
    g = jax.jit(lambda x: x * 2)
    f(jnp.zeros(2))
    g(jnp.zeros(2))
    det = obs.RecompileDetector()
    det.track("t", {"f": f, "g": g})
    det.snapshot()
    f(jnp.ones(2))                          # same shape: cached
    assert det.steady_state_recompiles() == 0
    f(jnp.zeros(3))                         # new shape: a recompile
    assert det.steady_state_recompiles() == 1
    rep = det.report()
    assert rep["recompiles_by_fn"] == {"t.f": 1}
    assert rep["tracked_executables"] == 2
    with pytest.raises(AssertionError):
        det.track("bad", {"notjit": lambda x: x})


def test_gateway_jit_fns_zero_steady_state_recompiles():
    cfg, params = _setup()
    ad = make_adapter(cfg, params, n_slots=2, max_len=16, paged=True,
                      block_size=BS)
    gw = PromptGateway(ContinuousBatcher(ad), max_new_tokens=3)
    gw.warmup((8,))
    det = obs.RecompileDetector()
    det.track("gateway", gw.jit_fns())
    det.snapshot()
    gw.run(_prompt_arrivals(cfg, 4))
    assert det.steady_state_recompiles() == 0, det.report()


# ==========================================================================
# Exporters.
# ==========================================================================

def test_chrome_trace_export_is_valid_and_loadable(tmp_path):
    cfg, params = _setup()
    ad = make_adapter(cfg, params, n_slots=2, max_len=16, paged=True,
                      block_size=BS)
    tracer = obs.Tracer()
    metrics = obs.MetricsRegistry(interval_s=1e-4)
    gw = PromptGateway(ContinuousBatcher(ad), max_new_tokens=2,
                       tracer=tracer, metrics=metrics)
    gw.warmup((8,))
    gw.run(_prompt_arrivals(cfg, 3))
    path = tmp_path / "trace.json"
    obj = obs.write_chrome_trace(str(path), tracer, metrics)
    assert obs.validate_chrome_trace(obj) == []
    with open(path) as f:
        loaded = json.load(f)               # round-trips as plain JSON
    assert obs.validate_chrome_trace(loaded) == []
    names = {e["name"] for e in loaded["traceEvents"]}
    assert {"request", "prefill", "decode", "tick",
            "metrics", "process_name"} <= names
    # counter tracks carry the sampled metrics
    cs = [e for e in loaded["traceEvents"] if e["ph"] == "C"]
    assert cs and all("queue_depth" in e["args"] for e in cs)
    mpath = tmp_path / "metrics.jsonl"
    n = obs.write_metrics_jsonl(str(mpath), metrics)
    assert n == len(metrics.samples) > 0
    lines = [json.loads(ln) for ln in mpath.read_text().splitlines()]
    assert len(lines) == n and all("t" in ln for ln in lines)


def test_chrome_trace_validator_catches_structural_breaks():
    assert obs.validate_chrome_trace([]) == ["trace is not a JSON object"]
    assert obs.validate_chrome_trace({}) == \
        ["missing/invalid 'traceEvents' array"]
    bad = {"traceEvents": [
        {"name": "x", "ph": "X", "pid": 0, "tid": 0, "ts": 0.0},
        {"name": "y", "ph": "Z", "pid": 0, "tid": 0, "ts": "no"},
    ]}
    errs = obs.validate_chrome_trace(bad)
    assert any("missing numeric dur" in e for e in errs)
    assert any("unknown phase" in e for e in errs)
    assert any("non-numeric ts" in e for e in errs)
    with pytest.raises(AssertionError, match="invalid trace"):
        obs.write_chrome_trace("/dev/null", obs.Tracer())  # empty events
