import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # bare env: deterministic sweep fallback
    from _hypothesis_compat import given, settings, st

from repro.nn import attention


def _naive(q, k, v, causal, window, q_offset=0):
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = Hq // Hkv
    k = np.repeat(np.asarray(k, np.float64), rep, axis=2)
    v = np.repeat(np.asarray(v, np.float64), rep, axis=2)
    q = np.asarray(q, np.float64)
    s = np.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
    qpos = q_offset + np.arange(Sq)[:, None]
    kpos = np.arange(Sk)[None, :]
    mask = np.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = np.where(mask[None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bhqk,bkhd->bqhd", p, v)


@given(st.integers(1, 2), st.sampled_from([3, 8, 17, 33]),
       st.sampled_from([(2, 1), (4, 2), (4, 4)]), st.booleans(),
       st.sampled_from([0, 5]), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_chunked_matches_naive(B, S, heads, causal, window, seed):
    Hq, Hkv = heads
    D = 8
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    out = attention.attend_chunked(q, k, v, causal=causal, window=window,
                                   q_chunk=8, kv_chunk=8)
    want = _naive(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out, np.float64), want,
                               rtol=2e-4, atol=2e-4)


def test_chunked_with_q_offset():
    """Chunked prefill continuation: q block positioned after the cache."""
    rng = np.random.default_rng(0)
    B, Sq, Sk, H, D = 1, 4, 12, 2, 8
    q = jnp.asarray(rng.normal(0, 1, (B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, Sk, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, Sk, H, D)), jnp.float32)
    out = attention.attend_chunked(q, k, v, causal=True, q_offset=8,
                                   q_chunk=4, kv_chunk=4)
    want = _naive(q, k, v, True, 0, q_offset=8)
    np.testing.assert_allclose(np.asarray(out, np.float64), want,
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [0, 3])
def test_decode_matches_naive(window):
    rng = np.random.default_rng(1)
    B, Smax, Hq, Hkv, D = 2, 10, 4, 2, 8
    cache_len = 7
    q = jnp.asarray(rng.normal(0, 1, (B, 1, Hq, D)), jnp.float32)
    kc = jnp.asarray(rng.normal(0, 1, (B, Smax, Hkv, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(0, 1, (B, Smax, Hkv, D)), jnp.float32)
    out = attention.attend_decode(q, kc, vc, jnp.int32(cache_len),
                                  window=window)
    # naive over the valid prefix with the window
    lo = max(0, cache_len - window) if window else 0
    want = _naive(q, kc[:, lo:cache_len], vc[:, lo:cache_len], False, 0)
    np.testing.assert_allclose(np.asarray(out, np.float64), want,
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 5), (False, 0)])
def test_flash_custom_vjp_gradients(causal, window):
    """The recompute-based backward equals autodiff-through-naive-attention
    gradients (the §Perf iteration-1 optimization must be exact)."""
    rng = np.random.default_rng(3)
    B, S, Hq, Hkv, D = 1, 12, 4, 2, 8
    q = jnp.asarray(rng.normal(0, 1, (B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    tgt = jnp.asarray(rng.normal(0, 1, (B, S, Hq, D)), jnp.float32)

    def loss_ours(q, k, v):
        o = attention.attend_chunked(q, k, v, causal=causal, window=window,
                                     q_chunk=4, kv_chunk=4)
        return jnp.sum((o - tgt) ** 2)

    def _naive_jax(q, k, v):
        rep = Hq // Hkv
        kk = jnp.repeat(k, rep, axis=2)
        vv = jnp.repeat(v, rep, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(D)
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(S)[None, :]
        m = jnp.ones((S, S), bool)
        if causal:
            m &= qpos >= kpos
        if window:
            m &= (qpos - kpos) < window
        s = jnp.where(m[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vv)

    def loss_naive(q, k, v):
        return jnp.sum((_naive_jax(q, k, v) - tgt) ** 2)

    g_ours = jax.grad(loss_ours, argnums=(0, 1, 2))(q, k, v)
    g_naive = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ours, g_naive):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_decode_consistent_with_chunked_last_row():
    """decode(q_t | cache) == last row of full chunked attention."""
    rng = np.random.default_rng(2)
    B, S, Hq, Hkv, D = 1, 9, 4, 2, 8
    q = jnp.asarray(rng.normal(0, 1, (B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    full = attention.attend_chunked(q, k, v, causal=True, q_chunk=4,
                                    kv_chunk=4)
    dec = attention.attend_decode(q[:, -1:], k, v, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)
