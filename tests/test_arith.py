"""The paper's arithmetic claims: TFF adder exactness (Fig. 2), Tables 1-2."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # bare env: deterministic sweep fallback
    from _hypothesis_compat import given, settings, st

from repro.core import arith, bitstream as bs, sng


def _str2bits(s):
    return jnp.asarray([int(c) for c in s], dtype=jnp.bool_)


class TestTFFAdder:
    def test_paper_example_fig2b(self):
        """X=1/2, Y=4/5 over N=20 -> Z=13/20, bit-for-bit (paper Fig. 2b)."""
        x = _str2bits("01100011010101111000")
        y = _str2bits("10111111010101111111")
        z, state = arith.tff_add_gate(x, y, 0)
        assert "".join(str(int(v)) for v in np.asarray(z)) == \
            "01101011010101111101"
        assert int(z.sum()) == 13

    @pytest.mark.parametrize("s0,want", [(0, 2), (1, 3)])
    def test_paper_example_fig2c_rounding(self, s0, want):
        """3/8 + 1/4 at N=8: 5/16 rounds down (s0=0) or up (s0=1)."""
        x = _str2bits("10100010")
        y = _str2bits("01000100")
        z, _ = arith.tff_add_gate(x, y, s0)
        assert int(z.sum()) == want

    @given(st.integers(1, 128), st.integers(0, 1), st.integers(0, 2**31 - 1))
    @settings(max_examples=60, deadline=None)
    def test_count_identity_and_packed_equivalence(self, n, s0, seed):
        """gate sim == packed impl == (cx+cy+s0)>>1 count identity."""
        rng = np.random.default_rng(seed)
        xb = jnp.asarray(rng.integers(0, 2, n), jnp.bool_)
        yb = jnp.asarray(rng.integers(0, 2, n), jnp.bool_)
        zg, st_g = arith.tff_add_gate(xb, yb, s0)
        cx, cy = int(xb.sum()), int(yb.sum())
        assert int(zg.sum()) == (cx + cy + s0) >> 1
        zp, st_p = arith.tff_add_packed(bs.pack_bits(xb[None])[0],
                                        bs.pack_bits(yb[None])[0], n, s0=s0)
        assert (np.asarray(bs.unpack_bits(zp, n)) == np.asarray(zg)).all()
        assert int(st_g) == int(st_p)

    def test_insensitive_to_autocorrelation(self):
        """Thermometer (maximally auto-correlated) streams still add exactly
        — the property that lets the ramp-compare A2S feed the adder."""
        N = 64
        for a in (0, 1, 17, 40, 64):
            for b_ in (0, 5, 33, 64):
                xa = sng.ramp_stream(jnp.asarray(a), N)
                xb = sng.ramp_stream(jnp.asarray(b_), N)
                z, _ = arith.tff_add_packed(xa, xb, N, s0=1)
                assert int(bs.popcount(z)) == (a + b_ + 1) >> 1


class TestTrees:
    @given(st.integers(2, 33), st.sampled_from(["zero", "one", "alt"]),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_tree_gate_equals_counts(self, m, mode, seed):
        N = 64
        rng = np.random.default_rng(seed)
        streams = jnp.asarray(rng.integers(0, 2, (m, N)), jnp.bool_)
        packed = bs.pack_bits(streams)
        root = arith.tff_tree_gate(packed, N, s0_mode=mode)
        assert int(bs.popcount(root)) == int(
            arith.tff_tree_counts(bs.popcount(packed), s0_mode=mode))

    def test_tree_scaling(self):
        """Root ~= sum / 2^depth within 1 LSB per level."""
        N = 256
        counts = jnp.asarray([100, 50, 200, 10, 90], jnp.int32)
        root = int(arith.tff_tree_counts(counts, "alt"))
        exact = sum([100, 50, 200, 10, 90]) / 8  # padded to 8 leaves
        assert abs(root - exact) <= 3


class TestMSETables:
    """Reproduce the paper's Table 1 / Table 2 (ordering + magnitudes)."""

    @staticmethod
    def _mult_mse(scheme, bits):
        N = 1 << bits
        ca, cb = sng.codes_for_scheme(scheme, bits)
        a = jnp.arange(N)
        SA = sng.generate(a, ca, N)
        SB = sng.generate(a, cb, N)
        prod = np.asarray(bs.popcount(arith.mult(SA[:, None], SB[None])),
                          np.float64)
        av = np.arange(N)[:, None] / N
        bv = np.arange(N)[None, :] / N
        return float(((prod / N - av * bv) ** 2).mean())

    def test_table1_ordering(self):
        for bits in (4, 8):
            mses = [self._mult_mse(s, bits) for s in sng.SCHEMES]
            assert mses[0] > mses[1] > mses[2] > mses[3], (bits, mses)

    def test_table1_magnitudes_8bit(self):
        """ramp+LD lands within ~3x of the paper's 8.66e-6."""
        m = self._mult_mse("ramp_lowdisc", 8)
        assert 8.66e-6 / 3 < m < 8.66e-6 * 3

    def test_table2_new_adder_exact(self):
        """The new adder's MSE is EXACTLY 1/(8N^2) — matches the paper's
        1.91e-6 (8-bit) and 4.88e-4 (4-bit) to all printed digits."""
        for bits, paper in ((8, 1.91e-6), (4, 4.88e-4)):
            N = 1 << bits
            a = jnp.arange(N)
            cz = arith.tff_add_count(a[:, None], a[None, :], 0)
            exact = (np.arange(N)[:, None] + np.arange(N)[None, :]) / (2 * N)
            mse = float(((np.asarray(cz, np.float64) / N - exact) ** 2).mean())
            assert mse == pytest.approx(1 / (8 * N * N), rel=1e-9)
            assert mse == pytest.approx(paper, rel=5e-3)

    def test_table2_new_beats_old(self):
        """New adder MSE << MUX adder MSE (paper: 50x at 8-bit)."""
        bits, N = 6, 64
        rng = np.random.default_rng(0)
        a = np.arange(N)
        draws = (rng.random((4, N, N)) < (a[:, None] / N))
        SA = bs.pack_bits(jnp.asarray(draws))
        SB = bs.pack_bits(jnp.asarray(
            rng.random((4, N, N)) < (a[:, None] / N)))
        sel = sng.generate(jnp.asarray(N // 2), sng.lfsr_sequence(bits), N)
        z = arith.mux_add(SA[:, :, None], SB[:, None, :], sel)
        exact = (a[:, None] + a[None, :]) / (2 * N)
        mse_old = float(((np.asarray(bs.popcount(z), np.float64) / N
                          - exact[None]) ** 2).mean())
        mse_new = 1 / (8 * N * N)
        assert mse_old > 10 * mse_new


def test_or_adder_biased():
    """OR 'adder' only works near zero (background §II)."""
    N = 64
    hi = sng.ramp_stream(jnp.asarray(48), N)
    z = arith.or_add(hi, hi)
    assert int(bs.popcount(z)) == 48  # OR of identical streams: no addition
