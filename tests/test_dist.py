"""Distribution-layer tests.  Multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its single-device view (per the dry-run contract)."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd


def test_batch_spec_axis():
    ms = {"data": 4, "model": 2}
    assert shd.batch_spec_axis(ms, 8) == "data"
    assert shd.batch_spec_axis(ms, 3) is None           # not divisible
    ms2 = {"pod": 2, "data": 4, "model": 2}
    assert shd.batch_spec_axis(ms2, 16) == ("pod", "data")
    assert shd.dp_size(ms2) == 8


def test_axis_if_divisible():
    assert shd.axis_if_divisible("model", 32, {"model": 16}) == "model"
    assert shd.axis_if_divisible("model", 25, {"model": 16}) is None


def test_zero_shard_specs():
    import jax.numpy as jnp
    params = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32),
              "b": jax.ShapeDtypeStruct((7,), jnp.float32)}
    specs = {"w": P(None, "model"), "b": P(None)}
    z = shd.zero_shard_specs(specs, params, {"data": 16, "model": 16})
    assert z["w"] == P("data", "model")    # largest free divisible dim
    assert z["b"] == P(None)               # 7 not divisible -> untouched


def test_hint_noop_without_mesh():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert shd.hint(x, "batch", "model") is x


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist import sharding as shd
    from repro.launch.mesh import make_mesh
    from repro.models import lm
    from repro.train import optim
    from repro.train.step import METRICS_KEYS, TrainConfig, make_train_step
    from repro.data.tokens import batch_at

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    ms = shd.mesh_shape_dict(mesh)
    cfg = lm.LMConfig(name="t", family="decoder", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
                      vocab=256, remat="full")
    # lr sized so the tiny model visibly learns inside the 10-step budget
    tcfg = TrainConfig(microbatches=2,
                       adamw=optim.AdamWConfig(lr=1e-2, weight_decay=0.1,
                                               grad_clip=1.0,
                                               master_dtype=jnp.float32))
    with shd.use_activation_mesh(mesh):
        params, specs = lm.init(jax.random.key(0), cfg, ms)
        params = jax.device_put(params, shd.named(mesh, specs))
        opt = optim.init(params, tcfg.adamw)
        opt_specs = shd.opt_state_specs(specs, params, ms)
        opt = jax.device_put(opt, shd.named(mesh, opt_specs))
        step = jax.jit(make_train_step(cfg, tcfg),
                       in_shardings=(shd.named(mesh, specs),
                                     shd.named(mesh, opt_specs),
                                     {k: shd.named(mesh, P(("pod","data"),
                                                           None))
                                      for k in ("tokens", "labels")}),
                       out_shardings=(shd.named(mesh, specs),
                                      shd.named(mesh, opt_specs),
                                      {k: shd.named(mesh, P())
                                       for k in METRICS_KEYS}),
                       donate_argnums=(0, 1))
        losses = []
        for i in range(10):
            b = {k: jnp.asarray(v) for k, v in
                 batch_at(0, i, 8, 32, cfg.vocab).items()}
            params, opt, m = step(params, opt, b)
            losses.append(float(m["loss"]))
        assert all(np.isfinite(l) for l in losses), losses
        # learns on the 3-axis (pod,data,model) mesh
        assert np.mean(losses[-3:]) < np.mean(losses[:3]), losses
        # compiled module must contain cross-device collectives
        txt = step.lower(params, opt, {"tokens": jax.ShapeDtypeStruct(
            (8, 32), jnp.int32), "labels": jax.ShapeDtypeStruct(
            (8, 32), jnp.int32)}).compile().as_text()
        assert "all-reduce" in txt
        print("SUBPROCESS_OK", losses[0], "->", losses[-1])
""")


@pytest.mark.slow
def test_multidevice_train_subprocess():
    """Real 8-virtual-device (2,2,2) pod×data×model training: loss decreases
    and collectives are emitted."""
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROG],
                       capture_output=True, text=True, timeout=600)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


def test_gradients_match_across_microbatch_counts():
    """Grad accumulation is exact: mb=1 vs mb=4 give the same update."""
    import jax.numpy as jnp
    from repro.models import lm
    from repro.train import optim
    from repro.train.step import TrainConfig, make_train_step
    from repro.data.tokens import batch_at

    cfg = lm.LMConfig(name="t", family="decoder", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_head=16, d_ff=64, vocab=128,
                      remat="none", param_dtype="float32")
    params, _ = lm.init(jax.random.key(0), cfg, {})
    batch = {k: jnp.asarray(v) for k, v in
             batch_at(0, 0, 8, 16, cfg.vocab).items()}
    outs = []
    for mb in (1, 4):
        tcfg = TrainConfig(microbatches=mb,
                           adamw=optim.AdamWConfig(lr=1e-2))
        opt = optim.init(params, tcfg.adamw)
        p2, _, m = jax.jit(make_train_step(cfg, tcfg))(params, opt, batch)
        outs.append(p2)
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)
