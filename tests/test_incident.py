"""Incident forensics (serve/obs/incident.py): every automatic trigger
(SLO warn->critical with the capture-before-first-drop ordering pin, drop
bursts, recompile leaks, energy-conservation breaks), the explicit
``capture_incident`` hook, bundle schema validation / refuse-on-invalid /
size bounding, ServeSpec wiring, and the offline CLI inspector."""
import dataclasses
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serve import obs
from repro.serve.gateway import frontend as fe
from repro.serve.gateway.gateway import (GatewayConfig, MicroBatchGateway,
                                         PromptGateway)
from repro.serve.gateway.sensors import Arrival
from repro.serve.gateway.slots import ContinuousBatcher, make_adapter
from repro.serve.obs import incident as inc_mod
from repro.serve.spec import ServeSpec, make_gateway

_SETUP_CACHE: dict = {}


def _setup(arch="stablelm_3b"):
    if arch not in _SETUP_CACHE:
        cfg = dataclasses.replace(configs.smoke_config(arch),
                                  param_dtype="float32")
        params, _ = lm.init(jax.random.key(0), cfg, {})
        _SETUP_CACHE[arch] = (cfg, params)
    return _SETUP_CACHE[arch]


def _prompt_arrivals(cfg, n, plen=8, seed=0, dt=0.001):
    rng = np.random.default_rng(seed)
    return [Arrival(t=i * dt, uid=i, endpoint=0, kind="prompt",
                    payload=rng.integers(0, cfg.vocab, plen)
                    .astype(np.int32)) for i in range(n)]


def _frame_arrivals(n, dt=0.001, seed=0):
    rng = np.random.default_rng(seed)
    return [Arrival(t=i * dt, uid=i, endpoint=0, kind="frame",
                    payload=rng.integers(0, 255, (28, 28, 1))
                    .astype(np.uint8)) for i in range(n)]


def _policy(target=0.006):
    return obs.SLOPolicy(
        objectives=(obs.SLObjective("queue_wait", target=target,
                                    budget=0.05),
                    obs.SLObjective("drop_rate", budget=0.05)),
        windows=(obs.BurnWindow(0.05, 0.01, 8.0, "critical"),
                 obs.BurnWindow(0.05, 0.01, 2.0, "warn")))


# ==========================================================================
# Trigger: SLO warn -> critical, with the ordering pin.
# ==========================================================================

def test_slo_critical_capture_lands_before_first_shed_drop(tmp_path):
    """The whole point of auto-capture: the bundle is written while
    dropping is still avoidable, so the forensic record shows the system
    *entering* distress — the flight ring inside the bundle must contain
    no drop instants yet."""
    gw = MicroBatchGateway(GatewayConfig(bucket_sizes=(1,), max_queue=16,
                                         max_delay_s=0.0005,
                                         service_model="fixed",
                                         fixed_service_s=0.002),
                           fe.FrontendSpec(mode="sc", bits=4))
    gw.warmup()
    fl = obs.FlightRecorder()
    mon = obs.SLOMonitor(_policy(), metrics=obs.MetricsRegistry(
        interval_s=0.005))
    inc = obs.IncidentCapture(str(tmp_path), flight=fl, slo=mon)
    tel = gw.run(_frame_arrivals(60), slo=mon, flight=fl, incident=inc)
    assert tel.dropped, "overload must eventually hit the queue bound"
    assert inc.captures and inc.captures[0]["reason"] == "slo_critical"
    first_drop_t = tel.dropped[0][3]
    assert inc.captures[0]["t"] < first_drop_t
    bundle = obs.load_incident_bundle(inc.captures[0]["path"])
    assert bundle["trigger_detail"]["from"] == "warn"
    assert not [e for e in bundle["flight"]["instants"]
                if e["name"] == "drop"]
    assert bundle["slo"]["state"] == "critical"
    assert bundle["state"]["kind"] == "frame_gateway"
    assert "jit_cache_sizes" in bundle["state"]


def test_cooldown_suppresses_back_to_back_auto_captures(tmp_path):
    inc = obs.IncidentCapture(str(tmp_path), drop_burst=2,
                              drop_window_s=1.0, cooldown_s=10.0)
    for t in (0.1, 0.2, 0.3, 0.4):
        inc.observe_drop(t)
    assert len(inc.captures) == 1              # burst fired once, then held
    # explicit captures bypass the cooldown
    inc.capture("operator_probe", t=0.5)
    assert [c["reason"] for c in inc.captures] == \
        ["drop_burst", "operator_probe"]


# ==========================================================================
# Trigger: drop burst.
# ==========================================================================

def test_drop_burst_needs_a_dense_window(tmp_path):
    inc = obs.IncidentCapture(str(tmp_path), drop_burst=4,
                              drop_window_s=0.1, cooldown_s=0.0)
    for i in range(8):                         # sparse: one drop per 0.2s
        inc.observe_drop(i * 0.2)
    assert not inc.captures
    for i in range(4):                         # dense burst inside 0.1s
        inc.observe_drop(2.0 + i * 0.01)
    assert len(inc.captures) == 1
    b = obs.load_incident_bundle(inc.captures[0]["path"])
    assert b["reason"] == "drop_burst"
    assert b["trigger_detail"]["drops_in_window"] == 4


# ==========================================================================
# Trigger: recompile leak.
# ==========================================================================

def test_recompile_leak_polled_into_a_bundle(tmp_path):
    f = jax.jit(lambda x: x * 2)
    det = obs.RecompileDetector()
    det.track("t", {"f": f})
    f(jnp.ones(2))
    det.snapshot()
    inc = obs.IncidentCapture(str(tmp_path), detector=det, cooldown_s=0.0)
    inc.poll(0.1)
    assert not inc.captures                    # steady state: nothing
    f(jnp.zeros(3))                            # new shape: a leak
    inc.poll(0.2)
    assert len(inc.captures) == 1
    b = obs.load_incident_bundle(inc.captures[0]["path"])
    assert b["reason"] == "recompile_leak"
    assert b["trigger_detail"]["by_fn"] == {"t.f": 1}
    assert b["recompile"]["steady_state_recompiles"] == 1
    inc.poll(0.3)                              # same leak: not re-captured
    assert len(inc.captures) == 1


def test_unarmed_detector_never_trips(tmp_path):
    det = obs.RecompileDetector()              # no snapshot taken
    inc = obs.IncidentCapture(str(tmp_path), detector=det)
    inc.poll(0.1)
    assert not inc.captures


# ==========================================================================
# Trigger: energy-conservation mismatch.
# ==========================================================================

class _Ledger:
    def __init__(self, ok):
        self.ok = ok

    def assert_conserved(self):
        assert self.ok, "per-span energy does not fold to the fleet total"


def test_energy_mismatch_capture(tmp_path):
    inc = obs.IncidentCapture(str(tmp_path), cooldown_s=0.0)
    assert inc.check_energy(_Ledger(True), 1.0)
    assert not inc.captures
    assert not inc.check_energy(_Ledger(False), 2.0)
    b = obs.load_incident_bundle(inc.captures[0]["path"])
    assert b["reason"] == "energy_mismatch" and b["t"] == 2.0
    assert "fold" in b["trigger_detail"]["error"]


# ==========================================================================
# Explicit captures + gateway / ServeSpec wiring.
# ==========================================================================

def test_gateway_capture_incident_snapshots_debug_state(tmp_path):
    cfg, params = _setup()
    ad = make_adapter(cfg, params, n_slots=2, max_len=32, paged=True,
                      block_size=4)
    inc = obs.IncidentCapture(str(tmp_path), flight=obs.FlightRecorder())
    gw = PromptGateway(ContinuousBatcher(ad), max_new_tokens=4,
                       flight=obs.FlightRecorder(), incident=inc)
    gw.run(_prompt_arrivals(cfg, 3))
    path = gw.capture_incident("operator_probe", extra={"ticket": "X-1"})
    assert pathlib.Path(path).name.endswith("operator_probe.json")
    b = obs.load_incident_bundle(path)
    assert b["trigger_detail"] == {"ticket": "X-1"}
    st = b["state"]
    assert st["kind"] == "prompt_gateway"
    assert st["pool"]["free_blocks"] >= 0      # pool snapshot rode along
    assert st["batcher"]["n_slots"] == 2
    gw_plain = PromptGateway(ContinuousBatcher(ad))
    with pytest.raises(RuntimeError):
        gw_plain.capture_incident("nope")


def test_servespec_arms_flight_and_incident(tmp_path):
    cfg, params = _setup()
    spec = ServeSpec(n_slots=2, max_len=32, paged=True, block_size=4,
                     max_new_tokens=4, flight=True,
                     incident_dir=str(tmp_path))
    gw = make_gateway(cfg, params, spec)
    assert isinstance(gw.incident, obs.IncidentCapture)
    assert isinstance(gw.flight, obs.FlightRecorder)
    assert gw.incident.flight is gw.flight
    tel = gw.run(_prompt_arrivals(cfg, 3))
    assert len(tel.records) == 3
    path = gw.capture_incident("smoke")
    assert obs.load_incident_bundle(path)["state"]["kind"] == \
        "prompt_gateway"


# ==========================================================================
# Bundle schema: refuse-on-invalid, size bound, truncation detection.
# ==========================================================================

def _many_span_flight(n=600):
    fl = obs.FlightRecorder()
    for i in range(n):
        fl({"name": "decode", "ph": "X", "pid": 0, "tid": i % 7,
            "ts": i * 1e-3, "dur": 1e-4,
            "args": {"note": "x" * 40}})
    return fl


def test_size_bound_shrinks_flight_until_bundle_fits(tmp_path):
    inc = obs.IncidentCapture(str(tmp_path), flight=_many_span_flight(),
                              max_bytes=16 * 1024)
    path = inc.capture("probe")
    assert pathlib.Path(path).stat().st_size <= 16 * 1024
    b = obs.load_incident_bundle(path)
    acct = b["flight"]["accounting"]
    assert acct["spans_kept"] < acct["spans_seen"]
    assert acct["spans_dropped"] == acct["spans_seen"] - acct["spans_kept"]


def test_impossible_size_bound_raises_instead_of_writing(tmp_path):
    inc = obs.IncidentCapture(str(tmp_path), flight=_many_span_flight(),
                              max_bytes=64)
    with pytest.raises(ValueError, match="cannot fit"):
        inc.capture("probe")
    assert not list(tmp_path.glob("*.json"))   # nothing half-written


def test_writer_refuses_schema_violations(tmp_path):
    good = {"schema": inc_mod.SCHEMA, "reason": "probe", "t": 0.0,
            "seq": 0, "trigger_detail": {}, "state": {}, "flight": None,
            "slo": None, "recompile": None}
    path = str(tmp_path / "b.json")
    inc_mod.write_incident_bundle(path, good)
    assert obs.validate_incident_bundle(json.load(open(path))) == []
    for bad in (
        {**good, "schema": "repro.incident.v0"},      # wrong schema tag
        {**good, "reason": ""},                       # empty reason
        {k: v for k, v in good.items() if k != "t"},  # missing field
        {**good, "flight": {"spans": []}},            # gutted flight section
    ):
        with pytest.raises(ValueError, match="refusing"):
            inc_mod.write_incident_bundle(str(tmp_path / "bad.json"), bad)
    assert not (tmp_path / "bad.json").exists()


def test_truncated_bundle_is_rejected_on_load(tmp_path):
    inc = obs.IncidentCapture(str(tmp_path), flight=_many_span_flight(64))
    path = inc.capture("probe")
    text = open(path).read()
    open(path, "w").write(text[:len(text) // 2])
    with pytest.raises(ValueError, match="unreadable"):
        obs.load_incident_bundle(path)
    # a parseable-but-doctored bundle fails the schema pass instead
    doctored = json.loads(text)
    del doctored["flight"]["accounting"]
    open(path, "w").write(json.dumps(doctored))
    with pytest.raises(ValueError, match="accounting"):
        obs.load_incident_bundle(path)


def test_accounting_seen_lt_kept_is_invalid():
    fl = obs.FlightRecorder()
    fl({"name": "a", "ph": "X", "pid": 0, "tid": 0, "ts": 0.0,
        "dur": 1e-3, "args": {}})
    snap = fl.snapshot()
    snap["accounting"]["spans_seen"] = 0       # forged: kept > seen
    bundle = {"schema": inc_mod.SCHEMA, "reason": "probe", "t": 0.0,
              "seq": 0, "trigger_detail": {}, "state": {}, "flight": snap,
              "slo": None, "recompile": None}
    assert any("spans_seen" in e
               for e in obs.validate_incident_bundle(bundle))


# ==========================================================================
# CLI: inspect / diff / critpath without the live process.
# ==========================================================================

def test_cli_inspect_diff_critpath(tmp_path, capsys):
    gw = MicroBatchGateway(GatewayConfig(bucket_sizes=(1,), max_queue=16,
                                         max_delay_s=0.0005,
                                         service_model="fixed",
                                         fixed_service_s=0.002),
                           fe.FrontendSpec(mode="sc", bits=4))
    gw.warmup()
    fl = obs.FlightRecorder()
    mon = obs.SLOMonitor(_policy())
    inc = obs.IncidentCapture(str(tmp_path), flight=fl, slo=mon,
                              cooldown_s=0.0, drop_burst=4,
                              drop_window_s=0.05)
    gw.run(_frame_arrivals(60), slo=mon, flight=fl, incident=inc)
    assert len(inc.captures) >= 2              # slo_critical then drop_burst
    a, b = inc.captures[0]["path"], inc.captures[-1]["path"]

    assert inc_mod.main(["inspect", a]) == 0
    out = capsys.readouterr().out
    assert "reason=slo_critical" in out and "flight:" in out
    assert "warn -> critical" in out

    assert inc_mod.main(["diff", a, b]) == 0
    out = capsys.readouterr().out
    assert "->" in out

    assert inc_mod.main(["critpath", a]) == 0
    out = capsys.readouterr().out
    assert "exact re-fold: True" in out and "queue_wait" in out

    bad = tmp_path / "trunc.json"
    bad.write_text(open(a).read()[:100])
    assert inc_mod.main(["inspect", str(bad)]) == 1
    assert "ERROR" in capsys.readouterr().out
