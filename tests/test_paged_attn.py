"""Pallas paged decode-attention kernel vs the XLA gather reference
(interpret mode), plus the gather path's own masking semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.paged_attn import paged_decode_attention
from repro.nn import attention


def _make_case(rng, B, nb, bs, Hq, Hkv, D, num_blocks, dtype):
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, D)), dtype)
    k_arena = jnp.asarray(rng.normal(0, 1, (num_blocks, bs, Hkv, D)), dtype)
    v_arena = jnp.asarray(rng.normal(0, 1, (num_blocks, bs, Hkv, D)), dtype)
    # each row gets a distinct permutation of arena blocks (block 0 = trash)
    tables = np.zeros((B, nb), np.int32)
    lens = np.zeros((B,), np.int32)
    for b in range(B):
        lens[b] = int(rng.integers(1, nb * bs + 1))
        used = -(-int(lens[b]) // bs)
        tables[b, :used] = rng.choice(
            np.arange(1, num_blocks), size=used, replace=False)
    return q, k_arena, v_arena, jnp.asarray(tables), jnp.asarray(lens)


@pytest.mark.parametrize("B,nb,bs,Hq,Hkv,D,dtype", [
    (3, 4, 8, 4, 4, 32, jnp.float32),       # MHA
    (2, 3, 16, 8, 2, 64, jnp.float32),      # GQA 4:1
    (4, 2, 8, 6, 6, 16, jnp.bfloat16),
    (1, 5, 4, 4, 1, 32, jnp.float32),       # MQA
])
def test_paged_kernel_matches_gather_reference(B, nb, bs, Hq, Hkv, D, dtype):
    rng = np.random.default_rng(B * nb * bs)
    num_blocks = B * nb + 1
    q, ka, va, tables, lens = _make_case(rng, B, nb, bs, Hq, Hkv, D,
                                         num_blocks, dtype)
    got = paged_decode_attention(q, ka, va, tables, lens, interpret=True)
    want = attention.attend_decode_paged(q[:, None], ka, va, tables, lens)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want[:, 0], np.float32),
                               rtol=tol, atol=tol)


def test_gather_reference_matches_dense_attend_decode():
    """attend_decode_paged == attend_decode on the densely-laid-out cache:
    paging is a pure relayout, not a different attention."""
    rng = np.random.default_rng(0)
    B, nb, bs, Hq, Hkv, D = 2, 3, 8, 4, 2, 32
    S = nb * bs
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.normal(0, 1, (B, 1, Hq, D)), jnp.float32)
    # identity block layout: row b owns blocks [1 + b*nb, 1 + (b+1)*nb)
    k_arena = jnp.concatenate(
        [jnp.zeros((1, bs, Hkv, D))] + [k[b].reshape(nb, bs, Hkv, D)
                                        for b in range(B)]).astype(k.dtype)
    v_arena = jnp.concatenate(
        [jnp.zeros((1, bs, Hkv, D))] + [v[b].reshape(nb, bs, Hkv, D)
                                        for b in range(B)]).astype(v.dtype)
    tables = jnp.asarray([[1 + b * nb + j for j in range(nb)]
                          for b in range(B)], jnp.int32)
    for ln in (1, bs, S - 3, S):
        want = attention.attend_decode(q, k, v, ln)
        got = attention.attend_decode_paged(
            q, k_arena, v_arena, tables, jnp.full((B,), ln, jnp.int32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


def test_paged_kernel_ignores_trash_block_contents():
    """Positions masked by ``lens`` never reach the softmax, whatever the
    trash block or stale tail blocks hold."""
    rng = np.random.default_rng(3)
    B, nb, bs, H, D = 1, 3, 4, 2, 16
    q, ka, va, tables, lens = _make_case(rng, B, nb, bs, H, H, D, 8,
                                         jnp.float32)
    lens = jnp.asarray([5], jnp.int32)          # only block 0-1 partially live
    base = paged_decode_attention(q, ka, va, tables, lens, interpret=True)
    ka2 = ka.at[0].set(1e9)                     # poison the trash block
    va2 = va.at[0].set(-1e9)
    tables2 = jnp.asarray(tables).at[0, 2:].set(0)
    got = paged_decode_attention(q, ka2, va2, tables2, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-6, atol=1e-6)
