"""Pallas paged decode-attention kernel vs the XLA gather reference
(interpret mode), plus the gather path's own masking semantics, the
sliding-window operand, and the REPRO_KERNELS_INTERPRET override."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.paged_attn import paged_decode_attention, scatter_kv_rows
from repro.nn import attention


def _make_case(rng, B, nb, bs, Hq, Hkv, D, num_blocks, dtype):
    q = jnp.asarray(rng.normal(0, 1, (B, Hq, D)), dtype)
    k_arena = jnp.asarray(rng.normal(0, 1, (num_blocks, bs, Hkv, D)), dtype)
    v_arena = jnp.asarray(rng.normal(0, 1, (num_blocks, bs, Hkv, D)), dtype)
    # each row gets a distinct permutation of arena blocks (block 0 = trash)
    tables = np.zeros((B, nb), np.int32)
    lens = np.zeros((B,), np.int32)
    for b in range(B):
        lens[b] = int(rng.integers(1, nb * bs + 1))
        used = -(-int(lens[b]) // bs)
        tables[b, :used] = rng.choice(
            np.arange(1, num_blocks), size=used, replace=False)
    return q, k_arena, v_arena, jnp.asarray(tables), jnp.asarray(lens)


@pytest.mark.parametrize("B,nb,bs,Hq,Hkv,D,dtype", [
    (3, 4, 8, 4, 4, 32, jnp.float32),       # MHA
    (2, 3, 16, 8, 2, 64, jnp.float32),      # GQA 4:1
    (4, 2, 8, 6, 6, 16, jnp.bfloat16),
    (1, 5, 4, 4, 1, 32, jnp.float32),       # MQA
])
def test_paged_kernel_matches_gather_reference(B, nb, bs, Hq, Hkv, D, dtype):
    rng = np.random.default_rng(B * nb * bs)
    num_blocks = B * nb + 1
    q, ka, va, tables, lens = _make_case(rng, B, nb, bs, Hq, Hkv, D,
                                         num_blocks, dtype)
    got = paged_decode_attention(q, ka, va, tables, lens, interpret=True)
    want = attention.attend_decode_paged(q[:, None], ka, va, tables, lens)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want[:, 0], np.float32),
                               rtol=tol, atol=tol)


def test_gather_reference_matches_dense_attend_decode():
    """attend_decode_paged == attend_decode on the densely-laid-out cache:
    paging is a pure relayout, not a different attention."""
    rng = np.random.default_rng(0)
    B, nb, bs, Hq, Hkv, D = 2, 3, 8, 4, 2, 32
    S = nb * bs
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.normal(0, 1, (B, 1, Hq, D)), jnp.float32)
    # identity block layout: row b owns blocks [1 + b*nb, 1 + (b+1)*nb)
    k_arena = jnp.concatenate(
        [jnp.zeros((1, bs, Hkv, D))] + [k[b].reshape(nb, bs, Hkv, D)
                                        for b in range(B)]).astype(k.dtype)
    v_arena = jnp.concatenate(
        [jnp.zeros((1, bs, Hkv, D))] + [v[b].reshape(nb, bs, Hkv, D)
                                        for b in range(B)]).astype(v.dtype)
    tables = jnp.asarray([[1 + b * nb + j for j in range(nb)]
                          for b in range(B)], jnp.int32)
    for ln in (1, bs, S - 3, S):
        want = attention.attend_decode(q, k, v, ln)
        got = attention.attend_decode_paged(
            q, k_arena, v_arena, tables, jnp.full((B,), ln, jnp.int32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("window", [3, 8, 17])
def test_paged_kernel_windowed_matches_reference(window):
    """The kernel's trailing-window mask (scalar-prefetch operand) must
    agree with attend_decode_paged's — only positions in
    [lens - window, lens) attend, whatever blocks the table routes."""
    rng = np.random.default_rng(window)
    B, nb, bs, Hq, Hkv, D = 3, 4, 8, 4, 2, 32
    num_blocks = B * nb + 1
    q, ka, va, tables, lens = _make_case(rng, B, nb, bs, Hq, Hkv, D,
                                         num_blocks, jnp.float32)
    got = paged_decode_attention(q, ka, va, tables, lens, window=window,
                                 interpret=True)
    want = attention.attend_decode_paged(q[:, None], ka, va, tables, lens,
                                         window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want[:, 0]),
                               rtol=2e-5, atol=2e-5)


def test_paged_kernel_new_kv_splice_matches_insert():
    """attend_decode_paged(new_kv=...) — the in-place tick's read of the
    row it is mid-way through writing — must equal attending after the row
    was physically scattered into the arena."""
    rng = np.random.default_rng(11)
    B, nb, bs, Hq, Hkv, D = 2, 3, 4, 4, 2, 16
    num_blocks = B * nb + 1
    q, ka, va, _, _ = _make_case(rng, B, nb, bs, Hq, Hkv, D,
                                 num_blocks, jnp.float32)
    # fully-populated disjoint tables so every lane's new row (position
    # ``lens``, possibly the first row of a fresh block) has a real,
    # lane-private block to land in
    tables = jnp.asarray(
        rng.permutation(np.arange(1, num_blocks))[:B * nb].reshape(B, nb))
    lens = jnp.asarray([bs * 2, bs * 2 - 1], jnp.int32)  # boundary + mid
    k1 = jnp.asarray(rng.normal(0, 1, (B, Hkv, D)), jnp.float32)
    v1 = jnp.asarray(rng.normal(0, 1, (B, Hkv, D)), jnp.float32)
    # physically write the new row at position lens per lane
    ka2, va2 = ka, va
    for b in range(B):
        blk = int(tables[b, int(lens[b]) // bs])
        off = int(lens[b]) % bs
        ka2 = ka2.at[blk, off].set(k1[b])
        va2 = va2.at[blk, off].set(v1[b])
    want = attention.attend_decode_paged(q[:, None], ka2, va2, tables,
                                         lens + 1)
    got = attention.attend_decode_paged(q[:, None], ka, va, tables,
                                        lens + 1, new_kv=(k1, v1))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the kernel's in-VMEM overlay must agree with the physical write too
    # (this is how the serving tick reads the row it is mid-way through
    # writing without copying the arena slice)
    kern = paged_decode_attention(q, ka, va, tables, lens + 1,
                                  new_kv=(k1, v1), interpret=True)
    kern_want = paged_decode_attention(q, ka2, va2, tables, lens + 1,
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(kern), np.asarray(kern_want))


def test_default_interpret_env_override(monkeypatch):
    """REPRO_KERNELS_INTERPRET forces the mode either way; unset falls
    back to the backend probe — what the CI kernels-interpret leg relies
    on to exercise the Pallas bodies deliberately."""
    monkeypatch.setenv("REPRO_KERNELS_INTERPRET", "1")
    assert ops.default_interpret() is True
    monkeypatch.setenv("REPRO_KERNELS_INTERPRET", "0")
    assert ops.default_interpret() is False
    monkeypatch.setenv("REPRO_KERNELS_INTERPRET", "false")
    assert ops.default_interpret() is False
    monkeypatch.delenv("REPRO_KERNELS_INTERPRET")
    import jax
    assert ops.default_interpret() is (jax.default_backend() != "tpu")
    assert ops.resolve_interpret(True) is True      # explicit always wins


def test_paged_kernel_ignores_trash_block_contents():
    """Positions masked by ``lens`` never reach the softmax, whatever the
    trash block or stale tail blocks hold."""
    rng = np.random.default_rng(3)
    B, nb, bs, H, D = 1, 3, 4, 2, 16
    q, ka, va, tables, lens = _make_case(rng, B, nb, bs, H, H, D, 8,
                                         jnp.float32)
    lens = jnp.asarray([5], jnp.int32)          # only block 0-1 partially live
    base = paged_decode_attention(q, ka, va, tables, lens, interpret=True)
    ka2 = ka.at[0].set(1e9)                     # poison the trash block
    va2 = va.at[0].set(-1e9)
    tables2 = jnp.asarray(tables).at[0, 2:].set(0)
    got = paged_decode_attention(q, ka2, va2, tables2, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-6, atol=1e-6)


# ==========================================================================
# The in-place arena-write kernel (input_output_aliasing): the Pallas leg
# of the decode tick's row scatter.
# ==========================================================================

def test_scatter_kv_rows_matches_at_set():
    """scatter_kv_rows == arena.at[:, wbids, 0, offs].set(rows) on unique
    (block, row) targets, leaving every unaddressed block bit-untouched —
    the aliased outputs start as the input buffers, so nothing is
    functionally rebuilt."""
    rng = np.random.default_rng(7)
    L, nb, bs, H, D, S = 3, 6, 4, 2, 8, 4
    ka = jnp.asarray(rng.normal(size=(L, nb, 1, bs, H, D)), jnp.float32)
    va = jnp.asarray(rng.normal(size=(L, nb, 1, bs, H, D)), jnp.float32)
    kr = jnp.asarray(rng.normal(size=(L, S, H, D)), jnp.float32)
    vr = jnp.asarray(rng.normal(size=(L, S, H, D)), jnp.float32)
    wbids = np.array([2, 5, 1, 3], np.int32)
    offs = np.array([1, 3, 0, 2], np.int32)
    nk, nv = scatter_kv_rows(ka, va, kr, vr, jnp.asarray(wbids),
                             jnp.asarray(offs), interpret=True)
    np.testing.assert_array_equal(
        np.asarray(nk), np.asarray(ka.at[:, wbids, 0, offs].set(kr)))
    np.testing.assert_array_equal(
        np.asarray(nv), np.asarray(va.at[:, wbids, 0, offs].set(vr)))
    # untouched blocks (0 and 4) keep their exact bytes
    for b in (0, 4):
        np.testing.assert_array_equal(np.asarray(nk[:, b]),
                                      np.asarray(ka[:, b]))


def test_scatter_kv_rows_trash_collisions_stay_in_trash():
    """Several masked lanes colliding on the trash block must not touch
    any real block — collisions are absorbed by block 0 in some order,
    which is garbage under every order."""
    rng = np.random.default_rng(8)
    L, nb, bs, H, D, S = 2, 4, 4, 1, 8, 3
    ka = jnp.asarray(rng.normal(size=(L, nb, 1, bs, H, D)), jnp.float32)
    va = jnp.asarray(rng.normal(size=(L, nb, 1, bs, H, D)), jnp.float32)
    kr = jnp.asarray(rng.normal(size=(L, S, H, D)), jnp.float32)
    vr = jnp.asarray(rng.normal(size=(L, S, H, D)), jnp.float32)
    wbids = np.array([0, 0, 2], np.int32)       # two lanes trash-routed
    offs = np.array([1, 1, 3], np.int32)        # ... colliding on one row
    nk, nv = scatter_kv_rows(ka, va, kr, vr, jnp.asarray(wbids),
                             jnp.asarray(offs), interpret=True)
    for b in (1, 3):                            # untouched real blocks
        np.testing.assert_array_equal(np.asarray(nk[:, b]),
                                      np.asarray(ka[:, b]))
    np.testing.assert_array_equal(               # lane 2's real write lands
        np.asarray(nk[:, 2, 0, 3]), np.asarray(kr[:, 2]))
    np.testing.assert_array_equal(
        np.asarray(nv[:, 2, 0, 3]), np.asarray(vr[:, 2]))
