"""Continuous batching: slot reuse, isolation between concurrent requests,
and equivalence with dedicated single-request decoding."""
import jax
import numpy as np
from conftest import sequential_decode_reference

from repro import configs
from repro.models import lm
from repro.serve.scheduler import Request, RwkvContinuousBatcher


def test_continuous_batching_matches_dedicated_decode():
    cfg = configs.smoke_config("rwkv6_7b")
    import dataclasses
    cfg = dataclasses.replace(cfg, param_dtype="float32")
    params, _ = lm.init(jax.random.key(0), cfg, {})
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=s).astype(np.int32)
               for s in (5, 9, 7, 12, 6)]
    n_new = 6

    batcher = RwkvContinuousBatcher(cfg, params, n_slots=2)  # < n_requests
    for i, p in enumerate(prompts):
        batcher.submit(Request(uid=i, prompt=p, max_new_tokens=n_new))
    done = batcher.run()
    assert len(done) == len(prompts)
    by_uid = {r.uid: r.generated for r in done}

    for i, p in enumerate(prompts):
        want = sequential_decode_reference(cfg, params, p, n_new)
        assert by_uid[i] == want, (i, by_uid[i], want)


def test_slots_are_isolated():
    """A long request must not perturb a short one sharing the batch."""
    cfg = configs.smoke_config("rwkv6_7b")
    import dataclasses
    cfg = dataclasses.replace(cfg, param_dtype="float32")
    params, _ = lm.init(jax.random.key(1), cfg, {})
    rng = np.random.default_rng(1)
    a = rng.integers(0, cfg.vocab, size=4).astype(np.int32)
    b = rng.integers(0, cfg.vocab, size=15).astype(np.int32)

    solo = RwkvContinuousBatcher(cfg, params, n_slots=1)
    solo.submit(Request(uid=0, prompt=a, max_new_tokens=5))
    solo_out = {r.uid: r.generated for r in solo.run()}

    both = RwkvContinuousBatcher(cfg, params, n_slots=2)
    both.submit(Request(uid=0, prompt=a, max_new_tokens=5))
    both.submit(Request(uid=1, prompt=b, max_new_tokens=9))
    both_out = {r.uid: r.generated for r in both.run()}
    assert both_out[0] == solo_out[0]
