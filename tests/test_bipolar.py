"""Bipolar SC (the design the paper REJECTS in §IV.B) — verify the rejection
rationale quantitatively."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bipolar, bitstream as bs, sng


def test_xnor_multiplies_bipolar_values():
    bits, N = 6, 64
    for a in (-1.0, -0.5, 0.0, 0.5, 1.0):
        for b in (-1.0, 0.25, 1.0):
            xa = sng.generate(bipolar.to_level(jnp.asarray(a), bits),
                              sng.ramp_sequence(bits), N)
            xb = sng.generate(bipolar.to_level(jnp.asarray(b), bits),
                              sng.revgray_sequence(bits), N)
            z = bipolar.mult(xa, xb, N)
            got = float(bipolar.from_count(bs.popcount(z), N))
            assert abs(got - a * b) < 0.15, (a, b, got)


def test_dot_bipolar_estimates_dot():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.uniform(-1, 1, (8, 8)), jnp.float32)
    w = jnp.asarray(rng.uniform(-0.5, 0.5, (8, 2)), jnp.float32)
    est = np.asarray(bipolar.dot_bipolar(x, w, bits=8))
    exact = np.asarray(x) @ np.asarray(w)
    assert np.abs(est - exact).mean() < 0.5      # coarse but unbiased
    assert abs((est - exact).mean()) < 0.15      # pad bias removed


def test_paper_claim_split_beats_bipolar_at_decision_point():
    """§IV.B: near the sign decision point the bipolar estimate is noisier
    than the paper's split-unipolar comparator design."""
    err_b, err_s = bipolar.decision_point_errors(bits=6, n=512)
    assert err_s.mean() < err_b.mean(), (err_s.mean(), err_b.mean())


def test_bipolar_degrades_with_fewer_bits():
    e4_b, _ = bipolar.decision_point_errors(bits=4, n=256)
    e7_b, _ = bipolar.decision_point_errors(bits=7, n=256)
    assert e7_b.mean() < e4_b.mean()
