"""Paper Table 3 (power / energy / area block): calibrated analytical model
vs the paper's synthesis numbers, + the headline 9.8x / break-even claims,
+ beyond-paper near-sensor projections for the whisper / VLM frontends."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import energy


def run(quiet: bool = False):
    worst = 0.0
    for bits in range(2, 9):
        r = energy.report(bits)
        bp, sp, be, se, ba, sa = energy.PAPER_TABLE3[bits]
        errs = [abs(r.bin_power_mw / bp - 1), abs(r.sc_power_mw / sp - 1),
                abs(r.bin_energy_nj / be - 1), abs(r.sc_energy_nj / se - 1),
                abs(r.bin_area_mm2 / ba - 1), abs(r.sc_area_mm2 / sa - 1)]
        worst = max(worst, max(errs))
        emit(f"table3_energy/{bits}bit", 0.0,
             f"sc={r.sc_energy_nj:.2f}nJ (paper {se}) "
             f"bin={r.bin_energy_nj:.2f}nJ (paper {be}) "
             f"gain={r.efficiency_gain:.2f}x maxerr={max(errs)*100:.1f}%")
    emit("table3_energy/headline", 0.0,
         f"gain_4bit={energy.report(4).efficiency_gain:.1f}x (paper 9.8x) "
         f"breakeven_8bit={energy.report(8).efficiency_gain:.2f}x "
         f"worst_cell_err={worst*100:.1f}%")
    # beyond-paper: project the SC frontend to the assigned modality archs
    for name, (k, units, kernels) in {
        "whisper_frame_proj": (80, 1500, 16),   # 80-dim mel window per frame
        "vlm_patch_embed": (588, 1024, 32),     # 14x14x3 patch projection
    }.items():
        r4 = energy.scaled_report(4, k, units, kernels)
        emit(f"table3_energy/project_{name}", 0.0,
             f"sc={r4.sc_energy_nj:.0f}nJ bin={r4.bin_energy_nj:.0f}nJ "
             f"gain={r4.efficiency_gain:.1f}x @4bit")
    return worst


if __name__ == "__main__":
    run()
