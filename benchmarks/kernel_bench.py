"""sc_dot Pallas kernel: structural roofline + interpret-mode validation
timing.

No TPU in this container, so wall-clock here is the interpret-mode Python
evaluator (meaningless for TPU perf).  What IS meaningful — and reported —
is the structural analysis per BlockSpec tile: VMEM working set, bytes moved
per tile, op counts, and the derived arithmetic intensity of the packed
AND+popcount dot product (the quantity that decides compute- vs HBM-bound on
the v5e roofline).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed


def tile_analysis(bm: int, bo: int, K: int, bits: int):
    Wd = (1 << bits) // 32
    in_bytes = bm * K * Wd * 4 + K * bo * Wd * 4
    out_bytes = bm * bo * 4
    vmem = in_bytes + bm * K * bo * 4 + out_bytes   # + counts scratch
    # word-ops: AND + popcount-add per (m, o, k, word); tree adds per (m,o,K)
    word_ops = bm * bo * K * Wd * 2 + bm * bo * K
    intensity = word_ops / (in_bytes + out_bytes)
    return {"vmem_bytes": vmem, "hbm_bytes": in_bytes + out_bytes,
            "word_ops": word_ops, "intensity": intensity}


def layer_traffic(M: int, O: int, K: int, bits: int, bm: int, bo: int,
                  fused_posneg: bool):
    """Whole-layer HBM bytes for the pos/neg split design.

    Separate calls re-read X tiles once per weight bank AND per o-block;
    the fused variant packs both banks on the O axis.
    """
    Wd = (1 << bits) // 32
    O_eff = 2 * O if fused_posneg else O
    n_ob = -(-O_eff // bo)
    x_reads = (-(-M // bm)) * n_ob * (bm * K * Wd * 4)
    w_reads = (-(-M // bm)) * n_ob * (K * min(bo, O_eff) * Wd * 4)
    out = M * O_eff * 4
    total = x_reads + w_reads + out
    if not fused_posneg:
        total *= 2        # pos bank + neg bank as separate kernel calls
    return total


def run(quiet: bool = False):
    # paper's engine: 784 windows x 32 kernels (x2 pos/neg), K=25->32
    for bits in (5, 8):
        for bm, bo in ((128, 64), (256, 64), (512, 64)):
            a = tile_analysis(bm, bo, 32, bits)
            emit(f"kernel/sc_dot_tile_b{bits}_{bm}x{bo}", 0.0,
                 f"vmem={a['vmem_bytes']/2**20:.2f}MiB "
                 f"intensity={a['intensity']:.1f}ops/B "
                 f"fits_vmem={a['vmem_bytes'] < 16*2**20}")
    # fused pos/neg vs separate calls: whole-layer traffic (LeNet shapes)
    for bits in (5, 8):
        sep = layer_traffic(784, 32, 32, bits, 256, 64, fused_posneg=False)
        fus = layer_traffic(784, 32, 32, bits, 256, 64, fused_posneg=True)
        emit(f"kernel/posneg_fusion_b{bits}", 0.0,
             f"separate={sep/2**20:.2f}MiB fused={fus/2**20:.2f}MiB "
             f"saving={100*(1-fus/sep):.0f}%")
    # interpret-mode correctness + (non-TPU) timing of one LeNet-layer call
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    bits = 5
    Wd = (1 << bits) // 32
    x = jnp.asarray(rng.integers(0, 2**32, (784, 32, Wd), dtype=np.uint32))
    w = jnp.asarray(rng.integers(0, 2**32, (32, 64, Wd), dtype=np.uint32))
    out, us = timed(lambda: np.asarray(ops.sc_dot(x, w)), warmup=1, iters=3)
    want = np.asarray(ref.sc_dot(x, w))
    emit("kernel/sc_dot_lenet_layer", us,
         f"interpret_mode exact_match={bool((out == want).all())} "
         f"shape=784x64 (one image, 32k dot-products/s-equiv)")
    return True


if __name__ == "__main__":
    run()
