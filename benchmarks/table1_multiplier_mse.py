"""Paper Table 1: stochastic multiplier MSE per SNG scheme (exhaustive)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import arith, bitstream as bs, sng

PAPER = {  # scheme -> (8-bit, 4-bit)
    "lfsr_shared": (2.78e-3, 2.99e-3),
    "lfsr_pair": (2.57e-4, 1.60e-3),
    "lowdisc": (1.28e-5, 1.01e-3),
    "ramp_lowdisc": (8.66e-6, 7.21e-4),
}


def multiplier_mse(scheme: str, bits: int) -> float:
    """Exhaustive over all (a, b) input pairs, as in the paper."""
    N = 1 << bits
    ca, cb = sng.codes_for_scheme(scheme, bits)
    a = jnp.arange(N)
    SA = sng.generate(a, ca, N)
    SB = sng.generate(a, cb, N)
    prod = np.asarray(bs.popcount(arith.mult(SA[:, None], SB[None])),
                      np.float64)
    av = np.arange(N)[:, None] / N
    bv = np.arange(N)[None, :] / N
    return float(((prod / N - av * bv) ** 2).mean())


def run(quiet: bool = False):
    rows = {}
    for scheme in sng.SCHEMES:
        (m8, us8) = timed(multiplier_mse, scheme, 8, warmup=0, iters=1)
        m4 = multiplier_mse(scheme, 4)
        rows[scheme] = (m8, m4)
        p8, p4 = PAPER[scheme]
        emit(f"table1/{scheme}", us8,
             f"mse8={m8:.3e} (paper {p8:.2e}) mse4={m4:.3e} (paper {p4:.2e})")
    order8 = [rows[s][0] for s in sng.SCHEMES]
    ok = all(a > b for a, b in zip(order8, order8[1:]))
    emit("table1/ordering", 0.0, f"paper_ordering_reproduced={ok}")
    return rows


if __name__ == "__main__":
    run()
