"""Warm-vs-cold admission latency under prefix-hit chunked prefill.

The serving analogue of the paper's economy: work moved out of the
expensive domain is work you stop paying for.  For a fleet of sensors
sharing one system prompt, admission cost should fall with the shared
prefix length — a warm insert gathers the shared blocks from the arena and
folds prefill only over the remaining suffix chunks.

Per shared-block count H the bench builds prompts ``prefix(H*bs) + tail``
and measures, post-compile (median over --repeats):

  cold_ms   insert with no usable prefix in the radix index
  warm_ms   insert after a sibling seeded the same H-block prefix

The acceptance trend (gated by ``benchmarks/check_bench.py`` in CI) is
``warm_ms < cold_ms`` for every H >= 2 — admission latency must actually
drop once a meaningful prefix is shared, at equal prompt length.

Run:  PYTHONPATH=src python benchmarks/prefix_prefill_bench.py
      [--arch stablelm_3b] [--block-size 8] [--tail 8] [--repeats 5]
      [--smoke]
"""
import argparse
import dataclasses
import pathlib
import statistics
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import common  # noqa: E402
import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.serve.gateway.slots import make_adapter  # noqa: E402


def time_insert(ad, mk_prompt, max_new, repeats, want_skip):
    """Median wall-clock of ``insert`` into slot 0 (cleared between runs);
    callers are responsible for having warmed the relevant jit buckets.

    ``mk_prompt`` builds a FRESH prompt per repeat — clearing a slot parks
    its registered blocks in the LRU still indexed, so re-timing the same
    prompt would measure a prefix *hit* from the second repeat on and a
    "cold" series would silently turn warm.  ``want_skip`` asserts each
    repeat really took the intended path (0 = cold, else = tokens skipped).
    """
    times = []
    for _ in range(repeats):
        prompt = mk_prompt()
        t0 = time.perf_counter()
        ad.insert(0, prompt, max_new=max_new)
        times.append((time.perf_counter() - t0) * 1e3)
        skipped = ad.slot_stats(0)["prefill_tokens_skipped"]
        assert skipped == want_skip, (skipped, want_skip)
        ad.clear(0)
    return statistics.median(times)


def run_point(cfg, params, H, bs, tail, max_new, repeats, seed):
    """One (shared_blocks=H) measurement; a fresh adapter per point so the
    radix index holds exactly what the scenario says it holds."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab, size=H * bs, dtype=np.int32)
    mk_tail = lambda: rng.integers(0, cfg.vocab, size=tail, dtype=np.int32)
    P = H * bs + tail
    max_len = -(-(P + max_new) // bs) * bs + bs
    ad = make_adapter(cfg, params, n_slots=2, max_len=max_len,
                      paged=True, block_size=bs,
                      num_blocks=8 * (P + max_new) // bs + 8)

    mk_cold = lambda: np.concatenate(
        [rng.integers(0, cfg.vocab, size=H * bs, dtype=np.int32), mk_tail()])
    mk_warm = lambda: np.concatenate([prefix, mk_tail()])

    # compile every bucket the measurements will touch: a cold fold of this
    # length, then a warm (resumed) fold
    ad.insert(0, mk_cold(), max_new=max_new)
    ad.clear(0)
    ad.insert(0, mk_warm(), max_new=max_new)
    ad.clear(0)

    skipped = H * bs
    # cold: every repeat is a FRESH random prompt, so nothing in the radix
    # index matches and the whole prompt folds
    cold_ms = time_insert(ad, mk_cold, max_new, repeats, want_skip=0)
    # warm: the seeded H-block prefix hits; only the tail chunks fold
    warm_ms = time_insert(ad, mk_warm, max_new, repeats, want_skip=skipped)
    return {
        "shared_blocks": H,
        "prompt_len": P,
        "suffix_len": P - skipped,
        "prefill_tokens_skipped": skipped,
        "cold_ms": cold_ms,
        "warm_ms": warm_ms,
        "speedup": cold_ms / warm_ms if warm_ms else 0.0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--tail", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=2)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--shared", type=int, nargs="+",
                    default=[0, 2, 4, 8])
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: fewer points/repeats, same schema")
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent
                                         / "BENCH_prefix.json"))
    args = ap.parse_args()
    if args.smoke:
        args.shared, args.repeats = [0, 2, 4], 3

    cfg = dataclasses.replace(configs.smoke_config(args.arch),
                              param_dtype="float32")
    params, _ = lm.init(jax.random.key(0), cfg, {})

    results = []
    for H in args.shared:
        rec = run_point(cfg, params, H, args.block_size, args.tail,
                        args.max_new, args.repeats, seed=10 + H)
        results.append(rec)
        common.emit(f"prefix_H{H}", rec["warm_ms"] * 1e3,
                    f"cold={rec['cold_ms']:.2f}ms,"
                    f"skip={rec['prefill_tokens_skipped']}tok")
    payload = {
        "bench": "prefix",
        "arch": args.arch,
        "block_size": args.block_size,
        "results": results,
        "warm_beats_cold": all(r["warm_ms"] < r["cold_ms"]
                               for r in results if r["shared_blocks"] >= 2),
    }
    common.emit_json(args.out, payload)
    if not payload["warm_beats_cold"]:
        print("WARNING: warm admission did not beat cold at >=2 shared "
              "blocks")


if __name__ == "__main__":
    main()
