"""Generate the EXPERIMENTS.md roofline tables from the dry-run artifacts."""
from __future__ import annotations

import json
from pathlib import Path

HERE = Path(__file__).resolve().parent
CUR = HERE / "results" / "dryrun"
BASE = HERE / "results" / "dryrun_baseline"


def _load(d: Path, mesh: str):
    out = {}
    for f in sorted(d.glob(f"*__{mesh}.json")):
        r = json.loads(f.read_text())
        out[(r["arch"], r["shape"])] = r
    return out


def dryrun_table() -> str:
    lines = ["| arch | shape | mesh | ok | compile s | temp GiB/dev | "
             "args GiB/dev | collectives (per-device traffic) |",
             "|---|---|---|---|---|---|---|---|"]
    for mesh in ("single", "multi"):
        for (arch, shape), r in sorted(_load(CUR, mesh).items()):
            if not r["ok"]:
                lines.append(f"| {arch} | {shape} | {mesh} | **FAIL** | | | "
                             f"| {r.get('error', '')[:60]} |")
                continue
            colls = " ".join(
                f"{k.split('-')[0]}-{k.split('-')[1][:1]}:{v['traffic_bytes']/1e9:.1f}GB"
                for k, v in sorted(r["collectives"].items()))
            lines.append(
                f"| {arch} | {shape} | {mesh} | ok | "
                f"{r['compile_s']:.0f} | "
                f"{r['memory']['temp_bytes']/2**30:.1f} | "
                f"{r['memory']['argument_bytes']/2**30:.1f} | {colls} |")
    return "\n".join(lines)


def roofline_table(d: Path = CUR, mesh: str = "single") -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | dominant"
             " | MODEL/HLO flops | fix for dominant term |",
             "|---|---|---|---|---|---|---|---|"]
    fixes = {
        "memory_s": "flash/fused attn + scan-io dtype (done it.1-3); "
                    "Pallas SSM/attn kernels on real TPU",
        "collective_s": "TP comm is bf16 on TPU (CPU f32-upcast artifact "
                        "~2x); overlap RS/AG with compute",
        "compute_s": "selective remat; window KV skipping (done)",
    }
    for (arch, shape), r in sorted(_load(d, mesh).items()):
        if not r["ok"]:
            continue
        rf = r["roofline"]
        dom = max(rf, key=rf.get)
        lines.append(
            f"| {arch} | {shape} | {rf['compute_s']:.3f} | "
            f"{rf['memory_s']:.2f} | {rf['collective_s']:.2f} | "
            f"{dom.replace('_s', '')} | {r['useful_flops_ratio']:.2f} | "
            f"{fixes[dom]} |")
    return "\n".join(lines)


def perf_compare_table(cells) -> str:
    lines = ["| cell | term | paper-faithful baseline | optimized | ratio |",
             "|---|---|---|---|---|"]
    cur = _load(CUR, "single")
    base = _load(BASE, "single")
    for key in cells:
        b, a = base[key], cur[key]
        for t in ("compute_s", "memory_s", "collective_s"):
            lines.append(
                f"| {key[0]} {key[1]} | {t.replace('_s','')} | "
                f"{b['roofline'][t]:.2f}s | {a['roofline'][t]:.2f}s | "
                f"{a['roofline'][t]/max(b['roofline'][t],1e-9):.2f}x |")
    return "\n".join(lines)


if __name__ == "__main__":
    print("## Dry-run\n")
    print(dryrun_table())
    print("\n## Roofline (single-pod baseline)\n")
    print(roofline_table(BASE))
    print("\n## Roofline (optimized)\n")
    print(roofline_table(CUR))
    print("\n## Perf before/after\n")
    print(perf_compare_table([("llama3_405b", "train_4k"),
                              ("hymba_1_5b", "prefill_32k"),
                              ("stablelm_3b", "train_4k")]))
