"""Dense vs paged KV slots at a fixed simulated HBM budget.

Gives both layouts the same KV byte budget, offers the same prompt trace
(mixed lengths, a shared system-prefix cohort), and reports what each
sustains: max concurrent slots, p99 latency, completion, and — paged only —
the pool counters (prefix-hit rate, bytes saved vs dense, evictions).

Dense spends the budget on whole ``max_len`` slots; the pool spends it on
blocks, so short requests stop paying for their worst case and shared
prefixes stop paying at all.  The acceptance bar (checked by
``benchmarks/check_bench.py`` in CI) is ``paged.max_concurrent_slots >
dense.max_concurrent_slots`` at equal bytes.

A second series, ``decode_tick``, races the PR 2 gather tick against the
in-place tick (``engine.decode_step_paged``) at growing chain depth:
tokens/s on frozen steady state plus the dataflow-implied arena-bytes
proxy.  The CI trend gate requires the in-place tick not to lose at
``nb_max >= 4`` and its bytes proxy to stay strictly below the gather
tick's.

A third series, ``sharded_tick`` (``--sharded``), scales the paged stack
over gateway slices (serve/shard/): at a fixed per-device block budget it
compares one device against ``min(8, jax.device_count())`` slices —
aggregate concurrent slots, aggregate tokens/s, routing counters — and
replays a mid-decode cross-slice block migration, reporting its byte cost
and whether the migrated lane's logits stayed bitwise.  Run it under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the ``sharded`` CI
job does) for a real multi-device comparison; the CI gate requires the
8-slice aggregate to beat the single device's concurrency and the
migration to be bitwise.

A fourth series, ``disagg`` (``--disagg``), is the PR 8 acceptance run: at
an equal device budget it drives the same short-decode streams plus a
long-prompt prefill burst through a colocated 8-slice gateway and through
the same slices under ``RolePlan.split(2, 6)`` (2 prefill-only slices
handing finished prompts off to 6 decode-only slices).  The gated quantity
is per-role p99 tick latency: decode-role ticks structurally never contain
admission's chunked prefill folds, so disaggregation must beat the
colocated gateway's all-slice tick p99 under the burst, with every request
completing in both modes and every disagg request arriving via handoff.
``--disagg`` writes its own payload (``BENCH_disagg.json`` unless ``--out``
is given) instead of the kvcache one.

A fifth series, ``cascade`` (``--cascade``), is the PR 9 acceptance run:
lanes sharing a radix prefix decode through the flat in-place tick
(``backend="xla"``, every lane re-attends the whole prefix) and through
the cascade tick (``backend="cascade"``, one multi-query prefix pass per
shared chain + per-lane suffix passes + log-sum-exp merge) over a
lanes x prefix-depth grid.  Gated quantities (check_bench): the grouping
stats must show prefix KV rows O(prefix) — constant in the lane count at
fixed depth, vs the flat tick's O(lanes x prefix) — the cascade bytes
proxy must undercut the flat proxy everywhere, and at the deepest
shared-prefix cell the cascade tick must win wall-clock.  Shallow cells
are reported, not gated: the merge/scatter overhead only amortizes once
the prefix dominates the tick (on CPU the crossover sits near 32 shared
blocks at 8 lanes; a TPU's per-block DMA moves it earlier).  ``--cascade``
writes its own payload (``BENCH_cascade.json`` by default).

Run:  PYTHONPATH=src python benchmarks/kvcache_bench.py
      [--arch stablelm_3b] [--budget-slots 4] [--requests 32] [--smoke]
      [--sharded | --disagg | --cascade]
"""
import argparse
import dataclasses
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import common  # noqa: E402
import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.serve import engine  # noqa: E402
from repro.serve.gateway.gateway import PromptGateway  # noqa: E402
from repro.serve.gateway.sensors import Arrival  # noqa: E402
from repro.serve.gateway.slots import ContinuousBatcher, make_adapter  # noqa: E402


def kv_bytes_per_slot(cfg, max_len: int) -> int:
    """Sequence-axis cache bytes of one dense max_len slot."""
    arena = engine.init_paged_arena(cfg, 1, max_len, abstract=True)
    return sum(a.dtype.itemsize * int(np.prod(a.shape))
               for a in arena.values())


def make_trace(cfg, n_requests: int, max_len: int, n_new: int, seed: int = 0):
    """Short prompts, half sharing a common system prefix, arriving in one
    burst so concurrency is limited by memory, not by the arrival process."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab, size=8, dtype=np.int32)
    arrivals = []
    for i in range(n_requests):
        tail = rng.integers(0, cfg.vocab, size=int(rng.integers(2, 7)),
                            dtype=np.int32)
        prompt = np.concatenate([prefix, tail]) if i % 2 == 0 else \
            np.concatenate([rng.integers(0, cfg.vocab, size=4,
                                         dtype=np.int32), tail])
        arrivals.append(Arrival(uid=i, t=0.0005 * i, endpoint=i % 8,
                                kind="prompt", payload=prompt))
    return arrivals


def run_layout(layout: str, cfg, params, arrivals, *, max_len: int,
               n_new: int, budget_bytes: int, block_size: int,
               warm_lens: tuple) -> dict:
    slot_bytes = kv_bytes_per_slot(cfg, max_len)
    block_bytes = kv_bytes_per_slot(cfg, block_size)
    if layout == "dense":
        n_slots = max(1, budget_bytes // slot_bytes)
        adapter = make_adapter(cfg, params, n_slots=n_slots, max_len=max_len)
    else:
        num_blocks = max(2, budget_bytes // block_bytes)   # incl. trash blk
        n_slots = min(len(arrivals), num_blocks - 1)
        adapter = make_adapter(cfg, params, n_slots=n_slots, max_len=max_len,
                               paged=True, block_size=block_size,
                               num_blocks=num_blocks)
    batcher = ContinuousBatcher(adapter)
    gw = PromptGateway(batcher, max_new_tokens=n_new,
                       max_queue=len(arrivals))
    gw.warmup(warm_lens, cfg.vocab)
    batcher.peak_active = 0                       # don't count warmup
    t0 = time.perf_counter()
    tel = gw.run(arrivals)
    wall = time.perf_counter() - t0
    tel.assert_conserved()
    rep = tel.report(max(wall, 1e-9), kind="prompt")
    out = {
        "layout": layout,
        "budget_bytes": budget_bytes,
        "kv_bytes_allocated": (n_slots * slot_bytes if layout == "dense"
                               else (num_blocks - 1) * block_bytes),
        "n_slots": n_slots,
        "max_concurrent_slots": batcher.peak_active,
        "completed": rep["completed"],
        "dropped": rep["dropped"],
        "p50_latency_ms": rep.get("p50_latency_ms", 0.0),
        "p99_latency_ms": rep.get("p99_latency_ms", 0.0),
        "j_per_inference": rep.get("j_per_inference", 0.0),
    }
    if layout == "paged":
        out["block_size"] = block_size
        out["pool"] = tel.pool
    return out


def decode_tick_series(cfg, params, *, block_size: int, n_slots: int,
                       nb_list: tuple, iters: int) -> list[dict]:
    """Gather tick vs in-place tick at growing chain depth.

    Every slot holds a chain spanning all ``nb_max`` blocks, so the gather
    tick pays its full O(slots * nb_max * bs) per-key materialization while
    the in-place tick reads the same chains through the block tables.
    Reports steady-state decode throughput (the jitted tick re-invoked on
    frozen state — fixed shapes, host-synced each call) and the
    dataflow-implied arena-bytes proxy from ``tick_bytes_proxy`` (what the
    TPU kernel's per-block DMA would stream; the XLA paths on CPU fuse
    their reads, so wall time is the honest metric there).
    """
    rng = np.random.default_rng(7)
    out = []
    for nb in nb_list:
        max_len = nb * block_size
        prompt = rng.integers(0, cfg.vocab, size=max_len - 2,
                              dtype=np.int32)
        rec = {"nb_max": nb, "block_size": block_size, "n_slots": n_slots}
        for mode in ("gather", "inplace"):
            ad = make_adapter(cfg, params, n_slots=n_slots, max_len=max_len,
                              paged=True, block_size=block_size,
                              chunked=False, inplace=(mode == "inplace"))
            for slot in range(n_slots):
                ad.insert(slot, prompt, max_new=2)
            rec[f"{mode}_bytes_proxy"] = ad.tick_bytes_proxy()[mode]
            toks = np.zeros(n_slots, np.int32)
            active = np.ones(n_slots, bool)
            ad.decode(toks, active)                    # compile + warm
            # best-of-3 batches: min is the noise-robust estimator, so the
            # CI trend gate measures the ticks, not the runner's scheduler
            dt = np.inf
            for _ in range(3):
                t0 = time.perf_counter()
                for _ in range(iters):
                    ad.decode(toks, active)            # host-synced call
                dt = min(dt, time.perf_counter() - t0)
            rec[f"{mode}_tok_s"] = n_slots * iters / max(dt, 1e-9)
        rec["speedup"] = rec["inplace_tok_s"] / max(rec["gather_tok_s"],
                                                    1e-9)
        common.emit(f"decode_tick_nb{nb}",
                    1e6 * n_slots / rec["inplace_tok_s"],
                    f"{rec['speedup']:.2f}x_vs_gather")
        out.append(rec)
    return out


def sharded_tick_series(cfg, params, *, block_size: int) -> dict:
    """One device vs N single-device slices at a fixed per-device budget.

    The acceptance quantity is *aggregate concurrent slots*: each slice
    brings its own block pool, so the fleet's admissible working set
    scales with the slice count while no device holds more than
    ``budget`` blocks.  Wall-clock aggregate tokens/s is reported but not
    gated (on CPU the virtual devices share the same cores).  The series
    also replays a mid-decode migration between two slices and pins the
    migrated lane's logits bitwise against a stay-put oracle.
    """
    from repro.serve.gateway.slots import Request
    from repro.serve.shard import (ShardedPromptGateway, build_slices,
                                   migrate_slot)
    from repro.dist.sharding import slice_meshes
    from repro.launch import mesh as mesh_lib

    n_slices = min(8, jax.device_count())
    budget = 9                                  # 8 usable blocks per device
    max_len, max_new, n_req = 16, 4, 4 * n_slices
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab, size=6, dtype=np.int32)
               for _ in range(n_req)]
    arrivals = [Arrival(uid=i, t=0.0, endpoint=0, kind="prompt", payload=p)
                for i, p in enumerate(prompts)]
    rec = {"n_devices": jax.device_count(), "n_slices": n_slices,
           "budget_blocks_per_device": budget, "block_size": block_size}

    # single device, same per-device budget
    single = make_adapter(cfg, params, n_slots=8, max_len=max_len,
                          paged=True, block_size=block_size,
                          num_blocks=budget)
    sb = ContinuousBatcher(single)
    for i, p in enumerate(prompts):
        sb.submit(Request(uid=i, prompt=p, max_new_tokens=max_new))
    t0 = time.perf_counter()
    done = sb.run()
    dt = time.perf_counter() - t0
    rec["single_slots"] = sb.peak_active
    rec["single_tok_s"] = sum(len(r.generated) for r in done) / max(dt, 1e-9)

    # N slices, each with the same per-device budget
    mesh = mesh_lib.make_serving_mesh(n_slices, model=1)
    slices = build_slices(cfg, params, mesh, n_slots=8, max_len=max_len,
                          block_size=block_size, num_blocks=budget)
    gw = ShardedPromptGateway(slices, max_new_tokens=max_new,
                              max_queue=4 * n_req)
    t0 = time.perf_counter()
    tel = gw.run(arrivals)
    dt = time.perf_counter() - t0
    rep = tel.report(max(dt, 1e-9), kind="prompt")
    rec["sharded_slots"] = gw.peak_active_total()
    rec["sharded_tok_s"] = rep["completed"] * max_new / max(dt, 1e-9)
    rec["sharded_gt_single"] = rec["sharded_slots"] > rec["single_slots"]
    rec["routing"] = dict(gw.routing)

    # mid-decode migration: bytes moved + bitwise continuation
    subs = slice_meshes(mesh)
    mk = lambda m=None: make_adapter(cfg, params, n_slots=2, max_len=max_len,
                                     paged=True, block_size=block_size,
                                     mesh=m)
    oracle, A, B = mk(), mk(subs[0]), mk(subs[min(1, len(subs) - 1)])
    ps = [rng.integers(0, cfg.vocab, size=s, dtype=np.int32) for s in (5, 9)]
    active = np.asarray([True, True])
    for slot, p in enumerate(ps):
        oracle.insert(slot, p, max_new=7)
        A.insert(slot, p, max_new=7)
    for _ in range(3):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        oracle.decode(forced, active)
        A.decode(forced, active)
    receipt = migrate_slot(A, 1, B, 1, ps[1])
    bitwise = True
    lane1 = np.asarray([False, True])
    for _ in range(3):
        forced = rng.integers(0, cfg.vocab, size=2).astype(np.int32)
        oracle.decode(forced, active)
        B.decode(forced, lane1)
        bitwise &= bool(np.array_equal(np.asarray(oracle.last_logits)[1],
                                       np.asarray(B.last_logits)[1]))
    rec["migration_bytes"] = int(receipt.bytes_moved)
    rec["migration_blocks"] = int(receipt.blocks_moved)
    rec["migration_bitwise"] = bitwise
    common.emit("sharded_tick", 1e6 / max(rec["sharded_tok_s"], 1e-9),
                f"{rec['sharded_slots']}v{rec['single_slots']}slots,"
                f"{n_slices}slices,mig{'OK' if bitwise else 'DRIFT'}")
    return rec


def disagg_series(cfg, params, *, block_size: int) -> dict:
    """Colocated vs disaggregated gateway under a prefill burst.

    Mirrors the tests/test_disagg.py head-of-line bar: 8 single-device
    slices (re-using devices modulo the host's count; run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a real
    8-device comparison), 12 short decode-stream prompts plus 8 long
    burst prompts, equal block budget per slice in both modes.  The
    colocated gateway's ticks absorb admission's chunked folds; the
    disaggregated gateway's decode-role ticks never do, which is exactly
    the between-token latency a decode-bound serving tier sells.
    """
    from jax.sharding import Mesh
    from repro.serve.shard import (RolePlan, ShardedPromptGateway,
                                   build_slices)

    n_slices, max_len, max_new = 8, 36, 6
    plan = RolePlan.split(2, 6)
    rng = np.random.default_rng(61)
    short = [rng.integers(0, cfg.vocab, size=5, dtype=np.int32)
             for _ in range(12)]
    burst = [rng.integers(0, cfg.vocab, size=28, dtype=np.int32)
             for _ in range(8)]
    arrivals = [Arrival(uid=i, t=0.0, endpoint=0, kind="prompt", payload=p)
                for i, p in enumerate(short)]
    arrivals += [Arrival(uid=100 + i, t=0.0, endpoint=0, kind="prompt",
                         payload=p) for i, p in enumerate(burst)]
    devs = jax.devices()

    def run(roles):
        meshes = [Mesh(np.asarray([devs[i % len(devs)]]), ("model",))
                  for i in range(n_slices)]
        slices = build_slices(cfg, params, meshes, n_slots=2,
                              max_len=max_len, block_size=block_size)
        gw = ShardedPromptGateway(slices, max_new_tokens=max_new,
                                  max_queue=4 * len(arrivals), roles=roles,
                                  auto_rebalance=False)
        gw.warmup((4, 8))
        t0 = time.perf_counter()
        tel = gw.run(list(arrivals))
        wall = time.perf_counter() - t0
        return gw, tel.report(max(wall, 1e-9), kind="prompt")

    colo, crep = run(None)
    dis, drep = run(plan)
    results = [
        {"mode": "colocated", "completed": crep["completed"],
         "tick_p99_ms": colo.tick_latency_ms("all"),
         "prefill_tick_p99_ms": 0.0,
         "handoffs": 0, "handoff_bytes": 0,
         "routing": dict(colo.routing)},
        {"mode": "disagg", "completed": drep["completed"],
         "tick_p99_ms": dis.tick_latency_ms("decode"),
         "prefill_tick_p99_ms": dis.tick_latency_ms("prefill"),
         "handoffs": dis.handoffs, "handoff_bytes": dis.handoff_bytes,
         "routing": dict(dis.routing)},
    ]
    c_p99, d_p99 = results[0]["tick_p99_ms"], results[1]["tick_p99_ms"]
    beats = 0.0 < d_p99 < c_p99
    common.emit("disagg_tick", d_p99 * 1e3,
                f"{d_p99:.2f}v{c_p99:.2f}ms,"
                f"{dis.handoffs}handoffs,"
                f"{'WIN' if beats else 'LOSS'}")
    return {
        "bench": "disagg",
        "n_devices": jax.device_count(),
        "n_slices": n_slices,
        "roles": {"prefill": list(plan.prefill),
                  "decode": list(plan.decode)},
        "n_requests": len(arrivals),
        "block_size": block_size,
        "results": results,
        "disagg_beats_colocated": beats,
    }


def cascade_series(cfg, params, *, block_size: int, smoke: bool) -> dict:
    """Flat in-place tick vs cascade tick over shared-prefix lane groups.

    Each cell inserts ``lanes`` prompts that share a ``prefix_blocks``-deep
    radix prefix (the pool dedups it to one refcounted chain) plus a
    16-token distinct tail, then times *live* decode ticks — live, because
    frozen at-capacity lanes drop out of grouping and the cascade tick
    would degrade to the flat executable, timing nothing.  The tail length
    and tick count are chosen so every lane stays inside one pow2 suffix
    bucket: the whole run re-invokes a single jitted executable per
    backend.  Alongside wall time the cell records the grouping stats
    (``cascade_stats``) and the dataflow bytes proxy; those carry the
    structural O(prefix) claim, which holds regardless of the platform's
    wall-clock crossover.
    """
    lanes_list = (2, 4, 8)
    prefix_list = (8, 32) if smoke else (8, 32, 64)
    iters, tail = 4, 16
    rng = np.random.default_rng(7)
    results = []
    for nbp in prefix_list:
        shared = rng.integers(1, cfg.vocab, size=nbp * block_size,
                              dtype=np.int32)
        for lanes in lanes_list:
            rec = {"lanes": lanes, "prefix_blocks": nbp,
                   "prefix_tokens": nbp * block_size,
                   "block_size": block_size}
            for mode, backend in (("inplace", "xla"),
                                  ("cascade", "cascade")):
                ad = make_adapter(cfg, params, n_slots=lanes,
                                  max_len=nbp * block_size + 48,
                                  paged=True, block_size=block_size,
                                  backend=backend)
                for slot in range(lanes):
                    suffix = rng.integers(1, cfg.vocab, size=tail,
                                          dtype=np.int32)
                    ad.insert(slot, np.concatenate([shared, suffix]),
                              max_new=24)
                toks = np.zeros(lanes, np.int32)
                active = np.ones(lanes, bool)
                for _ in range(2):        # compile + settle suffix bucket
                    ad.decode(toks, active)
                if backend == "cascade":
                    if ad.last_groups < 1:
                        raise SystemExit(
                            f"cascade cell lanes={lanes} nbp={nbp}: prefix "
                            f"sharing did not form a group — the series "
                            f"would time the flat degrade path")
                    rec.update(ad.cascade_stats())
                rec[f"{mode}_bytes_proxy"] = ad.tick_bytes_proxy()[mode]
                dt = np.inf
                for _ in range(3):        # best-of-3: see decode_tick
                    t0 = time.perf_counter()
                    for _ in range(iters):
                        ad.decode(toks, active)   # live, host-synced
                    dt = min(dt, time.perf_counter() - t0)
                rec[f"{mode}_tok_s"] = lanes * iters / max(dt, 1e-9)
            rec["speedup"] = rec["cascade_tok_s"] / max(
                rec["inplace_tok_s"], 1e-9)
            common.emit(f"cascade_P{nbp}_L{lanes}",
                        1e6 * lanes / rec["cascade_tok_s"],
                        f"{rec['speedup']:.2f}x_vs_flat,"
                        f"{rec['prefix_rows']}v{rec['prefix_rows_flat']}"
                        f"prefix_rows")
            results.append(rec)
    deep = max(results,
               key=lambda r: (r["prefix_blocks"], r["lanes"]))
    return {
        "bench": "cascade",
        "block_size": block_size,
        "results": results,
        "cascade_beats_flat_deep": deep["speedup"] >= 1.0,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--budget-slots", type=int, default=4,
                    help="HBM budget expressed in dense max_len slots")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: minimal sizes, same schema")
    ap.add_argument("--sharded", action="store_true",
                    help="add the sharded_tick series (1 vs N virtual "
                         "devices; run under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--disagg", action="store_true",
                    help="run the disaggregated prefill/decode series "
                         "instead of the kvcache bench and write its own "
                         "payload (BENCH_disagg.json by default); run "
                         "under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8")
    ap.add_argument("--cascade", action="store_true",
                    help="run the shared-prefix cascade-vs-flat decode "
                         "tick series instead of the kvcache bench and "
                         "write its own payload (BENCH_cascade.json by "
                         "default)")
    ap.add_argument("--expect-devices", type=int, default=0,
                    help="fail fast unless jax sees at least this many "
                         "devices (the sharded CI job passes 8 so a "
                         "silently ineffective XLA_FLAGS cannot degrade "
                         "the series to a vacuous 1-slice run)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    if not args.out:
        args.out = str(pathlib.Path(__file__).parent /
                       ("BENCH_disagg.json" if args.disagg
                        else "BENCH_cascade.json" if args.cascade
                        else "BENCH_kvcache.json"))
    if args.smoke:
        args.requests, args.max_len, args.budget_slots = 8, 32, 2
    if args.expect_devices and jax.device_count() < args.expect_devices:
        raise SystemExit(
            f"expected >= {args.expect_devices} devices, jax sees "
            f"{jax.device_count()} — is XLA_FLAGS="
            f"--xla_force_host_platform_device_count set?")

    cfg = dataclasses.replace(configs.smoke_config(args.arch),
                              param_dtype="float32")
    params, _ = lm.init(jax.random.key(0), cfg, {})
    if args.disagg:
        payload = disagg_series(cfg, params, block_size=args.block_size)
        payload["arch"] = args.arch
        common.emit_json(args.out, payload)
        if not payload["disagg_beats_colocated"]:
            print("WARNING: disagg decode ticks did not beat the "
                  "colocated gateway under the prefill burst")
        return
    if args.cascade:
        payload = cascade_series(cfg, params, block_size=args.block_size,
                                 smoke=args.smoke)
        payload["arch"] = args.arch
        common.emit_json(args.out, payload)
        if not payload["cascade_beats_flat_deep"]:
            print("WARNING: cascade tick did not beat the flat tick at "
                  "the deepest shared-prefix cell")
        return
    arrivals = make_trace(cfg, args.requests, args.max_len, args.max_new)
    warm_lens = tuple(sorted({len(a.payload) for a in arrivals}))
    budget_bytes = args.budget_slots * kv_bytes_per_slot(cfg, args.max_len)

    results = []
    for layout in ("dense", "paged"):
        rec = run_layout(layout, cfg, params, arrivals,
                         max_len=args.max_len, n_new=args.max_new,
                         budget_bytes=budget_bytes,
                         block_size=args.block_size, warm_lens=warm_lens)
        results.append(rec)
        common.emit(
            f"kvcache_{layout}", rec["p99_latency_ms"] * 1e3,
            f"{rec['max_concurrent_slots']}slots,"
            f"{rec['completed']}done,{rec['dropped']}drop")
    dense, paged = results
    # n_slots large enough that the per-call compute dominates dispatch
    # overhead — at 4 slots the smoke-size ticks are overhead-bound and
    # the gather-vs-inplace ratio loses its discriminating power
    ticks = decode_tick_series(
        cfg, params, block_size=args.block_size,
        n_slots=12 if args.smoke else 16, nb_list=(2, 4, 8),
        iters=25 if args.smoke else 60)
    payload = {
        "bench": "kvcache",
        "arch": args.arch,
        "budget_bytes": budget_bytes,
        "max_len": args.max_len,
        "block_size": args.block_size,
        "results": results,
        "paged_gt_dense": (paged["max_concurrent_slots"]
                           > dense["max_concurrent_slots"]),
        "decode_tick": ticks,
    }
    if args.sharded:
        payload["sharded_tick"] = sharded_tick_series(
            cfg, params, block_size=args.block_size)
    common.emit_json(args.out, payload)
    if not payload["paged_gt_dense"]:
        print("WARNING: paged did not beat dense concurrency at this budget")


if __name__ == "__main__":
    main()
