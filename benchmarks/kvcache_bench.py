"""Dense vs paged KV slots at a fixed simulated HBM budget.

Gives both layouts the same KV byte budget, offers the same prompt trace
(mixed lengths, a shared system-prefix cohort), and reports what each
sustains: max concurrent slots, p99 latency, completion, and — paged only —
the pool counters (prefix-hit rate, bytes saved vs dense, evictions).

Dense spends the budget on whole ``max_len`` slots; the pool spends it on
blocks, so short requests stop paying for their worst case and shared
prefixes stop paying at all.  The acceptance bar (checked by
``benchmarks/check_bench.py`` in CI) is ``paged.max_concurrent_slots >
dense.max_concurrent_slots`` at equal bytes.

Run:  PYTHONPATH=src python benchmarks/kvcache_bench.py
      [--arch stablelm_3b] [--budget-slots 4] [--requests 32] [--smoke]
"""
import argparse
import dataclasses
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import common  # noqa: E402
import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.serve import engine  # noqa: E402
from repro.serve.gateway.gateway import PromptGateway  # noqa: E402
from repro.serve.gateway.sensors import Arrival  # noqa: E402
from repro.serve.gateway.slots import ContinuousBatcher, make_adapter  # noqa: E402


def kv_bytes_per_slot(cfg, max_len: int) -> int:
    """Sequence-axis cache bytes of one dense max_len slot."""
    arena = engine.init_paged_arena(cfg, 1, max_len, abstract=True)
    return sum(a.dtype.itemsize * int(np.prod(a.shape[1:]))
               for a in arena.values())


def make_trace(cfg, n_requests: int, max_len: int, n_new: int, seed: int = 0):
    """Short prompts, half sharing a common system prefix, arriving in one
    burst so concurrency is limited by memory, not by the arrival process."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab, size=8, dtype=np.int32)
    arrivals = []
    for i in range(n_requests):
        tail = rng.integers(0, cfg.vocab, size=int(rng.integers(2, 7)),
                            dtype=np.int32)
        prompt = np.concatenate([prefix, tail]) if i % 2 == 0 else \
            np.concatenate([rng.integers(0, cfg.vocab, size=4,
                                         dtype=np.int32), tail])
        arrivals.append(Arrival(uid=i, t=0.0005 * i, endpoint=i % 8,
                                kind="prompt", payload=prompt))
    return arrivals


def run_layout(layout: str, cfg, params, arrivals, *, max_len: int,
               n_new: int, budget_bytes: int, block_size: int,
               warm_lens: tuple) -> dict:
    slot_bytes = kv_bytes_per_slot(cfg, max_len)
    block_bytes = kv_bytes_per_slot(cfg, block_size)
    if layout == "dense":
        n_slots = max(1, budget_bytes // slot_bytes)
        adapter = make_adapter(cfg, params, n_slots=n_slots, max_len=max_len)
    else:
        num_blocks = max(2, budget_bytes // block_bytes)   # incl. trash blk
        n_slots = min(len(arrivals), num_blocks - 1)
        adapter = make_adapter(cfg, params, n_slots=n_slots, max_len=max_len,
                               paged=True, block_size=block_size,
                               num_blocks=num_blocks)
    batcher = ContinuousBatcher(adapter)
    gw = PromptGateway(batcher, max_new_tokens=n_new,
                       max_queue=len(arrivals))
    gw.warmup(warm_lens, cfg.vocab)
    batcher.peak_active = 0                       # don't count warmup
    t0 = time.perf_counter()
    tel = gw.run(arrivals)
    wall = time.perf_counter() - t0
    tel.assert_conserved()
    rep = tel.report(max(wall, 1e-9), kind="prompt")
    out = {
        "layout": layout,
        "budget_bytes": budget_bytes,
        "kv_bytes_allocated": (n_slots * slot_bytes if layout == "dense"
                               else (num_blocks - 1) * block_bytes),
        "n_slots": n_slots,
        "max_concurrent_slots": batcher.peak_active,
        "completed": rep["completed"],
        "dropped": rep["dropped"],
        "p50_latency_ms": rep.get("p50_latency_ms", 0.0),
        "p99_latency_ms": rep.get("p99_latency_ms", 0.0),
        "j_per_inference": rep.get("j_per_inference", 0.0),
    }
    if layout == "paged":
        out["block_size"] = block_size
        out["pool"] = tel.pool
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--budget-slots", type=int, default=4,
                    help="HBM budget expressed in dense max_len slots")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: minimal sizes, same schema")
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent
                                         / "BENCH_kvcache.json"))
    args = ap.parse_args()
    if args.smoke:
        args.requests, args.max_len, args.budget_slots = 8, 32, 2

    cfg = dataclasses.replace(configs.smoke_config(args.arch),
                              param_dtype="float32")
    params, _ = lm.init(jax.random.key(0), cfg, {})
    arrivals = make_trace(cfg, args.requests, args.max_len, args.max_new)
    warm_lens = tuple(sorted({len(a.payload) for a in arrivals}))
    budget_bytes = args.budget_slots * kv_bytes_per_slot(cfg, args.max_len)

    results = []
    for layout in ("dense", "paged"):
        rec = run_layout(layout, cfg, params, arrivals,
                         max_len=args.max_len, n_new=args.max_new,
                         budget_bytes=budget_bytes,
                         block_size=args.block_size, warm_lens=warm_lens)
        results.append(rec)
        common.emit(
            f"kvcache_{layout}", rec["p99_latency_ms"] * 1e3,
            f"{rec['max_concurrent_slots']}slots,"
            f"{rec['completed']}done,{rec['dropped']}drop")
    dense, paged = results
    payload = {
        "bench": "kvcache",
        "arch": args.arch,
        "budget_bytes": budget_bytes,
        "max_len": args.max_len,
        "block_size": args.block_size,
        "results": results,
        "paged_gt_dense": (paged["max_concurrent_slots"]
                           > dense["max_concurrent_slots"]),
    }
    common.emit_json(args.out, payload)
    if not payload["paged_gt_dense"]:
        print("WARNING: paged did not beat dense concurrency at this budget")


if __name__ == "__main__":
    main()
