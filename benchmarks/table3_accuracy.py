"""Paper Table 3 (misclassification block): binary vs old-SC vs new-SC hybrid
designs across precisions, with binary-tail retraining.

Offline note: runs on the procedural synthetic digit set (MNIST stand-in) —
absolute accuracies differ from the paper's MNIST numbers; the validated
claims are relative (see EXPERIMENTS.md): retraining recovers the hybrid to
within a small gap of the binary design at >=4 bits, the new adder beats the
old SC design, and 2-bit collapses.

Fast mode (default, used by benchmarks.run): bits {2,4,8}, reduced data.
Full mode (--full): bits 2..8, more data/steps, old-SC at 8-bit included.
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import hybrid
from repro.core.sc_layer import SCConfig
from repro.data import mnist_synth
from repro.models import lenet
from repro.train import optim

PAPER_MISCLASS = {  # bits: (binary, old_sc, this_work) %
    8: (0.89, 2.22, 0.94), 7: (0.86, 3.91, 0.99), 6: (0.89, 1.30, 1.04),
    5: (0.74, 1.55, 1.12), 4: (0.79, 1.63, 1.04), 3: (0.79, 2.71, 2.20),
    2: (1.30, 4.89, 43.82),
}


@functools.lru_cache(maxsize=2)
def _pretrained(n_train: int, n_test: int, steps: int):
    cfg = lenet.LeNetConfig()
    xtr, ytr, xte, yte = mnist_synth.dataset(n_train, n_test)
    params = lenet.init(jax.random.key(0), cfg)
    opt_cfg = optim.AdamWConfig(lr=1e-3)
    opt = optim.init(params, opt_cfg)
    key = jax.random.key(1)
    for xb, yb in mnist_synth.batches(xtr, ytr, 64, 0, steps):
        key, sub = jax.random.split(key)
        params, opt, _ = hybrid.float_train_step(
            params, opt, jnp.asarray(xb), jnp.asarray(yb), sub, cfg, opt_cfg)
    return cfg, params, (xtr, ytr, xte, yte)


def eval_design(cfg, params, data, hcfg, retrain_steps, n_retrain):
    xtr, ytr, xte, yte = data
    feats_tr = hybrid.cache_first_layer(params, xtr[:n_retrain], hcfg)
    feats_te = hybrid.cache_first_layer(params, xte, hcfg)
    p2 = hybrid.retrain_tail(params, feats_tr, ytr[:n_retrain], cfg,
                             steps=retrain_steps, batch=128)
    return 1.0 - hybrid.evaluate_cached(p2, feats_te, yte, cfg)


def run(full: bool = False):
    n_train, n_test, steps = (8000, 2000, 600) if full else (3000, 800, 250)
    retrain_steps, n_retrain = (400, 6000) if full else (150, 2500)
    bits_list = list(range(2, 9)) if full else [2, 4, 8]
    (out, us) = timed(_pretrained, n_train, n_test, steps, warmup=0, iters=1)
    cfg, params, data = out
    float_acc = hybrid.evaluate(params, data[2], data[3], cfg,
                                hybrid.HybridConfig(mode="float"))
    emit("table3_acc/float_baseline", us,
         f"misclass={100*(1-float_acc):.2f}%")

    results = {}
    for bits in bits_list:
        row = {}
        (row["binary"], us_b) = timed(
            eval_design, cfg, params, data,
            hybrid.HybridConfig(mode="binary", bits=bits),
            retrain_steps, n_retrain, warmup=0, iters=1)
        (row["new_sc"], us_n) = timed(
            eval_design, cfg, params, data,
            hybrid.HybridConfig(mode="sc", sc=SCConfig(bits=bits,
                                                       adder="tff")),
            retrain_steps, n_retrain, warmup=0, iters=1)
        # old SC (LFSR-pair SNGs + MUX tree) only at stream level — heavier;
        # run at <=4 bits in fast mode
        if full or bits <= 4:
            (row["old_sc"], us_o) = timed(
                eval_design, cfg, params, data,
                hybrid.HybridConfig(
                    mode="sc",
                    sc=SCConfig(bits=bits, scheme="lfsr_pair", adder="mux"),
                    sc_impl="streams"),
                retrain_steps, n_retrain, warmup=0, iters=1)
        results[bits] = row
        pb, po, pn = PAPER_MISCLASS[bits]
        emit(f"table3_acc/{bits}bit", us_b + us_n,
             " ".join(f"{k}={100*v:.2f}%" for k, v in row.items())
             + f" | paper: bin={pb}% old={po}% new={pn}%")

    # relative claims
    b4 = results.get(4, {})
    if "binary" in b4 and "new_sc" in b4:
        gap4 = (b4["new_sc"] - b4["binary"]) * 100
        emit("table3_acc/claim_gap_4bit", 0.0,
             f"hybrid_minus_binary={gap4:+.2f}pp (paper +0.25pp)")
    if "old_sc" in b4:
        emit("table3_acc/claim_new_beats_old_4bit", 0.0,
             f"old-new={100*(b4['old_sc']-b4['new_sc']):+.2f}pp (paper +0.59pp)")
    if 2 in results and 4 in results:
        emit("table3_acc/claim_2bit_collapse", 0.0,
             f"err2={100*results[2]['new_sc']:.1f}% >> "
             f"err4={100*results[4]['new_sc']:.1f}% "
             f"(paper 43.82% vs 1.04%)")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(full=ap.parse_args().full)
