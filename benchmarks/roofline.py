"""Roofline table from the dry-run artifacts (benchmarks/results/dryrun).

Per (arch x shape x mesh): the three terms in seconds, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs usefulness, per-device memory; see EXPERIMENTS.md for
the narrative.  Also emits the per-cell "what would move the dominant term"
hint from a rule table.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import emit

RESULTS = Path(__file__).resolve().parent / "results" / "dryrun"

HINTS = {
    ("memory_s", "train"): "flash-attention custom-VJP (kill S^2 residual "
                           "traffic) + bf16 probs",
    ("memory_s", "prefill"): "flash-attention fwd fusion; window-aware KV "
                             "chunk skipping where sliding",
    ("memory_s", "decode"): "fuse per-layer cache update+attend; quantize KV",
    ("collective_s", "train"): "overlap reduce-scatter with bwd compute; "
                               "int8 grad compression on the pod axis",
    ("collective_s", "decode"): "shrink TP degree for small models / "
                                "duplicate small weights",
    ("compute_s", "train"): "selective remat (dots-only) to cut recompute",
}


def load(mesh: str = "single"):
    rows = []
    for f in sorted(RESULTS.glob(f"*__{mesh}.json")):
        rows.append(json.loads(f.read_text()))
    return rows


def run(mesh: str = "single", quiet: bool = False):
    rows = load(mesh)
    n_ok = 0
    for r in rows:
        if not r["ok"]:
            emit(f"roofline/{r['arch']}/{r['shape']}/{mesh}", 0.0,
                 f"FAILED {r.get('error', '')[:80]}")
            continue
        n_ok += 1
        rf = r["roofline"]
        dom = max(rf, key=rf.get)
        kind = ("train" if r["shape"].startswith("train") else
                "prefill" if r["shape"].startswith("prefill") else "decode")
        emit(f"roofline/{r['arch']}/{r['shape']}/{mesh}", 0.0,
             f"compute={rf['compute_s']:.3f}s memory={rf['memory_s']:.3f}s "
             f"collective={rf['collective_s']:.3f}s dom={dom} "
             f"useful={r['useful_flops_ratio']:.2f} "
             f"temp={r['memory']['temp_bytes']/2**30:.1f}GiB "
             f"fix='{HINTS.get((dom, kind), 'n/a')}'")
    emit(f"roofline/summary/{mesh}", 0.0, f"cells_ok={n_ok}/{len(rows)}")
    return rows


if __name__ == "__main__":
    run("single")
    run("multi")
