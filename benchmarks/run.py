"""Benchmark aggregator — one section per paper table + kernel + roofline.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks.common.emit).
Fast by default (~5-10 min on CPU); per-table modules support --full runs.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bipolar_vs_split, kernel_bench, roofline,
                            table1_multiplier_mse, table2_adder_mse,
                            table3_accuracy, table3_energy)
    print("name,us_per_call,derived")
    sections = [
        ("table1", table1_multiplier_mse.run),
        ("table2", table2_adder_mse.run),
        ("table3_energy", table3_energy.run),
        ("kernel", kernel_bench.run),
        ("bipolar", bipolar_vs_split.run),
        ("table3_accuracy", table3_accuracy.run),
        ("roofline_single", lambda: roofline.run("single")),
        ("roofline_multi", lambda: roofline.run("multi")),
    ]
    failed = []
    for name, fn in sections:
        try:
            fn()
        except Exception:  # noqa: BLE001 — keep the suite running
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED_SECTIONS,{len(failed)},{';'.join(failed)}")
        sys.exit(1)
    print("all_sections,0,ok")


if __name__ == "__main__":
    main()
