"""Quantify §IV.B: bipolar vs split-unipolar error near the sign activation's
decision point (the reason the paper splits weights into pos/neg banks)."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import bipolar


def run(quiet: bool = False):
    for bits in (4, 6, 8):
        (pair, us) = timed(bipolar.decision_point_errors, bits, 512,
                           warmup=0, iters=1)
        err_b, err_s = pair
        emit(f"bipolar/decision_point_{bits}bit", us,
             f"bipolar_err={err_b.mean():.4f} split_err={err_s.mean():.4f} "
             f"split_advantage={err_b.mean()/max(err_s.mean(),1e-9):.2f}x")
    return True


if __name__ == "__main__":
    run()
