"""Paper Table 2: MUX-adder configurations vs the new TFF adder."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import arith, bitstream as bs, sng

PAPER = {  # config -> (8-bit, 4-bit)
    "old_random_lfsr": (3.24e-4, 5.55e-3),
    "old_random_tff": (5.49e-4, 5.49e-3),
    "old_lfsr_tff": (1.06e-4, 2.66e-3),
    "new_tff": (1.91e-6, 4.88e-4),
}


def _random_streams(bits, R, seed):
    N = 1 << bits
    rng = np.random.default_rng(seed)
    a = np.arange(N)
    return bs.pack_bits(jnp.asarray(rng.random((R, N, N)) < (a[:, None] / N)))


def old_adder_mse(bits: int, config: str, R: int = 8) -> float:
    N = 1 << bits
    a = np.arange(N)
    exact = (a[:, None] + a[None, :]) / (2 * N)
    if config == "old_lfsr_tff":
        # deterministic LFSR data streams + toggling select
        ca = sng.lfsr_sequence(bits, which=0, seed=9)
        cb = sng.lfsr_sequence(bits, which=1, seed=9)
        SA = sng.generate(jnp.arange(N), ca, N)[None]
        SB = sng.generate(jnp.arange(N), cb, N)[None]
        sel = arith.tff_select_stream(N)
    else:
        SA = _random_streams(bits, R, 0)
        SB = _random_streams(bits, R, 1)
        if config == "old_random_lfsr":
            sel = sng.generate(jnp.asarray(N // 2), sng.lfsr_sequence(bits), N)
        else:  # old_random_tff
            sel = arith.tff_select_stream(N)
    z = arith.mux_add(SA[:, :, None], SB[:, None, :], sel)
    cz = np.asarray(bs.popcount(z), np.float64)
    return float(((cz / N - exact[None]) ** 2).mean())


def new_adder_mse(bits: int) -> float:
    """Exhaustive; equals 1/(8N^2) analytically (tests prove it)."""
    N = 1 << bits
    a = jnp.arange(N)
    cz = arith.tff_add_count(a[:, None], a[None, :], 0)
    exact = (np.arange(N)[:, None] + np.arange(N)[None, :]) / (2 * N)
    return float(((np.asarray(cz, np.float64) / N - exact) ** 2).mean())


def run(quiet: bool = False):
    rows = {}
    for cfgname in ("old_random_lfsr", "old_random_tff", "old_lfsr_tff"):
        (m8, us) = timed(old_adder_mse, 8, cfgname, warmup=0, iters=1)
        m4 = old_adder_mse(4, cfgname)
        rows[cfgname] = (m8, m4)
        p8, p4 = PAPER[cfgname]
        emit(f"table2/{cfgname}", us,
             f"mse8={m8:.3e} (paper {p8:.2e}) mse4={m4:.3e} (paper {p4:.2e})")
    (n8, us) = timed(new_adder_mse, 8, warmup=0, iters=1)
    n4 = new_adder_mse(4)
    rows["new_tff"] = (n8, n4)
    emit("table2/new_tff", us,
         f"mse8={n8:.3e} (paper 1.91e-06 EXACT) mse4={n4:.3e} "
         f"(paper 4.88e-04 EXACT)")
    gain = rows["old_random_lfsr"][0] / n8
    emit("table2/new_vs_old_gain", 0.0, f"8bit_mse_improvement={gain:.0f}x")
    return rows


if __name__ == "__main__":
    run()
