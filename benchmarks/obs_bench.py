"""Observability overhead + integrity bench: tracing must be (near) free.

Measures wall-clock for identical virtual-time serving runs with tracing
off vs on (frame path and LM prompt path), best-of-N so scheduler noise
doesn't masquerade as tracer cost, and emits BENCH_obs.json carrying the
overhead fractions plus the integrity pins check_bench gates:

  - disabled_callbacks   == 0  (tracing off makes zero obs callbacks)
  - span_energy_conserved      (span stream == telemetry ledger, bitwise)
  - steady_state_recompiles == 0 over the traced run
  - trace_valid / trace_events / series_points  (exporter health)
  - overhead_frac <= overhead_budget (5%) per serving path
  - slo_overhead_frac <= overhead_budget + 1%  (burn-rate eval is cheap)
  - flight_overhead_frac <= 2% vs the traced arm (the always-on ring)
  - critpath_exact: per-request critical-path segments re-fold to the
    request span duration with float equality, both paths
  - roofline verdicts: in-place decode memory-bound, chunked prefill
    fold compute-bound (when XLA cost analysis is available)
  - stage_energy_conserved     (per-stage roofline energy re-fold, bitwise)
  - openmetrics_valid / burn_series_points  (health exposition intact)

Run:  PYTHONPATH=src python benchmarks/obs_bench.py [--smoke]
      [--repeats 5] [--duration 2] [--prompts 12]
"""
import argparse
import gc
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import common  # noqa: E402
import numpy as np  # noqa: E402

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.serve import obs  # noqa: E402
from repro.serve.gateway import frontend as fe  # noqa: E402
from repro.serve.gateway.gateway import (GatewayConfig, MicroBatchGateway,  # noqa: E402
                                         PromptGateway)
from repro.serve.gateway.sensors import (Arrival, FleetConfig,  # noqa: E402
                                         SensorFleet)
from repro.serve.gateway.slots import ContinuousBatcher, make_adapter  # noqa: E402

OVERHEAD_BUDGET = 0.05        # traced run may cost at most 5% wall-clock
SLO_EXTRA_BUDGET = 0.01       # burn-rate evaluation may add at most 1% more
FLIGHT_EXTRA_BUDGET = 0.02    # flight ring may add at most 2% over traced


def _interleaved_best(fns, repeats: int, baselines=None
                      ) -> tuple[list[float], list[float]]:
    """Measure every arm in every round, arm order rotated per round so a
    fixed position (e.g. always running after the garbage the previous
    arm produced) can't masquerade as instrumentation overhead.

    Returns ``(best, ratios)``: per-arm best-of-N wall clock and, per
    arm, the overhead ratio vs its baseline arm (``baselines[j]``, arm 0
    by default — the flight arm ratios against the *traced* arm, since
    its budget is "on top of tracing") as the minimum of (a) the ratio
    of bests and (b) the best *within-round* ratio.  The gate is
    one-sided (instrumentation must not cost more than the budget), so
    the honest estimator is the cleanest evidence available: if in any
    round the instrumented arm ran within budget of that same round's
    baseline, the instrumentation itself is within budget — the rest of
    the spread is machine noise, which a shared CI runner has plenty
    of."""
    baselines = baselines if baselines is not None else [0] * len(fns)
    times = [[0.0] * repeats for _ in fns]
    for r in range(repeats):
        for k in range(len(fns)):
            j = (r + k) % len(fns)
            gc.collect()
            t0 = time.perf_counter()
            fns[j]()
            times[j][r] = time.perf_counter() - t0
    best = [min(ts) for ts in times]
    ratios = [min(best[j] / best[baselines[j]],
                  min(times[j][r] / times[baselines[j]][r]
                      for r in range(repeats)))
              for j in range(len(fns))]
    return best, ratios


def frame_path(args) -> tuple[dict, dict]:
    """sc frame gateway under a fixed service model: the tracer's per-event
    Python cost against a mostly-device workload."""
    spec = fe.FrontendSpec(mode="sc", bits=4)
    gw = MicroBatchGateway(GatewayConfig(service_model="fixed",
                                         fixed_service_s=1e-3), spec)
    gw.warmup()
    fleet = SensorFleet(FleetConfig(n_endpoints=args.endpoints,
                                    frame_rate_hz=args.rate))
    events = fleet.events(args.duration)

    c0 = obs.callback_count()
    gw.run(events)                 # untraced probe: pins zero obs callbacks
    disabled_callbacks = obs.callback_count() - c0

    state = {}

    def traced():
        state["tracer"] = obs.Tracer()
        state["metrics"] = obs.MetricsRegistry(interval_s=args.duration / 20)
        state["tel"] = gw.run(events, tracer=state["tracer"],
                              metrics=state["metrics"])

    def traced_slo():
        # third arm: tracing + the burn-rate engine (evaluated every batch
        # tick) — the SLO layer must cost at most SLO_EXTRA_BUDGET beyond
        # the traced arm's budget
        m = obs.MetricsRegistry(interval_s=args.duration / 20)
        state["slo"] = obs.SLOMonitor(
            obs.SLOPolicy.default(period_s=args.duration, queue_wait_s=0.5),
            tracer=obs.Tracer(), metrics=m)
        state["slo_metrics"] = m
        gw.run(events, tracer=state["slo"].tracer, metrics=m,
               slo=state["slo"])

    def traced_flight():
        # fourth arm: tracing + the always-on flight ring as the event
        # sink — the ring's reservoir/tail upkeep must cost at most
        # FLIGHT_EXTRA_BUDGET beyond the traced arm (its baseline)
        fl = obs.FlightRecorder()
        state["flight"] = fl
        m = obs.MetricsRegistry(interval_s=args.duration / 20)
        gw.run(events, tracer=obs.Tracer(), metrics=m, flight=fl)

    (untraced_s, traced_s, slo_s, flight_s), \
        (_, traced_r, slo_r, flight_r) = _interleaved_best(
            [lambda: gw.run(events), traced, traced_slo, traced_flight],
            args.repeats, baselines=[0, 0, 0, 1])
    tel, tracer, metrics = state["tel"], state["tracer"], state["metrics"]
    tel.assert_conserved()
    tracer.assert_nested()
    tracer.assert_energy_conserved(tel)
    rep = tel.report(args.duration, "frame")
    # critical-path attribution over the traced run: every request span
    # must re-fold from its segments with float equality
    agg = obs.critpath.aggregate(obs.critpath.analyze(tracer.events))
    rec = {
        "path": "frame",
        "untraced_wall_s": untraced_s,
        "traced_wall_s": traced_s,
        "overhead_frac": traced_r - 1.0,
        "slo_wall_s": slo_s,
        "slo_overhead_frac": slo_r - 1.0,
        "flight_wall_s": flight_s,
        "flight_overhead_frac": flight_r - 1.0,
        "completed": rep["completed"],
        "n_samples": rep["n_samples"],
    }
    extras = {
        "disabled_callbacks": disabled_callbacks,
        "frame_trace_events": len(obs.chrome_trace(tracer, metrics)
                                  ["traceEvents"]),
        "frame_health": state["slo"].report()["state"],
        "frame_burn_series_points": len(
            state["slo_metrics"].series("burn_queue_wait")[0]),
        "frame_critpath": agg,
        "frame_flight_accounting": state["flight"].snapshot()["accounting"],
    }
    return rec, extras


def prompt_path(args) -> tuple[dict, dict]:
    """paged-KV LM prompt path: chunked prefill + decode ticks traced,
    recompile detector armed over the traced run, roofline attribution over
    the adapter's ``cost_args()`` registry.  Geometry (block_size 16,
    16-token prompts, max_len 64) puts the chunked prefill fold over the
    roofline ridge and the in-place decode tick under it."""
    cfg = configs.smoke_config(args.lm_arch)
    params, _ = lm.init(jax.random.key(0), cfg, {})
    adapter = make_adapter(cfg, params, n_slots=4, max_len=64, paged=True,
                           block_size=16)
    batcher = ContinuousBatcher(adapter)
    rng = np.random.default_rng(0)
    arrivals = [Arrival(t=i * 0.002, uid=i, endpoint=0, kind="prompt",
                        payload=rng.integers(0, cfg.vocab, 16)
                        .astype(np.int32))
                for i in range(args.prompts)]

    untraced_gw = PromptGateway(batcher, max_new_tokens=args.max_new)
    untraced_gw.warmup((8, 16), cfg.vocab)
    c0 = obs.callback_count()
    untraced_gw.run(arrivals)      # untraced probe: pins zero obs callbacks
    disabled_callbacks = obs.callback_count() - c0

    det = obs.RecompileDetector()
    det.track("gateway", untraced_gw.jit_fns())
    state = {}

    def traced():
        state["tracer"] = obs.Tracer()
        state["metrics"] = obs.MetricsRegistry(interval_s=1e-3)
        gw = PromptGateway(batcher, max_new_tokens=args.max_new,
                           tracer=state["tracer"],
                           metrics=state["metrics"])
        state["tel"] = gw.run(arrivals)

    def traced_slo():
        m = obs.MetricsRegistry(interval_s=1e-3)
        state["slo"] = obs.SLOMonitor(
            obs.SLOPolicy.default(period_s=args.prompts * 0.002,
                                  ttft_s=0.5, tpot_s=0.5, queue_wait_s=0.5),
            tracer=obs.Tracer(), metrics=m)
        state["slo_metrics"] = m
        gw = PromptGateway(batcher, max_new_tokens=args.max_new,
                           tracer=state["slo"].tracer, metrics=m,
                           slo=state["slo"])
        state["slo_tel"] = gw.run(arrivals)

    def traced_flight():
        fl = obs.FlightRecorder()
        state["flight"] = fl
        m = obs.MetricsRegistry(interval_s=1e-3)
        gw = PromptGateway(batcher, max_new_tokens=args.max_new,
                           tracer=obs.Tracer(), metrics=m, flight=fl)
        gw.run(arrivals)

    det.snapshot()
    (untraced_s, traced_s, slo_s, flight_s), \
        (_, traced_r, slo_r, flight_r) = _interleaved_best(
            [lambda: untraced_gw.run(arrivals), traced, traced_slo,
             traced_flight], args.lm_repeats, baselines=[0, 0, 0, 1])
    recompiles = det.steady_state_recompiles()
    tel, tracer, metrics = state["tel"], state["tracer"], state["metrics"]
    tel.assert_conserved()
    tracer.assert_nested()
    tracer.assert_energy_conserved(tel)
    rep = tel.report(args.duration, "prompt")
    trace = obs.chrome_trace(tracer, metrics)
    # roofline attribution: static XLA cost over the adapter's registry
    # joined with the traced run's span durations + energy re-fold
    roofline = obs.attribute(untraced_gw.cost_args(), tracer, telemetry=tel)
    omtext = obs.openmetrics_text(state["slo_metrics"], state["slo"])
    # critical-path attribution over the traced run's span stream: exact
    # (float-equal) re-fold per request, queue/prefill/decode ranking
    agg = obs.critpath.aggregate(obs.critpath.analyze(tracer.events))
    rec = {
        "path": "prompt",
        "untraced_wall_s": untraced_s,
        "traced_wall_s": traced_s,
        "overhead_frac": traced_r - 1.0,
        "slo_wall_s": slo_s,
        "slo_overhead_frac": slo_r - 1.0,
        "flight_wall_s": flight_s,
        "flight_overhead_frac": flight_r - 1.0,
        "completed": rep["completed"],
        "n_samples": rep["n_samples"],
    }
    extras = {
        "prompt_critpath": agg,
        "prompt_flight_accounting":
            state["flight"].snapshot()["accounting"],
        "disabled_callbacks": disabled_callbacks,
        "steady_state_recompiles": recompiles,
        "recompile_report": det.report(),
        "trace_events": len(trace["traceEvents"]),
        "trace_valid": obs.validate_chrome_trace(trace) == [],
        "series_points": len(metrics.samples),
        "ttft_p99_ms": rep.get("ttft_p99_ms", 0.0),
        "tpot_p99_ms": rep.get("tpot_p99_ms", 0.0),
        "roofline": {
            name: {k: entry[k] for k in
                   ("source", "verdict", "intensity", "calls")}
            for name, entry in roofline["stages"].items()},
        "ridge_flops_per_byte": roofline["ridge_flops_per_byte"],
        "stage_energy_conserved": roofline["energy"]["conserved"],
        "stage_energy_nj": roofline["energy"]["stages_nj"],
        "openmetrics_valid": obs.validate_openmetrics(omtext) == [],
        "burn_series_points": len(
            state["slo_metrics"].series("burn_ttft")[0]),
        "prompt_health": state["slo"].report()["state"],
    }
    return rec, extras


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--endpoints", type=int, default=16)
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--rate", type=float, default=16.0)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--lm-repeats", type=int, default=4)
    ap.add_argument("--lm-arch", default="stablelm_3b")
    ap.add_argument("--prompts", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing: fewer frames/prompts, fewer repeats")
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent
                                         / "BENCH_obs.json"))
    args = ap.parse_args()
    if args.smoke:
        args.endpoints, args.duration, args.rate = 8, 1.0, 16.0
        args.repeats, args.lm_repeats = 8, 8
        args.prompts, args.max_new = 8, 4

    frame_rec, frame_x = frame_path(args)
    prompt_rec, prompt_x = prompt_path(args)
    results = [frame_rec, prompt_rec]
    for rec in results:
        common.emit(f"obs_{rec['path']}_overhead",
                    rec["traced_wall_s"] * 1e6,
                    f"untraced {rec['untraced_wall_s'] * 1e6:.0f}us,"
                    f"{rec['overhead_frac'] * 100:+.2f}%")
        common.emit(f"obs_{rec['path']}_slo_overhead",
                    rec["slo_wall_s"] * 1e6,
                    f"burn-rate eval {rec['slo_overhead_frac'] * 100:+.2f}% "
                    f"vs untraced")
        common.emit(f"obs_{rec['path']}_flight_overhead",
                    rec["flight_wall_s"] * 1e6,
                    f"flight ring {rec['flight_overhead_frac'] * 100:+.2f}% "
                    f"vs traced")

    payload = {
        "bench": "obs",
        "results": results,
        "overhead_budget": OVERHEAD_BUDGET,
        "overhead_frac": max(r["overhead_frac"] for r in results),
        # SLO arm: tracing + burn-rate evaluation, allowed at most
        # SLO_EXTRA_BUDGET beyond the plain-traced budget
        "slo_overhead_budget": OVERHEAD_BUDGET + SLO_EXTRA_BUDGET,
        "slo_overhead_frac": max(r["slo_overhead_frac"] for r in results),
        # flight arm: the always-on ring as the trace sink, ratioed
        # against the *traced* arm — the ring may add at most
        # FLIGHT_EXTRA_BUDGET on top of tracing
        "flight_overhead_budget": FLIGHT_EXTRA_BUDGET,
        "flight_overhead_frac": max(r["flight_overhead_frac"]
                                    for r in results),
        # critical-path attribution over both traced span streams:
        # every request's segments re-fold to its span duration with
        # float equality, and the ranking names the dominant stage
        "critpath_exact": frame_x["frame_critpath"]["exact"]
        and prompt_x["prompt_critpath"]["exact"],
        "critpath_requests": frame_x["frame_critpath"]["requests"]
        + prompt_x["prompt_critpath"]["requests"],
        "critpath_dominant": {
            "frame": frame_x["frame_critpath"]["p_dominant"],
            "prompt": prompt_x["prompt_critpath"]["p_dominant"]},
        "flight_accounting": {
            "frame": frame_x["frame_flight_accounting"],
            "prompt": prompt_x["prompt_flight_accounting"]},
        "disabled_callbacks": frame_x["disabled_callbacks"]
        + prompt_x["disabled_callbacks"],
        # both paths' span streams reproduced their ledgers bitwise (the
        # asserts above would have thrown otherwise)
        "span_energy_conserved": True,
        "steady_state_recompiles": prompt_x["steady_state_recompiles"],
        "recompile_report": prompt_x["recompile_report"],
        "trace_events": prompt_x["trace_events"]
        + frame_x["frame_trace_events"],
        "trace_valid": prompt_x["trace_valid"],
        "series_points": prompt_x["series_points"],
        "ttft_p99_ms": prompt_x["ttft_p99_ms"],
        "tpot_p99_ms": prompt_x["tpot_p99_ms"],
        "roofline": prompt_x["roofline"],
        "ridge_flops_per_byte": prompt_x["ridge_flops_per_byte"],
        "stage_energy_conserved": prompt_x["stage_energy_conserved"],
        "stage_energy_nj": prompt_x["stage_energy_nj"],
        "openmetrics_valid": prompt_x["openmetrics_valid"]
        and frame_x["frame_health"] in ("ok", "warn", "critical"),
        "burn_series_points": prompt_x["burn_series_points"],
        "health": {"frame": frame_x["frame_health"],
                   "prompt": prompt_x["prompt_health"]},
    }
    common.emit_json(args.out, payload)


if __name__ == "__main__":
    main()
