"""Fold every committed BENCH_*.json into one performance trajectory.

Each benchmark writes a point-in-time BENCH_<name>.json; this script walks
the git history of each of those files, extracts one headline metric per
bench family at every commit that touched it, and emits
BENCH_trajectory.json: the per-metric time series plus the current value.
check_bench.py gates the output — the fold must cover at least the five
core bench families and every series must end at the value currently on
disk (an append-only history; a mismatch means a BENCH file was edited
without re-running its benchmark).

No network, no new deps: history comes from ``git log``/``git show`` and
degrades gracefully — a file with no committed history (or a historical
version missing the headline field) contributes a single working-tree
point.

Usage:
    python benchmarks/trajectory.py [--out benchmarks/BENCH_trajectory.json]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

sys.path.insert(0, os.path.dirname(__file__))
import common  # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)

# headline metric per bench family: (metric name, unit, extractor).
# Extractors are defensive — historical payloads predate some fields, and
# a commit whose version lacks the metric simply contributes no point.
_EXTRACTORS = {
    "gateway": ("p99_latency_ms_best", "ms",
                lambda d: min(r["p99_latency_ms"] for r in d["results"])),
    "kvcache": ("p99_latency_ms_best", "ms",
                lambda d: min(r["p99_latency_ms"] for r in d["results"])),
    "cascade": ("decode_speedup_max", "x",
                lambda d: max(r["speedup"] for r in d["results"])),
    "prefix": ("warm_speedup_max", "x",
               lambda d: max(r["speedup"] for r in d["results"])),
    "disagg": ("tick_p99_ms_best", "ms",
               lambda d: min(r["tick_p99_ms"] for r in d["results"])),
    "obs": ("tracing_overhead_frac", "frac",
            lambda d: d["overhead_frac"]),
}


def _git(*args):
    out = subprocess.run(
        ["git", "-C", REPO, *args], capture_output=True, text=True,
        timeout=30)
    if out.returncode != 0:
        raise RuntimeError(out.stderr.strip() or f"git {args[0]} failed")
    return out.stdout


def _history(rel_path):
    """(sha, payload) per commit touching rel_path, oldest first."""
    try:
        shas = _git("log", "--reverse", "--format=%h", "--",
                    rel_path).split()
    except (RuntimeError, OSError, subprocess.SubprocessError):
        return []
    points = []
    for sha in shas:
        try:
            points.append(
                (sha, json.loads(_git("show", f"{sha}:{rel_path}"))))
        except (RuntimeError, OSError, subprocess.SubprocessError,
                ValueError):
            continue
    return points


def fold(bench_dir=HERE):
    """Build the trajectory payload from the BENCH files in bench_dir."""
    results = []
    sources = set()
    for path in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        name = os.path.basename(path)
        if name == "BENCH_trajectory.json":
            continue
        with open(path) as f:
            current = json.load(f)
        spec = _EXTRACTORS.get(current.get("bench"))
        if spec is None:
            print(f"  skip {name}: no extractor for "
                  f"bench={current.get('bench')!r}")
            continue
        metric, unit, extract = spec
        try:
            value = extract(current)
        except (KeyError, ValueError, TypeError):
            print(f"  skip {name}: headline metric {metric} missing")
            continue
        rel = os.path.relpath(path, REPO)
        series = []
        for sha, payload in _history(rel):
            try:
                series.append({"commit": sha, "value": extract(payload)})
            except (KeyError, ValueError, TypeError):
                continue
        n_commits = len(series)
        # the series is pinned to end at the working-tree value so the
        # trend and the gated number can never silently diverge
        if not series or series[-1]["value"] != value:
            series.append({"commit": "worktree", "value": value})
        sources.add(name)
        results.append({
            "metric": metric,
            "bench_source": name,
            "value": value,
            "unit": unit,
            "series": series,
            "n_commits": n_commits,
        })
        common.emit(f"trajectory_{name[len('BENCH_'):-len('.json')]}"
                    f"_{metric}", 0.0,
                    f"{value} {unit} over {len(series)} points")
    return {"bench": "trajectory", "n_sources": len(sources),
            "results": results}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out",
                    default=os.path.join(HERE, "BENCH_trajectory.json"))
    args = ap.parse_args(argv)
    payload = fold()
    common.emit_json(args.out, payload)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
