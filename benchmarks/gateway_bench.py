"""Gateway load sweep: offered load vs achieved throughput / latency / energy.

Sweeps the fleet's per-endpoint rate for both frontend partitions and emits
BENCH_gateway.json (plus the usual CSV lines via common.emit), so the serving
perf trajectory accumulates across PRs.

Run:  PYTHONPATH=src python benchmarks/gateway_bench.py
      [--endpoints 32] [--duration 2] [--rates 2,8,32]
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

import common  # noqa: E402

from repro.serve.gateway import frontend as fe  # noqa: E402
from repro.serve.gateway.gateway import GatewayConfig, MicroBatchGateway  # noqa: E402
from repro.serve.gateway.sensors import FleetConfig, SensorFleet  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--endpoints", type=int, default=32)
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--rates", default="2,8,32",
                    help="per-endpoint frame rates (Hz) to sweep")
    ap.add_argument("--bits", type=int, default=4)
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent
                                         / "BENCH_gateway.json"))
    args = ap.parse_args()
    rates = [float(r) for r in args.rates.split(",")]

    results = []
    for mode in ("sc", "binary"):
        spec = fe.FrontendSpec(mode=mode, bits=args.bits)
        gw = MicroBatchGateway(GatewayConfig(), spec)
        gw.warmup()
        for rate in rates:
            fleet = SensorFleet(FleetConfig(
                n_endpoints=args.endpoints, frame_rate_hz=rate))
            events = fleet.events(args.duration)
            tel = gw.run(events)
            tel.assert_conserved()
            rep = tel.report(args.duration, kind="frame")
            rec = {
                "frontend": mode,
                "bits": args.bits,
                "endpoints": args.endpoints,
                "offered_hz": fleet.offered_load_hz(),
                "achieved_hz": rep["throughput_hz"],
                "p50_latency_ms": rep.get("p50_latency_ms", 0.0),
                "p99_latency_ms": rep.get("p99_latency_ms", 0.0),
                "j_per_inference": rep.get("j_per_inference", 0.0),
                "link_bytes_per_frame": fe.link_bytes_per_frame(spec),
                "dropped": rep["dropped"],
            }
            results.append(rec)
            common.emit(
                f"gateway_{mode}_{rate:g}hz",
                rep.get("p99_latency_ms", 0.0) * 1e3,
                f"{rep['throughput_hz']:.1f}fps,"
                f"{rec['j_per_inference']:.3e}J,"
                f"{rec['link_bytes_per_frame']}B")
    common.emit_json(args.out, {"bench": "gateway", "results": results})


if __name__ == "__main__":
    main()
