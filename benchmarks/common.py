"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time


def timed(fn, *args, warmup: int = 1, iters: int = 3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / iters
    return out, dt * 1e6  # µs


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def emit_json(path: str, payload: dict):
    """Write a benchmark result file (BENCH_*.json) and echo the path."""
    import json
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path}")
