"""BENCH_*.json schema gate for CI.

Validates every benchmark result file against its per-bench schema and
exits non-zero on any violation, so a refactor that silently breaks a
benchmark's output (missing field, wrong type, empty results, paged losing
to dense) fails the pipeline instead of rotting the trend data.

Run:  python benchmarks/check_bench.py benchmarks/BENCH_*.json
"""
from __future__ import annotations

import json
import numbers
import sys

# bench name -> (top-level required fields, per-result required fields)
SCHEMAS = {
    "gateway": (
        {"bench": str, "results": list},
        {"frontend": str, "bits": numbers.Integral,
         "endpoints": numbers.Integral, "offered_hz": numbers.Real,
         "achieved_hz": numbers.Real, "p50_latency_ms": numbers.Real,
         "p99_latency_ms": numbers.Real, "j_per_inference": numbers.Real,
         "link_bytes_per_frame": numbers.Integral,
         "dropped": numbers.Integral},
    ),
    "kvcache": (
        {"bench": str, "budget_bytes": numbers.Integral,
         "max_len": numbers.Integral, "block_size": numbers.Integral,
         "results": list, "paged_gt_dense": bool, "decode_tick": list},
        {"layout": str, "budget_bytes": numbers.Integral,
         "kv_bytes_allocated": numbers.Integral,
         "n_slots": numbers.Integral,
         "max_concurrent_slots": numbers.Integral,
         "completed": numbers.Integral, "dropped": numbers.Integral,
         "p50_latency_ms": numbers.Real, "p99_latency_ms": numbers.Real,
         "j_per_inference": numbers.Real},
    ),
}

# per-record schema of the kvcache "decode_tick" series (gather tick vs
# in-place tick; see kvcache_bench.decode_tick_series)
DECODE_TICK_FIELDS = {
    "nb_max": numbers.Integral, "block_size": numbers.Integral,
    "n_slots": numbers.Integral, "gather_tok_s": numbers.Real,
    "inplace_tok_s": numbers.Real, "gather_bytes_proxy": numbers.Integral,
    "inplace_bytes_proxy": numbers.Integral, "speedup": numbers.Real,
}

# schema of the optional kvcache "sharded_tick" record (1 device vs N
# gateway slices at a fixed per-device budget + a mid-decode migration
# replay; see kvcache_bench.sharded_tick_series — present when the bench
# ran with --sharded, which the sharded CI job does under
# XLA_FLAGS=--xla_force_host_platform_device_count=8)
SHARDED_TICK_FIELDS = {
    "n_devices": numbers.Integral, "n_slices": numbers.Integral,
    "budget_blocks_per_device": numbers.Integral,
    "block_size": numbers.Integral,
    "single_slots": numbers.Integral, "sharded_slots": numbers.Integral,
    "single_tok_s": numbers.Real, "sharded_tok_s": numbers.Real,
    "sharded_gt_single": bool, "routing": dict,
    "migration_bytes": numbers.Integral,
    "migration_blocks": numbers.Integral, "migration_bitwise": bool,
}

SCHEMAS |= {
    "obs": (
        {"bench": str, "results": list, "overhead_budget": numbers.Real,
         "overhead_frac": numbers.Real,
         "slo_overhead_budget": numbers.Real,
         "slo_overhead_frac": numbers.Real,
         "disabled_callbacks": numbers.Integral,
         "span_energy_conserved": bool,
         "steady_state_recompiles": numbers.Integral,
         "recompile_report": dict, "trace_events": numbers.Integral,
         "trace_valid": bool, "series_points": numbers.Integral,
         "ttft_p99_ms": numbers.Real, "tpot_p99_ms": numbers.Real,
         "roofline": dict, "ridge_flops_per_byte": numbers.Real,
         "stage_energy_conserved": bool, "stage_energy_nj": dict,
         "openmetrics_valid": bool,
         "burn_series_points": numbers.Integral, "health": dict,
         "flight_overhead_budget": numbers.Real,
         "flight_overhead_frac": numbers.Real,
         "critpath_exact": bool, "critpath_requests": numbers.Integral,
         "critpath_dominant": dict, "flight_accounting": dict},
        {"path": str, "untraced_wall_s": numbers.Real,
         "traced_wall_s": numbers.Real, "overhead_frac": numbers.Real,
         "slo_wall_s": numbers.Real, "slo_overhead_frac": numbers.Real,
         "flight_wall_s": numbers.Real,
         "flight_overhead_frac": numbers.Real,
         "completed": numbers.Integral, "n_samples": numbers.Integral},
    ),
    "trajectory": (
        {"bench": str, "n_sources": numbers.Integral, "results": list},
        {"metric": str, "bench_source": str, "value": numbers.Real,
         "unit": str, "series": list, "n_commits": numbers.Integral},
    ),
    "disagg": (
        {"bench": str, "n_devices": numbers.Integral,
         "n_slices": numbers.Integral, "roles": dict,
         "n_requests": numbers.Integral, "block_size": numbers.Integral,
         "results": list, "disagg_beats_colocated": bool},
        {"mode": str, "completed": numbers.Integral,
         "tick_p99_ms": numbers.Real, "prefill_tick_p99_ms": numbers.Real,
         "handoffs": numbers.Integral, "handoff_bytes": numbers.Integral,
         "routing": dict},
    ),
    "cascade": (
        {"bench": str, "block_size": numbers.Integral, "results": list,
         "cascade_beats_flat_deep": bool},
        {"lanes": numbers.Integral, "prefix_blocks": numbers.Integral,
         "prefix_tokens": numbers.Integral,
         "block_size": numbers.Integral, "groups": numbers.Integral,
         "grouped_lanes": numbers.Integral,
         "prefix_rows": numbers.Integral,
         "prefix_rows_flat": numbers.Integral,
         "inplace_tok_s": numbers.Real, "cascade_tok_s": numbers.Real,
         "inplace_bytes_proxy": numbers.Integral,
         "cascade_bytes_proxy": numbers.Integral,
         "speedup": numbers.Real},
    ),
    "prefix": (
        {"bench": str, "block_size": numbers.Integral, "results": list,
         "warm_beats_cold": bool},
        {"shared_blocks": numbers.Integral, "prompt_len": numbers.Integral,
         "suffix_len": numbers.Integral,
         "prefill_tokens_skipped": numbers.Integral,
         "cold_ms": numbers.Real, "warm_ms": numbers.Real,
         "speedup": numbers.Real},
    ),
}


def _check_fields(obj: dict, fields: dict, where: str) -> list[str]:
    errs = []
    for name, typ in fields.items():
        if name not in obj:
            errs.append(f"{where}: missing field '{name}'")
        elif not isinstance(obj[name], typ) or isinstance(obj[name], bool) \
                and typ is not bool:
            errs.append(f"{where}: field '{name}' is "
                        f"{type(obj[name]).__name__}, want {typ.__name__}")
    return errs


def check(path: str) -> list[str]:
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    bench = payload.get("bench")
    if bench not in SCHEMAS:
        return [f"{path}: unknown bench '{bench}' "
                f"(known: {sorted(SCHEMAS)})"]
    top, per_result = SCHEMAS[bench]
    errs = _check_fields(payload, top, path)
    results = payload.get("results") or []
    if not results:
        errs.append(f"{path}: empty results")
    for i, rec in enumerate(results):
        if not isinstance(rec, dict):
            errs.append(f"{path}: results[{i}] is not an object")
            continue
        errs += _check_fields(rec, per_result, f"{path}: results[{i}]")
    # bench-specific invariants
    if bench == "kvcache" and not errs:
        layouts = {r["layout"] for r in results}
        if layouts != {"dense", "paged"}:
            errs.append(f"{path}: need one dense and one paged result, "
                        f"got {sorted(layouts)}")
        elif not payload["paged_gt_dense"]:
            errs.append(f"{path}: paged did not sustain more concurrent "
                        f"slots than dense at the shared budget")
        if any(r["completed"] == 0 for r in results):
            errs.append(f"{path}: a layout completed zero requests")
        # trend gate: the gather-free tick must not lose to the gather
        # tick once chains are non-trivially deep, and its dataflow must
        # always move strictly fewer arena bytes
        ticks = payload.get("decode_tick") or []
        if not ticks:
            errs.append(f"{path}: empty decode_tick series")
        for i, rec in enumerate(ticks):
            if not isinstance(rec, dict):
                errs.append(f"{path}: decode_tick[{i}] is not an object")
                continue
            errs += _check_fields(rec, DECODE_TICK_FIELDS,
                                  f"{path}: decode_tick[{i}]")
        for rec in ticks:
            if not isinstance(rec, dict) or \
                    any(f not in rec for f in DECODE_TICK_FIELDS):
                continue
            if rec["inplace_bytes_proxy"] >= rec["gather_bytes_proxy"]:
                errs.append(
                    f"{path}: decode_tick nb_max={rec['nb_max']} in-place "
                    f"bytes proxy ({rec['inplace_bytes_proxy']}) not below "
                    f"gather ({rec['gather_bytes_proxy']})")
            # wall-clock trend: not losing beyond measurement noise (the
            # structural guarantee — no full-chain materialization — is
            # pinned deterministically by the jaxpr test and the bytes
            # gate above; this catches throughput rot on real runs)
            if rec["nb_max"] >= 4 and \
                    rec["inplace_tok_s"] < 0.85 * rec["gather_tok_s"]:
                errs.append(
                    f"{path}: decode_tick nb_max={rec['nb_max']} in-place "
                    f"tick lost to the gather tick "
                    f"({rec['inplace_tok_s']:.1f} < 0.85 * "
                    f"{rec['gather_tok_s']:.1f} tok/s)")
        # sharded trend gate (when the series ran): at a fixed per-device
        # budget, N slices must sustain more aggregate concurrent slots
        # than one device, and the mid-decode migration replay must have
        # preserved the migrated lane's logits bitwise
        sh = payload.get("sharded_tick")
        if sh is not None:
            errs += _check_fields(sh, SHARDED_TICK_FIELDS,
                                  f"{path}: sharded_tick")
            if not errs:
                # the migration replay runs whatever the device count —
                # these must never be skipped (a mesh that silently
                # collapses to one device must not green-wash the gate)
                if not sh["migration_bitwise"]:
                    errs.append(f"{path}: sharded_tick migration drifted "
                                f"from the stay-put oracle")
                if sh["migration_bytes"] <= 0:
                    errs.append(f"{path}: sharded_tick migration moved "
                                f"zero bytes")
                if sh["n_slices"] > 1 and (
                        not sh["sharded_gt_single"] or
                        sh["sharded_slots"] <= sh["single_slots"]):
                    errs.append(
                        f"{path}: sharded_tick {sh['n_slices']} slices "
                        f"did not beat one device's concurrency "
                        f"({sh['sharded_slots']} <= {sh['single_slots']})")
    if bench == "obs" and not errs:
        # observability must be free when off and near-free when on: zero
        # obs callbacks with tracing disabled, per-path wall-clock overhead
        # within the declared budget, and no steady-state recompiles (a
        # traced run must not perturb the fixed-shape executables)
        budget = payload["overhead_budget"]
        slo_budget = payload["slo_overhead_budget"]
        if payload["disabled_callbacks"] != 0:
            errs.append(f"{path}: tracing-disabled run made "
                        f"{payload['disabled_callbacks']} obs callbacks "
                        f"(contract is zero)")
        for r in results:
            if r["completed"] == 0:
                errs.append(f"{path}: {r['path']} path completed zero "
                            f"requests")
            if r["overhead_frac"] > budget:
                errs.append(
                    f"{path}: {r['path']} path tracing overhead "
                    f"{r['overhead_frac']:.1%} exceeds the "
                    f"{budget:.0%} budget")
            if r["slo_overhead_frac"] > slo_budget:
                errs.append(
                    f"{path}: {r['path']} path tracing + burn-rate "
                    f"overhead {r['slo_overhead_frac']:.1%} exceeds the "
                    f"{slo_budget:.0%} budget (SLO evaluation must add "
                    f"at most 1% beyond the tracing budget)")
            if r["flight_overhead_frac"] > \
                    payload["flight_overhead_budget"]:
                errs.append(
                    f"{path}: {r['path']} path flight-ring overhead "
                    f"{r['flight_overhead_frac']:.1%} over the traced "
                    f"arm exceeds the "
                    f"{payload['flight_overhead_budget']:.0%} budget "
                    f"(the always-on ring must stay near-free)")
        if {r["path"] for r in results} != {"frame", "prompt"}:
            errs.append(f"{path}: need one frame and one prompt result")
        if not payload["span_energy_conserved"]:
            errs.append(f"{path}: span energies did not reproduce the "
                        f"telemetry ledger bitwise")
        if payload["steady_state_recompiles"] != 0:
            errs.append(f"{path}: {payload['steady_state_recompiles']} "
                        f"steady-state recompiles during the traced run")
        if not payload["trace_valid"] or payload["trace_events"] <= 0:
            errs.append(f"{path}: exported trace invalid or empty")
        if payload["series_points"] <= 0:
            errs.append(f"{path}: no interval metric snapshots sampled")
        # roofline attribution: the serving geometries have known verdicts
        # when real XLA cost analysis backed the estimate; a degraded
        # backend (interpret mode) reports bytes-only/measured-only and
        # is exempt from the verdict pin but must still be present
        roofline = payload["roofline"]
        for stage, want in (("decode", "memory-bound"),
                            ("chunk_fold", "compute-bound")):
            entry = roofline.get(stage)
            if entry is None:
                errs.append(f"{path}: roofline is missing the "
                            f"'{stage}' stage")
            elif entry["source"] == "xla" and entry["verdict"] != want:
                errs.append(f"{path}: roofline calls {stage} "
                            f"{entry['verdict']} (want {want} at ridge "
                            f"{payload['ridge_flops_per_byte']} F/B)")
        if not payload["stage_energy_conserved"]:
            errs.append(f"{path}: per-stage attributed energy did not "
                        f"re-fold to the telemetry ledger bitwise")
        if not payload["openmetrics_valid"]:
            errs.append(f"{path}: OpenMetrics exposition failed its "
                        f"validator")
        if payload["burn_series_points"] <= 0:
            errs.append(f"{path}: no burn-rate series columns sampled")
        # critical-path attribution: every traced request's segments must
        # re-fold to its span duration with float equality, on both paths
        if not payload["critpath_exact"]:
            errs.append(f"{path}: critical-path segments did not re-fold "
                        f"to the request span durations with float "
                        f"equality")
        if payload["critpath_requests"] <= 0:
            errs.append(f"{path}: critical-path analyzer saw zero "
                        f"completed requests")
        for p, dom in payload["critpath_dominant"].items():
            if not dom:
                errs.append(f"{path}: {p} path has no dominant "
                            f"critical-path stage")
    if bench == "trajectory" and not errs:
        # the aggregator must have folded a meaningful set of BENCH files,
        # and each metric's history must end at its current value (the
        # series is append-only — a mismatch means the trend and the
        # gated value have drifted apart)
        if payload["n_sources"] < 5:
            errs.append(f"{path}: trajectory folded only "
                        f"{payload['n_sources']} BENCH sources (want >=5: "
                        f"gateway/kvcache/cascade/prefix/obs)")
        for r in results:
            where = f"{path}: {r['metric']}"
            if not r["series"]:
                errs.append(f"{where}: empty history series")
                continue
            last = r["series"][-1]
            if not isinstance(last, dict) or "value" not in last:
                errs.append(f"{where}: malformed series tail")
            elif last["value"] != r["value"]:
                errs.append(f"{where}: series tail {last['value']} != "
                            f"current value {r['value']}")
    if bench == "disagg" and not errs:
        # trend gate: at equal device budget, splitting the mesh into
        # prefill and decode roles must shield decode ticks from the
        # prefill burst's chunked folds — the JetStream-style argument
        # disaggregation exists to make.  Handoffs must actually have
        # carried the traffic (a disagg run where nothing crossed the
        # prefill->decode boundary proves nothing).
        by_mode = {r["mode"]: r for r in results}
        if set(by_mode) != {"colocated", "disagg"}:
            errs.append(f"{path}: need one colocated and one disagg "
                        f"result, got {sorted(by_mode)}")
        else:
            colo, dis = by_mode["colocated"], by_mode["disagg"]
            for r in (colo, dis):
                if r["completed"] != payload["n_requests"]:
                    errs.append(
                        f"{path}: {r['mode']} completed {r['completed']} "
                        f"of {payload['n_requests']} requests")
            if dis["handoffs"] <= 0 or dis["handoff_bytes"] <= 0:
                errs.append(f"{path}: disagg run made no prefill->decode "
                            f"handoffs")
            if not payload["disagg_beats_colocated"] or \
                    not 0.0 < dis["tick_p99_ms"] < colo["tick_p99_ms"]:
                errs.append(
                    f"{path}: disagg decode tick p99 "
                    f"({dis['tick_p99_ms']:.3f} ms) did not beat the "
                    f"colocated all-slice tick p99 "
                    f"({colo['tick_p99_ms']:.3f} ms) under the prefill "
                    f"burst")
    if bench == "cascade" and not errs:
        # structural gates, exact: cascade attends each shared prefix once
        # per *group*, so its per-layer prefix KV rows are O(prefix) —
        # constant in the lane count at a fixed depth — while the flat
        # tick's per-lane equivalent grows linearly with the lanes; the
        # dataflow bytes proxy must undercut the flat tick's everywhere.
        # Every cell must actually have grouped (a degraded cell times the
        # flat executable twice and proves nothing).
        bs = payload["block_size"]
        for r in results:
            cell = (f"{path}: cascade lanes={r['lanes']} "
                    f"prefix_blocks={r['prefix_blocks']}")
            if r["groups"] < 1 or r["grouped_lanes"] != r["lanes"]:
                errs.append(f"{cell}: not all lanes grouped "
                            f"({r['grouped_lanes']}/{r['lanes']} in "
                            f"{r['groups']} groups)")
            if r["prefix_rows"] != r["prefix_blocks"] * bs:
                errs.append(f"{cell}: prefix rows {r['prefix_rows']} != "
                            f"shared depth {r['prefix_blocks'] * bs} — "
                            f"not O(prefix)")
            if r["prefix_rows_flat"] != r["lanes"] * r["prefix_rows"]:
                errs.append(f"{cell}: flat-equivalent prefix rows "
                            f"{r['prefix_rows_flat']} != lanes x "
                            f"{r['prefix_rows']}")
            if r["cascade_bytes_proxy"] >= r["inplace_bytes_proxy"]:
                errs.append(f"{cell}: cascade bytes proxy "
                            f"({r['cascade_bytes_proxy']}) not below flat "
                            f"({r['inplace_bytes_proxy']})")
        # wall-clock gate at the deepest shared-prefix cell only: >= 4
        # lanes over >= 4 shared blocks where the prefix dominates the
        # tick, cascade must win outright.  Shallow cells pay the
        # merge/scatter overhead without enough prefix to amortize it —
        # reported for the trend, not gated (mirrors the sharded series'
        # CPU wall-clock stance).
        if results and not errs:
            deep = max(results,
                       key=lambda r: (r["prefix_blocks"], r["lanes"]))
            if deep["lanes"] < 4 or deep["prefix_blocks"] < 4:
                errs.append(f"{path}: deepest cascade cell "
                            f"(lanes={deep['lanes']}, prefix_blocks="
                            f"{deep['prefix_blocks']}) too shallow to "
                            f"carry the wall-clock gate")
            elif not payload["cascade_beats_flat_deep"] or \
                    deep["cascade_tok_s"] < deep["inplace_tok_s"]:
                errs.append(
                    f"{path}: cascade tick lost to the flat tick at the "
                    f"deepest shared-prefix cell (lanes={deep['lanes']}, "
                    f"prefix_blocks={deep['prefix_blocks']}: "
                    f"{deep['cascade_tok_s']:.1f} < "
                    f"{deep['inplace_tok_s']:.1f} tok/s)")
    if bench == "prefix" and not errs:
        # trend gate: prefix-hit admission must actually get cheaper once a
        # meaningful prefix (>= 2 shared blocks) is resumed
        if not payload["warm_beats_cold"]:
            errs.append(f"{path}: warm_beats_cold is false")
        for r in results:
            if r["shared_blocks"] >= 2 and not r["warm_ms"] < r["cold_ms"]:
                errs.append(
                    f"{path}: shared_blocks={r['shared_blocks']} warm "
                    f"({r['warm_ms']:.3f} ms) did not beat cold "
                    f"({r['cold_ms']:.3f} ms)")
            if (r["shared_blocks"] >= 1
                    and r["prefill_tokens_skipped"] == 0):
                errs.append(f"{path}: shared_blocks={r['shared_blocks']} "
                            f"skipped zero prefill tokens")
    return errs


def main() -> int:
    paths = sys.argv[1:]
    if not paths:
        print("usage: check_bench.py BENCH_foo.json [BENCH_bar.json ...]")
        return 2
    errs = []
    for path in paths:
        errs += check(path)
    for e in errs:
        print(f"SCHEMA ERROR: {e}")
    if not errs:
        print(f"{len(paths)} BENCH file(s) valid")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
