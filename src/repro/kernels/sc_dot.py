"""Pallas TPU kernel: bit-packed stochastic dot product (AND + popcount + TFF
tree), the compute hot-spot of the paper's 784-unit convolution engine.

TPU adaptation (see DESIGN.md §2): the ASIC's serial AND-gates + TFF adder
tree become, per grid cell, a word-parallel AND over packed uint32 streams,
a SWAR popcount (shift/mask adds only — no reliance on a native
population-count lowering), and an integer TFF-tree reduction in VMEM.

Tiling: grid (M/bm, O/bo); each cell loads
    X tile (bm, K, Wd)  and  W tile (K, bo, Wd)
into VMEM and emits a (bm, bo) int32 tile of root counts.  K (window size,
padded to a power of two by the wrapper) and Wd (words per stream, N/32) are
small — e.g. K=32, Wd=8 at 8-bit precision — so the working set is
  bm*K*Wd*4 + K*bo*Wd*4 + bm*K*bo*4 bytes;
with bm=bo=128, K=32, Wd=8: 128KiB + 128KiB + 2MiB ≈ 2.3MiB « 16MiB VMEM.
bm, bo are multiples of 8×128 MXU/VPU alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _swar_popcount(v: jax.Array) -> jax.Array:
    """Branch-free popcount of uint32 using shift/mask adds (VPU-friendly)."""
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _tree_reduce(counts: jax.Array, s0_mode: str) -> jax.Array:
    """TFF adder tree over axis 1 of (bm, K, bo) int32 -> (bm, bo)."""
    K = counts.shape[1]
    depth = int(np.log2(K))
    c = counts
    for level in range(depth):
        half = c.shape[1] // 2
        c2 = c.reshape(c.shape[0], half, 2, c.shape[2])
        left, right = c2[:, :, 0, :], c2[:, :, 1, :]
        if s0_mode == "zero":
            s0 = jnp.zeros((1, half, 1), jnp.int32)
        elif s0_mode == "one":
            s0 = jnp.ones((1, half, 1), jnp.int32)
        else:  # "alt"
            idx = jax.lax.broadcasted_iota(jnp.int32, (1, half, 1), 1)
            s0 = (idx + level) & 1
        c = (left + right + s0) >> 1
    return c[:, 0, :]


def _sc_dot_kernel(x_ref, w_ref, o_ref, *, s0_mode: str, adder: str):
    """x_ref: (bm, K, Wd) u32; w_ref: (K, bo, Wd) u32; o_ref: (bm, bo) i32."""
    x = x_ref[...]
    w = w_ref[...]
    K = x.shape[1]

    def body(k, acc):
        xk = jax.lax.dynamic_index_in_dim(x, k, axis=1, keepdims=False)  # (bm, Wd)
        wk = jax.lax.dynamic_index_in_dim(w, k, axis=0, keepdims=False)  # (bo, Wd)
        prod = xk[:, None, :] & wk[None, :, :]                            # (bm, bo, Wd)
        cnt = jnp.sum(_swar_popcount(prod), axis=-1)                      # (bm, bo)
        return jax.lax.dynamic_update_index_in_dim(acc, cnt, k, axis=1)

    counts = jnp.zeros((x.shape[0], K, w.shape[1]), jnp.int32)
    counts = jax.lax.fori_loop(0, K, body, counts)
    if adder == "ideal":
        depth = int(np.log2(K))
        o_ref[...] = (jnp.sum(counts, axis=1) >> depth).astype(jnp.int32)
    else:
        o_ref[...] = _tree_reduce(counts, s0_mode).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bo", "s0_mode", "adder", "interpret"))
def sc_dot_pallas(x_packed: jax.Array, w_packed: jax.Array, *,
                  bm: int = 128, bo: int = 128, s0_mode: str = "alt",
                  adder: str = "tff",
                  interpret: bool | None = None) -> jax.Array:
    """Raw pallas_call (operands must already be padded to block multiples
    and K padded to a power of two).  Use :mod:`repro.kernels.ops` instead.
    ``interpret=None`` auto-detects the backend (Mosaic on TPU only).
    """
    from repro.kernels.ops import resolve_interpret   # deferred: ops imports us
    interpret = resolve_interpret(interpret)
    M, K, Wd = x_packed.shape
    K2, O, Wd2 = w_packed.shape
    assert K == K2 and Wd == Wd2 and M % bm == 0 and O % bo == 0
    assert K & (K - 1) == 0, "K must be padded to a power of two"

    grid = (M // bm, O // bo)
    return pl.pallas_call(
        functools.partial(_sc_dot_kernel, s0_mode=s0_mode, adder=adder),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K, Wd), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((K, bo, Wd), lambda i, j: (0, j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, O), jnp.int32),
        interpret=interpret,
    )(x_packed, w_packed)
