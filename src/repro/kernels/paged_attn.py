"""Pallas TPU paged decode-attention kernel (single layer, one query token).

The XLA paged path (``nn.attention.attend_decode_paged``) gathers each
request's block chain into a dense (B, S, Hkv, D) array before attending —
at production sizes that materializes the whole cache in HBM every decode
tick.  This kernel reads K/V *directly out of the block arena*: the block
table rides in as a scalar-prefetch operand, so the BlockSpec index map can
route grid step (b, j) at the arena block ``table[b, j]`` and the DMA
engine streams exactly the blocks each request owns — HBM traffic is one
read of the live blocks and nothing else, and the trash block (id 0) that
pads short chains is masked out by ``lens`` like any overlong position.

Grid (B, nb): the trailing dim iterates a request's chain sequentially, so
the online-softmax state (m, l, acc) lives in VMEM scratch across the sweep
— the same structure as ``flash_attn.py`` with the block table supplying
the indirection.  GQA is handled in-kernel: q (Hq, D) is viewed as
(Hkv, n_rep, D) and batched against the block's (Hkv, bs, D) K tile.

Sliding windows ride in as a third scalar-prefetch operand: positions
outside ``[lens - window, lens)`` are masked to NEG_INF exactly like
``attend_decode``'s trailing-window bound (a huge window disables it, which
is also how ``lm.layer_window`` encodes per-layer global attention), so the
serving tick can dispatch every attention family's layers — global and
sliding alike — through one kernel.

Validated in interpret mode against ``attend_decode_paged`` over
shape/dtype/table/window permutations (tests/test_paged_attn.py), and
wired into the serving tick by ``engine.decode_step_paged`` (the
``backend="pallas"`` path of the paged slot adapter).

The cascade extension (``backend="cascade"``) splits decode attention over
a shared radix prefix and per-lane divergent suffixes and merges the
partial online-softmax states by log-sum-exp: ``cascade_prefix_attention``
runs one multi-query pass per shared chain (prefix KV streamed once per
*group*, not once per lane), ``paged_decode_attention_with_state`` is this
file's flat sweep restarted at an absolute position offset ``q0`` and
returning its *unnormalized* (acc, m, l) state, and ``merge_attn_states``
fuses the two states and normalizes.  Unlike the flat kernel, the state
kernels zero masked probabilities (``p *= valid``) so an all-masked sweep
yields the empty state (m = NEG_INF, l = 0) that the merge drops exactly —
the flat kernel can leave garbage in fully-masked lanes because it
normalizes in place and its callers mask those lanes out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# a window this large never masks (same encoding as lm._GLOBAL_WINDOW)
NO_WINDOW = 1 << 30


def _paged_kernel(tables_ref, lens_ref, win_ref, q_ref, k_ref, v_ref, *rest,
                  bs: int, nb: int, n_rep: int, scale: float, splice: bool):
    if splice:
        k1_ref, v1_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (Hq, D)
    k = k_ref[0].astype(jnp.float32)              # (bs, Hkv, D)
    v = v_ref[0].astype(jnp.float32)              # (bs, Hkv, D)
    Hq, D = q.shape
    Hkv = k.shape[1]
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    if splice:
        # the current token's K/V row, overlaid at its position instead of
        # pre-written into the arena: the sweep reads live blocks only and
        # the arena write stays a single post-scan row per layer
        here = (pos == lens_ref[b] - 1).reshape(bs, 1, 1)
        k = jnp.where(here, k1_ref[0].astype(jnp.float32)[None], k)
        v = jnp.where(here, v1_ref[0].astype(jnp.float32)[None], v)
    kt = jnp.swapaxes(k, 0, 1)                    # (Hkv, bs, D)
    qh = q.reshape(Hkv, n_rep, D)
    s = jax.lax.dot_general(qh, kt, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    s = s.reshape(Hq, bs)
    valid = (pos < lens_ref[b]) & (pos >= lens_ref[b] - win_ref[0])
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])               # (Hq, bs)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
    m_scr[...] = m_new
    vt = jnp.swapaxes(v, 0, 1)                    # (Hkv, bs, D)
    ph = p.reshape(Hkv, n_rep, bs)
    o = jax.lax.dot_general(ph, vt, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + o.reshape(Hq, D)

    @pl.when(j == nb - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def _scatter_kernel(wbids_ref, offs_ref, kr_ref, vr_ref, ka_ref, va_ref,
                    ko_ref, vo_ref):
    # grid (L, S): layer l writes lane b's K and V rows at row offs[b] of
    # arena block wbids[b].  The arena refs alias the outputs, so every
    # block not addressed by some (l, b) keeps its bytes untouched — no
    # functional rebuild of the layer slice.  A *visited* block's output
    # window, however, is written back whole at the window switch, so the
    # other bs-1 rows must be seeded from the fetched input block first —
    # without this, Mosaic would write back an uninitialized VMEM window
    # and clobber the live rows the lane already wrote this block
    # (interpret mode masks that, because there the aliased output
    # literally *is* the input buffer).
    b = pl.program_id(1)
    ko_ref[...] = ka_ref[...]
    vo_ref[...] = va_ref[...]
    ko_ref[0, 0, 0, offs_ref[b]] = kr_ref[0, 0]
    vo_ref[0, 0, 0, offs_ref[b]] = vr_ref[0, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def scatter_kv_rows(k_arena, v_arena, k_rows, v_rows, wbids, offs, *,
                    interpret: bool | None = None):
    """Land the decode tick's per-layer K/V rows in the arena **in place**.

    k_arena, v_arena: (L, num_blocks, 1, bs, Hkv, D) — the layer-leading
    ``engine.init_paged_arena`` layout; k_rows, v_rows: (L, S, Hkv, D) the
    new token's post-RoPE rows per layer and lane; wbids: (S,) int32 arena
    block per lane (the caller routes masked lanes to the trash block);
    offs: (S,) int32 row within the block (``len % bs``).

    ``input_output_aliases`` donates both arenas into their outputs: the
    kernel touches exactly the (layer, block) tiles the block table names
    and every other block's bytes stay where they are — the Pallas leg's
    counterpart of the XLA buffer donation that already makes the
    ``.at[].set`` reference leg update in place.  Semantically identical
    to ``arena.at[:, wbids, 0, offs].set(rows)`` wherever the (block,
    row) targets are unique — they are for every live lane; only
    trash-routed lanes may collide, and the trash block's contents are
    garbage under both orders (asserted in tests/test_paged_attn.py).
    """
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    L, nb, _, bs, Hkv, D = k_arena.shape
    S = wbids.shape[0]
    row = pl.BlockSpec((1, 1, Hkv, D), lambda l, b, w, o: (l, b, 0, 0))
    blk = pl.BlockSpec((1, 1, 1, bs, Hkv, D),
                       lambda l, b, w, o: (l, w[b], 0, 0, 0, 0))
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(L, S),
            in_specs=[row, row, blk, blk],
            out_specs=[blk, blk],
        ),
        out_shape=[jax.ShapeDtypeStruct(k_arena.shape, k_arena.dtype),
                   jax.ShapeDtypeStruct(v_arena.shape, v_arena.dtype)],
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(jnp.asarray(wbids, jnp.int32), jnp.asarray(offs, jnp.int32),
      k_rows, v_rows, k_arena, v_arena)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_arena, v_arena, tables, lens, *,
                           window=None, new_kv=None,
                           interpret: bool | None = None):
    """q: (B, Hq, D); k_arena, v_arena: (num_blocks, bs, Hkv, D);
    tables: (B, nb) int32 arena block ids; lens: (B,) int32 valid lengths.
    ``window``: optional scalar (may be traced — the per-layer
    sliding/global selection is data-dependent inside a layer scan); only
    the trailing ``window`` positions attend.  None or 0 disables masking.
    ``new_kv``: optional (k1, v1), each (B, Hkv, D) — the current token's
    K/V row, overlaid in-kernel at position ``lens - 1`` so the serving
    tick never has to pre-write the row into the arena (a functional
    arena-slice update per layer would copy every block, live or not —
    exactly the traffic this kernel exists to avoid).
    Returns (B, Hq, D) in v_arena.dtype.
    ``interpret=None`` auto-detects the backend (Mosaic on TPU only).
    """
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, Hq, D = q.shape
    _, bs, Hkv, _ = k_arena.shape
    nb = tables.shape[1]
    n_rep = Hq // Hkv
    scale = D ** -0.5
    if window is None:
        window = NO_WINDOW
    win = jnp.where(jnp.asarray(window, jnp.int32) == 0, NO_WINDOW,
                    jnp.asarray(window, jnp.int32)).reshape(1)
    row = pl.BlockSpec((1, Hq, D), lambda b, j, t, ln, w: (b, 0, 0))
    blk = pl.BlockSpec((1, bs, Hkv, D),
                       lambda b, j, t, ln, w: (t[b, j], 0, 0, 0))
    kv_row = pl.BlockSpec((1, Hkv, D), lambda b, j, t, ln, w: (b, 0, 0))
    splice = new_kv is not None
    operands = (jnp.asarray(tables, jnp.int32), jnp.asarray(lens, jnp.int32),
                win, q, k_arena, v_arena)
    in_specs = [row, blk, blk]
    if splice:
        operands += tuple(new_kv)
        in_specs += [kv_row, kv_row]
    return pl.pallas_call(
        functools.partial(_paged_kernel, bs=bs, nb=nb, n_rep=n_rep,
                          scale=scale, splice=splice),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, nb),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, Hq, D),
                                   lambda b, j, t, ln, w: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((Hq,), jnp.float32),      # running max
                pltpu.VMEM((Hq,), jnp.float32),      # running sum
                pltpu.VMEM((Hq, D), jnp.float32),    # output accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), v_arena.dtype),
        interpret=interpret,
    )(*operands)


def _paged_state_kernel(tables_ref, lens_ref, win_ref, q0_ref, q_ref, k_ref,
                        v_ref, *rest, bs: int, nb: int, n_rep: int,
                        scale: float, splice: bool):
    if splice:
        k1_ref, v1_ref, acc_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = rest
    else:
        acc_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (Hq, D)
    k = k_ref[0].astype(jnp.float32)              # (bs, Hkv, D)
    v = v_ref[0].astype(jnp.float32)
    Hq, D = q.shape
    Hkv = k.shape[1]
    # absolute positions: this sweep covers [q0, q0 + nb*bs) of the chain
    pos = q0_ref[b] + j * bs + \
        jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    if splice:
        here = (pos == lens_ref[b] - 1).reshape(bs, 1, 1)
        k = jnp.where(here, k1_ref[0].astype(jnp.float32)[None], k)
        v = jnp.where(here, v1_ref[0].astype(jnp.float32)[None], v)
    kt = jnp.swapaxes(k, 0, 1)                    # (Hkv, bs, D)
    qh = q.reshape(Hkv, n_rep, D)
    s = jax.lax.dot_general(qh, kt, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    s = s.reshape(Hq, bs)
    valid = (pos < lens_ref[b]) & (pos >= lens_ref[b] - win_ref[0])
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    # unlike the flat kernel, zero masked probabilities: an all-masked
    # sweep must return the EMPTY state (m = NEG_INF, l = 0) — with both
    # operands at NEG_INF, exp(s - m) is exp(0) = 1 per position, which
    # would poison the cascade merge with a phantom uniform distribution
    p = jnp.exp(s - m_new[:, None]) * valid.astype(jnp.float32)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
    m_scr[...] = m_new
    vt = jnp.swapaxes(v, 0, 1)                    # (Hkv, bs, D)
    ph = p.reshape(Hkv, n_rep, bs)
    o = jax.lax.dot_general(ph, vt, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + o.reshape(Hq, D)

    @pl.when(j == nb - 1)
    def _finish():
        acc_ref[0] = acc_scr[...]
        m_ref[0] = m_scr[...]
        l_ref[0] = l_scr[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_with_state(q, k_arena, v_arena, tables, lens, *,
                                      window=None, q0=None, new_kv=None,
                                      interpret: bool | None = None):
    """The flat paged sweep, restarted at an offset and left unnormalized.

    Same operands as :func:`paged_decode_attention` plus ``q0``: (B,)
    int32 absolute position of each lane's first table entry — the table
    names the lane's *divergent suffix* blocks and positions are
    ``q0[b] + j*bs + i``, so the ``lens``/``window`` bounds select exactly
    the suffix share of the flat kernel's key set (the group prefix pass
    covers ``[0, q0)``; disjoint and complete).  Returns the float32
    online-softmax state ``(acc (B, Hq, D), m (B, Hq), l (B, Hq))`` for
    :func:`merge_attn_states`; the new-token row still splices at
    ``lens - 1``, which always falls in the suffix (the shared prefix is
    full blocks only).
    """
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, Hq, D = q.shape
    _, bs, Hkv, _ = k_arena.shape
    nb = tables.shape[1]
    n_rep = Hq // Hkv
    scale = D ** -0.5
    if window is None:
        window = NO_WINDOW
    win = jnp.where(jnp.asarray(window, jnp.int32) == 0, NO_WINDOW,
                    jnp.asarray(window, jnp.int32)).reshape(1)
    if q0 is None:
        q0 = jnp.zeros((B,), jnp.int32)
    row = pl.BlockSpec((1, Hq, D), lambda b, j, t, ln, w, z: (b, 0, 0))
    hrow = pl.BlockSpec((1, Hq), lambda b, j, t, ln, w, z: (b, 0))
    blk = pl.BlockSpec((1, bs, Hkv, D),
                       lambda b, j, t, ln, w, z: (t[b, j], 0, 0, 0))
    kv_row = pl.BlockSpec((1, Hkv, D), lambda b, j, t, ln, w, z: (b, 0, 0))
    splice = new_kv is not None
    operands = (jnp.asarray(tables, jnp.int32), jnp.asarray(lens, jnp.int32),
                win, jnp.asarray(q0, jnp.int32), q, k_arena, v_arena)
    in_specs = [row, blk, blk]
    if splice:
        operands += tuple(new_kv)
        in_specs += [kv_row, kv_row]
    return pl.pallas_call(
        functools.partial(_paged_state_kernel, bs=bs, nb=nb, n_rep=n_rep,
                          scale=scale, splice=splice),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(B, nb),
            in_specs=in_specs,
            out_specs=[row, hrow, hrow],
            scratch_shapes=[
                pltpu.VMEM((Hq,), jnp.float32),      # running max
                pltpu.VMEM((Hq,), jnp.float32),      # running sum
                pltpu.VMEM((Hq, D), jnp.float32),    # output accumulator
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((B, Hq, D), jnp.float32),
                   jax.ShapeDtypeStruct((B, Hq), jnp.float32),
                   jax.ShapeDtypeStruct((B, Hq), jnp.float32)],
        interpret=interpret,
    )(*operands)


def _cascade_prefix_kernel(tables_ref, glen_ref, win_ref, ll_ref, q_ref,
                           k_ref, v_ref, acc_ref, m_ref, l_ref, m_scr,
                           l_scr, acc_scr, *, bs: int, nb: int, n_rep: int,
                           scale: float):
    g = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (Lc, Hq, D)
    k = k_ref[0].astype(jnp.float32)              # (bs, Hkv, D)
    v = v_ref[0].astype(jnp.float32)
    Lc, Hq, D = q.shape
    Hkv = k.shape[1]
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    # one key set per group, one validity row per lane: the chain bound is
    # shared (group_len), the window bound is each lane's own length
    valid = pos < glen_ref[g]                                  # (1, 1, bs)
    lane_len = ll_ref[0].reshape(Lc, 1, 1)                     # (Lc, 1, 1)
    valid = valid & (pos >= lane_len - win_ref[0])             # (Lc, 1, bs)

    kt = jnp.swapaxes(k, 0, 1)                    # (Hkv, bs, D)
    qh = q.reshape(Lc, Hkv, n_rep, D).transpose(1, 0, 2, 3) \
        .reshape(Hkv, Lc * n_rep, D)
    s = jax.lax.dot_general(qh, kt, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    s = s.reshape(Hkv, Lc, n_rep, bs).transpose(1, 0, 2, 3) \
        .reshape(Lc, Hq, bs)
    valid = jnp.broadcast_to(valid, (Lc, Hq, bs))
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None]) * valid.astype(jnp.float32)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
    m_scr[...] = m_new
    vt = jnp.swapaxes(v, 0, 1)                    # (Hkv, bs, D)
    ph = p.reshape(Lc, Hkv, n_rep, bs).transpose(1, 0, 2, 3) \
        .reshape(Hkv, Lc * n_rep, bs)
    o = jax.lax.dot_general(ph, vt, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    o = o.reshape(Hkv, Lc, n_rep, D).transpose(1, 0, 2, 3) \
        .reshape(Lc, Hq, D)
    acc_scr[...] = acc_scr[...] * corr[..., None] + o

    @pl.when(j == nb - 1)
    def _finish():
        acc_ref[0] = acc_scr[...]
        m_ref[0] = m_scr[...]
        l_ref[0] = l_scr[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def cascade_prefix_attention(qg, k_arena, v_arena, group_tables, group_len,
                             lane_lens, *, window=None,
                             interpret: bool | None = None):
    """One multi-query pass per shared-prefix chain.

    qg: (G, Lc, Hq, D) the grouped lanes' query rows; group_tables:
    (G, npre) int32 shared chain block ids (trash-padded); group_len: (G,)
    int32 prefix tokens (0 for pad groups — their state comes back empty);
    lane_lens: (G, Lc) int32 each lane's cache length, which anchors the
    sliding-window bound ``pos >= lane_len - window`` when the window
    clips into the shared prefix.  Grid (G, npre): each chain's KV
    streams out of the arena ONCE and every lane of the group attends it
    from the Lc axis.  Returns float32 ``(acc (G, Lc, Hq, D), m, l
    (G, Lc, Hq))`` — unnormalized, for :func:`merge_attn_states` after
    the caller scatters group slots back to lanes.
    """
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    G, Lc, Hq, D = qg.shape
    _, bs, Hkv, _ = k_arena.shape
    nb = group_tables.shape[1]
    n_rep = Hq // Hkv
    scale = D ** -0.5
    if window is None:
        window = NO_WINDOW
    win = jnp.where(jnp.asarray(window, jnp.int32) == 0, NO_WINDOW,
                    jnp.asarray(window, jnp.int32)).reshape(1)
    qrow = pl.BlockSpec((1, Lc, Hq, D), lambda g, j, t, gl, w: (g, 0, 0, 0))
    lrow = pl.BlockSpec((1, Lc), lambda g, j, t, gl, w: (g, 0))
    blk = pl.BlockSpec((1, bs, Hkv, D),
                       lambda g, j, t, gl, w: (t[g, j], 0, 0, 0))
    grow = pl.BlockSpec((1, Lc, Hq, D), lambda g, j, t, gl, w: (g, 0, 0, 0))
    hrow = pl.BlockSpec((1, Lc, Hq), lambda g, j, t, gl, w: (g, 0, 0))
    return pl.pallas_call(
        functools.partial(_cascade_prefix_kernel, bs=bs, nb=nb, n_rep=n_rep,
                          scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(G, nb),
            in_specs=[lrow, qrow, blk, blk],
            out_specs=[grow, hrow, hrow],
            scratch_shapes=[
                pltpu.VMEM((Lc, Hq), jnp.float32),     # running max
                pltpu.VMEM((Lc, Hq), jnp.float32),     # running sum
                pltpu.VMEM((Lc, Hq, D), jnp.float32),  # output accumulator
            ],
        ),
        out_shape=[jax.ShapeDtypeStruct((G, Lc, Hq, D), jnp.float32),
                   jax.ShapeDtypeStruct((G, Lc, Hq), jnp.float32),
                   jax.ShapeDtypeStruct((G, Lc, Hq), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(group_tables, jnp.int32), jnp.asarray(group_len, jnp.int32),
      win, jnp.asarray(lane_lens, jnp.int32), qg, k_arena, v_arena)


def _merge_kernel(acc1_ref, m1_ref, l1_ref, acc2_ref, m2_ref, l2_ref, o_ref):
    m1 = m1_ref[0]
    m2 = m2_ref[0]
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    l = c1 * l1_ref[0] + c2 * l2_ref[0]
    acc = c1[:, None] * acc1_ref[0] + c2[:, None] * acc2_ref[0]
    o_ref[0] = acc / jnp.maximum(l, 1e-30)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_attn_states(acc1, m1, l1, acc2, m2, l2, *,
                      interpret: bool | None = None):
    """Log-sum-exp merge of two partial softmax states, then normalize.

    acc: (B, Hq, D) float32 unnormalized accumulators; m, l: (B, Hq)
    float32 running max / sum.  The Pallas counterpart of
    ``nn.attention.merge_softmax_states`` + the final ``acc / max(l,
    tiny)`` division: an empty side (m = NEG_INF, l = 0) drops out
    through exp underflow, both sides empty yields zeros.  Returns
    (B, Hq, D) float32.
    """
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, Hq, D = acc1.shape
    arow = pl.BlockSpec((1, Hq, D), lambda b: (b, 0, 0))
    hrow = pl.BlockSpec((1, Hq), lambda b: (b, 0))
    return pl.pallas_call(
        _merge_kernel,
        grid=(B,),
        in_specs=[arow, hrow, hrow, arow, hrow, hrow],
        out_specs=arow,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), jnp.float32),
        interpret=interpret,
    )(acc1, m1, l1, acc2, m2, l2)
