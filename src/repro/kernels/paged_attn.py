"""Pallas TPU paged decode-attention kernel (single layer, one query token).

The XLA paged path (``nn.attention.attend_decode_paged``) gathers each
request's block chain into a dense (B, S, Hkv, D) array before attending —
at production sizes that materializes the whole cache in HBM every decode
tick.  This kernel reads K/V *directly out of the block arena*: the block
table rides in as a scalar-prefetch operand, so the BlockSpec index map can
route grid step (b, j) at the arena block ``table[b, j]`` and the DMA
engine streams exactly the blocks each request owns — HBM traffic is one
read of the live blocks and nothing else, and the trash block (id 0) that
pads short chains is masked out by ``lens`` like any overlong position.

Grid (B, nb): the trailing dim iterates a request's chain sequentially, so
the online-softmax state (m, l, acc) lives in VMEM scratch across the sweep
— the same structure as ``flash_attn.py`` with the block table supplying
the indirection.  GQA is handled in-kernel: q (Hq, D) is viewed as
(Hkv, n_rep, D) and batched against the block's (Hkv, bs, D) K tile.

Validated in interpret mode against ``attend_decode_paged`` over
shape/dtype/table permutations (tests/test_paged_attn.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, bs: int, nb: int, n_rep: int,
                  scale: float):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (Hq, D)
    k = k_ref[0].astype(jnp.float32)              # (bs, Hkv, D)
    Hq, D = q.shape
    Hkv = k.shape[1]
    kt = jnp.swapaxes(k, 0, 1)                    # (Hkv, bs, D)
    qh = q.reshape(Hkv, n_rep, D)
    s = jax.lax.dot_general(qh, kt, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    s = s.reshape(Hq, bs)
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    s = jnp.where(pos < lens_ref[b], s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])               # (Hq, bs)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
    m_scr[...] = m_new
    vt = jnp.swapaxes(v_ref[0].astype(jnp.float32), 0, 1)   # (Hkv, bs, D)
    ph = p.reshape(Hkv, n_rep, bs)
    o = jax.lax.dot_general(ph, vt, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + o.reshape(Hq, D)

    @pl.when(j == nb - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_arena, v_arena, tables, lens, *,
                           interpret: bool | None = None):
    """q: (B, Hq, D); k_arena, v_arena: (num_blocks, bs, Hkv, D);
    tables: (B, nb) int32 arena block ids; lens: (B,) int32 valid lengths.
    Returns (B, Hq, D) in v_arena.dtype.
    ``interpret=None`` auto-detects the backend (Mosaic on TPU only).
    """
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    B, Hq, D = q.shape
    _, bs, Hkv, _ = k_arena.shape
    nb = tables.shape[1]
    n_rep = Hq // Hkv
    scale = D ** -0.5
    return pl.pallas_call(
        functools.partial(_paged_kernel, bs=bs, nb=nb, n_rep=n_rep,
                          scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, nb),
            in_specs=[
                pl.BlockSpec((1, Hq, D), lambda b, j, t, ln: (b, 0, 0)),
                pl.BlockSpec((1, bs, Hkv, D),
                             lambda b, j, t, ln: (t[b, j], 0, 0, 0)),
                pl.BlockSpec((1, bs, Hkv, D),
                             lambda b, j, t, ln: (t[b, j], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, Hq, D), lambda b, j, t, ln: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((Hq,), jnp.float32),      # running max
                pltpu.VMEM((Hq,), jnp.float32),      # running sum
                pltpu.VMEM((Hq, D), jnp.float32),    # output accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), v_arena.dtype),
        interpret=interpret,
    )(jnp.asarray(tables, jnp.int32), jnp.asarray(lens, jnp.int32),
      q, k_arena, v_arena)
