"""Pure-jnp oracles for the Pallas kernels.

These are the ground-truth implementations every kernel is tested against
(``interpret=True`` on CPU, shape/dtype sweeps in tests/test_kernels.py).
They mirror the count-domain semantics proven bit-exact to the gate-level
simulation in tests/test_arith.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def popcount_u32(x: jax.Array) -> jax.Array:
    return jnp.bitwise_count(x).astype(jnp.int32)


def tff_tree(counts: jax.Array, s0_mode: str = "alt") -> jax.Array:
    """TFF adder tree over axis -2 of ``counts`` (..., K, O) -> (..., O)."""
    K = counts.shape[-2]
    depth = max(1, int(np.ceil(np.log2(max(K, 2)))))
    pad = (1 << depth) - K
    if pad:
        counts = jnp.concatenate(
            [counts, jnp.zeros(counts.shape[:-2] + (pad, counts.shape[-1]),
                               counts.dtype)], axis=-2)
    c = counts
    for level in range(depth):
        half = c.shape[-2] // 2
        c2 = c.reshape(c.shape[:-2] + (half, 2, c.shape[-1]))
        left, right = c2[..., 0, :], c2[..., 1, :]
        idx = jnp.arange(half, dtype=c.dtype)[..., None]
        if s0_mode == "zero":
            s0 = jnp.zeros_like(idx)
        elif s0_mode == "one":
            s0 = jnp.ones_like(idx)
        else:  # alt
            s0 = (idx + level) & 1
        c = (left + right + s0) >> 1
    return c[..., 0, :]


def sc_dot(x_packed: jax.Array, w_packed: jax.Array, s0_mode: str = "alt",
           adder: str = "tff") -> jax.Array:
    """Oracle for the sc_dot kernel.

    x_packed: (M, K, Wd) uint32 — M windows of K packed activation streams.
    w_packed: (K, O, Wd) uint32 — K packed weight streams for O outputs.
    Returns (M, O) int32: TFF-tree-reduced popcounts of the AND products
    (``adder="ideal"`` uses a plain sum >> depth instead).
    """
    prods = x_packed[:, :, None, :] & w_packed[None, :, :, :]   # (M, K, O, Wd)
    counts = jnp.sum(popcount_u32(prods), axis=-1)              # (M, K, O)
    if adder == "ideal":
        K = x_packed.shape[1]
        depth = max(1, int(np.ceil(np.log2(max(K, 2)))))
        return (jnp.sum(counts, axis=1) >> depth).astype(jnp.int32)
    return tff_tree(counts, s0_mode).astype(jnp.int32)


def flash_attention(q, k, v, causal: bool = True) -> jax.Array:
    """Oracle for the flash_attn kernel: naive softmax attention.
    q, k, v: (BH, S, D)."""
    D = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    if causal:
        S = q.shape[1]
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)
                      ).astype(v.dtype)


def sng_pack(levels: jax.Array, codes: jax.Array, length: int) -> jax.Array:
    """Oracle for the sng_pack kernel: comparator SNG + bit packing.

    levels: (...,) int32 in [0, N]; codes: (N,) int32.
    Returns (..., N//32) uint32 (N must be a multiple of 32 here; shorter
    streams are handled by the sc_layer path, not the kernel).
    """
    assert length % 32 == 0
    bits = (codes[None, :] < levels.reshape(-1, 1)).astype(jnp.uint32)
    bits = bits.reshape(-1, length // 32, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    packed = jnp.sum(bits * weights, axis=-1).astype(jnp.uint32)
    return packed.reshape(levels.shape + (length // 32,))
