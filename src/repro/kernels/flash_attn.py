"""Pallas TPU flash-attention (forward) kernel.

The §Perf iterations showed attention probability traffic dominating the
dry-run memory term even after the custom-VJP fix (the XLA chunked path
still materializes per-tile probabilities at fusion boundaries).  On real
TPU the fix is this kernel: probabilities never leave VMEM — HBM traffic is
one read of q/k/v and one write of out.

Grid (B*H, nq, nk): TPU iterates the trailing grid dim sequentially, so the
online-softmax state (m, l, acc) lives in VMEM scratch across the kv sweep
of each q block.  Blocks are (qc, D)/(kc, D) with D lane-aligned (the MXU
dims are qc x D x kc, all multiples of the 8x128 register tile at production
sizes).

Forward-only: training wires it through `jax.custom_vjp` exactly like
`nn.attention._flash` (the backward kernel mirrors the structure; the XLA
custom-VJP backward remains the fallback).  Validated in interpret mode
against `ref.flash_attention` over shape/dtype sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, qc: int, kc: int, nk: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (qc, D)
    k = k_ref[0].astype(jnp.float32)            # (kc, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = iq * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
        k_pos = jk * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(jk == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "qc", "kc",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, qc: int = 128,
                    kc: int = 128, interpret: bool | None = None):
    """q, k, v: (BH, S, D) — batch*heads flattened (GQA repeat upstream).
    Returns (BH, S, D) in v.dtype.  S must divide by qc and kc.
    ``interpret=None`` auto-detects the backend (Mosaic on TPU only).
    """
    from repro.kernels.ops import resolve_interpret
    interpret = resolve_interpret(interpret)
    BH, S, D = q.shape
    assert S % qc == 0 and S % kc == 0
    nq, nk = S // qc, S // kc
    scale = D ** -0.5
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          qc=qc, kc=kc, nk=nk),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, qc, D), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, kc, D), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, kc, D), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qc, D), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((qc,), jnp.float32),      # running max
            pltpu.VMEM((qc,), jnp.float32),      # running sum
            pltpu.VMEM((qc, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
