"""Pallas TPU kernel: comparator SNG + bit-packing.

Generates packed stochastic streams from integer levels on-chip, so the
HBM->VMEM traffic is ``4 bytes/level`` in and ``N/8 bytes/stream`` out with no
intermediate (N,)-bool materialization in HBM.  The comparator's code
sequence (ramp / van-der-Corput / reversed-Gray / LFSR) is a small constant
(N int32 = 1KiB at 8-bit) broadcast to every grid cell.

Per grid cell: levels tile (blk,) int32 and codes (N,) int32 produce a
(blk, N/32) uint32 tile: bit t of word w = (codes[32w+t] < level).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sng_pack_kernel(lvl_ref, codes_ref, out_ref, *, length: int):
    lvl = lvl_ref[...]                       # (blk,)
    codes = codes_ref[...]                   # (length,)
    nw = length // 32
    codes2 = codes.reshape(nw, 32)           # (nw, 32)
    bits = (codes2[None, :, :] < lvl[:, None, None]).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jax.lax.broadcasted_iota(jnp.uint32,
                                                         (1, 1, 32), 2))
    out_ref[...] = jnp.sum(bits * weights, axis=-1).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("length", "block", "interpret"))
def sng_pack_pallas(levels: jax.Array, codes: jax.Array, *, length: int,
                    block: int = 256,
                    interpret: bool | None = None) -> jax.Array:
    """levels: (M,) int32 (M % block == 0); codes: (length,) int32.
    Returns (M, length//32) uint32 packed streams.
    ``interpret=None`` auto-detects the backend (Mosaic on TPU only)."""
    from repro.kernels.ops import resolve_interpret   # deferred: ops imports us
    interpret = resolve_interpret(interpret)
    M = levels.shape[0]
    assert M % block == 0
    nw = length // 32
    return pl.pallas_call(
        functools.partial(_sng_pack_kernel, length=length),
        grid=(M // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((length,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block, nw), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, nw), jnp.uint32),
        interpret=interpret,
    )(levels, codes)
