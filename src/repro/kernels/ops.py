"""Public jit'd wrappers for the Pallas kernels: padding, blocking, and the
level->stream->dot composition used by the SC first layer.

Execution mode is auto-detected: off-TPU the kernels run in ``interpret``
mode (the kernel body executes bit-exactly through the Pallas interpreter);
on a TPU backend they lower through Mosaic.  Every kernel entry point takes
``interpret=None`` meaning "ask :func:`default_interpret`", so tests and
benchmarks can still force either mode explicitly.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sng
from repro.kernels import ref
from repro.kernels.sc_dot import sc_dot_pallas
from repro.kernels.sng_pack import sng_pack_pallas


def default_interpret() -> bool:
    """Pallas interpret mode unless a real TPU backend is attached.

    The single backend probe shared by every kernel wrapper (sng_pack,
    sc_dot, flash_attn, paged_attn): Mosaic lowering exists only for TPU, so
    anything else — the CPU CI container included — interprets.

    ``REPRO_KERNELS_INTERPRET`` overrides the probe when set and non-empty
    ("0"/"false"/"no" force Mosaic, anything else forces interpret) — the CI
    ``kernels-interpret`` matrix leg sets it to "1" so the Pallas kernel
    bodies are exercised deliberately rather than by backend accident.
    """
    env = os.environ.get("REPRO_KERNELS_INTERPRET", "").strip().lower()
    if env:
        return env not in ("0", "false", "no")
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` -> auto-detect; an explicit bool always wins."""
    return default_interpret() if interpret is None else bool(interpret)


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads)


def _next_pow2(k: int) -> int:
    return 1 << max(1, int(np.ceil(np.log2(max(k, 2)))))


def sc_dot(x_packed: jax.Array, w_packed: jax.Array, *, s0_mode: str = "alt",
           adder: str = "tff", bm: int = 128, bo: int = 128,
           interpret: bool | None = None) -> jax.Array:
    """Stochastic dot product on packed streams.

    x_packed: (M, K, Wd) uint32;  w_packed: (K, O, Wd) uint32.
    Returns (M, O) int32 TFF-tree root counts.  Zero-padding K to the next
    power of two adds all-zero streams — exactly the fixed tree's unused
    leaves (bit-identical to the oracle, which pads the same way).
    """
    interpret = resolve_interpret(interpret)
    M, K, Wd = x_packed.shape
    _, O, _ = w_packed.shape
    Kp = _next_pow2(K)
    x_packed = _pad_to(x_packed, 1, Kp)
    w_packed = _pad_to(w_packed, 0, Kp)
    bm_eff = min(bm, M) if M % bm else bm
    bo_eff = min(bo, O) if O % bo else bo
    xp = _pad_to(x_packed, 0, bm_eff)
    wp = _pad_to(w_packed, 1, bo_eff)
    out = sc_dot_pallas(xp, wp, bm=bm_eff, bo=bo_eff, s0_mode=s0_mode,
                        adder=adder, interpret=interpret)
    return out[:M, :O]


def sc_dot_from_levels(x_lvl: jax.Array, w_lvl: jax.Array, bits: int, *,
                       scheme: str = "ramp_lowdisc", s0_mode: str = "alt",
                       adder: str = "tff",
                       interpret: bool | None = None) -> jax.Array:
    """Full SC datapath from integer levels: SNG pack (kernel) -> dot (kernel).

    x_lvl: (M, K) int32 levels 0..N;  w_lvl: (K, O) int32 levels.
    Stream length N = 2**bits must be >= 32 to use the packed kernels
    (shorter streams use the sc_layer table path).
    """
    interpret = resolve_interpret(interpret)
    N = 1 << bits
    codes_a, codes_b = sng.codes_for_scheme(scheme, bits)
    x_stream = sng_pack(x_lvl, jnp.asarray(codes_a, jnp.int32), N,
                        interpret=interpret)
    w_stream = sng_pack(w_lvl, jnp.asarray(codes_b, jnp.int32), N,
                        interpret=interpret)
    return sc_dot(x_stream, w_stream, s0_mode=s0_mode, adder=adder,
                  interpret=interpret)


def sng_pack(levels: jax.Array, codes: jax.Array, length: int, *,
             interpret: bool | None = None, block: int = 256) -> jax.Array:
    """Comparator SNG + packing as a Pallas kernel.

    levels: any shape, int32 in [0, N]; returns (..., N//32) uint32.
    """
    interpret = resolve_interpret(interpret)
    assert length % 32 == 0, "packed SNG kernel needs N % 32 == 0"
    shape = levels.shape
    flat = levels.reshape(-1)
    n = flat.shape[0]
    blk = min(block, max(8, n))
    flat = _pad_to(flat, 0, blk)
    out = sng_pack_pallas(flat, codes, length=length, block=blk,
                          interpret=interpret)
    return out[:n].reshape(shape + (length // 32,))


def sc_dot_posneg(x_packed: jax.Array, w_pos: jax.Array, w_neg: jax.Array,
                  **kw) -> tuple[jax.Array, jax.Array]:
    """Fused pos/neg dot products (§Perf kernel iteration): the paper's
    split-weight design needs BOTH ``x∘w_pos`` and ``x∘w_neg``; running them
    as separate kernel calls reads every X tile from HBM twice.  Packing the
    two weight banks along the O axis computes both in one pass — X traffic
    halves (~40% total HBM-byte cut at LeNet shapes, see kernel_bench).

    Returns (counts_pos, counts_neg), each (M, O) int32.
    """
    O = w_pos.shape[1]
    w = jnp.concatenate([w_pos, w_neg], axis=1)    # (K, 2O, Wd)
    out = sc_dot(x_packed, w, **kw)                # X tiles read once
    return out[:, :O], out[:, O:]


# Re-export oracle for convenience in tests/benchmarks.
oracle_sc_dot = ref.sc_dot
oracle_sng_pack = ref.sng_pack
