"""Attention: GQA / MHA / cross, memory-efficient chunked ("flash-style in
XLA") for train/prefill and masked single-shot for decode.

The chunked path is a double ``lax.scan`` (query chunks x KV chunks) with an
online-softmax accumulator, so peak memory is O(q_chunk x kv_chunk) scores
per head instead of O(S^2); XLA keeps the HLO compact (one scan body), which
matters for the 126-layer dry-run compiles.  Scores/softmax accumulate in f32.

Sliding-window masks reuse the same body (mask-only; no dynamic skipping —
shapes stay static for SPMD).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.sharding import hint

NEG_INF = -1e30


def _repeat_kv(k, n_rep: int):
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D) by broadcast (GQA)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d))
    return k.reshape(b, s, h * n_rep, d)


def _static_zero(window) -> bool:
    return isinstance(window, int) and window == 0


def _mask(q_pos, k_pos, causal: bool, window):
    """(Sq, Sk) bool validity mask from absolute positions.

    ``window`` may be a traced scalar (per-layer global/sliding selection
    inside a layer scan encodes "global" as a huge window); a static 0 means
    no windowing at all.
    """
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), jnp.bool_)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if not _static_zero(window):
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def attend_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                   q_offset: int = 0, q_chunk: int = 512, kv_chunk: int = 1024,
                   ) -> jax.Array:
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D).  Returns (B, Sq, Hq, D).

    Flash-attention-style: online softmax forward, and a custom VJP that
    RECOMPUTES probabilities in the backward instead of letting autodiff
    store every (q_chunk x kv_chunk) probability tile as a scan residual —
    the O(S^2) f32 residual traffic was the dominant HBM term in the
    baseline dry-run (see EXPERIMENTS.md §Perf iteration 1).

    ``q_offset``: absolute position of q[0] relative to k[0] (chunked prefill
    against an existing cache uses q_offset > 0).  ``window`` may be traced
    (per-layer sliding/global selection); it participates as an array arg.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    n_rep = Hq // Hkv
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)

    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    nq, nk = -(-Sq // qc), -(-Sk // kc)
    # pad to chunk multiples (masked out via positions)
    q = jnp.pad(q, ((0, 0), (0, nq * qc - Sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, nk * kc - Sk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, nk * kc - Sk), (0, 0), (0, 0)))

    q = hint(q, "batch", None, "model", None)
    k = hint(k, "batch", None, "model", None)
    v = hint(v, "batch", None, "model", None)
    q = q.reshape(B, nq, qc, Hq, D).transpose(1, 0, 3, 2, 4)   # (nq,B,H,qc,D)
    k = k.reshape(B, nk, kc, Hq, D).transpose(1, 0, 3, 2, 4)
    v = v.reshape(B, nk, kc, Hq, D).transpose(1, 0, 3, 2, 4)
    q = hint(q, None, "batch", "model", None, None)
    k = hint(k, None, "batch", "model", None, None)
    v = hint(v, None, "batch", "model", None, None)

    # window / offsets as f32 scalars so custom_vjp cotangents are trivial
    warr = jnp.asarray(window if not _static_zero(window) else (1 << 30),
                       jnp.float32)
    outs = _flash(q, k, v, warr, jnp.float32(q_offset), jnp.float32(Sk),
                  causal, qc, kc)
    outs = hint(outs, None, "batch", "model", None, None)
    outs = outs.transpose(1, 0, 3, 2, 4).reshape(B, nq * qc, Hq, D)
    return hint(outs[:, :Sq].astype(v.dtype), "batch", None, "model", None)


def _tile_mask(q_pos, k_pos, causal, window, sk):
    """q_pos/k_pos: f32 position vectors; window/sk: f32 scalars."""
    m = k_pos[None, :] < sk
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _flash(q, k, v, window, q_offset, sk, causal, qc, kc):
    out, _ = _flash_fwd_impl(q, k, v, window, q_offset, sk, causal, qc, kc)
    return out


def _flash_fwd_impl(q, k, v, window, q_offset, sk, causal, qc, kc):
    """q: (nq,B,H,qc,D); k,v: (nk,B,H,kc,D) -> out (nq,B,H,qc,D), lse."""
    nq, B, H, _, D = q.shape
    nk = k.shape[0]
    scale = D ** -0.5

    def q_body(_, q_i_and_idx):
        q_i, iq = q_i_and_idx
        q_pos = q_offset + (iq * qc + jnp.arange(qc)).astype(jnp.float32)

        def kv_body(carry, k_j_v_j_idx):
            m_prev, l_prev, acc = carry
            k_j, v_j, jk = k_j_v_j_idx
            k_pos = (jk * kc + jnp.arange(kc)).astype(jnp.float32)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            mask = _tile_mask(q_pos, k_pos, causal, window, sk)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, H, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, H, qc, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0), (k, v, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out.astype(v.dtype), lse)

    _, (outs, lses) = jax.lax.scan(q_body, None, (q, jnp.arange(nq)))
    return outs, lses


def _flash_fwd(q, k, v, window, q_offset, sk, causal, qc, kc):
    out, lse = _flash_fwd_impl(q, k, v, window, q_offset, sk, causal, qc, kc)
    return out, (q, k, v, out, lse, window, q_offset, sk)


def _flash_bwd(causal, qc, kc, res, g):
    """FA2 backward: recompute p tiles from (q, k, lse); never store S^2."""
    q, k, v, out, lse, window, q_offset, sk = res
    nq, B, H, _, D = q.shape
    nk = k.shape[0]
    scale = D ** -0.5
    g = g.astype(jnp.float32)
    # delta_i = rowsum(dout * out)
    delta = jnp.sum(g * out.astype(jnp.float32), axis=-1)   # (nq,B,H,qc)

    def kv_body(dq_acc, kv_idx):
        k_j, v_j, jk = kv_idx
        k_pos = (jk * kc + jnp.arange(kc)).astype(jnp.float32)

        def q_body(carry, q_idx):
            dk_j, dv_j = carry
            q_i, g_i, lse_i, delta_i, iq = q_idx
            q_pos = q_offset + (iq * qc + jnp.arange(qc)).astype(jnp.float32)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            mask = _tile_mask(q_pos, k_pos, causal, window, sk)
            s = jnp.where(mask[None, None], s, NEG_INF)
            p = jnp.exp(s - lse_i[..., None])                # (B,H,qc,kc)
            dv_j = dv_j + jnp.einsum("bhqk,bhqd->bhkd",
                                     p.astype(g_i.dtype), g_i,
                                     preferred_element_type=jnp.float32)
            dp = jnp.einsum("bhqd,bhkd->bhqk", g_i,
                            v_j.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            ds = p * (dp - delta_i[..., None]) * scale       # (B,H,qc,kc)
            dsl = ds.astype(k_j.dtype)
            dq_i = jnp.einsum("bhqk,bhkd->bhqd", dsl, k_j,
                              preferred_element_type=jnp.float32)
            dk_j = dk_j + jnp.einsum("bhqk,bhqd->bhkd", dsl,
                                     q_i.astype(k_j.dtype),
                                     preferred_element_type=jnp.float32)
            return (dk_j, dv_j), dq_i

        zeros = jnp.zeros((B, H, kc, D), jnp.float32)
        (dk_j, dv_j), dq_inc = jax.lax.scan(
            q_body, (zeros, zeros),
            (q, g, lse, delta, jnp.arange(nq)))
        return dq_acc + dq_inc, (dk_j, dv_j)

    dq0 = jnp.zeros((nq, B, H, q.shape[3], D), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(kv_body, dq0, (k, v, jnp.arange(nk)))
    zero = jnp.zeros((), jnp.float32)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            zero, zero, zero)


_flash.defvjp(_flash_fwd, _flash_bwd)


def attend_sliding(q, k, v, *, window: int, q_offset: int = 0,
                   q_chunk: int = 512) -> jax.Array:
    """Sliding-window attention with true KV skipping (static ``window``).

    Each q chunk attends only to the ``window + q_chunk`` keys it can see —
    FLOPs and traffic are O(S·window) instead of O(S^2) (the §Perf
    iteration-2 fix for sliding-window layers; a 21x FLOP cut at 32k/1024).
    q: (B, S, Hq, D); k, v: (B, S, Hkv, D) — self-attention layout:
    queries and keys share an origin (``q_offset`` shifts both together),
    so a *resumed* prefill — queries starting mid-sequence against a longer
    prefix+suffix key axis — must go through :func:`attend_chunked`'s
    mask-only windowing instead (``lm._attn_apply`` routes this).
    """
    B, S, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    k = _repeat_kv(k, Hq // Hkv)
    v = _repeat_kv(v, Hq // Hkv)
    qc = min(q_chunk, S)
    nq = -(-S // qc)
    L = window + qc                      # static slice length per q chunk
    qp = jnp.pad(q, ((0, 0), (0, nq * qc - S), (0, 0), (0, 0)))
    # front-pad keys by `window` so slice starts are always in range
    kp = jnp.pad(k, ((0, 0), (window, nq * qc - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, nq * qc - Sk), (0, 0), (0, 0)))
    qp = hint(qp, "batch", None, "model", None)
    kp = hint(kp, "batch", None, "model", None)
    vp = hint(vp, "batch", None, "model", None)
    q5 = qp.reshape(B, nq, qc, Hq, D).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qc,D)
    out = _sliding(q5, kp.transpose(0, 2, 1, 3), vp.transpose(0, 2, 1, 3),
                   jnp.float32(q_offset), jnp.float32(Sk), window, qc)
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, nq * qc, Hq, D)
    return out[:, :S].astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _sliding(q5, kt, vt, q_offset, sk, window, qc):
    return _sliding_fwd_impl(q5, kt, vt, q_offset, sk, window, qc)[0]


def _sliding_tile(q_i, k_i, iq, q_offset, sk, window, qc):
    """One q chunk vs its (window+qc) key slice.  Returns (s, mask)."""
    D = q_i.shape[-1]
    L = window + qc
    q_pos = q_offset + (iq * qc + jnp.arange(qc)).astype(jnp.float32)
    k_pos = q_offset + (iq * qc - window + jnp.arange(L)).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q_i, k_i,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    mask = (k_pos[None, :] >= q_offset) & (k_pos[None, :] < q_offset + sk)
    mask &= q_pos[:, None] >= k_pos[None, :]
    mask &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(mask[None, None], s, NEG_INF)


def _sliding_fwd_impl(q5, kt, vt, q_offset, sk, window, qc):
    """q5: (nq,B,H,qc,D); kt, vt: (B,H,window+nq*qc,D)."""
    nq, B, H, _, D = q5.shape
    L = window + qc

    def body(_, q_idx):
        q_i, iq = q_idx
        k_i = jax.lax.dynamic_slice_in_dim(kt, iq * qc, L, axis=2)
        v_i = jax.lax.dynamic_slice_in_dim(vt, iq * qc, L, axis=2)
        s = _sliding_tile(q_i, k_i, iq, q_offset, sk, window, qc)
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v_i.dtype), v_i,
                       preferred_element_type=jnp.float32)
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return None, (o.astype(vt.dtype), m + jnp.log(jnp.maximum(l, 1e-30)))

    _, (outs, lses) = jax.lax.scan(body, None, (q5, jnp.arange(nq)))
    return outs, lses


def _sliding_fwd(q5, kt, vt, q_offset, sk, window, qc):
    outs, lses = _sliding_fwd_impl(q5, kt, vt, q_offset, sk, window, qc)
    return outs, (q5, kt, vt, outs, lses, q_offset, sk)


def _sliding_bwd(window, qc, res, g):
    q5, kt, vt, outs, lses, q_offset, sk = res
    nq, B, H, _, D = q5.shape
    L = window + qc
    g = g.astype(jnp.float32)
    delta = jnp.sum(g * outs.astype(jnp.float32), axis=-1)

    def body(carry, q_idx):
        dk_acc, dv_acc = carry
        q_i, g_i, lse_i, delta_i, iq = q_idx
        k_i = jax.lax.dynamic_slice_in_dim(kt, iq * qc, L, axis=2)
        v_i = jax.lax.dynamic_slice_in_dim(vt, iq * qc, L, axis=2)
        s = _sliding_tile(q_i, k_i, iq, q_offset, sk, window, qc)
        p = jnp.exp(s - lse_i[..., None])
        dv_i = jnp.einsum("bhqk,bhqd->bhkd", p.astype(g_i.dtype), g_i,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhqd,bhkd->bhqk", g_i, v_i.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta_i[..., None]) * (D ** -0.5)
        dsl = ds.astype(kt.dtype)
        dq_i = jnp.einsum("bhqk,bhkd->bhqd", dsl, k_i,
                          preferred_element_type=jnp.float32)
        dk_i = jnp.einsum("bhqk,bhqd->bhkd", dsl, q_i.astype(kt.dtype),
                          preferred_element_type=jnp.float32)
        upd = jax.lax.dynamic_slice_in_dim(dk_acc, iq * qc, L, axis=2) + dk_i
        dk_acc = jax.lax.dynamic_update_slice_in_dim(dk_acc, upd, iq * qc,
                                                     axis=2)
        updv = jax.lax.dynamic_slice_in_dim(dv_acc, iq * qc, L, axis=2) + dv_i
        dv_acc = jax.lax.dynamic_update_slice_in_dim(dv_acc, updv, iq * qc,
                                                     axis=2)
        return (dk_acc, dv_acc), dq_i

    zk = jnp.zeros(kt.shape, jnp.float32)
    (dk, dv), dq = jax.lax.scan(
        body, (zk, zk), (q5, g, lses, delta, jnp.arange(nq)))
    zero = jnp.zeros((), jnp.float32)
    return (dq.astype(q5.dtype), dk.astype(kt.dtype), dv.astype(vt.dtype),
            zero, zero)


_sliding.defvjp(_sliding_fwd, _sliding_bwd)


def attend_decode(q, k_cache, v_cache, cache_len, *, window=0) -> jax.Array:
    """One-token decode attention against a cache.

    q: (B, 1, Hq, D); k_cache, v_cache: (B, Smax, Hkv, D);
    cache_len: scalar int32 — number of valid cache positions (the new token's
    K/V must already be written at cache_len - 1).
    """
    B, _, Hq, D = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    n_rep = Hq // Hkv
    scale = D ** -0.5
    qh = q[:, 0].reshape(B, Hkv, n_rep, D)
    qh = hint(qh, "batch", "model", None, None)
    s = jnp.einsum("bhrd,bshd->bhrs", qh, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(Smax)
    valid = pos[None, None, None, :] < cache_len
    if not _static_zero(window):
        valid &= pos[None, None, None, :] >= (cache_len - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhrs,bshd->bhrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(v_cache.dtype)


def gather_paged_kv(arena, block_table) -> jax.Array:
    """arena: (num_blocks, bs, Hkv, D); block_table: (B, nb) int32.

    Returns the dense (B, nb*bs, Hkv, D) view of each row's block chain —
    the ``jnp.take``-based gather that feeds :func:`attend_decode`.  Unused
    table entries point at the trash block (id 0); whatever it holds is
    masked out by ``cache_len`` downstream.
    """
    nb, bs = block_table.shape[1], arena.shape[1]
    g = jnp.take(arena, block_table, axis=0)        # (B, nb, bs, Hkv, D)
    return g.reshape(g.shape[0], nb * bs, *g.shape[3:])


def attend_decode_paged(q, k_arena, v_arena, block_table, cache_len, *,
                        window=0, new_kv=None, scales=None,
                        out_dtype=None) -> jax.Array:
    """One-token decode attention against a *paged* cache (single layer).

    q: (B, 1, Hq, D); k_arena, v_arena: (num_blocks, bs, Hkv, D);
    block_table: (B, nb) int32 block ids; cache_len: (B,) int32 per-row
    valid lengths (the new token's K/V already written at cache_len - 1).

    ``new_kv``: optional (k1, v1), each (B, Hkv, D) — the current token's
    K/V row, inserted into the gathered view at ``cache_len - 1`` instead
    of requiring the caller to have scattered it into the arena first.
    This is how the in-place decode tick reads the token it is mid-way
    through writing: the arena write happens once, after the layer scan
    (mode="drop" so a lane already at capacity never corrupts a live row;
    such lanes are masked upstream and their output is discarded).

    ``scales``: optional (k_scale_arena, v_scale_arena), each
    (num_blocks, bs, Hkv, 1) f32 — the int8 ``kv_quant`` layout.  The
    gathered view is dequantized to ``out_dtype`` *after* the per-table
    gather (elementwise, so it is bit-identical to dequantizing the dense
    cache and gathering), and ``new_kv`` must then carry the already
    dequantized current row — exactly what the dense quant tick attends
    over after writing the quantized row.

    Gathers each row's block chain into the dense layout and applies the
    same masked softmax as :func:`attend_decode`, with a per-row length
    vector instead of a shared scalar.  This is the XLA reference semantics
    for ``kernels/paged_attn.py``.
    """
    B, _, Hq, D = q.shape
    Hkv = k_arena.shape[2]
    n_rep = Hq // Hkv
    scale = D ** -0.5
    k = gather_paged_kv(k_arena, block_table)       # (B, S, Hkv, D)
    v = gather_paged_kv(v_arena, block_table)
    if scales is not None:
        from repro.serve import kvquant
        ks = gather_paged_kv(scales[0], block_table)
        vs = gather_paged_kv(scales[1], block_table)
        k = kvquant.dequantize(k, ks, out_dtype)
        v = kvquant.dequantize(v, vs, out_dtype)
    if new_kv is not None:
        k1, v1 = new_kv
        rows = jnp.arange(B)
        k = k.at[rows, cache_len - 1].set(k1.astype(k.dtype), mode="drop")
        v = v.at[rows, cache_len - 1].set(v1.astype(v.dtype), mode="drop")
    qh = q[:, 0].reshape(B, Hkv, n_rep, D)
    s = jnp.einsum("bhrd,bshd->bhrs", qh, k,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(k.shape[1])
    valid = pos[None, None, None, :] < cache_len[:, None, None, None]
    if not _static_zero(window):
        valid &= pos[None, None, None, :] >= \
            (cache_len[:, None, None, None] - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhrs,bshd->bhrd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(v.dtype)
