"""Attention: GQA / MHA / cross, memory-efficient chunked ("flash-style in
XLA") for train/prefill and masked single-shot for decode.

The chunked path is a double ``lax.scan`` (query chunks x KV chunks) with an
online-softmax accumulator, so peak memory is O(q_chunk x kv_chunk) scores
per head instead of O(S^2); XLA keeps the HLO compact (one scan body), which
matters for the 126-layer dry-run compiles.  Scores/softmax accumulate in f32.

Sliding-window masks reuse the same body (mask-only; no dynamic skipping —
shapes stay static for SPMD).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.dist.sharding import hint

NEG_INF = -1e30


def _repeat_kv(k, n_rep: int):
    """(B, S, Hkv, D) -> (B, S, Hkv*n_rep, D) by broadcast (GQA)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d))
    return k.reshape(b, s, h * n_rep, d)


def _static_zero(window) -> bool:
    return isinstance(window, int) and window == 0


def _mask(q_pos, k_pos, causal: bool, window):
    """(Sq, Sk) bool validity mask from absolute positions.

    ``window`` may be a traced scalar (per-layer global/sliding selection
    inside a layer scan encodes "global" as a huge window); a static 0 means
    no windowing at all.
    """
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), jnp.bool_)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if not _static_zero(window):
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def attend_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                   q_offset: int = 0, q_chunk: int = 512, kv_chunk: int = 1024,
                   ) -> jax.Array:
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D).  Returns (B, Sq, Hq, D).

    Flash-attention-style: online softmax forward, and a custom VJP that
    RECOMPUTES probabilities in the backward instead of letting autodiff
    store every (q_chunk x kv_chunk) probability tile as a scan residual —
    the O(S^2) f32 residual traffic was the dominant HBM term in the
    baseline dry-run (see EXPERIMENTS.md §Perf iteration 1).

    ``q_offset``: absolute position of q[0] relative to k[0] (chunked prefill
    against an existing cache uses q_offset > 0).  ``window`` may be traced
    (per-layer sliding/global selection); it participates as an array arg.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    n_rep = Hq // Hkv
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)

    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    nq, nk = -(-Sq // qc), -(-Sk // kc)
    # pad to chunk multiples (masked out via positions)
    q = jnp.pad(q, ((0, 0), (0, nq * qc - Sq), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, nk * kc - Sk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, nk * kc - Sk), (0, 0), (0, 0)))

    q = hint(q, "batch", None, "model", None)
    k = hint(k, "batch", None, "model", None)
    v = hint(v, "batch", None, "model", None)
    q = q.reshape(B, nq, qc, Hq, D).transpose(1, 0, 3, 2, 4)   # (nq,B,H,qc,D)
    k = k.reshape(B, nk, kc, Hq, D).transpose(1, 0, 3, 2, 4)
    v = v.reshape(B, nk, kc, Hq, D).transpose(1, 0, 3, 2, 4)
    q = hint(q, None, "batch", "model", None, None)
    k = hint(k, None, "batch", "model", None, None)
    v = hint(v, None, "batch", "model", None, None)

    # window / offsets as f32 scalars so custom_vjp cotangents are trivial
    warr = jnp.asarray(window if not _static_zero(window) else (1 << 30),
                       jnp.float32)
    outs = _flash(q, k, v, warr, jnp.float32(q_offset), jnp.float32(Sk),
                  causal, qc, kc)
    outs = hint(outs, None, "batch", "model", None, None)
    outs = outs.transpose(1, 0, 3, 2, 4).reshape(B, nq * qc, Hq, D)
    return hint(outs[:, :Sq].astype(v.dtype), "batch", None, "model", None)


def _tile_mask(q_pos, k_pos, causal, window, sk):
    """q_pos/k_pos: f32 position vectors; window/sk: f32 scalars."""
    m = k_pos[None, :] < sk
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _flash(q, k, v, window, q_offset, sk, causal, qc, kc):
    out, _ = _flash_fwd_impl(q, k, v, window, q_offset, sk, causal, qc, kc)
    return out


def _flash_fwd_impl(q, k, v, window, q_offset, sk, causal, qc, kc):
    """q: (nq,B,H,qc,D); k,v: (nk,B,H,kc,D) -> out (nq,B,H,qc,D), lse."""
    nq, B, H, _, D = q.shape
    nk = k.shape[0]
    scale = D ** -0.5

    def q_body(_, q_i_and_idx):
        q_i, iq = q_i_and_idx
        q_pos = q_offset + (iq * qc + jnp.arange(qc)).astype(jnp.float32)

        def kv_body(carry, k_j_v_j_idx):
            m_prev, l_prev, acc = carry
            k_j, v_j, jk = k_j_v_j_idx
            k_pos = (jk * kc + jnp.arange(kc)).astype(jnp.float32)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            mask = _tile_mask(q_pos, k_pos, causal, window, sk)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, H, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, H, qc, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0), (k, v, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out.astype(v.dtype), lse)

    _, (outs, lses) = jax.lax.scan(q_body, None, (q, jnp.arange(nq)))
    return outs, lses


def _flash_fwd(q, k, v, window, q_offset, sk, causal, qc, kc):
    out, lse = _flash_fwd_impl(q, k, v, window, q_offset, sk, causal, qc, kc)
    return out, (q, k, v, out, lse, window, q_offset, sk)


def _flash_bwd(causal, qc, kc, res, g):
    """FA2 backward: recompute p tiles from (q, k, lse); never store S^2."""
    q, k, v, out, lse, window, q_offset, sk = res
    nq, B, H, _, D = q.shape
    nk = k.shape[0]
    scale = D ** -0.5
    g = g.astype(jnp.float32)
    # delta_i = rowsum(dout * out)
    delta = jnp.sum(g * out.astype(jnp.float32), axis=-1)   # (nq,B,H,qc)

    def kv_body(dq_acc, kv_idx):
        k_j, v_j, jk = kv_idx
        k_pos = (jk * kc + jnp.arange(kc)).astype(jnp.float32)

        def q_body(carry, q_idx):
            dk_j, dv_j = carry
            q_i, g_i, lse_i, delta_i, iq = q_idx
            q_pos = q_offset + (iq * qc + jnp.arange(qc)).astype(jnp.float32)
            s = jnp.einsum("bhqd,bhkd->bhqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            mask = _tile_mask(q_pos, k_pos, causal, window, sk)
            s = jnp.where(mask[None, None], s, NEG_INF)
            p = jnp.exp(s - lse_i[..., None])                # (B,H,qc,kc)
            dv_j = dv_j + jnp.einsum("bhqk,bhqd->bhkd",
                                     p.astype(g_i.dtype), g_i,
                                     preferred_element_type=jnp.float32)
            dp = jnp.einsum("bhqd,bhkd->bhqk", g_i,
                            v_j.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
            ds = p * (dp - delta_i[..., None]) * scale       # (B,H,qc,kc)
            dsl = ds.astype(k_j.dtype)
            dq_i = jnp.einsum("bhqk,bhkd->bhqd", dsl, k_j,
                              preferred_element_type=jnp.float32)
            dk_j = dk_j + jnp.einsum("bhqk,bhqd->bhkd", dsl,
                                     q_i.astype(k_j.dtype),
                                     preferred_element_type=jnp.float32)
            return (dk_j, dv_j), dq_i

        zeros = jnp.zeros((B, H, kc, D), jnp.float32)
        (dk_j, dv_j), dq_inc = jax.lax.scan(
            q_body, (zeros, zeros),
            (q, g, lse, delta, jnp.arange(nq)))
        return dq_acc + dq_inc, (dk_j, dv_j)

    dq0 = jnp.zeros((nq, B, H, q.shape[3], D), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(kv_body, dq0, (k, v, jnp.arange(nk)))
    zero = jnp.zeros((), jnp.float32)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            zero, zero, zero)


_flash.defvjp(_flash_fwd, _flash_bwd)


def attend_sliding(q, k, v, *, window: int, q_offset: int = 0,
                   q_chunk: int = 512) -> jax.Array:
    """Sliding-window attention with true KV skipping (static ``window``).

    Each q chunk attends only to the ``window + q_chunk`` keys it can see —
    FLOPs and traffic are O(S·window) instead of O(S^2) (the §Perf
    iteration-2 fix for sliding-window layers; a 21x FLOP cut at 32k/1024).
    q: (B, S, Hq, D); k, v: (B, S, Hkv, D) — self-attention layout:
    queries and keys share an origin (``q_offset`` shifts both together),
    so a *resumed* prefill — queries starting mid-sequence against a longer
    prefix+suffix key axis — must go through :func:`attend_chunked`'s
    mask-only windowing instead (``lm._attn_apply`` routes this).
    """
    B, S, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    k = _repeat_kv(k, Hq // Hkv)
    v = _repeat_kv(v, Hq // Hkv)
    qc = min(q_chunk, S)
    nq = -(-S // qc)
    L = window + qc                      # static slice length per q chunk
    qp = jnp.pad(q, ((0, 0), (0, nq * qc - S), (0, 0), (0, 0)))
    # front-pad keys by `window` so slice starts are always in range
    kp = jnp.pad(k, ((0, 0), (window, nq * qc - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (window, nq * qc - Sk), (0, 0), (0, 0)))
    qp = hint(qp, "batch", None, "model", None)
    kp = hint(kp, "batch", None, "model", None)
    vp = hint(vp, "batch", None, "model", None)
    q5 = qp.reshape(B, nq, qc, Hq, D).transpose(1, 0, 3, 2, 4)  # (nq,B,H,qc,D)
    out = _sliding(q5, kp.transpose(0, 2, 1, 3), vp.transpose(0, 2, 1, 3),
                   jnp.float32(q_offset), jnp.float32(Sk), window, qc)
    out = out.transpose(1, 0, 3, 2, 4).reshape(B, nq * qc, Hq, D)
    return out[:, :S].astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _sliding(q5, kt, vt, q_offset, sk, window, qc):
    return _sliding_fwd_impl(q5, kt, vt, q_offset, sk, window, qc)[0]


def _sliding_tile(q_i, k_i, iq, q_offset, sk, window, qc):
    """One q chunk vs its (window+qc) key slice.  Returns (s, mask)."""
    D = q_i.shape[-1]
    L = window + qc
    q_pos = q_offset + (iq * qc + jnp.arange(qc)).astype(jnp.float32)
    k_pos = q_offset + (iq * qc - window + jnp.arange(L)).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q_i, k_i,
                   preferred_element_type=jnp.float32) * (D ** -0.5)
    mask = (k_pos[None, :] >= q_offset) & (k_pos[None, :] < q_offset + sk)
    mask &= q_pos[:, None] >= k_pos[None, :]
    mask &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(mask[None, None], s, NEG_INF)


def _sliding_fwd_impl(q5, kt, vt, q_offset, sk, window, qc):
    """q5: (nq,B,H,qc,D); kt, vt: (B,H,window+nq*qc,D)."""
    nq, B, H, _, D = q5.shape
    L = window + qc

    def body(_, q_idx):
        q_i, iq = q_idx
        k_i = jax.lax.dynamic_slice_in_dim(kt, iq * qc, L, axis=2)
        v_i = jax.lax.dynamic_slice_in_dim(vt, iq * qc, L, axis=2)
        s = _sliding_tile(q_i, k_i, iq, q_offset, sk, window, qc)
        m = jnp.max(s, axis=-1)
        p = jnp.exp(s - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v_i.dtype), v_i,
                       preferred_element_type=jnp.float32)
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return None, (o.astype(vt.dtype), m + jnp.log(jnp.maximum(l, 1e-30)))

    _, (outs, lses) = jax.lax.scan(body, None, (q5, jnp.arange(nq)))
    return outs, lses


def _sliding_fwd(q5, kt, vt, q_offset, sk, window, qc):
    outs, lses = _sliding_fwd_impl(q5, kt, vt, q_offset, sk, window, qc)
    return outs, (q5, kt, vt, outs, lses, q_offset, sk)


def _sliding_bwd(window, qc, res, g):
    q5, kt, vt, outs, lses, q_offset, sk = res
    nq, B, H, _, D = q5.shape
    L = window + qc
    g = g.astype(jnp.float32)
    delta = jnp.sum(g * outs.astype(jnp.float32), axis=-1)

    def body(carry, q_idx):
        dk_acc, dv_acc = carry
        q_i, g_i, lse_i, delta_i, iq = q_idx
        k_i = jax.lax.dynamic_slice_in_dim(kt, iq * qc, L, axis=2)
        v_i = jax.lax.dynamic_slice_in_dim(vt, iq * qc, L, axis=2)
        s = _sliding_tile(q_i, k_i, iq, q_offset, sk, window, qc)
        p = jnp.exp(s - lse_i[..., None])
        dv_i = jnp.einsum("bhqk,bhqd->bhkd", p.astype(g_i.dtype), g_i,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhqd,bhkd->bhqk", g_i, v_i.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta_i[..., None]) * (D ** -0.5)
        dsl = ds.astype(kt.dtype)
        dq_i = jnp.einsum("bhqk,bhkd->bhqd", dsl, k_i,
                          preferred_element_type=jnp.float32)
        dk_i = jnp.einsum("bhqk,bhqd->bhkd", dsl, q_i.astype(kt.dtype),
                          preferred_element_type=jnp.float32)
        upd = jax.lax.dynamic_slice_in_dim(dk_acc, iq * qc, L, axis=2) + dk_i
        dk_acc = jax.lax.dynamic_update_slice_in_dim(dk_acc, upd, iq * qc,
                                                     axis=2)
        updv = jax.lax.dynamic_slice_in_dim(dv_acc, iq * qc, L, axis=2) + dv_i
        dv_acc = jax.lax.dynamic_update_slice_in_dim(dv_acc, updv, iq * qc,
                                                     axis=2)
        return (dk_acc, dv_acc), dq_i

    zk = jnp.zeros(kt.shape, jnp.float32)
    (dk, dv), dq = jax.lax.scan(
        body, (zk, zk), (q5, g, lses, delta, jnp.arange(nq)))
    zero = jnp.zeros((), jnp.float32)
    return (dq.astype(q5.dtype), dk.astype(kt.dtype), dv.astype(vt.dtype),
            zero, zero)


_sliding.defvjp(_sliding_fwd, _sliding_bwd)


def attend_decode(q, k_cache, v_cache, cache_len, *, window=0) -> jax.Array:
    """One-token decode attention against a cache.

    q: (B, 1, Hq, D); k_cache, v_cache: (B, Smax, Hkv, D);
    cache_len: scalar int32 — number of valid cache positions (the new token's
    K/V must already be written at cache_len - 1).
    """
    B, _, Hq, D = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    n_rep = Hq // Hkv
    scale = D ** -0.5
    qh = q[:, 0].reshape(B, Hkv, n_rep, D)
    qh = hint(qh, "batch", "model", None, None)
    s = jnp.einsum("bhrd,bshd->bhrs", qh, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(Smax)
    valid = pos[None, None, None, :] < cache_len
    if not _static_zero(window):
        valid &= pos[None, None, None, :] >= (cache_len - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhrs,bshd->bhrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(v_cache.dtype)


def gather_paged_kv(arena, block_table) -> jax.Array:
    """arena: (num_blocks, bs, Hkv, D); block_table: (B, nb) int32.

    Returns the dense (B, nb*bs, Hkv, D) view of each row's block chain —
    the ``jnp.take``-based gather that feeds :func:`attend_decode`.  Unused
    table entries point at the trash block (id 0); whatever it holds is
    masked out by ``cache_len`` downstream.
    """
    nb, bs = block_table.shape[1], arena.shape[1]
    g = jnp.take(arena, block_table, axis=0)        # (B, nb, bs, Hkv, D)
    return g.reshape(g.shape[0], nb * bs, *g.shape[3:])


def attend_decode_paged(q, k_arena, v_arena, block_table, cache_len, *,
                        window=0, new_kv=None, scales=None,
                        out_dtype=None, backend=None, cascade=None,
                        interpret=None) -> jax.Array:
    """One-token decode attention against a *paged* cache (single layer).

    q: (B, 1, Hq, D); k_arena, v_arena: (num_blocks, bs, Hkv, D);
    block_table: (B, nb) int32 block ids; cache_len: (B,) int32 per-row
    valid lengths (the new token's K/V already written at cache_len - 1).

    ``new_kv``: optional (k1, v1), each (B, Hkv, D) — the current token's
    K/V row, inserted into the gathered view at ``cache_len - 1`` instead
    of requiring the caller to have scattered it into the arena first.
    This is how the in-place decode tick reads the token it is mid-way
    through writing: the arena write happens once, after the layer scan
    (mode="drop" so a lane already at capacity never corrupts a live row;
    such lanes are masked upstream and their output is discarded).

    ``scales``: optional (k_scale_arena, v_scale_arena), each
    (num_blocks, bs, Hkv, 1) f32 — the int8 ``kv_quant`` layout.  The
    gathered view is dequantized to ``out_dtype`` *after* the per-table
    gather (elementwise, so it is bit-identical to dequantizing the dense
    cache and gathering), and ``new_kv`` must then carry the already
    dequantized current row — exactly what the dense quant tick attends
    over after writing the quantized row.

    ``backend`` is the per-layer read-path dispatch (see
    :mod:`repro.serve.backend`): ``None``/``"xla"`` gathers each row's
    block chain into the dense layout and applies the same masked softmax
    as :func:`attend_decode` with a per-row length vector; ``"pallas"``
    routes to :func:`repro.kernels.paged_attn.paged_decode_attention`
    (no gather — the block table rides in as a scalar-prefetch operand);
    ``"cascade"`` routes to :func:`attend_decode_cascade` with the group
    metadata in ``cascade``.  The ``"xla"`` body is the reference
    semantics the other two are pinned against.
    """
    if backend == "pallas":
        from repro.kernels.paged_attn import paged_decode_attention
        assert scales is None, "pallas backend does not cover kv_quant"
        nk = None if new_kv is None else (new_kv[0], new_kv[1])
        out = paged_decode_attention(q[:, 0], k_arena, v_arena, block_table,
                                     cache_len, window=window, new_kv=nk,
                                     interpret=interpret)
        return out[:, None]
    if backend == "cascade":
        assert cascade is not None, "cascade backend needs group metadata"
        return attend_decode_cascade(q, k_arena, v_arena, cascade, cache_len,
                                     window=window, new_kv=new_kv,
                                     scales=scales, out_dtype=out_dtype,
                                     interpret=interpret)
    assert backend in (None, "xla"), f"unknown attention backend {backend!r}"
    B, _, Hq, D = q.shape
    Hkv = k_arena.shape[2]
    n_rep = Hq // Hkv
    scale = D ** -0.5
    k = gather_paged_kv(k_arena, block_table)       # (B, S, Hkv, D)
    v = gather_paged_kv(v_arena, block_table)
    if scales is not None:
        from repro.serve import kvquant
        ks = gather_paged_kv(scales[0], block_table)
        vs = gather_paged_kv(scales[1], block_table)
        k = kvquant.dequantize(k, ks, out_dtype)
        v = kvquant.dequantize(v, vs, out_dtype)
    if new_kv is not None:
        k1, v1 = new_kv
        rows = jnp.arange(B)
        k = k.at[rows, cache_len - 1].set(k1.astype(k.dtype), mode="drop")
        v = v.at[rows, cache_len - 1].set(v1.astype(v.dtype), mode="drop")
    qh = q[:, 0].reshape(B, Hkv, n_rep, D)
    s = jnp.einsum("bhrd,bshd->bhrs", qh, k,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(k.shape[1])
    valid = pos[None, None, None, :] < cache_len[:, None, None, None]
    if not _static_zero(window):
        valid &= pos[None, None, None, :] >= \
            (cache_len[:, None, None, None] - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhrs,bshd->bhrd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(v.dtype)


def merge_softmax_states(acc1, m1, l1, acc2, m2, l2):
    """Log-sum-exp merge of two partial online-softmax states.

    Each side carries the flash-attention ``(acc, m, l)`` triple over its
    own key set: ``m = max_j s_j`` (``NEG_INF`` for an empty set),
    ``l = sum_j exp(s_j - m)`` (0 for empty), ``acc = sum_j exp(s_j - m)
    v_j`` (unnormalized; trailing feature axis).  Returns the merged
    triple over the union of the two key sets; the caller normalizes once
    with ``acc / max(l, tiny)``.  An empty side drops out exactly:
    ``exp(NEG_INF - m)`` underflows to zero against a finite ``m``, and
    with both sides empty every term is already zero — so a lane with no
    shared prefix reproduces its suffix-only softmax state bit-for-bit.
    """
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    l = c1 * l1 + c2 * l2
    acc = c1[..., None] * acc1 + c2[..., None] * acc2
    return acc, m, l


def _softmax_state(s, valid):
    """Masked online-softmax state: s (..., S) f32 scores, valid (..., S)
    bool.  Returns (p, m, l) with p the unnormalized probabilities (zero
    where invalid — a fully-masked row yields l == 0, not a uniform
    distribution, which is what lets the merge drop it exactly)."""
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None]) * valid
    return p, m, jnp.sum(p, axis=-1)


def attend_decode_cascade(q, k_arena, v_arena, cascade, cache_len, *,
                          window=0, new_kv=None, scales=None,
                          out_dtype=None, kernel=False,
                          interpret=None) -> jax.Array:
    """Two-level shared-prefix decode attention (flashinfer-style cascade).

    Lanes sharing an indexed radix prefix chain attend it *once as a
    group*: one multi-query pass over the shared prefix KV (gathered once
    per group instead of once per lane), a per-lane pass over each
    divergent suffix, and a log-sum-exp merge of the two partial softmax
    states (:func:`merge_softmax_states`).  ``cascade`` carries the
    host-built group metadata, padded to static bucket shapes:

      group_tables  (G, npre)  int32  shared-prefix block ids, trash-padded
      group_len     (G,)       int32  prefix tokens (multiple of bs; 0 pads)
      group_lanes   (G, Lc)    int32  lane ids per group, 0-padded
      group_mask    (G, Lc)    bool   which lane slots are real
      lane_q0       (B,)       int32  per-lane prefix tokens (0 = ungrouped)
      suffix_tables (B, nsuf)  int32  per-lane divergent-suffix block ids

    Positions ``[0, lane_q0)`` are covered by the lane's group prefix
    pass and ``[lane_q0, cache_len)`` by its suffix pass — disjoint and
    complete, with absolute positions throughout, so the same
    ``cache_len``/``window`` masking as flat :func:`attend_decode_paged`
    selects exactly the same key set.  A window that clips into the
    shared prefix masks the clipped prefix positions inside the group
    pass (per-lane lengths broadcast against the shared keys); a window
    entirely inside the suffix empties the lane's prefix state, which the
    merge then drops exactly.  Scores and accumulators are float32; the
    flat path normalizes *before* its value contraction and this one
    after, so flat-vs-cascade parity is last-ulp tolerance rather than
    bitwise (docs/kvcache.md §Cascade decode — the serving adapter
    degrades to the flat executable when no chain is shared, which *is*
    bitwise).

    ``kernel=True`` runs the three stages through the Pallas kernels
    (``kernels.paged_attn.cascade_prefix_attention`` /
    ``paged_decode_attention_with_state`` / ``merge_attn_states``)
    instead of the XLA math; the kernels-interpret suite pins the two
    against each other.
    """
    assert scales is None, "cascade does not cover the kv_quant layout"
    B, _, Hq, D = q.shape
    Hkv = k_arena.shape[2]
    n_rep = Hq // Hkv
    scale = D ** -0.5
    group_tables = cascade["group_tables"]
    group_len = cascade["group_len"]
    group_lanes = cascade["group_lanes"]
    group_mask = cascade["group_mask"]
    lane_q0 = cascade["lane_q0"]
    suffix_tables = cascade["suffix_tables"]
    G, Lc = group_lanes.shape

    if kernel:
        from repro.kernels import paged_attn as pk
        qg = q[:, 0][group_lanes]                       # (G, Lc, Hq, D)
        lane_len = cache_len[group_lanes]
        acc1g, m1g, l1g = pk.cascade_prefix_attention(
            qg, k_arena, v_arena, group_tables, group_len,
            lane_len.astype(jnp.int32), window=window, interpret=interpret)
        nk = None if new_kv is None else (new_kv[0], new_kv[1])
        acc2, m2, l2 = pk.paged_decode_attention_with_state(
            q[:, 0], k_arena, v_arena, suffix_tables, cache_len,
            window=window, q0=lane_q0, new_kv=nk, interpret=interpret)
    else:
        # -- shared-prefix pass: one gather + one multi-query attention per
        # group; every lane of the group rides in the Lc axis
        qg = q[:, 0][group_lanes].reshape(G, Lc, Hkv, n_rep, D)
        kp = gather_paged_kv(k_arena, group_tables)     # (G, Sp, Hkv, D)
        vp = gather_paged_kv(v_arena, group_tables)
        s1 = jnp.einsum("gchrd,gshd->gchrs", qg, kp,
                        preferred_element_type=jnp.float32) * scale
        posp = jnp.arange(kp.shape[1])
        valid1 = posp[None, None, :] < group_len[:, None, None]  # (G,1,Sp)
        valid1 = jnp.broadcast_to(valid1, (G, Lc, kp.shape[1]))
        if not _static_zero(window):
            lane_len = cache_len[group_lanes]           # (G, Lc)
            valid1 &= posp[None, None, :] >= (lane_len[:, :, None] - window)
        p1, m1g, l1g = _softmax_state(
            s1.astype(jnp.float32), valid1[:, :, None, None, :])
        acc1g = jnp.einsum("gchrs,gshd->gchrd", p1, vp.astype(jnp.float32),
                           preferred_element_type=jnp.float32)
        acc1g = acc1g.reshape(G, Lc, Hq, D)
        m1g = m1g.reshape(G, Lc, Hq)
        l1g = l1g.reshape(G, Lc, Hq)
        # -- divergent-suffix pass: per-lane, absolute positions from q0
        qh = q[:, 0].reshape(B, Hkv, n_rep, D)
        ks = gather_paged_kv(k_arena, suffix_tables)    # (B, Ss, Hkv, D)
        vs = gather_paged_kv(v_arena, suffix_tables)
        if new_kv is not None:
            k1, v1 = new_kv
            rows = jnp.arange(B)
            loc = cache_len - 1 - lane_q0
            ks = ks.at[rows, loc].set(k1.astype(ks.dtype), mode="drop")
            vs = vs.at[rows, loc].set(v1.astype(vs.dtype), mode="drop")
        s2 = jnp.einsum("bhrd,bshd->bhrs", qh, ks,
                        preferred_element_type=jnp.float32) * scale
        pos_abs = lane_q0[:, None] + jnp.arange(ks.shape[1])     # (B, Ss)
        valid2 = pos_abs < cache_len[:, None]
        if not _static_zero(window):
            valid2 &= pos_abs >= (cache_len - window)[:, None]
        p2, m2, l2 = _softmax_state(
            s2.astype(jnp.float32), valid2[:, None, None, :])
        acc2 = jnp.einsum("bhrs,bshd->bhrd", p2, vs.astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        acc2 = acc2.reshape(B, Hq, D)
        m2 = m2.reshape(B, Hq)
        l2 = l2.reshape(B, Hq)

    # -- scatter group states back to lanes (each real lane sits in exactly
    # one group slot, so the adds are pure placement; padded slots are
    # zeroed / NEG_INF'd here rather than inside the passes)
    flat = group_lanes.reshape(-1)
    fmask = group_mask.reshape(-1)
    acc1 = jnp.zeros((B, Hq, D), jnp.float32).at[flat].add(
        jnp.where(fmask[:, None, None], acc1g.reshape(-1, Hq, D), 0.0))
    l1 = jnp.zeros((B, Hq), jnp.float32).at[flat].add(
        jnp.where(fmask[:, None], l1g.reshape(-1, Hq), 0.0))
    m1 = jnp.full((B, Hq), NEG_INF, jnp.float32).at[flat].max(
        jnp.where(fmask[:, None], m1g.reshape(-1, Hq), NEG_INF))

    if kernel:
        from repro.kernels import paged_attn as pk
        out = pk.merge_attn_states(acc1, m1, l1, acc2, m2, l2,
                                   interpret=interpret)
    else:
        acc, _, l = merge_softmax_states(acc1, m1, l1, acc2, m2, l2)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, Hq, D).astype(out_dtype or v_arena.dtype)
