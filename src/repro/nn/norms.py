"""Normalization layers.

Numerics policy: the variance/mean REDUCTIONS accumulate in f32 (the part
that matters for stability), but every full-size (B, S, d) intermediate stays
in the activation dtype — the f32 elementwise chain of the naive formulation
was the single largest HBM term in the llama-405B training dry-run
(§Perf iteration 4: 4 x 512MB f32 tensors per norm per layer per microbatch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x, scale, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)        # (..., 1) tiny
    return (x * inv) * scale.astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)            # (..., 1)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True) - jnp.square(mu)
    inv = jax.lax.rsqrt(var + eps)
    mu_t = mu.astype(x.dtype)
    inv_t = inv.astype(x.dtype)
    out = (x - mu_t) * inv_t
    if isinstance(bias, (int, float)):
        return out * scale.astype(x.dtype) + bias
    return out * scale.astype(x.dtype) + bias.astype(x.dtype)
