"""Rotary position embeddings."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(d_head: int, theta: float):
    return theta ** (-jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)                       # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
