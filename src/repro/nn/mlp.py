"""Dense feed-forward blocks: SwiGLU (llama-family) and GELU (whisper/GPT)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import hint


def _hint_ff(h):
    return hint(h, *(["batch"] + [None] * (h.ndim - 2) + ["model"]))


def swiglu(x, w_gate, w_in, w_out):
    g = _hint_ff(jnp.einsum("...d,df->...f", x, w_gate))
    h = _hint_ff(jnp.einsum("...d,df->...f", x, w_in))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g.astype(jnp.float32)
                                                   ).astype(h.dtype) * h, w_out)


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = _hint_ff(jnp.einsum("...d,df->...f", x, w_in) + b_in)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out
