"""Fine-grained mixture-of-experts (DeepSeekMoE-style: shared experts + many
small routed experts, top-k with renormalized gates).

Dispatch is the GShard grouped-einsum formulation: tokens are split into
groups of ``group_size``; each group routes into per-expert capacity buffers
via a one-hot dispatch tensor.  Groups shard over the data axis and experts
over the model axis (EP), so the dispatch/combine einsums induce exactly the
expected all-to-all pattern under pjit.  An alternative sort-based dispatch
(``impl="sort"``) exists for the perf study.

Capacity overflow drops tokens (standard GShard semantics); an auxiliary
load-balance loss is returned for training.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.dist.sharding import hint


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    group_size: int = 2048
    aux_loss_weight: float = 0.01
    impl: str = "einsum"   # "einsum" | "sort"
    # dropless: capacity = the whole group, so routing never drops a token.
    # Serving prefill uses this (a token's output must not depend on which
    # other prompts share its dispatch group — the prerequisite for resuming
    # a prompt from a cached prefix); training keeps GShard drop semantics.
    dropless: bool = False


def capacity(cfg: MoEConfig, group_tokens: int) -> int:
    if cfg.dropless:
        return -(-group_tokens // 4) * 4    # every token always fits
    c = int(group_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(cfg.top_k, -(-c // 4) * 4)   # round up to 4 for layout


def router(x, w_router, cfg: MoEConfig):
    """x: (G, T, d) -> (weights (G,T,k), experts (G,T,k) int32, aux loss)."""
    logits = jnp.einsum("gtd,de->gte", x.astype(jnp.float32),
                        w_router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * mean(density * mean_prob)
    density = jnp.mean(
        jax.nn.one_hot(top_e, cfg.n_experts, dtype=jnp.float32), axis=(1, 2))
    mean_prob = jnp.mean(probs, axis=1)
    aux = cfg.n_experts * jnp.mean(jnp.sum(density * mean_prob, axis=-1))
    return top_w, top_e, aux


def _dispatch_einsum(x, top_w, top_e, cfg: MoEConfig, params):
    G, T, d = x.shape
    C = capacity(cfg, T)
    E = cfg.n_experts
    # position of each (token, k) within its expert's buffer
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.int32)          # (G,T,k,E)
    pos = jnp.cumsum(onehot.reshape(G, T * cfg.top_k, E), axis=1)
    pos = pos.reshape(G, T, cfg.top_k, E) - 1                    # 0-based slot
    in_cap = (pos < C) & (onehot > 0)
    # Accumulate dispatch/combine per routing choice so the peak intermediate
    # stays (G,T,E,C) — never (G,T,k,E,C).
    dispatch = jnp.zeros((G, T, E, C), x.dtype)
    combine = jnp.zeros((G, T, E, C), x.dtype)
    for i in range(cfg.top_k):
        e_oh = (onehot[:, :, i, :] * in_cap[:, :, i, :]).astype(x.dtype)
        p_i = jnp.sum(pos[:, :, i, :] * onehot[:, :, i, :], axis=-1)  # (G,T)
        p_oh = jax.nn.one_hot(p_i, C, dtype=x.dtype)                  # (G,T,C)
        contrib = jnp.einsum("gte,gtc->gtec", e_oh, p_oh)
        dispatch = dispatch + contrib
        combine = combine + contrib * top_w[:, :, i, None, None].astype(x.dtype)
    xin = jnp.einsum("gtec,gtd->gecd", dispatch, x)              # (G,E,C,d)
    xin = hint(xin, "batch", "model", None, None)                # EP all-to-all
    h = _expert_ffn(xin, params)                                 # (G,E,C,d)
    h = hint(h, "batch", "model", None, None)
    return jnp.einsum("gtec,gecd->gtd", combine, h)


def _dispatch_sort(x, top_w, top_e, cfg: MoEConfig, params):
    """Sort-based dispatch: argsort tokens by expert, scatter into (E*C, d)
    buffers.  Fewer FLOPs than the one-hot einsums; relies on SPMD handling
    of gather/scatter (perf-study alternative)."""
    G, T, d = x.shape
    C = capacity(cfg, T)
    E = cfg.n_experts
    k = cfg.top_k
    flat_e = top_e.reshape(G, T * k)
    order = jnp.argsort(flat_e, axis=1)                          # stable
    tok = order // k
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    # position within expert = running index minus start of expert segment
    seg_start = jnp.cumsum(
        jax.nn.one_hot(sorted_e, E, dtype=jnp.int32), axis=1) - 1
    pos = jnp.take_along_axis(seg_start, sorted_e[..., None], axis=2)[..., 0]
    slot = sorted_e * C + pos
    ok = pos < C
    slot = jnp.where(ok, slot, E * C)                            # overflow bin
    xg = jnp.take_along_axis(x, tok[..., None], axis=1)          # (G,T*k,d)
    buf = jnp.zeros((G, E * C + 1, d), x.dtype)
    buf = jax.vmap(lambda b, s, v: b.at[s].add(v))(buf, slot, xg)
    h = _expert_ffn(buf[:, :E * C].reshape(G, E, C, d), params)
    h = h.reshape(G, E * C, d)
    hg = jnp.take_along_axis(h, jnp.minimum(slot, E * C - 1)[..., None],
                             axis=1)
    w = jnp.take_along_axis(top_w.reshape(G, T * k), order, axis=1)
    hg = hg * (w * ok.astype(w.dtype))[..., None]
    out = jnp.zeros((G, T, d), x.dtype)
    return jax.vmap(lambda o, t, v: o.at[t].add(v))(out, tok, hg)


def _expert_ffn(xin, params):
    """xin: (G, E, C, d) -> SwiGLU per expert with weights (E, d, f)/(E, f, d)."""
    g = hint(jnp.einsum("gecd,edf->gecf", xin, params["w_gate"]),
             "batch", "model", None, None)
    h = hint(jnp.einsum("gecd,edf->gecf", xin, params["w_in"]),
             "batch", "model", None, None)
    a = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    return jnp.einsum("gecf,efd->gecd", a, params["w_out"])


def moe_ffn(x, params, cfg: MoEConfig):
    """x: (B, S, d).  Returns (out (B,S,d), aux_loss scalar).

    params: {w_router (d,E), w_gate/w_in (E,d,f), w_out (E,f,d),
             shared_gate/shared_in (d, n_shared*f), shared_out (n_shared*f, d)}
    """
    B, S, d = x.shape
    tokens = B * S
    gs = min(cfg.group_size, tokens)
    G = tokens // gs
    assert G * gs == tokens, f"group_size {gs} must divide tokens {tokens}"
    xg = hint(x.reshape(G, gs, d), "batch", None, None)
    top_w, top_e, aux = router(xg, params["w_router"], cfg)
    impl = {"einsum": _dispatch_einsum, "sort": _dispatch_sort}[cfg.impl]
    routed = impl(xg, top_w, top_e, cfg, params).reshape(B, S, d)
    if cfg.n_shared > 0:
        from repro.nn.mlp import swiglu
        routed = routed + swiglu(x, params["shared_gate"], params["shared_in"],
                                 params["shared_out"])
    return routed, aux
