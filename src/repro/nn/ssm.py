"""Recurrent sequence mixers: RWKV6 (Finch) time-mix / channel-mix and a
Mamba-style selective SSM (for Hymba's parallel attn+SSM heads).

Both use chunked formulations for training (O(S) memory, parallel within
chunk) and O(1)-state recurrent steps for decode — this is what makes the
``long_500k`` cells feasible where full attention is quadratic-infeasible.

RWKV6 recurrence (per head, k-dim d, v-dim d):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (w_t in (0,1), data-dependent)
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
Chunked: with b_j = sum_{i<=j} log w_i (monotone decreasing within a chunk),
all decay factors appear as exp(b_i - b_j) <= 1 for j <= i, so the intra-chunk
score tensor is computed stably in f32 from pairwise differences.  Chunk size
is kept small (16) because the pairwise-difference tensor is (C, C, d).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import hint


# --------------------------------------------------------------------------
# RWKV6 time-mix core (wkv) — chunked scan + recurrent step.
# --------------------------------------------------------------------------

def wkv6_chunked(r, k, v, w, u, chunk: int = 16, state0=None):
    """r,k,v,w: (B, S, H, D); u: (H, D).  Returns (B, S, H, D), final state.

    w is the per-step decay in (0,1).  S must be a multiple of ``chunk``.
    ``state0``: optional initial (B, H, D, D) f32 state (cache continuation).
    """
    B, S, H, D = r.shape
    assert S % chunk == 0
    nc = S // chunk
    f32 = jnp.float32

    def prep(t):
        # keep the stacked xs in the compute dtype (bf16) — the f32 upcast
        # happens per-chunk inside the body where it fuses (halves the
        # stacked-input HBM traffic; §Perf iteration 3)
        t = t.reshape(B, nc, chunk, H, D).transpose(1, 0, 3, 2, 4)
        return hint(t, None, "batch", "model", None, None)

    rr, kk, vv = prep(r), prep(k), prep(v)
    lw = prep(jnp.log(jnp.clip(w.astype(f32), 1e-8, 1.0)).astype(r.dtype))
    uu = u.astype(f32)

    def body(S0, xs):
        rc, kc, vc, lwc = (t.astype(f32) for t in xs)   # (B, H, C, D)
        b = jnp.cumsum(lwc, axis=2)                # inclusive log-decay
        b_excl = b - lwc                           # decay before step i
        # inter-chunk: o_i += (r_i ⊙ exp(b_excl_i)) @ S0
        r_dec = rc * jnp.exp(b_excl)
        o = jnp.einsum("bhcd,bhde->bhce", r_dec, S0)
        # intra-chunk (j < i): scores_ij = sum_d r_id k_jd exp(b_excl_i - b_j)
        diff = b_excl[:, :, :, None, :] - b[:, :, None, :, :]   # (B,H,C,C,D)
        strict = jnp.tril(jnp.ones((chunk, chunk), bool), -1)
        diff = jnp.where(strict[None, None, :, :, None], diff, -jnp.inf)
        scores = jnp.einsum("bhcd,bhjd,bhcjd->bhcj", rc, kc,
                            jnp.exp(diff))
        o = o + jnp.einsum("bhcj,bhjd->bhcd", scores, vc)
        # current-token bonus: r_i · diag(u) k_i v_i^T
        bonus = jnp.einsum("bhcd,hd,bhcd->bhc", rc, uu, kc)
        o = o + bonus[..., None] * vc
        # state update: S1 = diag(exp(b_C)) S0 + sum_j exp(b_C - b_j) k_j v_j^T
        wC = jnp.exp(b[:, :, -1:, :])              # (B,H,1,D)
        k_scaled = kc * jnp.exp(b[:, :, -1:, :] - b)
        S1 = wC[:, :, 0, :, None] * S0 + jnp.einsum("bhjd,bhje->bhde",
                                                    k_scaled, vc)
        return S1, o

    S0 = jnp.zeros((B, H, D, D), f32) if state0 is None else state0.astype(f32)
    Sf, outs = jax.lax.scan(body, S0, (rr, kk, vv, lw))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, D)
    return out.astype(r.dtype), Sf


def wkv6_step(r1, k1, v1, w1, u, state):
    """Single decode step.  r1..w1: (B, H, D); state: (B, H, D, D) f32.
    Returns (out (B,H,D), new_state)."""
    f32 = jnp.float32
    r1, k1, v1, w1 = (x.astype(f32) for x in (r1, k1, v1, w1))
    kv = k1[..., :, None] * v1[..., None, :]              # (B,H,D,D)
    out = jnp.einsum("bhd,bhde->bhe", r1, state + u.astype(f32)[..., None] * kv)
    new_state = w1[..., None] * state + kv
    return out, new_state


# --------------------------------------------------------------------------
# Mamba-style selective SSM (diagonal state, data-dependent dt/B/C).
# --------------------------------------------------------------------------

def selective_scan(x, dt, A_log, Bm, Cm, D_skip, chunk: int = 32,
                   state0=None):
    """x, dt: (B, S, d);  A_log: (d, N);  Bm, Cm: (B, S, N);  D_skip: (d,).

    h_t = exp(dt_t ⊙ A) h_{t-1} + (dt_t x_t) B_t;  y_t = (h_t C_t) + D x_t.
    Chunked: outer scan over S/chunk carries h (B, d, N); inner associative
    scan parallelizes within the chunk.  Returns (y (B,S,d), final h).

    ``state0``: optional initial (B, d, N) f32 state (cache continuation —
    chunked prefill resumes the stream mid-sequence).  The outer scan
    threads the carry exactly, so a resumed scan is bit-identical to the
    uninterrupted one whenever the chunk boundaries line up (``chunk=1``
    makes the whole scan a sequential fold, decomposable at any position).
    """
    B, S, d = x.shape
    N = A_log.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    f32 = jnp.float32
    A = -jnp.exp(A_log.astype(f32))                            # (d, N) negative
    # stacked xs stay in compute dtype; f32 upcast fuses inside the body
    xr = x.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    dtr = dt.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    Br = Bm.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    Cr = Cm.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    xr = hint(xr, None, "batch", None, "model")
    dtr = hint(dtr, None, "batch", None, "model")

    def body(h0, xs):
        xc, dtc, bc, cc = (t.astype(f32) for t in xs)          # (B, C, ...)
        a = jnp.exp(dtc[..., None] * A)                        # (B,C,d,N)
        u = (dtc * xc)[..., None] * bc[:, :, None, :]          # (B,C,d,N)

        def combine(e1, e2):
            a1, u1 = e1
            a2, u2 = e2
            return a1 * a2, u1 * a2 + u2

        a_sc, u_sc = jax.lax.associative_scan(combine, (a, u), axis=1)
        h = a_sc * h0[:, None] + u_sc                          # (B,C,d,N)
        y = jnp.einsum("bcdn,bcn->bcd", h, cc) + D_skip.astype(f32) * xc
        return h[:, -1], y

    h0 = jnp.zeros((B, d, N), f32) if state0 is None else state0.astype(f32)
    hf, ys = jax.lax.scan(body, h0, (xr, dtr, Br, Cr))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, d)
    return y.astype(x.dtype), hf


def selective_step(x1, dt1, A_log, B1, C1, D_skip, h):
    """One decode step.  x1, dt1: (B, d); B1, C1: (B, N); h: (B, d, N) f32."""
    f32 = jnp.float32
    A = -jnp.exp(A_log.astype(f32))
    a = jnp.exp(dt1.astype(f32)[..., None] * A)
    u = (dt1.astype(f32) * x1.astype(f32))[..., None] * B1.astype(f32)[:, None, :]
    h_new = a * h + u
    y = jnp.einsum("bdn,bn->bd", h_new, C1.astype(f32)) \
        + D_skip.astype(f32) * x1.astype(f32)
    return y.astype(x1.dtype), h_new
