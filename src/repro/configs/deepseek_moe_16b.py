"""deepseek-moe-16b [moe] — 28L d_model=2048 16H d_ff(expert)=1408
vocab=102400; fine-grained MoE: 2 shared + 64 routed top-6, dense layer 0
(width 10944, per the released model).  [arXiv:2401.06066; hf]"""
from repro.models.lm import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1408, vocab=102400, mlp_type="swiglu", rope_theta=10000.0,
        n_experts=64, top_k=6, n_shared=2, d_expert=1408,
        first_dense_ff=10944,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="deepseek-moe-16b-smoke", family="moe",
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=96, vocab=512, mlp_type="swiglu", rope_theta=10000.0,
        n_experts=8, top_k=2, n_shared=1, d_expert=96, first_dense_ff=384,
        moe_group_size=64, remat="none", moe_dropless_prefill=True,
    )
