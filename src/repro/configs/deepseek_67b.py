"""deepseek-67b [dense] — 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400; llama-arch (SwiGLU, RMSNorm, RoPE 1e4).
[arXiv:2401.02954; hf]"""
from repro.models.lm import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="deepseek-67b", family="decoder",
        n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=22016, vocab=102400, mlp_type="swiglu", rope_theta=10000.0,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="deepseek-67b-smoke", family="decoder",
        n_layers=5, d_model=256, n_heads=8, n_kv_heads=2, d_head=32,
        d_ff=688, vocab=512, mlp_type="swiglu", rope_theta=10000.0,
        remat="none",
    )
