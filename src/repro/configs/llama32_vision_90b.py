"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256; gated cross-attention image layers every 5th layer.  The vision
tower is a STUB: input_specs provides precomputed patch embeddings
(B, 1024, d).  [hf:meta-llama/Llama-3.2-11B-Vision family; unverified]"""
from repro.models.lm import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
        d_ff=28672, vocab=128256, mlp_type="swiglu", rope_theta=500000.0,
        cross_every=5, n_vision_tokens=1024,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="llama-3.2-vision-90b-smoke", family="vlm",
        n_layers=6, d_model=256, n_heads=8, n_kv_heads=2, d_head=32,
        d_ff=896, vocab=512, mlp_type="swiglu", rope_theta=500000.0,
        cross_every=3, n_vision_tokens=16, remat="none",
    )
