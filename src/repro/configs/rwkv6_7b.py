"""rwkv6-7b [ssm] — Finch: 32L d_model=4096 (attention-free, 64 wkv heads of
64) d_ff=14336 vocab=65536; data-dependent per-channel decay.
[arXiv:2404.05892; hf]"""
from repro.models.lm import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="rwkv6-7b", family="rwkv",
        n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, d_head=64,
        d_ff=14336, vocab=65536, rwkv_chunk=64,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="rwkv6-7b-smoke", family="rwkv",
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=448, vocab=512, remat="none",
    )
