"""Architecture registry: the ten assigned configs + the paper's LeNet-5.

Each ``<arch>.py`` exposes:
  config()        — the exact published configuration (LMConfig)
  smoke_config()  — a reduced same-family config for CPU smoke tests
and this package provides the shape-cell definitions (train_4k / prefill_32k
/ decode_32k / long_500k) with per-arch skip rules, plus ``input_specs`` —
ShapeDtypeStruct stand-ins for every model input (no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib

import jax
import jax.numpy as jnp

ARCHS = (
    "llama3_405b",
    "starcoder2_15b",
    "deepseek_67b",
    "stablelm_3b",
    "whisper_medium",
    "llama32_vision_90b",
    "rwkv6_7b",
    "hymba_1_5b",
    "deepseek_moe_16b",
    "moonshot_v1_16b_a3b",
)

# Canonical ids as given in the assignment (dashes) -> module names.
ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({
    "llama3-405b": "llama3_405b",
    "starcoder2-15b": "starcoder2_15b",
    "deepseek-67b": "deepseek_67b",
    "stablelm-3b": "stablelm_3b",
    "whisper-medium": "whisper_medium",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "rwkv6-7b": "rwkv6_7b",
    "hymba-1.5b": "hymba_1_5b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
})


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str       # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = (
    Shape("train_4k", "train", 4096, 256),
    Shape("prefill_32k", "prefill", 32768, 32),
    Shape("decode_32k", "decode", 32768, 128),
    Shape("long_500k", "decode", 524288, 1),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}

# long_500k needs sub-quadratic attention: only the SSM/hybrid families run
# it; the skip for full-attention archs is recorded in DESIGN.md.
LONG_OK = {"rwkv6_7b", "hymba_1_5b"}


def get(arch: str):
    mod = ALIASES.get(arch, arch)
    return importlib.import_module(f"repro.configs.{mod}")


def config(arch: str):
    return get(arch).config()


def smoke_config(arch: str):
    return get(arch).smoke_config()


def cells(arch: str):
    """The shape cells this arch runs (with skip reasons for the rest)."""
    mod = ALIASES.get(arch, arch)
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and mod not in LONG_OK:
            out.append((s, "skip: full quadratic attention at 512k infeasible"))
        else:
            out.append((s, None))
    return out


def input_specs(cfg, shape: Shape, abstract: bool = True):
    """ShapeDtypeStruct stand-ins for every input of the lowered step.

    train  -> {"tokens","labels"} (+ modality stubs)
    prefill-> {"tokens"} (+ modality stubs)
    decode -> ({"tokens"}, cache)
    """
    from repro.serve import engine

    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
         (lambda s, d: jnp.zeros(s, d))
    B, S = shape.batch, shape.seq
    i32 = jnp.int32

    def stubs():
        e = {}
        if cfg.family == "encdec":
            e["enc_embed"] = mk((B, cfg.enc_len, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            e["vision_embed"] = mk((B, cfg.n_vision_tokens, cfg.d_model),
                                   jnp.bfloat16)
        return e

    if shape.kind == "train":
        batch = {"tokens": mk((B, S), i32), "labels": mk((B, S), i32)}
        batch.update(stubs())
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": mk((B, S), i32)}
        batch.update(stubs())
        return batch
    if shape.kind == "decode":
        cache = engine.init_cache(cfg, B, S, abstract=abstract)
        return {"tokens": mk((B, 1), i32)}, cache
    raise ValueError(shape.kind)
