"""moonshot-v1-16b-a3b [moe] — kimi/moonlight: 48L d_model=2048 16H
d_ff(expert)=1408 vocab=163840; 2 shared + 64 routed top-6, dense layer 0
(width 11264).  [hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.models.lm import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="moonshot-v1-16b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
        d_ff=1408, vocab=163840, mlp_type="swiglu", rope_theta=50000.0,
        n_experts=64, top_k=6, n_shared=2, d_expert=1408,
        first_dense_ff=11264,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="moonshot-v1-16b-a3b-smoke", family="moe",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=96, vocab=512, mlp_type="swiglu", rope_theta=50000.0,
        n_experts=8, top_k=2, n_shared=1, d_expert=96, first_dense_ff=384,
        moe_group_size=64, remat="none", moe_dropless_prefill=True,
    )
