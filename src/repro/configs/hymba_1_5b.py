"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + Mamba heads per block,
sliding window 1024 with full-attention layers every 16 (layers 0 and 16 —
approximating the paper's {first, middle, last} placement with a uniform
group structure; placement is a minor effect per Hymba's own ablation and
the uniform grouping enables static-window KV skipping, see EXPERIMENTS.md
SPerf iteration 2).  [arXiv:2411.13676; hf]"""
from repro.models.lm import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
        d_ff=5504, vocab=32001, ssm_state=16, d_inner=3200,
        window=1024, global_every=16, rope_theta=10000.0,
        ssm_chunk=512,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="hymba-1.5b-smoke", family="hybrid",
        n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
        d_ff=448, vocab=512, ssm_state=8, d_inner=256,
        window=16, global_every=2, rope_theta=10000.0, remat="none",
    )
