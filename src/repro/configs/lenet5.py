"""The paper's own architecture: LeNet-5 (Keras variant, Fig. 3) with the
hybrid stochastic-binary first layer."""
from repro.core.sc_layer import SCConfig
from repro.models.lenet import LeNetConfig


def config() -> LeNetConfig:
    return LeNetConfig()


def sc_config(bits: int = 4) -> SCConfig:
    return SCConfig(bits=bits, scheme="ramp_lowdisc", adder="tff")


def smoke_config() -> LeNetConfig:
    return LeNetConfig(conv1_filters=8, conv2_filters=8, dense=32)
