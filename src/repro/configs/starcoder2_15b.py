"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152; GELU MLP with biases, LayerNorm, RoPE (base 1e5).
[arXiv:2402.19173; hf]"""
from repro.models.lm import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="starcoder2-15b", family="decoder",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_head=128,
        d_ff=24576, vocab=49152, mlp_type="gelu", use_bias=True,
        norm_type="layernorm", rope_theta=100000.0,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="starcoder2-15b-smoke", family="decoder",
        n_layers=4, d_model=192, n_heads=6, n_kv_heads=2, d_head=32,
        d_ff=768, vocab=512, mlp_type="gelu", use_bias=True,
        norm_type="layernorm", rope_theta=100000.0, remat="none",
    )
