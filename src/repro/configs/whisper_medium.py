"""whisper-medium [audio] — enc-dec, 24L+24L d_model=1024 16H d_ff=4096
vocab=51865; GELU+biases, LayerNorm, sinusoidal positions.  The conv audio
frontend is a STUB per the assignment: input_specs provides precomputed
frame embeddings (B, 1500, d).  [arXiv:2212.04356; unverified]"""
from repro.models.lm import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="whisper-medium", family="encdec",
        n_layers=24, enc_layers=24, enc_len=1500,
        d_model=1024, n_heads=16, n_kv_heads=16, d_head=64,
        d_ff=4096, vocab=51865, mlp_type="gelu", use_bias=True,
        norm_type="layernorm", pos_embedding="sinusoidal",
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="whisper-medium-smoke", family="encdec",
        n_layers=3, enc_layers=3, enc_len=32,
        d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
        d_ff=512, vocab=512, mlp_type="gelu", use_bias=True,
        norm_type="layernorm", pos_embedding="sinusoidal", remat="none",
    )
