"""stablelm-3b [dense] — 32L d_model=2560 32H (MHA kv=32) d_ff=6912
vocab=50304.  [hf:stabilityai/stablelm-2-1_6b family; unverified]"""
from repro.models.lm import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="stablelm-3b", family="decoder",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_head=80,
        d_ff=6912, vocab=50304, mlp_type="swiglu", rope_theta=10000.0,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="stablelm-3b-smoke", family="decoder",
        n_layers=4, d_model=160, n_heads=4, n_kv_heads=4, d_head=40,
        d_ff=432, vocab=512, mlp_type="swiglu", rope_theta=10000.0,
        remat="none",
    )
