"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256, RoPE theta 5e5.  [arXiv:2407.21783; unverified]"""
from repro.models.lm import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="llama3-405b", family="decoder",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_head=128,
        d_ff=53248, vocab=128256, mlp_type="swiglu", rope_theta=500000.0,
    )


def smoke_config() -> LMConfig:
    return LMConfig(
        name="llama3-405b-smoke", family="decoder",
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=2, d_head=32,
        d_ff=832, vocab=512, mlp_type="swiglu", rope_theta=500000.0,
        remat="none",
    )
