"""Loop-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, which
undercounts scan-over-layers / microbatch / chunked-attention models by the
full trip-count product (~100-1000x).  This module re-derives the roofline
inputs by walking the post-SPMD optimized HLO text with loop multipliers:

  flops        — dot/convolution FLOPs (MXU flops, the MFU convention)
  hbm_bytes    — Σ over *top-level* ops of (operand + output) tensor bytes.
                 Fusion internals are excluded: a fusion op's operands/outputs
                 are exactly its HBM reads/writes under XLA semantics, so this
                 is a faithful first-order HBM-traffic model.
  collectives  — per-primitive counts/bytes with ring-traffic factors,
                 multiplied through loops.

Trip counts come from the largest integer constant in each while's condition
region (exact for lax.scan's counted loops — the only while loops we emit).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
                "s4": 1, "u4": 1, "bf8": 1, "tuple": 0, "token": 0, "u1": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_REGION_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLLS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")


def _shapes_in(segment: str):
    return _SHAPE_RE.findall(segment)


def _bytes_of(segment: str) -> int:
    total = 0
    for dt, dims in _shapes_in(segment):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems_of_first(segment: str) -> tuple[int, list[int]]:
    m = _SHAPE_RE.search(segment)
    if not m:
        return 0, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return n, dims


@dataclass
class Op:
    name: str
    rhs: str            # everything after '='
    out_bytes: int
    kind: str           # opcode-ish token


@dataclass
class Region:
    name: str
    ops: list = field(default_factory=list)
    defs: dict = field(default_factory=dict)   # name -> shape segment


def parse_regions(text: str) -> dict[str, Region]:
    regions: dict[str, Region] = {}
    current: Region | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if current is None:
            m = _REGION_RE.match(line)
            if m:
                current = Region(m.group(1))
            continue
        if line.strip() == "}" or line.endswith("} // " + current.name):
            regions[current.name] = current
            current = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # shape segment = rhs up to the opcode token; find first identifier
        # after the shape literal(s)
        current.defs[name] = rhs
        current.ops.append(Op(name, rhs, 0, _opcode(rhs)))
    if current is not None:
        regions[current.name] = current
    return regions


_OPCODE_RE = re.compile(r"(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
                        r"([a-z][\w\-]*)\(")


def _opcode(rhs: str) -> str:
    m = _OPCODE_RE.search(rhs)
    return m.group(1) if m else ""


def _out_segment(rhs: str) -> str:
    m = _OPCODE_RE.search(rhs)
    return rhs[:m.start(1)] if m else rhs


def _operands(rhs: str) -> list[str]:
    m = _OPCODE_RE.search(rhs)
    if not m:
        return []
    rest = rhs[m.end(1):]
    depth = 0
    args = ""
    for ch in rest:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            args += ch
    return _OPERAND_RE.findall(args)


def _dot_flops(rhs: str, defs: dict) -> float:
    out_elems, _ = _elems_of_first(_out_segment(rhs))
    ops = _operands(rhs)
    if not ops:
        return 0.0
    lhs_shape_seg = defs.get(ops[0], "")
    _, lhs_dims = _elems_of_first(lhs_shape_seg)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    contract = 1
    if m and lhs_dims:
        for i in m.group(1).split(","):
            if i and int(i) < len(lhs_dims):
                contract *= lhs_dims[int(i)]
    return 2.0 * out_elems * contract


def _conv_flops(rhs: str, defs: dict) -> float:
    out_elems, _ = _elems_of_first(_out_segment(rhs))
    ops = _operands(rhs)
    if len(ops) < 2:
        return 0.0
    _, k_dims = _elems_of_first(defs.get(ops[1], ""))
    if not k_dims:
        return 0.0
    k_elems = 1
    for d in k_dims:
        k_elems *= d
    # per output element: kernel_elems / output_features MACs * 2
    m = re.search(r"dim_labels=\S*->\S*", rhs)
    return 2.0 * out_elems * k_elems  # coarse (feature dims cancel approx)


def _trip_count(cond_region: Region) -> int:
    best = 1
    for op in cond_region.ops:
        for c in _CONST_RE.findall(op.rhs):
            best = max(best, int(c))
    return best


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.collectives.items():
            s = self.collectives.setdefault(
                k, {"count": 0.0, "bytes": 0.0, "traffic_bytes": 0.0})
            for f in s:
                s[f] += v[f] * mult


def _group_size(rhs: str) -> int:
    m = _GROUPS_RE.search(rhs)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rhs)
    if m:
        return len(m.group(1).split(","))
    return 1


_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "reshape", "after-all", "partition-id",
                   "replica-id", ""}


def region_cost(rname: str, regions: dict[str, Region],
                memo: dict[str, Cost]) -> Cost:
    if rname in memo:
        return memo[rname]
    region = regions[rname]
    cost = Cost()
    for op in region.ops:
        kind = op.kind
        rhs = op.rhs
        if kind == "while":
            body = re.search(r"body=%?([\w.\-]+)", rhs)
            cond = re.search(r"condition=%?([\w.\-]+)", rhs)
            if body and cond and body.group(1) in regions:
                trips = _trip_count(regions[cond.group(1)])
                cost.add(region_cost(body.group(1), regions, memo), trips)
            continue
        if kind in ("call", "conditional", "async-start"):
            for target in re.findall(
                    r"(?:to_apply|branch_computations=\{|called_computations="
                    r"\{|calls)=?%?([\w.\-]+)", rhs):
                if target in regions:
                    cost.add(region_cost(target, regions, memo))
            continue
        if kind == "fusion":
            # descend for FLOPs only (fused dots), not bytes — the fusion op's
            # own operands/outputs are the HBM traffic
            m_f = re.search(r"calls=%?([\w.\-]+)", rhs)
            if m_f and m_f.group(1) in regions:
                cost.flops += region_cost(m_f.group(1), regions, memo).flops
        if kind == "dot":
            cost.flops += _dot_flops(rhs, region.defs)
        elif kind == "convolution":
            cost.flops += _conv_flops(rhs, region.defs)
        coll = next((c for c in _COLLS
                     if kind == c or kind == c + "-start"), None)
        if coll:
            b = _bytes_of(_out_segment(rhs))
            n = _group_size(rhs)
            if coll == "all-reduce":
                factor = 2.0 * (n - 1) / max(n, 1)
            elif coll == "collective-permute":
                factor = 1.0
            else:
                factor = (n - 1) / max(n, 1)
            s = cost.collectives.setdefault(
                coll, {"count": 0.0, "bytes": 0.0, "traffic_bytes": 0.0})
            s["count"] += 1
            s["bytes"] += b
            s["traffic_bytes"] += b * factor
        # HBM traffic: top-level op operand + output bytes (fusion internals
        # never appear here; their region is only reachable via calls=, which
        # we do not descend into for bytes)
        if kind not in _SKIP_BYTES_OPS and kind != "fusion":
            out_b = _bytes_of(_out_segment(rhs))
            in_b = sum(_bytes_of(_out_segment(region.defs.get(o, "")))
                       for o in _operands(rhs))
            cost.hbm_bytes += out_b + in_b
        elif kind == "fusion":
            out_b = _bytes_of(_out_segment(rhs))
            in_b = sum(_bytes_of(_out_segment(region.defs.get(o, "")))
                       for o in _operands(rhs))
            cost.hbm_bytes += out_b + in_b
    memo[rname] = cost
    return cost


def analyze(hlo_text: str, entry_hint: str = "main") -> dict:
    regions = parse_regions(hlo_text)
    entry = None
    for name in regions:
        if entry_hint in name:
            entry = name
            break
    if entry is None:
        # fall back: region that is not referenced by others
        referenced = set()
        for r in regions.values():
            for op in r.ops:
                referenced.update(re.findall(
                    r"(?:body|condition|to_apply|calls)=%?([\w.\-]+)", op.rhs))
        entries = [n for n in regions if n not in referenced]
        entry = entries[-1] if entries else next(iter(regions))
    cost = region_cost(entry, regions, {})
    return {
        "flops": cost.flops,
        "hbm_bytes": cost.hbm_bytes,
        "collectives": cost.collectives,
        "n_regions": len(regions),
        "entry": entry,
    }
