"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (smoke tests see 1 device; only dryrun.py forces 512).
"""
from __future__ import annotations

import jax


def _make(shape: tuple[int, ...], axes: tuple[str, ...]):
    # axis_types landed after jax 0.4.x; Auto is the default either way.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) single-pod (256 chips) or (2,16,16) two-pod (512 chips).

    Axes: "pod" — cross-pod data parallelism (gradient all-reduce only);
    "data" — in-pod data parallel + FSDP/ZeRO; "model" — tensor/expert
    parallel (highest-bandwidth, innermost axis).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (elastic scaling / tests): same axis-name contract."""
    return _make(shape, axes)


def make_serving_mesh(n_slices: int | None = None, model: int = 1):
    """("data", "model") mesh for the sharded serving gateway.

    ``n_slices`` data-parallel gateway slices (default: as many as the
    device count affords at ``model`` tensor-parallel devices per slice).
    On CPU the device count comes from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — how the
    sharded CI job and tests/test_sharded.py run an 8-way mesh on one
    host.  Factor the result with ``dist.sharding.slice_meshes`` to get
    the per-slice sub-meshes the gateway router schedules over.
    """
    n_dev = jax.device_count()
    if n_slices is None:
        n_slices = max(1, n_dev // model)
    assert n_slices * model <= n_dev, \
        f"serving mesh {n_slices}x{model} exceeds {n_dev} devices"
    return _make((n_slices, model), ("data", "model"))


def make_disagg_meshes(n_prefill: int, n_decode: int, *,
                       prefill_model: int = 1, decode_model: int = 1):
    """Role-partitioned slice meshes for disaggregated prefill/decode.

    Prefill and decode want different partitionings (JetStream's engine
    API makes the same split): prefill slices are few and model-parallel
    (compute-bound chunked folds), decode slices are many lanes
    (memory-bound in-place ticks).  Returns ``(prefill_meshes,
    decode_meshes)`` — per-slice ``("model",)`` sub-meshes over disjoint
    device groups, prefill slices taking the leading devices.  Feed the
    concatenated list to ``shard.build_slices`` and describe the split
    with a ``shard.RolePlan`` (``RolePlan.split(n_prefill, n_decode)``).
    """
    from jax.sharding import Mesh
    import numpy as np
    assert n_prefill >= 1 and n_decode >= 1, \
        "disaggregation needs at least one slice per role"
    need = n_prefill * prefill_model + n_decode * decode_model
    devs = jax.devices()
    assert need <= len(devs), \
        f"disagg mesh needs {need} devices; have {len(devs)}"
    out, k = [], 0
    for n, model in ((n_prefill, prefill_model), (n_decode, decode_model)):
        role = []
        for _ in range(n):
            role.append(Mesh(np.asarray(devs[k:k + model]), ("model",)))
            k += model
        out.append(role)
    return tuple(out)
