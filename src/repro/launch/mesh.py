"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (smoke tests see 1 device; only dryrun.py forces 512).
"""
from __future__ import annotations

import jax


def _make(shape: tuple[int, ...], axes: tuple[str, ...]):
    # axis_types landed after jax 0.4.x; Auto is the default either way.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) single-pod (256 chips) or (2,16,16) two-pod (512 chips).

    Axes: "pod" — cross-pod data parallelism (gradient all-reduce only);
    "data" — in-pod data parallel + FSDP/ZeRO; "model" — tensor/expert
    parallel (highest-bandwidth, innermost axis).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (elastic scaling / tests): same axis-name contract."""
    return _make(shape, axes)
