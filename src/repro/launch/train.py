"""Training launcher: mesh setup, sharded init, checkpoint/restart loop.

Fault-tolerance contract (designed for 1000+ nodes, exercised here on the
local device set):
  - RESTART: on launch, the latest intact checkpoint (atomic dirs + CRC) is
    restored and the data pipeline resumes from the recorded step — re-run
    the same command after killing the process and training continues.
  - ELASTIC: pass a different --mesh and the same checkpoint re-shards onto
    the new topology (specs are functions of the mesh, see dist.sharding).
  - STRAGGLERS / LOST HOSTS: batches are a stateless (seed, step) map, so a
    respawned host recomputes its shard without coordination.  On a real
    multi-controller deployment the runner wraps this loop with a step
    barrier + timeout + respawn (the checkpoint/restore path here is exactly
    what that respawn executes).
  - ASYNC CHECKPOINTS: device->host snapshot is synchronous, file I/O
    overlaps the next steps (CheckpointManager.save_async).

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b --smoke \
      --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.ckpt import manager as ckpt
from repro.data.tokens import TokenPipeline
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.train import optim
from repro.train.step import METRICS_KEYS, TrainConfig, make_train_step


def parse_mesh(spec: str):
    """"1" | "2x2" | "2x4 data,model" style."""
    if " " in spec:
        dims, names = spec.split(" ")
        shape = tuple(int(x) for x in dims.split("x"))
        axes = tuple(names.split(","))
    else:
        shape = tuple(int(x) for x in spec.split("x"))
        axes = ("data", "model")[:len(shape)] if len(shape) <= 2 else \
               ("pod", "data", "model")
    return make_mesh(shape, axes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (configs.smoke_config(args.arch) if args.smoke
           else configs.config(args.arch))
    mesh = parse_mesh(args.mesh)
    mesh_shape = shd.mesh_shape_dict(mesh)
    print(f"arch={cfg.name} params~{lm.count_params(cfg)/1e6:.1f}M "
          f"mesh={mesh_shape}")

    tcfg = TrainConfig(
        microbatches=args.microbatches,
        adamw=optim.AdamWConfig(lr=args.lr, weight_decay=0.1, grad_clip=1.0,
                                master_dtype=jnp.float32))
    with shd.use_activation_mesh(mesh):
        params, specs = lm.init(jax.random.key(args.seed), cfg, mesh_shape)
        params = jax.device_put(params, shd.named(mesh, specs))
        opt_state = optim.init(params, tcfg.adamw)
        opt_specs = shd.opt_state_specs(specs, params, mesh_shape)
        opt_state = jax.device_put(opt_state, shd.named(mesh, opt_specs))

        step_fn = make_train_step(cfg, tcfg)
        bspec = P(shd.batch_spec_axis(mesh_shape, args.batch), None)
        train_step = jax.jit(
            step_fn,
            in_shardings=(shd.named(mesh, specs), shd.named(mesh, opt_specs),
                          {"tokens": shd.named(mesh, bspec),
                           "labels": shd.named(mesh, bspec)}),
            out_shardings=(shd.named(mesh, specs),
                           shd.named(mesh, opt_specs),
                           {k: shd.named(mesh, P()) for k in METRICS_KEYS}),
            donate_argnums=(0, 1))

        start_step = 0
        mgr = None
        if args.ckpt_dir:
            mgr = ckpt.CheckpointManager(args.ckpt_dir, keep=3,
                                         save_interval=args.ckpt_every)
            if ckpt.latest_step(args.ckpt_dir) is not None:
                (params, opt_state), manifest = mgr.restore_latest(
                    (params, opt_state),
                    shardings=(shd.named(mesh, specs),
                               shd.named(mesh, opt_specs)))
                start_step = manifest["step"]
                print(f"resumed from step {start_step}")

        pipe = TokenPipeline(args.seed, args.batch, args.seq, cfg.vocab,
                             start_step=start_step)
        t0 = time.time()
        for step in range(start_step, args.steps):
            batch = jax.tree.map(jnp.asarray, pipe.next())
            params, opt_state, metrics = train_step(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                dt = (time.time() - t0) / max(1, step - start_step + 1)
                print(f"step {step:5d} loss {loss:.4f} "
                      f"({dt*1000:.0f} ms/step)")
                assert np.isfinite(loss), "loss diverged"
            if mgr and mgr.should_save(step):
                mgr.save_async(step + 1, (params, opt_state),
                               extra={"arch": cfg.name})
        if mgr:
            mgr.save_sync(args.steps, (params, opt_state),
                          extra={"arch": cfg.name})
            mgr.wait()
    print("done")


if __name__ == "__main__":
    main()
