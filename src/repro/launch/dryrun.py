import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove every (arch x shape x mesh) lowers AND compiles
under the production sharding, and extract the roofline inputs.

The two lines above MUST run before any jax import (jax locks the device
count at first init); dryrun is the only entry point that forces 512 host
devices — tests/benchmarks see the real single CPU device.

Per cell we record (benchmarks/results/dryrun/<cell>.json):
  - compiled.memory_analysis()  — per-device bytes (proves it fits / or not)
  - compiled.cost_analysis()    — per-device HLO FLOPs + bytes accessed
  - collective bytes parsed from the post-SPMD optimized HLO, per primitive
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), with ring-traffic factors and group sizes
  - MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for the usefulness ratio

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all [--mesh both] [--skip-done]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import hlo_analysis
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.serve import engine
from repro.train import optim
from repro.train.step import TrainConfig, make_train_step

RESULTS = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

# TPU v5e hardware constants (roofline targets).
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

def model_flops(cfg, shape) -> float:
    """6·N·D (training) / 2·N·D (inference) with N = active params."""
    n_active = lm.active_params(cfg)
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n_active * tokens
    tokens = shape.batch  # one token per sequence
    return 2.0 * n_active * tokens


def build_cell(arch: str, shape_name: str, mesh, kv_quant: bool = False):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    import dataclasses
    cfg = configs.config(arch)
    if kv_quant:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    shape = configs.SHAPES_BY_NAME[shape_name]
    mesh_shape = shd.mesh_shape_dict(mesh)
    params_abs, specs = lm.init(None, cfg, mesh_shape, abstract=True)
    bspec = shd.batch_spec_axis(mesh_shape, shape.batch)

    def nm(tree):
        return shd.named(mesh, tree)

    if shape.kind == "train":
        tcfg = TrainConfig(microbatches=min(8, shape.batch))
        step = make_train_step(cfg, tcfg)
        opt_abs = jax.eval_shape(lambda p: optim.init(p, tcfg.adamw),
                                 params_abs)
        opt_specs = shd.opt_state_specs(specs, params_abs, mesh_shape)
        batch = configs.input_specs(cfg, shape)
        batch_specs = {k: P(*((bspec,) + (None,) * (len(v.shape) - 1)))
                       for k, v in batch.items()}
        from repro.train.step import METRICS_KEYS
        in_sh = (nm(specs), nm(opt_specs), nm(batch_specs))
        out_sh = (nm(specs), nm(opt_specs),
                  nm({k: P() for k in METRICS_KEYS}))
        return step, (params_abs, opt_abs, batch), in_sh, out_sh

    if shape.kind == "prefill":
        batch = configs.input_specs(cfg, shape)
        batch_specs = {k: P(*((bspec,) + (None,) * (len(v.shape) - 1)))
                       for k, v in batch.items()}
        cache_sp = engine.cache_specs(cfg, mesh_shape, shape.batch)

        def step(params, b):
            return engine.prefill(cfg, params, b)
        in_sh = (nm(specs), nm(batch_specs))
        out_sh = (nm(cache_sp), NamedSharding(mesh, P(bspec, "model")))
        return step, (params_abs, batch), in_sh, out_sh

    if shape.kind == "decode":
        batch, cache = configs.input_specs(cfg, shape)
        batch_specs = {"tokens": P(bspec, None)}
        cache_sp = engine.cache_specs(cfg, mesh_shape, shape.batch)

        def step(params, c, b):
            return engine.decode_step(cfg, params, c, b["tokens"])
        in_sh = (nm(specs), nm(cache_sp), nm(batch_specs))
        out_sh = (nm(cache_sp), NamedSharding(mesh, P(bspec, "model")))
        return step, (params_abs, cache, batch), in_sh, out_sh
    raise ValueError(shape.kind)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             kv_quant: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    cfg = configs.config(arch)
    shape = configs.SHAPES_BY_NAME[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "devices": int(n_dev), "params": lm.count_params(cfg),
           "active_params": lm.active_params(cfg),
           "model_flops": model_flops(cfg, shape), "kv_quant": kv_quant}
    t0 = time.time()
    try:
        with shd.use_activation_mesh(mesh):
            fn, args, in_sh, out_sh = build_cell(arch, shape_name, mesh,
                                                 kv_quant)
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
        rec["lower_s"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
        ca = compiled.cost_analysis() or {}
        rec["cost_xla_raw"] = {               # un-loop-corrected (reference)
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
        txt = compiled.as_text()
        t2 = time.time()
        cost = hlo_analysis.analyze(txt)      # loop-corrected walk
        rec["analyze_s"] = time.time() - t2
        rec["cost"] = {"flops": cost["flops"], "hbm_bytes": cost["hbm_bytes"]}
        rec["collectives"] = cost["collectives"]
        rec["hlo_chars"] = len(txt)
        coll_traffic = sum(v["traffic_bytes"]
                           for v in rec["collectives"].values())
        # roofline terms (seconds) — the HLO module is per-device post-SPMD,
        # so per-device quantities divide by per-chip peaks directly
        rec["roofline"] = {
            "compute_s": cost["flops"] / PEAK_FLOPS,
            "memory_s": cost["hbm_bytes"] / HBM_BW,
            "collective_s": coll_traffic / LINK_BW,
        }
        rec["useful_flops_ratio"] = (
            rec["model_flops"] / rec["devices"] / cost["flops"]
            if cost["flops"] else 0.0)
        rec["ok"] = True
        dom = max(rec["roofline"], key=rec["roofline"].get)
        print(f"OK  {arch} {shape_name} {mesh_kind}: "
              f"lower {rec['lower_s']:.0f}s compile {rec['compile_s']:.0f}s "
              f"flops/dev {cost['flops']:.3e} "
              f"temp {rec['memory']['temp_bytes']/2**30:.2f}GiB "
              f"dom={dom} useful={rec['useful_flops_ratio']:.2f}")
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"FAIL {arch} {shape_name} {mesh_kind}: {rec['error'][:200]}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=("single", "multi",
                                                         "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--kv-quant", action="store_true",
                    help="int8 KV cache variant (writes *__kvq.json)")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        todo = [(a, s.name, m) for a in configs.ARCHS
                for s, skip in configs.cells(a) if skip is None
                for m in meshes]
    else:
        assert args.arch and args.shape
        todo = [(configs.ALIASES.get(args.arch, args.arch), args.shape, m)
                for m in meshes]

    for arch, shape_name, mesh_kind in todo:
        suffix = "__kvq" if args.kv_quant else ""
        out = RESULTS / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
        if args.skip_done and out.exists() and \
                json.loads(out.read_text()).get("ok"):
            print(f"skip {out.name} (done)")
            continue
        rec = run_cell(arch, shape_name, mesh_kind, kv_quant=args.kv_quant)
        out.write_text(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
