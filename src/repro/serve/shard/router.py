"""Cross-shard gateway router: one bucket ladder + block pool per mesh slice.

The sharded counterpart of :class:`gateway.PromptGateway`: a serving mesh
(``launch.mesh.make_serving_mesh``) is factored into per-slice sub-meshes
(``dist.sharding.slice_meshes``), each slice owning its own
``PagedKVSlotAdapter`` (arena committed to the slice's devices via
``engine.arena_specs``) and ``ContinuousBatcher``.  The router owns the
policy layer above them:

  admission     a prompt is hashed once (``chain_keys``) and every slice's
                radix index is probed with the same keys.  The request
                routes to the deepest-prefix slice when that slice can take
                it now (**affinity**); a saturated affinity slice spills to
                the least-loaded slice (**affinity_spill** — the prompt is
                recomputed there, correctness never depends on the hit);
                no hit anywhere routes least-loaded (**load**).

  rebalance     when a slice has queued work while another sits idle, the
                router migrates the loaded slice's youngest active request
                onto the idle slice (serve/shard/migrate.py) — refcounts
                and radix entries re-established on the destination, bytes
                moved charged to the request through
                ``frontend.migration_energy_nj``.

  telemetry     per-request records identical to the single-slice gateway,
                plus per-slice pool snapshots (``Telemetry.pools``), the
                routing counters, and migration byte totals.

Parity contract: slices are built with identical ``n_slots``, so every
slice's decode tick is the same fixed-shape executable — a single-device
slice produces bit-identical logits to the unsharded adapter, and a
migrated request's post-move logits are bit-identical to the ones it would
have produced in place (tests/test_sharded.py pins both).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.dist.sharding import slice_meshes
from repro.serve.gateway import frontend as fe
from repro.serve.gateway.gateway import (drive_prompt_loop,
                                         record_prompt_completion)
from repro.serve.gateway.slots import (ContinuousBatcher, Request,
                                       make_adapter)
from repro.serve.gateway.telemetry import Telemetry
from repro.serve.kvcache.pool import chain_keys
from repro.serve.shard.migrate import migrate_slot


@dataclasses.dataclass
class GatewaySlice:
    """One mesh slice: sub-mesh + paged adapter + its bucket ladder."""
    idx: int
    mesh: object
    adapter: object
    batcher: ContinuousBatcher


def build_slices(cfg, params, mesh, *, n_slots: int, max_len: int,
                 block_size: int = 16, num_blocks: int | None = None,
                 extras=None, chunked: bool = True, inplace: bool = True,
                 kernel: bool | None = None) -> list[GatewaySlice]:
    """One :class:`GatewaySlice` per sub-mesh of ``mesh``.

    ``mesh`` is a serving mesh (factored via ``slice_meshes``) or an
    explicit list of per-slice sub-meshes — slices may share devices, so a
    multi-slice gateway's *policy* layer runs anywhere (tests exercise
    routing/migration on a single CPU device; the ``sharded`` CI job gives
    every slice its own forced host device).  ``num_blocks`` is the
    **per-slice** (per-device-group) block budget — the fixed per-device
    HBM the acceptance bar holds constant while the aggregate slot count
    scales with the slice count."""
    assert cfg.family != "rwkv", \
        "sharded gateway: rwkv has O(1) state and no block pool to shard"
    subs = list(mesh) if isinstance(mesh, (list, tuple)) else \
        slice_meshes(mesh)
    slices = []
    for i, sm in enumerate(subs):
        ad = make_adapter(cfg, params, n_slots=n_slots, max_len=max_len,
                          extras=extras, paged=True, block_size=block_size,
                          num_blocks=num_blocks, chunked=chunked,
                          inplace=inplace, kernel=kernel, mesh=sm)
        slices.append(GatewaySlice(i, sm, ad, ContinuousBatcher(ad)))
    return slices


class ShardedPromptGateway:
    """LM front door over N gateway slices (virtual-time event loop)."""

    def __init__(self, slices: list[GatewaySlice], *,
                 max_new_tokens: int = 16, bytes_per_token: int = 4,
                 max_queue: int = 64,
                 energy_spec: fe.FrontendSpec | None = None,
                 auto_rebalance: bool = True,
                 tracer=None, metrics=None, slo=None,
                 shed_factor: int = 4):
        assert slices, "need at least one slice"
        assert len({sl.adapter.n_slots for sl in slices}) == 1, \
            "slices must share n_slots (the bitwise-parity contract)"
        assert len({(sl.adapter.bs, sl.adapter.nb_max)
                    for sl in slices}) == 1, \
            "slices must share block geometry (routing hashes prompts at " \
            "one block size and migration asserts bs/nb_max equality)"
        self.slices = slices
        self.max_new_tokens = max_new_tokens
        self.bytes_per_token = bytes_per_token
        self.max_queue = max_queue
        self.auto_rebalance = auto_rebalance
        if energy_spec is None:
            energy_spec = fe.FrontendSpec()
        self.energy_spec = energy_spec
        self._token_energy_nj = fe.lm_token_energy_nj(
            energy_spec, slices[0].adapter.cfg.d_model)
        self.routing = {"affinity": 0, "affinity_spill": 0, "load": 0}
        self.migrations = 0
        self.migration_bytes = 0
        self.peak_concurrent = 0    # max simultaneous active, fleet-wide
        # observability (serve/obs/): wired into every slice's batcher +
        # adapter only for the duration of run() — warmup stays untraced,
        # and without a tracer the fleet makes zero obs calls
        self.tracer = tracer
        self.metrics = metrics
        self.slo = slo
        # SLO-driven backpressure, same policy as the one-slice gateway:
        # under critical burn the fleet-wide admission bound shrinks by
        # shed_factor (see PromptGateway; pressure is the subscription
        # hook the ROADMAP degradation controller will also consume)
        self.shed_factor = shed_factor
        self._shedding = False
        if slo is not None:
            slo.pressure.subscribe(self._on_pressure)

    def _on_pressure(self, event) -> None:
        self._shedding = event.state == "critical"

    def _admit_bound(self) -> int:
        if self._shedding:
            return max(1, self.max_queue // self.shed_factor)
        return self.max_queue

    def jit_fns(self) -> dict[str, object]:
        """Named jitted entry points across every slice, for
        obs.RecompileDetector.track (slice-prefixed; the chunk-fold
        executables are process-wide, so they repeat under each prefix)."""
        fns: dict[str, object] = {}
        for sl in self.slices:
            for name, fn in sl.adapter.jit_fns().items():
                fns[f"slice{sl.idx}.{name}"] = fn
        return fns

    def cost_args(self) -> dict[str, tuple]:
        """Slice-prefixed adapter stages + representative args, for
        obs.costmodel roofline attribution — per-slice copies are distinct
        executables (each compiled against its own mesh placement), so
        each is costed under its own prefix."""
        out: dict[str, tuple] = {}
        for sl in self.slices:
            for name, pair in sl.adapter.cost_args().items():
                out[f"slice{sl.idx}.{name}"] = pair
        return out

    # -- routing ------------------------------------------------------------

    def _load(self, sl: GatewaySlice) -> tuple[int, int]:
        """Load key: blocks a slice has committed (in use + queued
        worst-case demand), then queue depth as the tie-break."""
        queued = sum(sl.adapter._block_demand(len(r.prompt),
                                              r.max_new_tokens)
                     for r in sl.batcher.pending)
        return (sl.adapter.pool.blocks_in_use() + queued,
                len(sl.batcher.pending))

    def route(self, prompt: np.ndarray, max_new: int) -> tuple[int, str]:
        """(slice index, reason): radix-prefix affinity first, then
        least-loaded.  Pure policy — no references taken, no state
        mutated except the routing counters."""
        prompt = np.asarray(prompt, np.int32)
        keys, pkey = chain_keys(prompt, self.slices[0].adapter.bs)
        hits = [len(sl.adapter.pool.probe_chain(keys, pkey, count=False)[0])
                for sl in self.slices]
        best = int(np.argmax(hits))
        cand = range(len(self.slices))
        if hits[best] > 0:
            sl = self.slices[best]
            if len(self.slices) == 1 or (
                    not sl.batcher.pending and
                    sl.adapter.can_admit(prompt, max_new)):
                self.routing["affinity"] += 1
                return best, "affinity"
            # owning slice saturated: the hit is storage, not correctness —
            # spill to the least-loaded *other* slice and recompute there
            # (queueing on the owner would be an affinity route, not a
            # spill, and would sit behind the very congestion we saw)
            reason = "affinity_spill"
            cand = [i for i in cand if i != best]
        else:
            reason = "load"
        order = sorted(cand, key=lambda i: self._load(self.slices[i]))
        self.routing[reason] += 1
        return order[0], reason

    def submit(self, req: Request) -> int:
        """Route + enqueue; returns the slice index chosen."""
        idx, _ = self.route(req.prompt, req.max_new_tokens)
        self.slices[idx].batcher.submit(req)
        return idx

    # -- rebalancing --------------------------------------------------------

    def _free_slot(self, sl: GatewaySlice) -> int | None:
        for j, r in enumerate(sl.batcher.active):
            if r is None and not sl.adapter.slot_bids[j]:
                return j
        return None

    def migrate(self, src_idx: int, slot: int, dst_idx: int) -> int:
        """Move the active request in ``(src_idx, slot)`` to ``dst_idx``.
        Returns bytes moved (also accumulated on the request and the
        router's totals)."""
        src, dst = self.slices[src_idx], self.slices[dst_idx]
        req = src.batcher.active[slot]
        assert req is not None, f"slice {src_idx} slot {slot} not active"
        dst_slot = self._free_slot(dst)
        assert dst_slot is not None, f"slice {dst_idx} has no free slot"
        if self.tracer is not None:
            # child of the request's open decode span — the move happens
            # mid-generation on the request's own track
            self.tracer.begin("migrate", tid=req.uid)
        receipt = migrate_slot(src.adapter, slot, dst.adapter, dst_slot,
                               req.prompt)
        if self.tracer is not None:
            self.tracer.end("migrate", tid=req.uid,
                            args=receipt.trace_args(src_idx, dst_idx))
        dst.batcher.active[dst_slot] = req
        dst.batcher.last_token[dst_slot] = src.batcher.last_token[slot]
        src.batcher.active[slot] = None
        src.batcher.last_token[slot] = 0
        req.migrations += 1
        req.migration_bytes += receipt.bytes_moved
        self.migrations += 1
        self.migration_bytes += receipt.bytes_moved
        return receipt.bytes_moved

    def maybe_rebalance(self) -> int:
        """One rebalance pass: every slice with queued work sheds its
        *cheapest* active request — the one holding the fewest blocks, so
        the move costs the fewest bytes — to an idle slice (free slot +
        no queue), unblocking the queued admission.  Returns migrations
        performed."""
        n = 0
        for src in self.slices:
            if not src.batcher.pending:
                continue
            # only a genuinely *blocked* queue justifies paying for a
            # migration: a pending head that will admit into a free slot
            # this very tick must be left alone
            head = src.batcher.pending[0]
            if self._free_slot(src) is not None and \
                    src.adapter.can_admit(head.prompt,
                                          head.max_new_tokens):
                continue
            victims = [j for j, r in enumerate(src.batcher.active)
                       if r is not None]
            if not victims:
                continue
            slot = min(victims, key=lambda j: len(src.adapter.slot_bids[j]))
            for dst in sorted(self.slices, key=self._load):
                if dst is src or dst.batcher.pending:
                    continue
                dst_slot = self._free_slot(dst)
                req = src.batcher.active[slot]
                demand = dst.adapter._block_demand(
                    len(req.prompt), req.max_new_tokens)
                if dst_slot is None or \
                        demand > dst.adapter.pool.available():
                    continue
                self.migrate(src.idx, slot, dst.idx)
                n += 1
                break
        return n

    # -- the event loop -----------------------------------------------------

    @property
    def busy(self) -> bool:
        return any(sl.batcher.busy for sl in self.slices)

    @property
    def queued(self) -> int:
        return sum(len(sl.batcher.pending) for sl in self.slices)

    def warmup(self, prompt_lens: tuple[int, ...]) -> None:
        """Compile every slice's prefill buckets + decode tick up front
        (the chunk-fold executables are shared process-wide, so slices
        after the first mostly re-trace nothing)."""
        for sl in self.slices:
            for j, n in enumerate(prompt_lens):
                sl.batcher.submit(Request(
                    uid=-1 - j, prompt=np.zeros((n,), np.int32),
                    max_new_tokens=2))
            sl.batcher.run()
            sl.batcher.peak_active = 0

    def step(self) -> list[Request]:
        """Rebalance, then one decode tick on every busy slice."""
        if self.auto_rebalance:
            self.maybe_rebalance()
        finished: list[Request] = []
        concurrent = 0
        for sl in self.slices:
            if sl.batcher.busy:
                finished.extend(sl.batcher.step())
                # lanes that actually decoded this round's tick
                # (batcher.last_active — the same quantity the
                # single-device peak_active maximizes, so the sharded
                # acceptance metric is symmetric with its baseline).
                # Every slice is stepped in the same virtual-time round,
                # so the sum is true simultaneous fleet concurrency —
                # per-slice peaks can occur at different times and must
                # not be added
                concurrent += sl.batcher.last_active
        self.peak_concurrent = max(self.peak_concurrent, concurrent)
        return finished

    def run(self, arrivals, telemetry: Telemetry | None = None) -> Telemetry:
        tel = telemetry if telemetry is not None else Telemetry()
        arrivals = [a for a in arrivals if a.kind == "prompt"]
        arr_t = {a.uid: a.t for a in arrivals}
        arr_ep = {a.uid: a.endpoint for a in arrivals}
        # SLO timestamps (t_dequeue/t_admit) need one shared virtual clock
        # across every slice, tracer or not
        from repro.serve.obs import SimClock
        clock = self.tracer.clock if self.tracer is not None else SimClock()
        if self.metrics is not None:
            m = self.metrics
            m.register("queue_depth", lambda: self.queued)
            m.register("migrations", lambda: self.migrations)
            m.register("spills", lambda: self.routing["affinity_spill"])
            for sl in self.slices:
                m.register(f"slice{sl.idx}_blocks_in_use",
                           lambda sl=sl:
                           sl.adapter.pool.gauges()["pool_blocks_in_use"])
                m.register(f"slice{sl.idx}_queue",
                           lambda sl=sl: len(sl.batcher.pending))
                m.register(f"slice{sl.idx}_active",
                           lambda sl=sl: sl.batcher.last_active)
        for sl in self.slices:
            sl.batcher.clock = clock
            sl.batcher.tracer = self.tracer
            sl.batcher.trace_pid = 1 + sl.idx       # engine track per slice
            sl.adapter.tracer = self.tracer
        try:
            drive_prompt_loop(
                arrivals, tel,
                busy=lambda: self.busy,
                queue_depth=lambda: self.queued,
                max_queue=self._admit_bound,
                submit=lambda a: self.submit(Request(
                    uid=a.uid, prompt=np.asarray(a.payload, np.int32),
                    max_new_tokens=self.max_new_tokens)),
                step=self.step,
                # .get defaults: requests submitted directly (not via an
                # Arrival) can still drain through run([])
                record=lambda req, now: record_prompt_completion(
                    tel, req, now, arr_t.get(req.uid, 0.0),
                    arr_ep.get(req.uid, -1), self._token_energy_nj,
                    self.bytes_per_token, self.energy_spec,
                    tracer=self.tracer, slo=self.slo),
                clock=clock, tracer=self.tracer, metrics=self.metrics,
                slo=self.slo)
        finally:
            for sl in self.slices:
                sl.batcher.clock = None
                sl.batcher.tracer = None
                sl.adapter.tracer = None
        for sl in self.slices:
            tel.record_pool(sl.adapter.pool_stats(), slice_idx=sl.idx)
        tel.record_routing({**self.routing, "migrations": self.migrations,
                            "migration_bytes": self.migration_bytes})
        if self.metrics is not None and self.metrics.samples:
            tel.record_series(self.metrics.samples)
        return tel

    # -- telemetry ----------------------------------------------------------

    def peak_active_total(self) -> int:
        """Aggregate concurrency: the fleet-wide maximum of *simultaneous*
        active slots, tracked per step round.  Deliberately not the sum of
        per-slice peaks — those can occur at different times and would
        overstate what the fleet ever ran at once."""
        return self.peak_concurrent
