"""Cross-shard gateway router: one bucket ladder + block pool per mesh slice.

The sharded counterpart of :class:`gateway.PromptGateway`: a serving mesh
(``launch.mesh.make_serving_mesh``) is factored into per-slice sub-meshes
(``dist.sharding.slice_meshes``), each slice owning its own
``PagedKVSlotAdapter`` (arena committed to the slice's devices via
``engine.arena_specs``) and ``ContinuousBatcher``.  The router owns the
policy layer above them:

  admission     a prompt is hashed once (``chain_keys``) and every slice's
                radix index is probed with the same keys.  The request
                routes to the deepest-prefix slice when that slice can take
                it now (**affinity**); a saturated affinity slice spills to
                the least-loaded slice (**affinity_spill** — the prompt is
                recomputed there, correctness never depends on the hit);
                no hit anywhere routes least-loaded (**load**).

  rebalance     when a slice has queued work while another sits idle, the
                router migrates the loaded slice's youngest active request
                onto the idle slice (serve/shard/migrate.py) — refcounts
                and radix entries re-established on the destination, bytes
                moved charged to the request through
                ``frontend.migration_energy_nj``.

  telemetry     per-request records identical to the single-slice gateway,
                plus per-slice pool snapshots (``Telemetry.pools``), the
                routing counters, and migration byte totals.

Parity contract: slices are built with identical ``n_slots``, so every
slice's decode tick is the same fixed-shape executable — a single-device
slice produces bit-identical logits to the unsharded adapter, and a
migrated request's post-move logits are bit-identical to the ones it would
have produced in place (tests/test_sharded.py pins both).

Disaggregated prefill/decode (PR 8): pass a :class:`RolePlan` and the flat
slice list splits into **prefill slices** (admit-only ticks — chunked
folds, no decode; see ``ContinuousBatcher.step(decode=False)``) and
**decode slices** (in-place ticks only).  Finished prefixes hand off
prefill → decode through the PR 5 migration path, routed by radix
affinity then decode occupancy; handoff bytes ride the same
``migration_energy_nj`` pricing so the energy ledger stays conserved.
``roles=None`` keeps the colocated gateway byte-identical to PR 5/7
behaviour (tests/test_disagg.py pins both sides).  See docs/sharding.md
§Disaggregated prefill/decode.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.dist.sharding import slice_meshes
from repro.serve.gateway import frontend as fe
from repro.serve.gateway.gateway import (drive_prompt_loop,
                                         record_prompt_completion)
from repro.serve.gateway.slots import (ContinuousBatcher, Request,
                                       make_adapter)
from repro.serve.gateway.telemetry import Telemetry
from repro.serve.kvcache.pool import chain_keys
from repro.serve.shard.migrate import migrate_slot


@dataclasses.dataclass
class GatewaySlice:
    """One mesh slice: sub-mesh + paged adapter + its bucket ladder."""
    idx: int
    mesh: object
    adapter: object
    batcher: ContinuousBatcher


@dataclasses.dataclass(frozen=True)
class RolePlan:
    """Role partition of a gateway's slice list: which slice indices run
    prefill (admit-only chunked folds) and which run decode (in-place
    ticks).  Replaces the flat "every slice does everything" plan; the
    sets must be disjoint and non-empty, and together cover the gateway's
    slices exactly (the gateway asserts coverage at construction)."""
    prefill: tuple[int, ...]
    decode: tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "prefill", tuple(self.prefill))
        object.__setattr__(self, "decode", tuple(self.decode))
        assert self.prefill and self.decode, \
            "disaggregation needs at least one slice per role"
        assert not set(self.prefill) & set(self.decode), \
            "a slice cannot serve both roles"

    @classmethod
    def split(cls, n_prefill: int, n_decode: int) -> "RolePlan":
        """Leading ``n_prefill`` slices prefill, the rest decode — the
        layout ``launch.mesh.make_disagg_meshes`` produces."""
        return cls(tuple(range(n_prefill)),
                   tuple(range(n_prefill, n_prefill + n_decode)))

    def role_of(self, idx: int) -> str:
        if idx in self.prefill:
            return "prefill"
        assert idx in self.decode, f"slice {idx} not in the plan"
        return "decode"


def build_slices(cfg, params, mesh, *, n_slots: int, max_len: int,
                 block_size: int = 16, num_blocks: int | None = None,
                 extras=None, chunked: bool = True,
                 inplace: bool | None = None, kernel: bool | None = None,
                 backend: str | None = None) -> list[GatewaySlice]:
    """One :class:`GatewaySlice` per sub-mesh of ``mesh``.

    ``mesh`` is a serving mesh (factored via ``slice_meshes``) or an
    explicit list of per-slice sub-meshes — slices may share devices, so a
    multi-slice gateway's *policy* layer runs anywhere (tests exercise
    routing/migration on a single CPU device; the ``sharded`` CI job gives
    every slice its own forced host device).  ``num_blocks`` is the
    **per-slice** (per-device-group) block budget — the fixed per-device
    HBM the acceptance bar holds constant while the aggregate slot count
    scales with the slice count."""
    assert cfg.family != "rwkv", \
        "sharded gateway: rwkv has O(1) state and no block pool to shard"
    subs = list(mesh) if isinstance(mesh, (list, tuple)) else \
        slice_meshes(mesh)
    slices = []
    for i, sm in enumerate(subs):
        ad = make_adapter(cfg, params, n_slots=n_slots, max_len=max_len,
                          extras=extras, paged=True, block_size=block_size,
                          num_blocks=num_blocks, chunked=chunked,
                          inplace=inplace, kernel=kernel, mesh=sm,
                          backend=backend)
        slices.append(GatewaySlice(i, sm, ad, ContinuousBatcher(ad)))
    return slices


class ShardedPromptGateway:
    """LM front door over N gateway slices (virtual-time event loop)."""

    def __init__(self, slices: list[GatewaySlice], *,
                 max_new_tokens: int = 16, bytes_per_token: int = 4,
                 max_queue: int = 64,
                 energy_spec: fe.FrontendSpec | None = None,
                 auto_rebalance: bool = True,
                 roles: RolePlan | None = None,
                 tracer=None, metrics=None, slo=None,
                 shed_factor: int = 4, flight=None, incident=None):
        assert slices, "need at least one slice"
        assert len({sl.adapter.n_slots for sl in slices}) == 1, \
            "slices must share n_slots (the bitwise-parity contract)"
        assert len({(sl.adapter.bs, sl.adapter.nb_max)
                    for sl in slices}) == 1, \
            "slices must share block geometry (routing hashes prompts at " \
            "one block size and migration asserts bs/nb_max equality)"
        self.slices = slices
        # role-partitioned (disaggregated) serving: prefill slices run
        # admit-only ticks, decode slices run in-place ticks, finished
        # prefixes hand off through the migration path.  roles=None is
        # the colocated gateway, byte-identical to the pre-disagg router.
        self.roles = roles
        if roles is not None:
            assert set(roles.prefill) | set(roles.decode) == \
                set(range(len(slices))), \
                "RolePlan must cover the slice list exactly"
        self.max_new_tokens = max_new_tokens
        self.bytes_per_token = bytes_per_token
        self.max_queue = max_queue
        self.auto_rebalance = auto_rebalance
        if energy_spec is None:
            energy_spec = fe.FrontendSpec()
        self.energy_spec = energy_spec
        self._token_energy_nj = fe.lm_token_energy_nj(
            energy_spec, slices[0].adapter.cfg.d_model)
        self.routing = {"affinity": 0, "affinity_spill": 0, "load": 0}
        self.migrations = 0
        self.migration_bytes = 0
        self.handoffs = 0           # prefill -> decode moves (role mode)
        self.handoff_bytes = 0
        self.peak_concurrent = 0    # max simultaneous active, fleet-wide
        # observability (serve/obs/): wired into every slice's batcher +
        # adapter only for the duration of run() — warmup stays untraced,
        # and without a tracer the fleet makes zero obs calls
        self.tracer = tracer
        self.metrics = metrics
        self.slo = slo
        # flight recorder + incident forensics, same contracts as the
        # one-slice gateway (see PromptGateway); debug_state adds the
        # fleet view: routing/migration/handoff counters, per-slice pool
        # snapshots, the RolePlan
        self.flight = flight
        self.incident = incident
        if incident is not None and incident.context_fn is None:
            incident.context_fn = self.debug_state
        # SLO-driven backpressure, same policy as the one-slice gateway:
        # under critical burn the fleet-wide admission bound shrinks by
        # shed_factor (see PromptGateway; pressure is the subscription
        # hook the ROADMAP degradation controller will also consume)
        self.shed_factor = shed_factor
        self._shedding = False
        self._shed_role = None      # role mode: which scheduler sheds
        # per-round slice-tick wall times, for concurrent-slice clock
        # accounting (see _step_cost)
        self._tick_sum = 0.0
        self._tick_max = 0.0
        # every slice tick's wall seconds, keyed by role ("all" when
        # colocated) — the head-of-line metric: a decode-slice tick never
        # contains a prefill fold, so its latency distribution is what a
        # decode device's between-token time looks like (tick_latency_ms)
        self.tick_times: dict[str, list[float]] = {}
        if slo is not None:
            slo.pressure.subscribe(self._on_pressure)

    def _on_pressure(self, event) -> None:
        self._shedding = event.state == "critical"
        if self.roles is None or not self._shedding:
            self._shed_role = None
        else:
            # per-role shedding: TPOT burn is a decode-side symptom — the
            # decode-occupancy scheduler tightens (handoffs need
            # shed_factor x headroom, so prefill lanes back up and throttle
            # themselves); every other objective (ttft / queue_wait /
            # drop_rate) is admission-side — the prefill-capacity
            # scheduler sheds at the door exactly like the colocated bound
            self._shed_role = "decode" if event.worst == "tpot" \
                else "prefill"

    def _admit_bound(self) -> int:
        if self._shedding and self._shed_role != "decode":
            return max(1, self.max_queue // self.shed_factor)
        return self.max_queue

    def jit_fns(self) -> dict[str, object]:
        """Named jitted entry points across every slice, for
        obs.RecompileDetector.track (slice-prefixed; the chunk-fold
        executables are process-wide, so they repeat under each prefix)."""
        fns: dict[str, object] = {}
        for sl in self.slices:
            for name, fn in sl.adapter.jit_fns().items():
                fns[f"slice{sl.idx}.{name}"] = fn
        return fns

    def cost_args(self) -> dict[str, tuple]:
        """Slice-prefixed adapter stages + representative args, for
        obs.costmodel roofline attribution — per-slice copies are distinct
        executables (each compiled against its own mesh placement), so
        each is costed under its own prefix.  Under a :class:`RolePlan`
        the attribution is per role: a prefill slice only ever runs the
        prefill/chunk-fold stages and a decode slice only the decode tick
        + block copy, so each contributes exactly its role's stages under
        a role-named prefix (``prefill0.chunk_fold``, ``decode2.decode``)."""
        out: dict[str, tuple] = {}
        for sl in self.slices:
            for name, pair in sl.adapter.cost_args().items():
                if self.roles is None:
                    out[f"slice{sl.idx}.{name}"] = pair
                    continue
                role = self.roles.role_of(sl.idx)
                keep = ("prefill", "chunk_fold") if role == "prefill" \
                    else ("decode", "copy")
                if name in keep:
                    out[f"{role}{sl.idx}.{name}"] = pair
        return out

    # -- routing ------------------------------------------------------------

    def _load(self, sl: GatewaySlice) -> tuple[int, int]:
        """Load key: blocks a slice has committed (in use + queued
        worst-case demand), then queue depth as the tie-break."""
        queued = sum(sl.adapter._block_demand(len(r.prompt),
                                              r.max_new_tokens)
                     for r in sl.batcher.pending)
        return (sl.adapter.pool.blocks_in_use() + queued,
                len(sl.batcher.pending))

    def _admission_slices(self) -> list[int]:
        """Slice indices admissions may route to: every slice when
        colocated, only the prefill slices under a :class:`RolePlan`."""
        if self.roles is None:
            return list(range(len(self.slices)))
        return list(self.roles.prefill)

    def route(self, prompt: np.ndarray, max_new: int) -> tuple[int, str]:
        """(slice index, reason): radix-prefix affinity first, then
        least-loaded.  Pure policy — no references taken, no state
        mutated except the routing counters.  Under a :class:`RolePlan`
        only prefill slices are candidates (admission is scheduled by
        prefill capacity; decode slices receive work via handoff)."""
        prompt = np.asarray(prompt, np.int32)
        keys, pkey = chain_keys(prompt, self.slices[0].adapter.bs)
        cand = self._admission_slices()
        hits = {i: len(self.slices[i].adapter.pool.probe_chain(
            keys, pkey, count=False)[0]) for i in cand}
        best = max(cand, key=lambda i: hits[i])
        if hits[best] > 0:
            sl = self.slices[best]
            if len(cand) == 1 or (
                    not sl.batcher.pending and
                    sl.adapter.can_admit(prompt, max_new)):
                self.routing["affinity"] += 1
                return best, "affinity"
            # owning slice saturated: the hit is storage, not correctness —
            # spill to the least-loaded *other* slice and recompute there
            # (queueing on the owner would be an affinity route, not a
            # spill, and would sit behind the very congestion we saw)
            reason = "affinity_spill"
            cand = [i for i in cand if i != best]
        else:
            reason = "load"
        order = sorted(cand, key=lambda i: self._load(self.slices[i]))
        self.routing[reason] += 1
        return order[0], reason

    def submit(self, req: Request) -> int:
        """Route + enqueue; returns the slice index chosen."""
        idx, _ = self.route(req.prompt, req.max_new_tokens)
        self.slices[idx].batcher.submit(req)
        return idx

    # -- rebalancing --------------------------------------------------------

    def _free_slot(self, sl: GatewaySlice) -> int | None:
        for j, r in enumerate(sl.batcher.active):
            if r is None and not sl.adapter.slot_bids[j]:
                return j
        return None

    def migrate(self, src_idx: int, slot: int, dst_idx: int, *,
                kind: str = "migrate") -> int:
        """Move the active request in ``(src_idx, slot)`` to ``dst_idx``.
        Returns bytes moved (also accumulated on the request and the
        router's totals).  ``kind`` names the trace span — "migrate" for
        rebalancing moves, "handoff" for prefill->decode moves."""
        src, dst = self.slices[src_idx], self.slices[dst_idx]
        req = src.batcher.active[slot]
        assert req is not None, f"slice {src_idx} slot {slot} not active"
        dst_slot = self._free_slot(dst)
        assert dst_slot is not None, f"slice {dst_idx} has no free slot"
        if self.tracer is not None:
            # child of the request's open decode span — the move happens
            # mid-generation on the request's own track
            self.tracer.begin(kind, tid=req.uid)
        receipt = migrate_slot(src.adapter, slot, dst.adapter, dst_slot,
                               req.prompt)
        if self.tracer is not None:
            self.tracer.end(kind, tid=req.uid,
                            args=receipt.trace_args(src_idx, dst_idx))
        dst.batcher.active[dst_slot] = req
        dst.batcher.last_token[dst_slot] = src.batcher.last_token[slot]
        src.batcher.active[slot] = None
        src.batcher.last_token[slot] = 0
        req.migrations += 1
        req.migration_bytes += receipt.bytes_moved
        # router totals are per-kind: rebalance moves vs prefill->decode
        # handoffs (the request-side bytes above ride the energy pricing
        # identically either way)
        if kind == "handoff":
            self.handoffs += 1
            self.handoff_bytes += receipt.bytes_moved
        else:
            self.migrations += 1
            self.migration_bytes += receipt.bytes_moved
        return receipt.bytes_moved

    # -- disaggregated handoff (role mode) ----------------------------------

    def route_handoff(self, req: Request) -> int | None:
        """Decode slice for a finished prefix: deepest radix-affinity hit
        first (the prompt's chain may already live there from an earlier
        handoff), then lowest decode occupancy.  None when no decode slice
        has a free lane + block headroom right now — the lane then waits
        on its prefill slice (natural backpressure), and under decode-side
        shedding the headroom requirement tightens by ``shed_factor``."""
        prompt = np.asarray(req.prompt, np.int32)
        keys, pkey = chain_keys(prompt, self.slices[0].adapter.bs)
        factor = self.shed_factor if self._shed_role == "decode" else 1
        cands = []
        for i in self.roles.decode:
            sl = self.slices[i]
            if self._free_slot(sl) is None:
                continue
            demand = sl.adapter._block_demand(len(prompt),
                                              req.max_new_tokens)
            if demand * factor > sl.adapter.pool.available():
                continue
            hits = len(sl.adapter.pool.probe_chain(keys, pkey,
                                                   count=False)[0])
            cands.append((-hits, self._load(sl), i))
        return min(cands)[2] if cands else None

    def handoff(self, src_idx: int, slot: int, dst_idx: int) -> int:
        """One prefill->decode handoff: the migration move plus the
        handoff counters, and the handed-off prompt chain is *protected*
        on its owning decode slice — eviction under later handoff or
        allocation pressure prefers unprotected blocks, keeping the hot
        shared prefix resident where its lanes decode (affinity-aware
        eviction; the pool falls back to evicting protected blocks only
        when nothing else is left)."""
        req = self.slices[src_idx].batcher.active[slot]
        moved = self.migrate(src_idx, slot, dst_idx, kind="handoff")
        dst = self.slices[dst_idx]
        keys, _ = chain_keys(np.asarray(req.prompt, np.int32),
                             dst.adapter.bs)
        dst.adapter.pool.protect(keys)
        return moved

    def _handoff_pass(self) -> int:
        """Hand off every prefilled lane whose chosen decode slice can
        take it now; lanes with no target stay put until decode capacity
        frees up.  Returns handoffs performed."""
        n = 0
        for i in self.roles.prefill:
            src = self.slices[i]
            for slot, req in enumerate(src.batcher.active):
                if req is None:
                    continue
                dst_idx = self.route_handoff(req)
                if dst_idx is None:
                    continue
                self.handoff(i, slot, dst_idx)
                n += 1
        return n

    def maybe_rebalance(self) -> int:
        """One rebalance pass: every slice with queued work sheds its
        *cheapest* active request — the one holding the fewest blocks, so
        the move costs the fewest bytes — to an idle slice (free slot +
        no queue), unblocking the queued admission.  Returns migrations
        performed."""
        n = 0
        for src in self.slices:
            if not src.batcher.pending:
                continue
            # only a genuinely *blocked* queue justifies paying for a
            # migration: a pending head that will admit into a free slot
            # this very tick must be left alone
            head = src.batcher.pending[0]
            if self._free_slot(src) is not None and \
                    src.adapter.can_admit(head.prompt,
                                          head.max_new_tokens):
                continue
            victims = [j for j, r in enumerate(src.batcher.active)
                       if r is not None]
            if not victims:
                continue
            slot = min(victims, key=lambda j: len(src.adapter.slot_bids[j]))
            for dst in sorted(self.slices, key=self._load):
                if dst is src or dst.batcher.pending:
                    continue
                dst_slot = self._free_slot(dst)
                req = src.batcher.active[slot]
                demand = dst.adapter._block_demand(
                    len(req.prompt), req.max_new_tokens)
                if dst_slot is None or \
                        demand > dst.adapter.pool.available():
                    continue
                self.migrate(src.idx, slot, dst.idx)
                n += 1
                break
        return n

    # -- the event loop -----------------------------------------------------

    @property
    def busy(self) -> bool:
        return any(sl.batcher.busy for sl in self.slices)

    @property
    def queued(self) -> int:
        return sum(len(sl.batcher.pending) for sl in self.slices)

    def warmup(self, prompt_lens: tuple[int, ...]) -> None:
        """Compile every slice's prefill buckets + decode tick up front
        (the chunk-fold executables are shared process-wide, so slices
        after the first mostly re-trace nothing)."""
        for sl in self.slices:
            for j, n in enumerate(prompt_lens):
                sl.batcher.submit(Request(
                    uid=-1 - j, prompt=np.zeros((n,), np.int32),
                    max_new_tokens=2))
            sl.batcher.run()
            sl.batcher.peak_active = 0

    def step(self) -> list[Request]:
        """Rebalance, then one decode tick on every busy slice (colocated);
        admit → handoff → decode tick in role mode."""
        if self.roles is not None:
            return self._step_disagg()
        if self.auto_rebalance:
            self.maybe_rebalance()
        finished: list[Request] = []
        concurrent = 0
        ticks: list[float] = []
        for sl in self.slices:
            if sl.batcher.busy:
                t0 = time.perf_counter()
                finished.extend(sl.batcher.step())
                ticks.append(time.perf_counter() - t0)
                self.tick_times.setdefault("all", []).append(ticks[-1])
                # lanes that actually decoded this round's tick
                # (batcher.last_active — the same quantity the
                # single-device peak_active maximizes, so the sharded
                # acceptance metric is symmetric with its baseline).
                # Every slice is stepped in the same virtual-time round,
                # so the sum is true simultaneous fleet concurrency —
                # per-slice peaks can occur at different times and must
                # not be added
                concurrent += sl.batcher.last_active
        self.peak_concurrent = max(self.peak_concurrent, concurrent)
        self._tick_sum, self._tick_max = sum(ticks), max(ticks, default=0.0)
        return finished

    def _step_cost(self, wall: float) -> float:
        """Virtual cost of the round just stepped: slices are disjoint
        device groups that tick *simultaneously* in a real fleet, so the
        round costs the slowest slice's tick plus the router's serial
        work (routing, rebalance/handoff copies through the host) — not
        the sum a single-host simulation measures.  Fed to
        ``drive_prompt_loop(step_cost=...)`` for untraced runs; with a
        tracer attached wall accounting stays (sub-tick spans anchor to
        real offsets), which the loop asserts."""
        return max(0.0, wall - self._tick_sum) + self._tick_max

    def _step_disagg(self) -> list[Request]:
        """One disaggregated round: prefill slices run admit-only ticks
        (chunked folds, no decode), finished prefixes hand off onto decode
        slices, decode slices run their in-place tick.  Rebalancing is the
        handoff pass itself — ``maybe_rebalance`` never runs in role mode
        (a migration onto a prefill slice would put decode work there)."""
        finished: list[Request] = []
        ticks: list[float] = []
        for i in self.roles.prefill:
            sl = self.slices[i]
            if sl.batcher.busy:
                # admission can retire a request here (EOS at prefill /
                # at_capacity) — those never reach a decode slice
                t0 = time.perf_counter()
                finished.extend(sl.batcher.step(decode=False))
                ticks.append(time.perf_counter() - t0)
                self.tick_times.setdefault("prefill", []).append(ticks[-1])
        self._handoff_pass()
        concurrent = 0
        for i in self.roles.decode:
            sl = self.slices[i]
            if sl.batcher.busy:
                t0 = time.perf_counter()
                finished.extend(sl.batcher.step())
                ticks.append(time.perf_counter() - t0)
                self.tick_times.setdefault("decode", []).append(ticks[-1])
                # only lanes that actually decoded count toward fleet
                # concurrency — prefill lanes parked awaiting handoff are
                # queueing, not decoding
                concurrent += sl.batcher.last_active
        self.peak_concurrent = max(self.peak_concurrent, concurrent)
        self._tick_sum, self._tick_max = sum(ticks), max(ticks, default=0.0)
        return finished

    def run(self, arrivals, telemetry: Telemetry | None = None) -> Telemetry:
        tel = telemetry if telemetry is not None else Telemetry()
        arrivals = [a for a in arrivals if a.kind == "prompt"]
        arr_t = {a.uid: a.t for a in arrivals}
        arr_ep = {a.uid: a.endpoint for a in arrivals}
        if self.flight is not None:
            from repro.serve.obs import Tracer
            if self.tracer is None:
                # always-on mode: the bounded ring is the only retention
                self.tracer = Tracer(retain=False, sink=self.flight)
            elif self.tracer.sink is None:
                self.tracer.sink = self.flight
            if self.metrics is not None and self.metrics.sink is None:
                self.metrics.sink = self.flight.observe_sample
        # SLO timestamps (t_dequeue/t_admit) need one shared virtual clock
        # across every slice, tracer or not
        from repro.serve.obs import SimClock
        clock = self.tracer.clock if self.tracer is not None else SimClock()
        if self.metrics is not None:
            m = self.metrics
            m.register("queue_depth", lambda: self.queued)
            m.register("migrations", lambda: self.migrations)
            m.register("spills", lambda: self.routing["affinity_spill"])
            if self.roles is not None:
                # per-role series (satellite: disagg observability) — queue
                # depth per scheduler, lane occupancy per role, handoff
                # volume.  Occupancy is lanes-in-use over lanes available,
                # the quantity route_handoff load-balances on
                def occ(idxs):
                    used = sum(
                        sum(r is not None
                            for r in self.slices[i].batcher.active)
                        for i in idxs)
                    return used / (len(idxs) *
                                   self.slices[0].adapter.n_slots)
                m.register("prefill_queue", lambda: sum(
                    len(self.slices[i].batcher.pending)
                    for i in self.roles.prefill))
                m.register("decode_queue", lambda: sum(
                    len(self.slices[i].batcher.pending)
                    for i in self.roles.decode))
                m.register("prefill_occupancy",
                           lambda: occ(self.roles.prefill))
                m.register("decode_occupancy",
                           lambda: occ(self.roles.decode))
                m.register("handoffs", lambda: self.handoffs)
                m.register("handoff_bytes", lambda: self.handoff_bytes)
            for sl in self.slices:
                m.register(f"slice{sl.idx}_blocks_in_use",
                           lambda sl=sl:
                           sl.adapter.pool.gauges()["pool_blocks_in_use"])
                m.register(f"slice{sl.idx}_queue",
                           lambda sl=sl: len(sl.batcher.pending))
                m.register(f"slice{sl.idx}_active",
                           lambda sl=sl: sl.batcher.last_active)
            casc = [sl for sl in self.slices
                    if getattr(sl.adapter, "backend", None) == "cascade"]
            if casc:
                # fleet-aggregated cascade grouping gauges; same
                # cascade_* names as the one-slice gateway, so the
                # repro_cascade_* OpenMetrics families are path-agnostic
                for key in ("groups", "grouped_lanes", "prefix_rows",
                            "prefix_rows_flat"):
                    m.register(f"cascade_{key}", lambda k=key: sum(
                        sl.adapter.cascade_stats()[k] for sl in casc))
        for sl in self.slices:
            sl.batcher.clock = clock
            sl.batcher.tracer = self.tracer
            sl.batcher.trace_pid = 1 + sl.idx       # engine track per slice
            sl.adapter.tracer = self.tracer
        try:
            drive_prompt_loop(
                arrivals, tel,
                busy=lambda: self.busy,
                queue_depth=lambda: self.queued,
                max_queue=self._admit_bound,
                submit=lambda a: self.submit(Request(
                    uid=a.uid, prompt=np.asarray(a.payload, np.int32),
                    max_new_tokens=self.max_new_tokens)),
                step=self.step,
                # .get defaults: requests submitted directly (not via an
                # Arrival) can still drain through run([])
                record=lambda req, now: record_prompt_completion(
                    tel, req, now, arr_t.get(req.uid, 0.0),
                    arr_ep.get(req.uid, -1), self._token_energy_nj,
                    self.bytes_per_token, self.energy_spec,
                    tracer=self.tracer, slo=self.slo),
                clock=clock, tracer=self.tracer, metrics=self.metrics,
                slo=self.slo, incident=self.incident,
                step_cost=self._step_cost if self.tracer is None else None)
        finally:
            for sl in self.slices:
                sl.batcher.clock = None
                sl.batcher.tracer = None
                sl.adapter.tracer = None
        for sl in self.slices:
            tel.record_pool(sl.adapter.pool_stats(), slice_idx=sl.idx)
        tel.record_routing({**self.routing, "migrations": self.migrations,
                            "migration_bytes": self.migration_bytes,
                            "handoffs": self.handoffs,
                            "handoff_bytes": self.handoff_bytes})
        if self.metrics is not None and self.metrics.samples:
            tel.record_series(self.metrics.samples)
        if self.incident is not None:
            self.incident.check_energy(tel, clock.t)
        return tel

    def debug_state(self) -> dict:
        """Fleet forensic state for incident bundles: routing/migration/
        handoff counters, the RolePlan, per-slice batcher + pool snapshots,
        jit-cache sizes — aggregate state only, no request payloads."""
        state: dict = {
            "kind": "sharded_gateway",
            "n_slices": len(self.slices),
            "max_queue": self.max_queue,
            "admit_bound": self._admit_bound(),
            "shedding": self._shedding,
            "shed_role": self._shed_role,
            "routing": dict(self.routing),
            "migrations": self.migrations,
            "migration_bytes": self.migration_bytes,
            "handoffs": self.handoffs,
            "handoff_bytes": self.handoff_bytes,
            "peak_concurrent": self.peak_concurrent,
            "jit_cache_sizes": {name: fn._cache_size()
                                for name, fn in self.jit_fns().items()},
        }
        if self.roles is not None:
            state["roles"] = {"prefill": list(self.roles.prefill),
                              "decode": list(self.roles.decode)}
        slices = []
        for sl in self.slices:
            rec = {"idx": sl.idx,
                   "role": self.roles.role_of(sl.idx)
                   if self.roles is not None else "all",
                   "batcher": sl.batcher.debug_state(),
                   "pool": sl.adapter.pool.debug_snapshot()}
            if getattr(sl.adapter, "backend", None) == "cascade":
                rec["cascade"] = sl.adapter.cascade_stats()
            slices.append(rec)
        state["slices"] = slices
        return state

    def capture_incident(self, reason: str, *, extra: dict | None = None):
        """Explicit forensic capture (trigger ``explicit``); requires an
        IncidentCapture attached at construction."""
        if self.incident is None:
            raise RuntimeError(
                "capture_incident() needs an IncidentCapture attached "
                "(ShardedPromptGateway(..., incident=...) or "
                "ServeSpec(incident_dir=...))")
        return self.incident.capture(reason, extra=extra)

    # -- telemetry ----------------------------------------------------------

    def peak_active_total(self) -> int:
        """Aggregate concurrency: the fleet-wide maximum of *simultaneous*
        active slots, tracked per step round.  Deliberately not the sum of
        per-slice peaks — those can occur at different times and would
        overstate what the fleet ever ran at once."""
        return self.peak_concurrent

    def tick_latency_ms(self, role: str = "all", q: float = 99.0) -> float:
        """Percentile of per-slice tick wall time in ms, the decode
        head-of-line metric: each tick is one generated token for every
        lane it decodes, so a slice's tick-latency distribution is its
        between-token time.  Colocated ticks ("all") absorb admission's
        chunked-prefill folds; a decode-role tick never does — under a
        prefill burst p99("decode") on a disaggregated gateway beating
        p99("all") on a colocated one at equal device budget is exactly
        the head-of-line relief disaggregation buys
        (benchmarks/kvcache_bench.py --disagg gates this)."""
        ts = self.tick_times.get(role, ())
        if not ts:
            return 0.0
        return float(np.percentile(np.asarray(ts, np.float64), q) * 1e3)
