"""Sharded paged serving: mesh-partitioned KV arenas + cross-shard routing.

The layer that takes every prior serving subsystem — the micro-batching
gateway (PR 1), the paged block pool (PR 2), prefix-hit chunked prefill
(PR 3), and the gather-free in-place decode tick (PR 4) — beyond one
device.  A serving mesh is factored into slices
(``dist.sharding.slice_meshes``); each slice owns a full paged serving
stack committed to its devices (``engine.arena_specs`` placement), and the
:class:`ShardedPromptGateway` routes admissions across slices by
radix-prefix affinity, spills by load, and migrates live requests between
slices with refcounts and prefix sharing preserved
(:func:`migrate.migrate_slot`).

Disaggregated prefill/decode (PR 8): a :class:`RolePlan` partitions the
slice list into prefill slices (admit-only chunked folds) and decode
slices (in-place ticks); finished prefixes hand off prefill → decode over
the migration path, scheduled by radix affinity then decode occupancy.

Verified on CPU via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(tests/test_sharded.py, tests/test_disagg.py; the ``sharded`` and
``disagg`` CI jobs).  See docs/sharding.md.
"""
from repro.serve.shard.migrate import MigrationReceipt, migrate_slot
from repro.serve.shard.router import (GatewaySlice, RolePlan,
                                      ShardedPromptGateway, build_slices)

__all__ = ["GatewaySlice", "MigrationReceipt", "RolePlan",
           "ShardedPromptGateway", "build_slices", "migrate_slot"]
