"""Cross-slice block migration: move a live request between gateway slices.

A migration rebuilds a request's paged context on the destination slice's
pool/arena and releases it from the source — the mechanism behind the
sharded gateway's rebalancing and, under a RolePlan, the prefill→decode
handoff (serve/shard/router.py).  The contract is the
one the parity suite pins (tests/test_sharded.py):

  exactness     the destination lane decodes the *same bits* the request
                would have produced had it stayed: every block's contents,
                the slot-stacked state row (len, conv/ssm, cross-K/V), and
                the generated-token tail all carry over unchanged, and the
                destination tick runs the same fixed-shape executable
                (slices are built with identical ``n_slots``).

  sharing       full prompt blocks re-enter the destination pool's radix
                index: a chain block the destination already indexes is
                *referenced* (refcount++, zero bytes moved) instead of
                copied — prefix sharing survives the move, and the moved
                request's prompt becomes hit-able for later admissions on
                the destination.

  copy-on-write a source slot still holding a shared partial block with a
                pending CoW spare gets the copy *materialized* by the
                migration (its contents land in a private destination
                block); the source sibling keeps the original bit-for-bit
                and the spare is released with the source slot.

Bytes are moved through the host (numpy round-trip) deliberately: that is
the real cross-host path a multi-machine gateway would pay, and the byte
count the receipt reports is charged to the request's energy ledger through
``frontend.migration_energy_nj`` (scaled_report pricing).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.serve.kvcache.pool import (TRASH_BLOCK, PoolExhausted, chain_keys)


@dataclasses.dataclass(frozen=True)
class MigrationReceipt:
    blocks_total: int            # blocks in the request's table
    blocks_moved: int            # copied through the host
    blocks_shared: int           # satisfied by the destination radix index
    bytes_moved: int             # arena block bytes + slot-state row bytes

    def trace_args(self, src_idx: int, dst_idx: int) -> dict:
        """Args for the router's ``migrate`` span (serve/obs): where the
        request moved and what the move actually cost on the wire."""
        return {"src": src_idx, "dst": dst_idx, "bytes": self.bytes_moved,
                "blocks_moved": self.blocks_moved,
                "blocks_shared": self.blocks_shared}


def migrate_slot(src, slot: int, dst, dst_slot: int,
                 prompt: np.ndarray) -> MigrationReceipt:
    """Move ``src``'s ``slot`` onto ``dst``'s free ``dst_slot``.

    ``src``/``dst`` are :class:`PagedKVSlotAdapter`-compatible adapters of
    the same config and block geometry; ``prompt`` is the request's
    original prompt (the radix chain keys are recomputed from it, so the
    destination can reference blocks it already indexes).  On
    ``PoolExhausted`` during allocation — or any failure mid-copy — the
    destination is rolled back (partially-copied blocks released, only
    this migration's index registrations undone) and the source is left
    untouched, radix index included.
    """
    assert src.cfg == dst.cfg, "migration across configs"
    assert src.bs == dst.bs and src.nb_max == dst.nb_max, \
        "migration across block geometries"
    assert not dst.slot_bids[dst_slot], f"dst slot {dst_slot} not free"
    prompt = np.asarray(prompt, np.int32)
    bids = src.slot_bids[slot]
    assert bids, f"src slot {slot} holds no blocks"
    n_full = len(prompt) // src.bs
    keys, _ = chain_keys(prompt, src.bs)

    # destination allocation first (it can fail; the source must survive):
    # full prompt blocks the destination already indexes are referenced,
    # everything else — unindexed prompt blocks, the partial prompt block,
    # decode-written generation blocks — gets a fresh private block
    dst_bids: list[int] = []
    fresh: list[tuple[int, bytes | None, int]] = []   # (chain idx, key, bid)
    shared = 0
    try:
        for j in range(len(bids)):
            key = keys[j] if j < n_full else None
            hit = dst.pool.lookup(key, count=False) if key is not None \
                else None
            if hit is not None:
                dst_bids.append(dst.pool.acquire(hit))
                shared += 1
            else:
                b = dst.pool.alloc()
                fresh.append((j, key, b))
                dst_bids.append(b)
    except PoolExhausted:
        for b in dst_bids:
            dst.pool.release(b)
        raise

    # block contents cross through the host — the honest multi-machine
    # path, and what the receipt's byte count means.  Only blocks holding
    # written rows move: the chain's pre-allocated generation tail
    # (admission reserves the worst-case chain up front) has no data yet,
    # and its fresh destination blocks are exactly as garbage-and-masked
    # as the source ones — copying them would inflate the byte count (and
    # the energy charged for it) by up to the whole unused budget
    block_bytes = src._token_bytes * src.bs
    live = -(-int(src.lens[slot]) // src.bs)
    moved = 0
    n_copied = 0
    try:
        for j, key, b in fresh:
            if j >= live:
                continue
            contents = {k: jnp.asarray(np.asarray(
                src.arena_block(k, bids[j]))) for k in src.seq_keys}
            dst.arena = dst._write_block(dst.arena,
                                         jnp.asarray(b, jnp.int32),
                                         contents)
            moved += block_bytes
            n_copied += 1
            if key is not None:
                # full prompt blocks are immutable from here on (the write
                # position is past them) — index them so later destination
                # admissions hit this chain
                dst.pool.register(key, b)

        # the slot-stacked state row: len, hybrid conv/ssm, encdec cross-K/V
        for k in dst.cache:
            row = np.asarray(src.cache[k][slot])
            dst.cache[k] = dst.cache[k].at[dst_slot].set(jnp.asarray(row))
            moved += row.nbytes
    except BaseException:
        # mid-copy failure (the cross-host hop is the fallible part of a
        # handoff): unwind the destination so the request can retry or
        # keep decoding where it is.  Unregister only chain keys whose
        # index entry points at a block *this* migration allocated —
        # register is first-wins, so an entry for the same key that
        # predates us belongs to another request's chain and must stay.
        # Then drop every destination reference taken above.  The slot
        # tables/lens/slot_bids commit below never ran and the source is
        # only cleared after commit, so both slices read back exactly as
        # they were before the call (src radix index included).
        ours = {b for _, _, b in fresh}
        for key in keys[:n_full]:
            if dst.pool.index.get(key) in ours:
                dst.pool._unindex(dst.pool.index[key])
        for b in dst_bids:
            dst.pool.release(b)
        raise

    dst.tables[dst_slot, :] = TRASH_BLOCK
    dst.tables[dst_slot, :len(dst_bids)] = dst_bids
    dst.lens[dst_slot] = src.lens[slot]
    dst.slot_bids[dst_slot] = dst_bids
    dst._stats[dst_slot] = dict(src._stats[slot])
    dst._update_peaks()

    # hybrid: boundary recurrent-state snapshots ride along for the chain
    # keys now indexed on the destination (a resume there would need them).
    # After the commit point on purpose — a rolled-back migration must not
    # leave side-cache entries behind
    src_states = getattr(src, "_boundary_states", None)
    if src_states:
        for key in keys[:n_full]:
            st = src_states.get(key)
            if st is not None and key in dst.pool.index and \
                    key not in dst._boundary_states:
                dst._boundary_states[key] = {
                    k: jnp.asarray(np.asarray(a)) for k, a in st.items()}
                dst._boundary_states.move_to_end(key)
        # same LRU bound the chunked-fold save path enforces — migration
        # must not grow the side cache past the arena-proportional cap
        while len(dst._boundary_states) > dst._max_boundary_states:
            dst._boundary_states.popitem(last=False)

    # release the source slot (drops its refs; a pending CoW spare — the
    # copy the migration just materialized — is released with it)
    src.clear(slot)
    return MigrationReceipt(blocks_total=len(bids), blocks_moved=n_copied,
                            blocks_shared=shared, bytes_moved=moved)
