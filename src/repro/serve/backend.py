"""The one attention-backend dispatch surface for the paged decode tick.

Five PRs of growth left backend selection smeared across two booleans:
``inplace=`` picked the tick (gather-oracle vs in-place) and ``kernel=``
picked the read path inside it (XLA reference vs Pallas), with ``None``
meaning "probe the platform".  This module replaces that with one enum —

    backend="gather"   the PR 2 gather tick (parity oracle; gathers the
                       full chain, vmapped dense decode, rescatter)
    backend="xla"      the in-place tick, XLA reference attention read
    backend="pallas"   the in-place tick, Pallas paged-attention kernel
                       (Mosaic on TPU; interpreter under
                       REPRO_KERNELS_INTERPRET=1)
    backend="cascade"  the in-place tick with shared-prefix cascade
                       grouping (one multi-query pass per shared radix
                       chain + per-lane suffix pass, log-sum-exp merged;
                       degrades to the flat "xla" executable on ticks
                       with no chain shared by >= 2 lanes)

— threaded through ``make_adapter`` / ``PagedKVSlotAdapter`` /
``engine.decode_step_paged`` / ``attention.attend_decode_paged``.  The old
booleans survive as deprecated aliases: ``resolve_backend`` maps them and
the public constructors warn (``DeprecationWarning``); alias<->enum
equivalence is pinned in tests/test_cascade.py.
"""
from __future__ import annotations

import warnings

BACKENDS = ("gather", "xla", "pallas", "cascade")

# backends that run the in-place tick (everything but the gather oracle)
INPLACE_BACKENDS = ("xla", "pallas", "cascade")


def auto_backend() -> str:
    """The platform default for the in-place tick: the Pallas kernel under
    Mosaic on a real TPU, the XLA reference everywhere else — the same
    probe the deprecated ``kernel=None`` made, honoring
    ``REPRO_KERNELS_INTERPRET`` through ``kernels.ops.default_interpret``.
    """
    import jax

    from repro.kernels.ops import default_interpret
    if jax.default_backend() == "tpu" and not default_interpret():
        return "pallas"
    return "xla"


def resolve_backend(backend: str | None = None, *,
                    inplace: bool | None = None,
                    kernel: bool | None = None,
                    warn: bool = False) -> str:
    """Resolve the backend enum, mapping the deprecated boolean aliases.

    ``backend`` wins when given (and the booleans must not disagree —
    mixing the old and new spelling in one call is an error, not a
    guess).  Otherwise: ``inplace=False`` -> "gather"; ``kernel=True`` ->
    "pallas"; ``kernel=False`` -> "xla"; both ``None`` -> the platform
    auto choice.  ``warn=True`` emits the ``DeprecationWarning`` for
    boolean callers — set by the public constructors, left off on the
    internal engine/lm plumbing so one adapter call warns once, not once
    per layer.
    """
    if backend is not None:
        if backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {backend!r}")
        if inplace is not None or kernel is not None:
            raise ValueError(
                "pass backend= alone; inplace=/kernel= are its deprecated "
                f"aliases (got backend={backend!r}, inplace={inplace!r}, "
                f"kernel={kernel!r})")
        return backend
    if inplace is None and kernel is None:
        return auto_backend()
    if warn:
        warnings.warn(
            "inplace=/kernel= are deprecated; pass backend="
            "\"gather\"|\"xla\"|\"pallas\"|\"cascade\" instead "
            "(docs/serving.md)", DeprecationWarning, stacklevel=3)
    if inplace is not None and not inplace:
        if kernel:
            raise ValueError("inplace=False (gather tick) has no kernel path")
        return "gather"
    if kernel is None:
        return auto_backend()
    return "pallas" if kernel else "xla"
