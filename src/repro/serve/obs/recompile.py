"""Recompile detection: jit cache entries per compiled executable.

The serving stack's steady-state contract is *no recompiles*: every hot
path runs fixed-shape executables compiled at warmup (per-bucket gateway
stages, per-(q_offset, shape) chunk-fold buckets, one decode tick per
adapter).  A shape leak — a stray Python int becoming a traced dimension, a
new padding bucket sneaking in — shows up as silent multi-second stalls
under load.  This detector turns it into a metric:

    det = RecompileDetector()
    det.track("gateway", gw.jit_fns())        # anything with _cache_size()
    gw.warmup(...); det.snapshot()            # steady state begins here
    gw.run(traffic)
    det.steady_state_recompiles()             # 0, or the leak count

``jit_fns()`` surfaces are provided by the slot adapters, the
micro-batch gateway, and the prompt gateways; per-executable counts (and
the post-snapshot deltas) go into BENCH_obs.json, where check_bench gates
them at zero.
"""
from __future__ import annotations


class RecompileDetector:
    """Tracks named jitted callables and diffs their cache-entry counts
    against a steady-state baseline snapshot."""

    def __init__(self):
        self._fns: dict[str, object] = {}
        self._baseline: dict[str, int] | None = None

    def track(self, prefix: str, fns: dict[str, object]) -> None:
        """Register named jitted callables (anything exposing
        ``_cache_size()``, i.e. ``jax.jit`` wrappers)."""
        for name, fn in fns.items():
            assert hasattr(fn, "_cache_size"), \
                f"{prefix}.{name} is not a jitted callable"
            self._fns[f"{prefix}.{name}"] = fn

    def counts(self) -> dict[str, int]:
        """Current jit cache entries per tracked executable."""
        return {name: fn._cache_size() for name, fn in self._fns.items()}

    def snapshot(self) -> dict[str, int]:
        """Mark the steady state: compilations after this point count as
        recompiles."""
        self._baseline = self.counts()
        return dict(self._baseline)

    def deltas(self) -> dict[str, int]:
        """Per-executable cache growth since the snapshot (only growth:
        caches never shrink, and a negative delta would mean the tracked
        function was swapped out from under us)."""
        assert self._baseline is not None, "snapshot() the steady state first"
        cur = self.counts()
        return {name: cur[name] - self._baseline.get(name, 0)
                for name in cur}

    def steady_state_recompiles(self) -> int:
        """Total compilations since the steady-state snapshot — the metric
        benches flag (zero in a healthy serving loop)."""
        return sum(max(0, d) for d in self.deltas().values())

    def report(self) -> dict:
        """Metric payload: per-executable counts, deltas, and the flag."""
        deltas = self.deltas()
        return {
            "tracked_executables": len(self._fns),
            "cache_entries": self.counts(),
            "recompiles_by_fn": {k: v for k, v in deltas.items() if v > 0},
            "steady_state_recompiles": sum(max(0, d)
                                           for d in deltas.values()),
        }
