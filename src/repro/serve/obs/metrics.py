"""Named time-series metrics with periodic interval snapshots.

Replaces end-of-run aggregate dicts with counters, gauges, and histograms
sampled on a configurable virtual-time tick: the serving loops call
:meth:`MetricsRegistry.maybe_sample` every iteration, and the registry
snapshots at most once per ``interval_s`` of sim time — so a run yields
occupancy-over-time *curves* (pool blocks in use, queue depth, per-slice
load, migration/spill totals) instead of a single high-water mark.

Three instrument kinds:

  counter    monotone cumulative float (``inc``); snapshots carry the
             running value, so interval rates are first differences.
  gauge      instantaneous value; either pushed (``set_gauge``) or pulled —
             ``register(name, fn)`` samples ``fn()`` at snapshot time,
             which is how pool occupancy and queue depth are wired without
             the pool knowing the registry exists.
  histogram  value stream (``observe``); ``percentiles`` summarizes with
             the sample count attached (tiny-sample p99s are reported, but
             ``n`` rides along so gates can demand minimum counts).
             Retention is **capped** at ``hist_cap`` observations per
             histogram: beyond the cap, new values enter a uniform
             reservoir (Vitter's algorithm R, deterministic rng) so the
             percentile summary stays an unbiased estimate over the whole
             stream with bounded memory on long runs.  Truncation is never
             silent — ``percentiles`` carries ``n`` (everything observed)
             and ``n_dropped`` (observations no longer retained), and below
             the cap summaries are exact.

Snapshots are plain dicts (``{"t": ..., name: value, ...}``) so they drop
straight into ``Telemetry.record_series`` / the JSONL exporter.
"""
from __future__ import annotations

import numpy as np


class MetricsRegistry:
    """Counters / gauges / histograms + interval snapshot sampler."""

    def __init__(self, interval_s: float = 0.05, hist_cap: int = 4096,
                 seed: int = 0, sink=None):
        assert interval_s > 0, "snapshot interval must be positive"
        assert hist_cap > 0, "histogram retention cap must be positive"
        self.interval_s = interval_s
        self.hist_cap = hist_cap
        # optional per-snapshot sink (flight.FlightRecorder.observe_sample):
        # each interval record is delivered as it is taken, so a bounded
        # ring can keep the recent tail without re-walking ``samples``
        self.sink = sink
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.hists: dict[str, list[float]] = {}
        self.hist_counts: dict[str, int] = {}     # everything ever observed
        self._sources: dict[str, object] = {}     # pulled gauges: name -> fn
        self.samples: list[dict] = []
        self._next_t: float | None = None
        # reservoir replacement draws are deterministic (seeded) so capped
        # summaries are reproducible run to run
        self._rng = np.random.default_rng(seed)

    # -- instruments ---------------------------------------------------------

    def inc(self, name: str, v: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + v

    def set_gauge(self, name: str, v: float) -> None:
        self.gauges[name] = v

    def register(self, name: str, fn) -> None:
        """Pull-mode gauge: ``fn()`` is read at each snapshot."""
        self._sources[name] = fn

    def observe(self, name: str, v: float) -> None:
        """Record one histogram observation.  The first ``hist_cap`` values
        are retained exactly; past the cap the retained set becomes a
        uniform reservoir (each of the ``n`` observations so far kept with
        probability ``hist_cap / n``), so memory stays bounded on long
        runs while percentiles remain unbiased over the whole stream."""
        vals = self.hists.setdefault(name, [])
        n = self.hist_counts.get(name, 0) + 1
        self.hist_counts[name] = n
        if len(vals) < self.hist_cap:
            vals.append(float(v))
        else:
            j = int(self._rng.integers(0, n))     # algorithm R
            if j < self.hist_cap:
                vals[j] = float(v)

    def hist_dropped(self, name: str) -> int:
        """Observations of ``name`` no longer retained under ``hist_cap``
        (0 while the stream fits — truncation is explicit, not silent)."""
        return self.hist_counts.get(name, 0) - len(self.hists.get(name, []))

    # -- sampling ------------------------------------------------------------

    def snapshot(self, t: float) -> dict:
        """One interval record: sim time + every counter, pushed gauge,
        and pulled source value."""
        rec: dict = {"t": t}
        rec.update(self.counters)
        rec.update(self.gauges)
        for name, fn in self._sources.items():
            rec[name] = fn()
        self.samples.append(rec)
        if self.sink is not None:
            self.sink(rec)
        return rec

    def maybe_sample(self, t: float) -> bool:
        """Snapshot iff ``interval_s`` of sim time has passed since the
        last snapshot (the first call snapshots immediately, anchoring the
        series at the run's start).  Returns whether a sample was taken."""
        if self._next_t is not None and t < self._next_t:
            return False
        self.snapshot(t)
        self._next_t = t + self.interval_s
        return True

    # -- summaries -----------------------------------------------------------

    def series(self, name: str) -> tuple[list[float], list[float]]:
        """(t, value) arrays for one metric across the snapshots taken
        (snapshots missing the metric — taken before it was registered —
        are skipped)."""
        ts, vs = [], []
        for s in self.samples:
            if name in s:
                ts.append(s["t"])
                vs.append(s[name])
        return ts, vs

    def percentiles(self, name: str, qs=(50, 99)) -> dict:
        """Histogram summary with the sample counts attached — small-n
        percentiles are noise, and ``n`` lets consumers gate on it.  ``n``
        counts every observation ever made; ``n_dropped`` is how many of
        those the retention cap evicted from the reservoir (0 = the
        summary is exact, >0 = it is a uniform-sample estimate)."""
        vals = self.hists.get(name, [])
        out = {"n": self.hist_counts.get(name, 0),
               "n_dropped": self.hist_dropped(name)}
        if vals:
            a = np.asarray(vals)
            for q in qs:
                out[f"p{q}"] = float(np.percentile(a, q))
        return out
