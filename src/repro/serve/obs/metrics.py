"""Named time-series metrics with periodic interval snapshots.

Replaces end-of-run aggregate dicts with counters, gauges, and histograms
sampled on a configurable virtual-time tick: the serving loops call
:meth:`MetricsRegistry.maybe_sample` every iteration, and the registry
snapshots at most once per ``interval_s`` of sim time — so a run yields
occupancy-over-time *curves* (pool blocks in use, queue depth, per-slice
load, migration/spill totals) instead of a single high-water mark.

Three instrument kinds:

  counter    monotone cumulative float (``inc``); snapshots carry the
             running value, so interval rates are first differences.
  gauge      instantaneous value; either pushed (``set_gauge``) or pulled —
             ``register(name, fn)`` samples ``fn()`` at snapshot time,
             which is how pool occupancy and queue depth are wired without
             the pool knowing the registry exists.
  histogram  value stream (``observe``); ``percentiles`` summarizes with
             the sample count attached (tiny-sample p99s are reported, but
             ``n`` rides along so gates can demand minimum counts).

Snapshots are plain dicts (``{"t": ..., name: value, ...}``) so they drop
straight into ``Telemetry.record_series`` / the JSONL exporter.
"""
from __future__ import annotations

import numpy as np


class MetricsRegistry:
    """Counters / gauges / histograms + interval snapshot sampler."""

    def __init__(self, interval_s: float = 0.05):
        assert interval_s > 0, "snapshot interval must be positive"
        self.interval_s = interval_s
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.hists: dict[str, list[float]] = {}
        self._sources: dict[str, object] = {}     # pulled gauges: name -> fn
        self.samples: list[dict] = []
        self._next_t: float | None = None

    # -- instruments ---------------------------------------------------------

    def inc(self, name: str, v: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + v

    def set_gauge(self, name: str, v: float) -> None:
        self.gauges[name] = v

    def register(self, name: str, fn) -> None:
        """Pull-mode gauge: ``fn()`` is read at each snapshot."""
        self._sources[name] = fn

    def observe(self, name: str, v: float) -> None:
        self.hists.setdefault(name, []).append(float(v))

    # -- sampling ------------------------------------------------------------

    def snapshot(self, t: float) -> dict:
        """One interval record: sim time + every counter, pushed gauge,
        and pulled source value."""
        rec: dict = {"t": t}
        rec.update(self.counters)
        rec.update(self.gauges)
        for name, fn in self._sources.items():
            rec[name] = fn()
        self.samples.append(rec)
        return rec

    def maybe_sample(self, t: float) -> bool:
        """Snapshot iff ``interval_s`` of sim time has passed since the
        last snapshot (the first call snapshots immediately, anchoring the
        series at the run's start).  Returns whether a sample was taken."""
        if self._next_t is not None and t < self._next_t:
            return False
        self.snapshot(t)
        self._next_t = t + self.interval_s
        return True

    # -- summaries -----------------------------------------------------------

    def series(self, name: str) -> tuple[list[float], list[float]]:
        """(t, value) arrays for one metric across the snapshots taken
        (snapshots missing the metric — taken before it was registered —
        are skipped)."""
        ts, vs = [], []
        for s in self.samples:
            if name in s:
                ts.append(s["t"])
                vs.append(s[name])
        return ts, vs

    def percentiles(self, name: str, qs=(50, 99)) -> dict:
        """Histogram summary with the sample count attached — small-n
        percentiles are noise, and ``n`` lets consumers gate on it."""
        vals = self.hists.get(name, [])
        out = {"n": len(vals)}
        if vals:
            a = np.asarray(vals)
            for q in qs:
                out[f"p{q}"] = float(np.percentile(a, q))
        return out
