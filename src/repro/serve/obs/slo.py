"""SLO health evaluation: multi-window burn-rate alerting + pressure signal.

Turns the raw telemetry PR 6 produced (per-request SLO timestamps, drop
ledger, interval metric series) into *verdicts*: a Google-SRE-style
multi-window burn-rate engine evaluates an :class:`SLOPolicy` online over
the serving loop's virtual clock and drives an ok → warn → critical health
state machine.

Burn rate, per objective, is the classic SRE quantity: the fraction of
recent events that violated the objective (a completion over the TTFT
target, a dropped admission) divided by the objective's error *budget*
(the violation fraction the SLO tolerates).  Burn 1.0 consumes the budget
exactly at the sustainable rate; burn 14.4 over a 30-day SLO exhausts it
in ~2 days.  Each :class:`BurnWindow` pairs a **long** window (evidence —
enough events that the rate is real) with a **short** window (recency —
the problem is still happening *now*): the pair trips only when *both*
windows exceed the threshold, the standard construction that pages fast on
real incidents without flapping on noise, and resets quickly once the
burn actually stops.  Window lengths are virtual-time seconds scaled to
the serving run (``SLOPolicy.default(period_s=...)`` applies the SRE
workbook's canonical window/threshold ratios to any period).

Outputs, all riding existing PR 6 surfaces:

  - health state + transition log (:meth:`SLOMonitor.report`);
  - trace instants at every transition (``slo_transition`` on the engine
    track) when a tracer is attached;
  - burn-rate series columns: at each evaluation the monitor pushes
    ``slo_state`` and per-objective ``burn_<name>`` gauges into the
    attached :class:`~repro.serve.obs.metrics.MetricsRegistry`, so the
    burn curves land in ``Telemetry.report()["series"]`` and the
    OpenMetrics exposition next to the occupancy curves;
  - a subscribable :class:`PressureSignal` that fires on every state
    transition — the hook the gateway's backpressure path consumes today
    (shed earlier under critical burn instead of waiting for the queue
    bound) and the planned closed-loop bit-width degradation controller
    (ROADMAP: step endpoints down the 8→4→2 stochastic bitstream ladder
    under pressure instead of dropping) will consume tomorrow.

Zero-cost-when-disabled: the serving loops only call into this module when
an ``slo`` monitor was explicitly attached, and every public entry point
charges the process-wide obs callback counter, so the pinned
"disabled == zero obs callbacks" contract covers the SLO path too.
"""
from __future__ import annotations

import dataclasses
from collections import deque

from repro.serve.obs.tracer import ENGINE_PID, _bump

# health states, in escalation order (indices double as series values)
STATES = ("ok", "warn", "critical")


@dataclasses.dataclass(frozen=True)
class SLObjective:
    """One objective: observations over ``target`` are budget burn.

    ``target`` is in the observation's own unit (seconds for the latency
    objectives; drop-rate observations are booleans and ignore it).
    ``budget`` is the tolerated violation fraction — the SLO is
    "at most ``budget`` of events exceed ``target``".
    """
    name: str                 # "ttft" | "tpot" | "queue_wait" | "drop_rate"
    target: float = 0.0
    budget: float = 0.01

    def __post_init__(self):
        assert 0.0 < self.budget <= 1.0, "budget is a fraction of events"


@dataclasses.dataclass(frozen=True)
class BurnWindow:
    """A (long, short) window pair with its burn threshold + severity.
    Trips only when the burn rate exceeds ``threshold`` over *both*
    windows — long for evidence, short for recency."""
    long_s: float
    short_s: float
    threshold: float
    severity: str             # "warn" | "critical"

    def __post_init__(self):
        assert 0.0 < self.short_s <= self.long_s
        assert self.severity in ("warn", "critical")


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Objectives + the multi-window burn ladder evaluating them."""
    objectives: tuple[SLObjective, ...]
    windows: tuple[BurnWindow, ...]

    @classmethod
    def default(cls, *, period_s: float, ttft_s: float | None = None,
                tpot_s: float | None = None,
                queue_wait_s: float | None = None,
                drop_budget: float | None = 0.01,
                budget: float = 0.01) -> "SLOPolicy":
        """The SRE workbook's canonical alert ladder, rescaled from the
        30-day period to ``period_s`` of virtual serving time: critical at
        burn 14.4 over (1h, 5m)/30d, warn at burn 6 over (6h, 30m)/30d and
        at burn 1 over (3d, 6h)/30d.  Pass a target to enable an
        objective; None leaves it out."""
        objectives = []
        for name, tgt in (("ttft", ttft_s), ("tpot", tpot_s),
                          ("queue_wait", queue_wait_s)):
            if tgt is not None:
                objectives.append(SLObjective(name, tgt, budget))
        if drop_budget is not None:
            objectives.append(SLObjective("drop_rate", 0.0, drop_budget))
        assert objectives, "policy needs at least one objective"
        month = 30 * 24 * 3600.0
        scale = period_s / month

        def w(long_h, short_h, thr, sev):
            return BurnWindow(long_h * 3600 * scale, short_h * 3600 * scale,
                              thr, sev)
        return cls(tuple(objectives),
                   (w(1, 1 / 12, 14.4, "critical"),
                    w(6, 0.5, 6.0, "warn"),
                    w(72, 6, 1.0, "warn")))

    def __post_init__(self):
        assert self.objectives and self.windows
        names = [o.name for o in self.objectives]
        assert len(set(names)) == len(names), f"duplicate objectives {names}"

    def objective(self, name: str) -> SLObjective | None:
        for o in self.objectives:
            if o.name == name:
                return o
        return None


@dataclasses.dataclass(frozen=True)
class PressureEvent:
    """One health transition, as delivered to pressure subscribers."""
    t: float
    prev: str                 # state left
    state: str                # state entered
    worst: str | None         # objective with the highest burn (None: ok)
    burns: dict               # objective -> max burn over the long windows


class PressureSignal:
    """Subscribable health-transition feed.

    Consumers register a callable; every state transition delivers a
    :class:`PressureEvent` synchronously, in virtual-time order.  This is
    deliberately the *whole* API — the future bit-width degradation
    controller subscribes here and walks the 8→4→2 stream-length ladder on
    warn/critical; today the prompt gateways subscribe their backpressure
    shedding (docs/serving.md).
    """

    def __init__(self):
        self._subs: list = []
        self.events: list[PressureEvent] = []

    @property
    def last(self) -> PressureEvent | None:
        return self.events[-1] if self.events else None

    def subscribe(self, fn) -> None:
        _bump()
        self._subs.append(fn)

    def unsubscribe(self, fn) -> None:
        _bump()
        self._subs.remove(fn)

    def fire(self, event: PressureEvent) -> None:
        _bump()
        self.events.append(event)
        for fn in list(self._subs):
            fn(event)


class SLOMonitor:
    """The burn-rate engine: event windows + state machine + outputs.

    The serving loops feed it observations as virtual time advances
    (:meth:`observe_record` at each completion, :meth:`observe_event` at
    each admission decision) and call :meth:`evaluate` once per tick; the
    monitor keeps per-objective event windows no longer than the policy's
    longest window, computes burn rates, walks the health state machine,
    and emits the transition outputs (trace instant, burn gauges,
    pressure event).
    """

    def __init__(self, policy: SLOPolicy, tracer=None, metrics=None):
        self.policy = policy
        self.tracer = tracer
        self.metrics = metrics
        self.pressure = PressureSignal()
        self.state = "ok"
        self.transitions: list[tuple[float, str, str, str | None]] = []
        self._events: dict[str, deque] = {
            o.name: deque() for o in policy.objectives}
        self._counts: dict[str, list[int]] = {
            o.name: [0, 0] for o in policy.objectives}   # [good, bad] ever
        self._horizon = max(w.long_s for w in policy.windows)
        self.last_burns: dict[str, float] = {
            o.name: 0.0 for o in policy.objectives}

    # -- observations --------------------------------------------------------

    def observe(self, name: str, t: float, value: float) -> None:
        """One measured observation for objective ``name`` (seconds for the
        latency objectives); burns budget iff it exceeds the target."""
        _bump()
        obj = self.policy.objective(name)
        if obj is None:
            return
        self._push(name, t, value > obj.target)

    def observe_event(self, name: str, t: float, bad: bool) -> None:
        """One boolean observation — how drop_rate is fed: every admission
        decision is an event, a rejection is a bad one."""
        _bump()
        if name in self._events:
            self._push(name, t, bad)

    def observe_record(self, rec, t: float | None = None) -> None:
        """Derive the latency observations from one completed
        :class:`~repro.serve.gateway.telemetry.RequestRecord` — TTFT
        (arrival → first token), TPOT (per generated token), queue wait
        (arrival → dequeue) — stamped at the completion's virtual time."""
        _bump()
        t = rec.t_done if t is None else t
        if rec.t_admit >= 0:
            self.observe("ttft", t, rec.t_admit - rec.t_arrival)
            self.observe("tpot", t, (rec.t_done - rec.t_admit)
                         / max(1, rec.tokens_out - 1))
        if rec.t_dequeue >= 0:
            self.observe("queue_wait", t, rec.t_dequeue - rec.t_arrival)
        elif rec.kind == "frame":
            # frames have no slot admission; their queue wait is the whole
            # pre-service latency net of the (fixed) sensor+link offset
            self.observe("queue_wait", t, rec.latency_s)

    def _push(self, name: str, t: float, bad: bool) -> None:
        dq = self._events[name]
        dq.append((t, bad))
        self._counts[name][1 if bad else 0] += 1
        cutoff = t - self._horizon
        while dq and dq[0][0] < cutoff:
            dq.popleft()

    # -- burn math -----------------------------------------------------------

    def burn_rate(self, name: str, window_s: float, t: float) -> float:
        """bad fraction over ``(t - window_s, t]``, divided by the
        objective's budget.  No events in the window -> 0 (no evidence is
        not an incident)."""
        _bump()
        obj = self.policy.objective(name)
        dq = self._events.get(name)
        if obj is None or not dq:
            return 0.0
        lo = t - window_s
        n = bad = 0
        for ts, b in reversed(dq):
            if ts <= lo:
                break
            n += 1
            bad += b
        return (bad / n) / obj.budget if n else 0.0

    # -- the state machine ---------------------------------------------------

    def evaluate(self, t: float) -> str:
        """Evaluate every (objective, window-pair) at virtual time ``t``,
        update the health state, and emit the transition outputs.  Returns
        the current state."""
        _bump()
        severity = 0
        burns: dict[str, float] = {}
        for obj in self.policy.objectives:
            peak = 0.0
            for w in self.policy.windows:
                b_long = self.burn_rate(obj.name, w.long_s, t)
                peak = max(peak, b_long)
                if b_long >= w.threshold and \
                        self.burn_rate(obj.name, w.short_s, t) >= w.threshold:
                    severity = max(severity, STATES.index(w.severity))
            burns[obj.name] = peak
        self.last_burns = burns
        new = STATES[severity]
        if self.metrics is not None:
            self.metrics.set_gauge("slo_state", severity)
            for name, b in burns.items():
                self.metrics.set_gauge(f"burn_{name}", b)
        if new != self.state:
            worst = max(burns, key=burns.get) if severity else None
            self.transitions.append((t, self.state, new, worst))
            if self.tracer is not None:
                self.tracer.instant(
                    "slo_transition", pid=ENGINE_PID, tid=0, t=t,
                    args={"from": self.state, "to": new, "objective": worst,
                          **{f"burn_{k}": v for k, v in burns.items()}})
            prev, self.state = self.state, new
            self.pressure.fire(PressureEvent(t, prev, new, worst, burns))
        return self.state

    # -- reporting -----------------------------------------------------------

    def report(self) -> dict:
        """End-of-run health verdict: final state, the transition log, the
        last burn snapshot, and per-objective totals."""
        _bump()
        return {
            "state": self.state,
            "transitions": [
                {"t": t, "from": a, "to": b, "objective": o}
                for t, a, b, o in self.transitions],
            "burns": dict(self.last_burns),
            "objectives": {
                o.name: {"target": o.target, "budget": o.budget,
                         "good": self._counts[o.name][0],
                         "bad": self._counts[o.name][1]}
                for o in self.policy.objectives},
            "pressure_events": len(self.pressure.events),
        }
