"""Request critical-path attribution over the span stream.

Per completed request, split the end-to-end ``request`` span exactly into
attributed segments — ``queue_wait`` / ``prefill`` (chunked folds) /
``handoff`` / ``decode`` ticks / ``migrate`` — plus one explicit
``unattributed`` residual (scheduler gaps between stages, e.g. a prefilled
lane parked awaiting a decode-slice handoff slot).  The contract mirrors
the PR 6 energy re-fold: a left-fold of a request's segment durations
reproduces the request span's ``dur`` with **float equality**, not a
tolerance — the residual is constructed against the same fold order the
verifier uses, so "the segments explain the whole latency" is a checkable
invariant, not a rounding hope.

``aggregate`` turns per-request attributions into a serving critical-path
ranking: which stage dominates total latency, which stage dominates the
slowest (p99) requests, and — under a disaggregated ``RolePlan`` — the same
shares grouped by the role that executes each stage (queue/prefill work on
the prefill tier, ticks/migrations on the decode tier, handoffs on the
boundary between them).

Works on any event list shaped like the tracer's: the live
``Tracer.events``, a flight-recorder snapshot's ``spans`` (reservoir
sampling may have dropped children — the residual absorbs them and
``complete`` is marked accordingly), or an incident bundle.
"""
from __future__ import annotations

from repro.serve.obs.tracer import REQUESTS_PID, _bump

# child span name -> critical-path stage
STAGES = ("queue_wait", "prefill", "handoff", "decode", "migrate",
          "sensor_link", "service", "unattributed")

# stage -> executing role under a disaggregated RolePlan (PR 8): queue and
# chunked prefill run on the prefill tier, ticks and migrations on the
# decode tier, the handoff copy on the boundary between them; the frame
# path's stages and the residual belong to neither tier
STAGE_ROLE = {"queue_wait": "prefill", "prefill": "prefill",
              "handoff": "boundary", "decode": "decode",
              "migrate": "decode", "sensor_link": "frontend",
              "service": "frontend", "unattributed": "overhead"}


def fold(durs) -> float:
    """The canonical left-fold — the verifier and the residual constructor
    must agree on association order for float equality to be meaningful."""
    total = 0.0
    for d in durs:
        total += d
    return total


def _exact_residual(total: float, durs: list[float]) -> float | None:
    """Residual ``r`` such that ``fold(durs + [r]) == total`` exactly.
    One Newton-style correction converges in a step or two for IEEE
    doubles; None if it doesn't (caller falls back to a single segment)."""
    r = total - fold(durs)
    for _ in range(8):
        f = fold(durs + [r])
        if f == total:
            return r
        r += total - f
    return None


def attribute_request(request: dict, children: list[dict]) -> dict:
    """Split one ``request`` span into exactly-folding segments.

    ``children`` are the finished spans on the request's lane (any depth);
    nesting is reconstructed here so a ``migrate`` inside ``decode`` is
    charged to migration, not double-counted.
    """
    dur = request["dur"]
    inner = [c for c in children
             if c is not request and c["name"] != "request"
             and c["ts"] >= request["ts"] - 1e-12
             and c["ts"] + c["dur"] <= request["ts"] + dur + 1e-9]
    # parents precede children under (start asc, dur desc); a span's direct
    # parent is the innermost still-open interval containing it
    inner.sort(key=lambda e: (e["ts"], -e["dur"]))
    segments: list[list] = []          # [stage, dur] in lane order
    stack: list[tuple[dict, int]] = []  # (span, its segment index)
    for c in inner:
        while stack and c["ts"] >= stack[-1][0]["ts"] \
                + stack[-1][0]["dur"] - 1e-12:
            stack.pop()
        stage = c["name"] if c["name"] in STAGE_ROLE else None
        if stage is None:              # prefill_chunk etc.: stays inside
            continue                   # its parent's segment
        if stack:
            # nested stage (migrate/handoff inside decode): carve it out
            # of the parent's segment so time is attributed once
            p_seg = segments[stack[-1][1]]
            p_seg[1] = p_seg[1] - c["dur"]
        segments.append([stage, c["dur"]])
        stack.append((c, len(segments) - 1))
    durs = [d for _, d in segments]
    residual = _exact_residual(dur, durs)
    if residual is None:               # pathological floats: stay exact
        segments, residual = [], dur
    segments = segments + [["unattributed", residual]]
    cp = {
        "uid": request["tid"],
        "dur": dur,
        "ts": request["ts"],
        "segments": [(s, d) for s, d in segments],
        "late_open": bool(request["args"].get("late_open")),
    }
    by_stage: dict[str, float] = {}
    for s, d in cp["segments"]:
        by_stage[s] = by_stage.get(s, 0.0) + d
    cp["by_stage"] = by_stage
    attributed = {s: v for s, v in by_stage.items() if s != "unattributed"}
    cp["dominant"] = max(attributed, key=attributed.get) \
        if attributed and max(attributed.values()) > 0.0 else "unattributed"
    return cp


def verify(cp: dict) -> bool:
    """The float-equality contract: the left-fold of a request's segment
    durations reproduces the request span duration bitwise."""
    return fold([d for _, d in cp["segments"]]) == cp["dur"]


def analyze(events: list[dict]) -> list[dict]:
    """Per-request critical paths for every completed ``request`` span in
    an event list (tracer stream, flight snapshot, or incident bundle)."""
    _bump()
    lanes: dict[int, list[dict]] = {}
    requests: list[dict] = []
    for e in events:
        if e.get("ph") != "X" or e.get("pid") != REQUESTS_PID:
            continue
        lanes.setdefault(e["tid"], []).append(e)
        if e["name"] == "request":
            requests.append(e)
    return [attribute_request(r, lanes[r["tid"]]) for r in requests]


def aggregate(cps: list[dict], *, roles: bool = False,
              p: float = 0.99) -> dict:
    """Serving critical-path ranking over per-request attributions.

    Returns stage totals/shares ranked by total time, the dominant stage
    among the slowest ``p``-tail requests (which stage to fix to move
    p99), and — with ``roles=True`` (a RolePlan was active) — the same
    shares grouped by executing role."""
    _bump()
    out: dict = {"requests": len(cps), "exact": all(map(verify, cps)),
                 "stages": {}, "p": p}
    if not cps:
        out.update(p_dur=0.0, p_dominant=None, ranking=[])
        if roles:
            out["by_role"] = {}
        return out
    totals: dict[str, float] = {}
    dominated: dict[str, int] = {}
    for cp in cps:
        for s, d in cp["by_stage"].items():
            totals[s] = totals.get(s, 0.0) + d
        dominated[cp["dominant"]] = dominated.get(cp["dominant"], 0) + 1
    grand = fold(sorted(totals.values()))
    out["stages"] = {
        s: {"total_s": t,
            "share": (t / grand) if grand > 0.0 else 0.0,
            "requests_dominated": dominated.get(s, 0)}
        for s, t in totals.items()}
    out["ranking"] = sorted(totals, key=totals.get, reverse=True)
    # tail: the dominant stage among requests at/above the p-quantile
    # duration is the lever that moves p99
    durs = sorted(cp["dur"] for cp in cps)
    k = min(len(durs) - 1, max(0, int(p * len(durs))))
    p_dur = durs[k]
    tail = [cp for cp in cps if cp["dur"] >= p_dur]
    tail_tot: dict[str, float] = {}
    for cp in tail:
        for s, d in cp["by_stage"].items():
            if s != "unattributed":
                tail_tot[s] = tail_tot.get(s, 0.0) + d
    out["p_dur"] = p_dur
    out["p_dominant"] = max(tail_tot, key=tail_tot.get) if tail_tot \
        and max(tail_tot.values()) > 0.0 else "unattributed"
    if roles:
        by_role: dict[str, dict] = {}
        for s, t in totals.items():
            role = STAGE_ROLE.get(s, "overhead")
            rec = by_role.setdefault(role, {"total_s": 0.0, "stages": []})
            rec["total_s"] += t
            rec["stages"].append(s)
        for rec in by_role.values():
            rec["share"] = (rec["total_s"] / grand) if grand > 0.0 else 0.0
            rec["stages"].sort()
        out["by_role"] = by_role
    return out


# package-level names (obs.analyze is already the costmodel's roofline
# entry point, so these carry their full meaning in their names)
analyze_critical_paths = analyze
aggregate_critical_paths = aggregate
