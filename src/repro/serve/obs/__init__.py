"""Serving-wide observability: tracing, metrics, health verdicts, exporters.

Low-overhead instrumentation for the serving stack (gateway, slot batcher,
paged KV adapter, sharded router):

  tracer.py     per-request lifecycle spans over the virtual serving clock
                (arrival -> queue wait -> prefill chunks -> decode ticks ->
                migration -> completion), each completion carrying a
                stage-attributed energy breakdown that sums *bitwise* to the
                conserved telemetry ledger.
  metrics.py    named counters / gauges / histograms (capped reservoir
                retention, explicit ``n_dropped``) with periodic interval
                snapshots — occupancy-over-time curves instead of end-only
                aggregates.
  slo.py        SLO policy + Google-SRE multi-window burn-rate engine:
                ok/warn/critical health state machine over the serving
                clock, trace instants at transitions, burn-rate series
                columns, and the subscribable ``PressureSignal`` the
                gateway backpressure path (and the future bit-width
                degradation controller) consumes.
  costmodel.py  per-stage roofline attribution: XLA ``cost_analysis()``
                FLOPs/bytes over the ``cost_args()`` registries, joined
                with measured span durations into achieved rates and
                compute- vs memory-bound verdicts, cross-checked against
                the energy ledger.
  export.py     Chrome trace-event (Perfetto-loadable) JSON export with
                bounded ``max_events``, an incremental JSONL span-stream
                writer, a JSONL metrics dump, an OpenMetrics text
                exposition, and structural validators for all of them.
  recompile.py  jit-cache-entry accounting per compiled executable; flags
                steady-state recompiles as a metric.
  flight.py     always-on bounded ring buffer over the trace stream
                (reservoir-sampled spans, exact instant/counter/sample
                tails) — the cheap ever-running recorder incident bundles
                snapshot.
  critpath.py   per-request critical-path attribution: the request span
                split exactly (float-equal re-fold) into queue / prefill /
                handoff / decode / migration segments, aggregated into a
                which-stage-dominates-p99 ranking (per role under a
                RolePlan).
  incident.py   trigger -> bundle forensics pipeline: SLO warn->critical,
                drop bursts, recompile leaks, energy-conservation breaks
                and explicit captures snapshot the flight ring + gateway
                debug state into schema-validated, size-bounded JSON
                bundles, inspectable offline via
                ``python -m repro.serve.obs.incident``.

The contract every instrumented hot path keeps: **disabled observability
costs zero Python-level callbacks** — call sites guard on
``tracer/slo is None`` and the module-level :func:`callback_count` (which
every obs entry point charges, SLO and costmodel included) lets tests pin
that the guards really short-circuit (tests/test_obs.py, tests/test_slo.py).
"""
from repro.serve.obs.metrics import MetricsRegistry
from repro.serve.obs.recompile import RecompileDetector
from repro.serve.obs.tracer import (ENGINE_PID, REQUESTS_PID, SimClock,
                                    Tracer, callback_count)
from repro.serve.obs.flight import FlightRecorder
from repro.serve.obs import critpath
from repro.serve.obs.critpath import (aggregate_critical_paths,
                                      analyze_critical_paths)

# incident.py is also the CLI module (`python -m repro.serve.obs.incident`);
# importing it eagerly here would double-load it under -m (runpy warns), so
# its names resolve lazily on first attribute access (PEP 562)
_INCIDENT_NAMES = ("IncidentCapture", "load_incident_bundle",
                   "validate_incident_bundle", "write_incident_bundle")


def __getattr__(name: str):
    if name in _INCIDENT_NAMES:
        from repro.serve.obs import incident
        return getattr(incident, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
from repro.serve.obs.slo import (BurnWindow, PressureEvent, PressureSignal,
                                 SLObjective, SLOMonitor, SLOPolicy)
from repro.serve.obs.costmodel import (DEFAULT_RIDGE, analyze, attribute,
                                       span_for, stage_energy)
from repro.serve.obs.export import (SpanStreamWriter, chrome_trace,
                                    openmetrics_text, read_span_stream,
                                    validate_chrome_trace,
                                    validate_openmetrics,
                                    write_chrome_trace, write_metrics_jsonl,
                                    write_openmetrics)

__all__ = [
    "ENGINE_PID", "MetricsRegistry", "RecompileDetector", "REQUESTS_PID",
    "SimClock", "Tracer", "callback_count",
    "BurnWindow", "PressureEvent", "PressureSignal", "SLObjective",
    "SLOMonitor", "SLOPolicy",
    "DEFAULT_RIDGE", "analyze", "attribute", "span_for", "stage_energy",
    "SpanStreamWriter", "chrome_trace", "openmetrics_text",
    "read_span_stream", "validate_chrome_trace", "validate_openmetrics",
    "write_chrome_trace", "write_metrics_jsonl", "write_openmetrics",
    "FlightRecorder", "critpath",
    "aggregate_critical_paths", "analyze_critical_paths",
    "IncidentCapture", "load_incident_bundle", "validate_incident_bundle",
    "write_incident_bundle",
]
