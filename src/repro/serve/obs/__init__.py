"""Serving-wide observability: tracing, metrics, exporters, profiling.

Low-overhead instrumentation for the serving stack (gateway, slot batcher,
paged KV adapter, sharded router):

  tracer.py     per-request lifecycle spans over the virtual serving clock
                (arrival -> queue wait -> prefill chunks -> decode ticks ->
                migration -> completion), each completion carrying a
                stage-attributed energy breakdown that sums *bitwise* to the
                conserved telemetry ledger.
  metrics.py    named counters / gauges / histograms with periodic interval
                snapshots — occupancy-over-time curves instead of end-only
                aggregates.
  export.py     Chrome trace-event (Perfetto-loadable) JSON export, a
                JSONL metrics dump, and a trace-schema validator.
  recompile.py  jit-cache-entry accounting per compiled executable; flags
                steady-state recompiles as a metric.

The contract every instrumented hot path keeps: **disabled tracing costs
zero Python-level callbacks** — call sites guard on ``tracer is None`` and
the module-level :func:`callback_count` lets tests pin that the guard
really short-circuits (tests/test_obs.py).
"""
from repro.serve.obs.metrics import MetricsRegistry
from repro.serve.obs.recompile import RecompileDetector
from repro.serve.obs.tracer import SimClock, Tracer, callback_count
from repro.serve.obs.export import (chrome_trace, validate_chrome_trace,
                                    write_chrome_trace, write_metrics_jsonl)

__all__ = [
    "MetricsRegistry", "RecompileDetector", "SimClock", "Tracer",
    "callback_count", "chrome_trace", "validate_chrome_trace",
    "write_chrome_trace", "write_metrics_jsonl",
]
