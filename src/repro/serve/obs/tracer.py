"""Span/event tracer over the serving stack's virtual clock.

Timestamps are **sim-clock** seconds: the discrete-event serving loops
(``gateway.drive_prompt_loop``, ``MicroBatchGateway.run``) advance a
:class:`SimClock` as virtual time progresses, and events stamped *inside* a
decode tick interpolate with the measured wall offset from the tick's start
(``anchor``/``release``), so sub-tick spans (per-chunk prefill folds,
migrations) land between the tick's virtual endpoints instead of collapsing
onto one instant.

Span discipline is strict per lane ``(pid, tid)``: ``end`` must close the
innermost open span of that lane with the same name, or it raises — the
nesting invariant is enforced at record time, not post-hoc.  Lanes:

  pid 0           request lifecycle tracks, one tid per request uid:
                  ``request`` > ``queue_wait`` / ``prefill`` (with
                  ``prefill_chunk`` children, prefix hits marked) /
                  ``decode`` (with ``migrate`` children).
  pid 1 + slice   engine tracks: one ``tick`` / ``batch`` span per batched
                  step, args carrying the lane/bucket occupancy.

Energy attribution: each completed request span ends with an
``energy_parts`` dict (frontend prefill/decode, link, migration — the same
addends, in the same order, that the telemetry ledger folded into the
request's ``energy_nj``), so :meth:`Tracer.assert_energy_conserved` can
check the span stream against ``Telemetry.fleet_energy_nj`` **bitwise**.

Zero-cost-when-disabled contract: nothing in the serving stack calls into
this module unless a tracer was explicitly attached; every public method
bumps a module-level counter (:func:`callback_count`) so the test suite can
pin "disabled tracing == zero Python-level callbacks" exactly.
"""
from __future__ import annotations

import time

REQUESTS_PID = 0          # request lifecycle tracks (tid = request uid)
ENGINE_PID = 1            # engine track of slice 0 (1 + slice_idx generally)

# every public Tracer entry point increments this; tests assert a run with
# tracing disabled leaves it untouched (the hot paths' `if tracer is None`
# guards really do short-circuit all instrumentation)
_N_CALLBACKS = 0


def callback_count() -> int:
    """Python-level tracer callbacks made process-wide so far."""
    return _N_CALLBACKS


def _bump() -> None:
    """Count one obs callback.  Every public entry point of the obs layer
    (tracer, SLO monitor, pressure signal, cost attributor) charges itself
    here, so the zero-cost-when-disabled pin covers the *whole* obs
    surface: a run with no tracer/monitor attached must leave
    :func:`callback_count` untouched."""
    global _N_CALLBACKS
    _N_CALLBACKS += 1


class SimClock:
    """Monotone virtual-time clock shared by loop, batcher, and tracer."""

    __slots__ = ("t",)

    def __init__(self, t: float = 0.0):
        self.t = t

    def advance(self, t: float) -> None:
        if t > self.t:
            self.t = t


class Tracer:
    """Strictly-nested span recorder with sim-clock timestamps."""

    def __init__(self, clock: SimClock | None = None, sink=None,
                 retain: bool = True):
        self.clock = clock if clock is not None else SimClock()
        self.events: list[dict] = []      # finished spans/instants, append order
        self._stacks: dict[tuple, list[dict]] = {}   # lane -> open spans
        self._ctx: tuple[int, int] = (REQUESTS_PID, 0)
        self._anchor_wall: float | None = None
        self._anchor_sim = 0.0
        # optional incremental event sink (obs.export.SpanStreamWriter, or
        # a flight.FlightRecorder ring): called with each finished event as
        # it is recorded, so long runs can stream spans to disk — or keep a
        # bounded ring — instead of holding only the in-memory list.
        # ``retain=False`` makes the sink the *only* retention (always-on
        # flight mode on a long-running gateway must not grow an unbounded
        # event list); post-hoc checks that re-fold the full stream
        # (assert_nested / assert_energy_conserved) need retain=True.
        self.sink = sink
        self.retain = retain

    def _emit(self, event: dict) -> None:
        if self.retain:
            self.events.append(event)
        if self.sink is not None:
            self.sink(event)

    # -- clock ---------------------------------------------------------------

    def now(self) -> float:
        """Current trace time: the sim clock, plus the measured wall offset
        when inside an anchored tick (see :meth:`anchor`)."""
        global _N_CALLBACKS
        _N_CALLBACKS += 1
        if self._anchor_wall is not None:
            return self._anchor_sim + (time.perf_counter()
                                       - self._anchor_wall)
        return self.clock.t

    def anchor(self) -> None:
        """Start a measured window at the clock's current virtual time:
        until :meth:`release`, stamps are ``clock.t + wall_offset`` — the
        event loop brackets each ``step()`` with anchor/release so sub-tick
        events spread over the tick's (measured) virtual extent."""
        global _N_CALLBACKS
        _N_CALLBACKS += 1
        self._anchor_sim = self.clock.t
        self._anchor_wall = time.perf_counter()

    def release(self) -> None:
        global _N_CALLBACKS
        _N_CALLBACKS += 1
        self._anchor_wall = None

    # -- lane context --------------------------------------------------------

    def set_ctx(self, tid: int, pid: int = REQUESTS_PID) -> None:
        """Default lane for events that omit pid/tid — the batcher points
        this at the request being admitted so the paged adapter's chunk
        spans land on the right request track without threading uids
        through every fold call."""
        global _N_CALLBACKS
        _N_CALLBACKS += 1
        self._ctx = (pid, tid)

    def _lane(self, pid, tid) -> tuple[int, int]:
        return (self._ctx[0] if pid is None else pid,
                self._ctx[1] if tid is None else tid)

    # -- spans ---------------------------------------------------------------

    def begin(self, name: str, *, pid: int | None = None,
              tid: int | None = None, t: float | None = None,
              args: dict | None = None) -> None:
        global _N_CALLBACKS
        _N_CALLBACKS += 1
        lane = self._lane(pid, tid)
        span = {"name": name, "ph": "X", "pid": lane[0], "tid": lane[1],
                "ts": self.now() if t is None else t,
                "args": dict(args) if args else {}}
        self._stacks.setdefault(lane, []).append(span)

    def end(self, name: str, *, pid: int | None = None,
            tid: int | None = None, t: float | None = None,
            args: dict | None = None) -> dict:
        """Close the innermost open span of the lane; it must carry
        ``name`` (strict nesting, enforced here).  ``args`` merge into the
        span's args; the finished span joins the event stream."""
        global _N_CALLBACKS
        _N_CALLBACKS += 1
        lane = self._lane(pid, tid)
        stack = self._stacks.get(lane)
        if not stack:
            raise AssertionError(f"end('{name}') on lane {lane} with no "
                                 f"open span")
        span = stack.pop()
        if span["name"] != name:
            stack.append(span)
            raise AssertionError(
                f"end('{name}') on lane {lane} but innermost open span is "
                f"'{span['name']}' — spans must nest")
        t_end = self.now() if t is None else t
        # a child stamped by a wall offset can overrun the loop's virtual
        # endpoint by scheduler noise; clamp so durations stay non-negative
        span["dur"] = max(0.0, t_end - span["ts"])
        if args:
            span["args"].update(args)
        self._emit(span)
        return span

    def instant(self, name: str, *, pid: int | None = None,
                tid: int | None = None, t: float | None = None,
                args: dict | None = None) -> None:
        global _N_CALLBACKS
        _N_CALLBACKS += 1
        lane = self._lane(pid, tid)
        self._emit({
            "name": name, "ph": "i", "pid": lane[0], "tid": lane[1],
            "ts": self.now() if t is None else t, "s": "t",
            "args": dict(args) if args else {}})

    def counter(self, name: str, values: dict, *, pid: int = ENGINE_PID,
                t: float | None = None) -> None:
        global _N_CALLBACKS
        _N_CALLBACKS += 1
        self._emit({
            "name": name, "ph": "C", "pid": pid, "tid": 0,
            "ts": self.now() if t is None else t, "args": dict(values)})

    def innermost(self, *, pid: int | None = None,
                  tid: int | None = None) -> str | None:
        """Name of the lane's innermost open span (None when the lane is
        empty).  The serving instrumentation uses this to heal partially
        traced lifecycles — a request admitted before the tracer was wired
        has no open ``queue_wait``/``decode`` to close, and closing blind
        would (correctly) raise."""
        global _N_CALLBACKS
        _N_CALLBACKS += 1
        stack = self._stacks.get(self._lane(pid, tid))
        return stack[-1]["name"] if stack else None

    # -- inspection ----------------------------------------------------------

    def open_spans(self) -> list[dict]:
        return [s for stack in self._stacks.values() for s in stack]

    def spans(self, name: str | None = None) -> list[dict]:
        return [e for e in self.events if e["ph"] == "X"
                and (name is None or e["name"] == name)]

    def request_spans(self) -> dict[int, dict]:
        """uid -> completed ``request`` span (requests pid only)."""
        return {e["tid"]: e for e in self.spans("request")
                if e["pid"] == REQUESTS_PID}

    def assert_nested(self) -> None:
        """Every lane's finished spans form a proper nesting (children
        inside parents, siblings disjoint up to clamp rounding) and no
        span is left open.  ``end``'s stack discipline makes violations
        impossible to *record*; this re-checks the resulting intervals."""
        if self.open_spans():
            raise AssertionError(f"open spans at trace end: "
                                 f"{[s['name'] for s in self.open_spans()]}")
        lanes: dict[tuple, list[dict]] = {}
        for e in self.spans():
            lanes.setdefault((e["pid"], e["tid"]), []).append(e)
        for lane, evs in lanes.items():
            # sort by start asc, duration desc: parents precede children
            evs = sorted(evs, key=lambda e: (e["ts"], -e["dur"]))
            stack: list[dict] = []
            for e in evs:
                while stack and e["ts"] >= stack[-1]["ts"] \
                        + stack[-1]["dur"] - 1e-12:
                    stack.pop()
                if stack and e["ts"] + e["dur"] > stack[-1]["ts"] \
                        + stack[-1]["dur"] + 1e-9:
                    raise AssertionError(
                        f"lane {lane}: span '{e['name']}' "
                        f"[{e['ts']}, {e['ts'] + e['dur']}] overlaps "
                        f"parent '{stack[-1]['name']}' boundary")
                stack.append(e)

    def assert_energy_conserved(self, telemetry) -> None:
        """The span stream's stage-attributed energies sum **bitwise** to
        the telemetry ledger's conserved fleet total.

        Request spans end in completion-record order and their
        ``energy_parts`` hold the exact addends (same values, same fold
        order) the ledger summed into each record's ``energy_nj`` — so a
        left-fold here reproduces ``fleet_energy_nj`` with float equality,
        not a tolerance.  Any drift means an instrumentation path charged
        energy the ledger never saw (or vice versa).
        """
        total = 0.0
        n = 0
        for e in self.events:               # append order == record order
            if e["ph"] != "X" or e["name"] != "request":
                continue
            parts = e["args"].get("energy_parts")
            if parts is None:
                raise AssertionError(
                    f"request span uid={e['tid']} carries no energy_parts")
            span_e = 0.0
            for v in parts.values():
                span_e += v
            if span_e != e["args"].get("energy_nj"):
                raise AssertionError(
                    f"request span uid={e['tid']}: parts sum {span_e} != "
                    f"span energy_nj {e['args'].get('energy_nj')}")
            total += span_e
            n += 1
        if n != len(telemetry.records):
            raise AssertionError(
                f"{n} request spans vs {len(telemetry.records)} ledger "
                f"records — span coverage is incomplete")
        if total != telemetry.fleet_energy_nj:
            raise AssertionError(
                f"span energy sum {total!r} != fleet ledger total "
                f"{telemetry.fleet_energy_nj!r} (must match bitwise)")
