"""Always-on flight recorder: a bounded ring buffer over the trace stream.

The PR 6 tracer is opt-in and forward-only — when an incident finally
happens, the spans that explain it were either never recorded or live in an
unbounded list nobody can afford to keep on a long-running gateway.  The
:class:`FlightRecorder` closes that gap: it attaches as a :class:`Tracer`
sink (``Tracer(sink=flight)`` — the same hook the span-stream writer uses)
and keeps a *bounded*, statistically honest picture of the recent past:

  spans ("X")     seeded reservoir (algorithm R, the same scheme the
                  metrics histograms use): every span ever emitted has an
                  equal chance of surviving, so a post-hoc critical-path
                  ranking over the ring is unbiased — a plain tail would
                  only ever show the last tick.
  instants ("i")  exact tail (deque): drops, SLO transitions and
                  prefix-resume markers are rare and the *most recent* ones
                  are exactly what an incident bundle needs verbatim.
  counters ("C")  exact tail.
  metadata ("M")  kept in full up to a small cap (process/track names).
  samples         exact tail of interval metric snapshots, fed by
                  ``MetricsRegistry(sink=flight.observe_sample)``.

The fast path allocates nothing per event: the tracer's own finished-event
dicts are stored by reference (a flight-only run uses
``Tracer(retain=False)`` so the recorder's ring is the *only* retention),
and an event past capacity costs one RNG draw plus at most one list store.
Every entry point charges the module callback counter, so the
zero-cost-when-disabled pin covers the recorder too.

``snapshot()`` returns a plain-JSON view (spans sorted by start time,
accounting fields making any loss explicit) — the incident bundle embeds it
verbatim and ``shrink()`` lets the bundle writer halve the ring until the
bundle fits its size bound.
"""
from __future__ import annotations

import random
from collections import deque

from repro.serve.obs.tracer import _bump


class FlightRecorder:
    """Bounded ring buffer of trace events + metric samples.

    Parameters
    ----------
    span_cap, instant_cap, counter_cap, sample_cap, meta_cap:
        retention bounds per stream.  Spans use reservoir sampling; the
        other streams keep an exact tail.
    seed:
        reservoir RNG seed — two recorders over the same event stream keep
        the same spans.
    """

    def __init__(self, *, span_cap: int = 512, instant_cap: int = 256,
                 counter_cap: int = 256, sample_cap: int = 128,
                 meta_cap: int = 64, seed: int = 0):
        if min(span_cap, instant_cap, counter_cap, sample_cap, meta_cap) < 1:
            raise ValueError("flight recorder capacities must be >= 1")
        self.span_cap = span_cap
        self._rng = random.Random(seed)
        self._seed = seed
        self.spans: list[dict] = []         # reservoir, insertion order
        self.instants: deque = deque(maxlen=instant_cap)
        self.counters: deque = deque(maxlen=counter_cap)
        self.meta: list[dict] = []
        self.meta_cap = meta_cap
        self.samples: deque = deque(maxlen=sample_cap)
        # accounting: seen counts make any loss explicit in the snapshot
        self.spans_seen = 0
        self.instants_seen = 0
        self.counters_seen = 0
        self.samples_seen = 0

    # -- ingest (tracer sink + metrics sink) --------------------------------

    def __call__(self, event: dict) -> None:
        """Tracer sink: one finished span/instant/counter/metadata event.
        Stores the tracer's dict by reference — no copy on the hot path."""
        _bump()
        ph = event["ph"]
        if ph == "X":
            self.spans_seen += 1
            if len(self.spans) < self.span_cap:
                self.spans.append(event)
            else:
                # algorithm R: keep each of the n seen so far with
                # probability cap/n — uniform over the whole run
                j = self._rng.randrange(self.spans_seen)
                if j < self.span_cap:
                    self.spans[j] = event
        elif ph == "i":
            self.instants_seen += 1
            self.instants.append(event)
        elif ph == "C":
            self.counters_seen += 1
            self.counters.append(event)
        elif len(self.meta) < self.meta_cap:
            self.meta.append(event)

    def observe_sample(self, snap: dict) -> None:
        """Metrics sink: one interval snapshot (``MetricsRegistry(sink=)``)."""
        _bump()
        self.samples_seen += 1
        self.samples.append(snap)

    # -- views --------------------------------------------------------------

    @property
    def spans_dropped(self) -> int:
        return self.spans_seen - len(self.spans)

    def snapshot(self) -> dict:
        """Plain-JSON view of the ring: the incident bundle's ``flight``
        section.  Spans come out sorted by start time (the reservoir holds
        them in replacement order); accounting fields state exactly what
        was lost to the bounds."""
        _bump()
        return {
            "spans": sorted(self.spans, key=lambda e: (e["ts"], e["tid"])),
            "instants": list(self.instants),
            "counters": list(self.counters),
            "meta": list(self.meta),
            "samples": list(self.samples),
            "accounting": {
                "spans_seen": self.spans_seen,
                "spans_kept": len(self.spans),
                "spans_dropped": self.spans_dropped,
                "instants_seen": self.instants_seen,
                "instants_kept": len(self.instants),
                "counters_seen": self.counters_seen,
                "counters_kept": len(self.counters),
                "samples_seen": self.samples_seen,
                "samples_kept": len(self.samples),
            },
            "config": {"span_cap": self.span_cap,
                       "instant_cap": self.instants.maxlen,
                       "counter_cap": self.counters.maxlen,
                       "sample_cap": self.samples.maxlen,
                       "seed": self._seed},
        }

    @staticmethod
    def shrink(snap: dict) -> dict:
        """Halve a snapshot's retained content (oldest entries first for the
        tails, tail of the reservoir for spans), preserving the accounting.
        The incident writer calls this until the bundle fits its size
        bound; ``*_kept`` fields track the shrink so a validator can tell a
        deliberately-shrunk bundle from a truncated file."""
        out = {k: v for k, v in snap.items()}
        acct = dict(snap["accounting"])
        for key in ("spans", "instants", "counters", "samples"):
            kept = snap[key]
            keep = max(1, len(kept) // 2) if kept else 0
            out[key] = kept[-keep:] if keep else []
            acct[f"{key}_kept"] = len(out[key])
        acct["spans_dropped"] = acct["spans_seen"] - acct["spans_kept"]
        out["accounting"] = acct
        return out
