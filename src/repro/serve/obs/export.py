"""Exporters: Chrome trace JSON, span-stream JSONL, metrics JSONL, OpenMetrics.

The trace format is the Chrome trace-event *JSON object format*
(``{"traceEvents": [...]}``) with complete-duration events (``ph: "X"``),
instants (``"i"``), counters (``"C"``), and process-name metadata
(``"M"``) — the subset Perfetto's legacy-trace importer accepts, so
``chrome://tracing`` and https://ui.perfetto.dev open the file directly.
Timestamps convert from the tracer's sim-clock seconds to the format's
microseconds.  Long runs can bound the export with ``max_events``; the cut
is never silent — a ``trace_truncated`` instant carrying the drop count is
appended where the stream was cut.  For runs too long to hold in memory at
all, :class:`SpanStreamWriter` plugs into ``Tracer(sink=...)`` and streams
each finished event to JSONL as it is recorded.

:func:`validate_chrome_trace` is the schema gate CI runs over exported
traces: structural errors (missing fields, bad phases, negative durations,
non-numeric timestamps) are returned as a list so the pipeline fails
loudly instead of shipping a trace Perfetto would silently drop events
from.  The span-stream writer validates each event against the same
per-event checks at write time.

:func:`openmetrics_text` renders a finished run's metrics registry (and,
optionally, the SLO monitor's burn state) in the OpenMetrics text
exposition format — counters as ``_total`` samples, gauges, histogram
summaries with quantile labels — terminated by ``# EOF``, so a run's
health surface scrapes like a production server's ``/metrics`` endpoint.
:func:`validate_openmetrics` is its structural gate.
"""
from __future__ import annotations

import json
import re

_VALID_PHASES = {"X", "i", "C", "M"}


def _validate_event(e, where: str) -> list[str]:
    """Per-event structural checks, shared by the whole-trace validator
    and the incremental span-stream writer."""
    if not isinstance(e, dict):
        return [f"{where}: not an object"]
    errs: list[str] = []
    for field in ("name", "ph", "pid", "tid", "ts"):
        if field not in e:
            errs.append(f"{where}: missing '{field}'")
    ph = e.get("ph")
    if ph not in _VALID_PHASES:
        errs.append(f"{where}: unknown phase {ph!r}")
    if not isinstance(e.get("ts"), (int, float)) or \
            isinstance(e.get("ts"), bool):
        errs.append(f"{where}: non-numeric ts {e.get('ts')!r}")
    if ph == "X":
        dur = e.get("dur")
        if not isinstance(dur, (int, float)) or isinstance(dur, bool):
            errs.append(f"{where}: X event missing numeric dur")
        elif dur < 0:
            errs.append(f"{where}: negative dur {dur}")
    if ph == "C" and not isinstance(e.get("args"), dict):
        errs.append(f"{where}: counter event without args dict")
    if "args" in e and not isinstance(e["args"], dict):
        errs.append(f"{where}: args is not an object")
    return errs


def chrome_trace(tracer, metrics=None,
                 process_names: dict[int, str] | None = None,
                 max_events: int | None = None) -> dict:
    """Assemble the Chrome trace-event object from a finished tracer
    (and, optionally, a metrics registry whose interval snapshots become
    counter tracks — occupancy curves right inside the trace UI).

    ``max_events`` bounds how many tracer events are exported (the
    chronological prefix is kept); the cut is marked by an explicit
    ``trace_truncated`` instant carrying the drop count — truncation is
    visible in the trace itself, never silent.  Metadata and metric
    counter tracks ride outside the cap.
    """
    evs: list[dict] = []
    pids = set()
    src = tracer.events
    dropped = 0
    if max_events is not None and len(src) > max_events:
        dropped = len(src) - max_events
        src = src[:max_events]
    for e in src:
        ev = {"name": e["name"], "ph": e["ph"], "pid": e["pid"],
              "tid": e["tid"], "ts": e["ts"] * 1e6, "args": e["args"]}
        if e["ph"] == "X":
            ev["dur"] = e["dur"] * 1e6
        if e["ph"] == "i":
            ev["s"] = e.get("s", "t")
        evs.append(ev)
        pids.add(e["pid"])
    if dropped:
        t_cut = evs[-1]["ts"] if evs else 0.0
        evs.append({"name": "trace_truncated", "ph": "i", "pid": 1,
                    "tid": 0, "ts": t_cut, "s": "t",
                    "args": {"dropped_events": dropped,
                             "max_events": max_events}})
        pids.add(1)
    if metrics is not None:
        for snap in metrics.samples:
            args = {k: v for k, v in snap.items() if k != "t"}
            if args:
                evs.append({"name": "metrics", "ph": "C", "pid": 1,
                            "tid": 0, "ts": snap["t"] * 1e6, "args": args})
                pids.add(1)
    names = {0: "requests", 1: "engine"}
    if process_names:
        names.update(process_names)
    for pid in sorted(pids):
        evs.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                    "ts": 0.0,
                    "args": {"name": names.get(pid, f"slice{pid - 1}")}})
    return {"traceEvents": evs, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, tracer, metrics=None,
                       process_names: dict[int, str] | None = None,
                       max_events: int | None = None) -> dict:
    """Export + write; returns the trace object (already validated —
    writing an invalid trace is a bug, not an artifact)."""
    obj = chrome_trace(tracer, metrics, process_names, max_events)
    errs = validate_chrome_trace(obj)
    if errs:
        raise AssertionError("refusing to write invalid trace: "
                             + "; ".join(errs[:5]))
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


def validate_chrome_trace(obj) -> list[str]:
    """Structural schema check for the trace-event object format.
    Returns the (possibly empty) list of violations."""
    if not isinstance(obj, dict):
        return ["trace is not a JSON object"]
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        return ["missing/invalid 'traceEvents' array"]
    errs: list[str] = [] if evs else ["empty traceEvents"]
    for i, e in enumerate(evs):
        errs.extend(_validate_event(e, f"traceEvents[{i}]"))
    return errs


class SpanStreamWriter:
    """Incremental JSONL span stream: one finished event per line.

    Plugs into ``Tracer(sink=writer)``: the tracer calls the writer with
    each finished span/instant/counter as it is recorded, so arbitrarily
    long runs stream to disk instead of relying on the in-memory event
    list.  Events are written in tracer-native form (sim-clock *seconds*,
    same fields the Chrome exporter reads) and each is checked against the
    structural validator before it hits the file — an instrumentation bug
    fails at record time, not at scrape time.

    Use as a context manager, or call :meth:`close` when the run ends.
    """

    def __init__(self, path: str, validate: bool = True):
        self.path = path
        self.validate = validate
        self.n_written = 0
        self._f = open(path, "w")

    def __call__(self, event: dict) -> None:
        if self.validate:
            errs = _validate_event(event, f"span_stream[{self.n_written}]")
            if errs:
                self._f.close()
                raise AssertionError("invalid event in span stream: "
                                     + "; ".join(errs))
        self._f.write(json.dumps(event) + "\n")
        self.n_written += 1

    def close(self) -> None:
        self._f.close()

    def __enter__(self) -> "SpanStreamWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_span_stream(path: str) -> list[dict]:
    """Load a :class:`SpanStreamWriter` JSONL file back into event dicts."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def write_metrics_jsonl(path: str, registry) -> int:
    """One JSON line per interval snapshot (benchmarks/ consume this).
    Returns the number of lines written."""
    with open(path, "w") as f:
        for snap in registry.samples:
            f.write(json.dumps(snap) + "\n")
    return len(registry.samples)


# -- OpenMetrics text exposition ---------------------------------------------

_NAME_OK = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_SAMPLE = re.compile(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"
                     r"(\{[^{}]*\})?"
                     r" (-?(?:[0-9]*\.?[0-9]+(?:[eE][-+]?[0-9]+)?"
                     r"|Inf)|NaN|\+Inf)$")


def _metric_name(raw: str) -> str:
    """Sanitize an internal metric name into the OpenMetrics charset."""
    name = re.sub(r"[^a-zA-Z0-9_:]", "_", raw)
    if not _NAME_OK.match(name):
        name = "_" + name
    return name


def _fmt(v: float) -> str:
    return repr(float(v))


def openmetrics_text(metrics=None, slo=None, prefix: str = "repro") -> str:
    """Render the run's health surface in the OpenMetrics text format.

    ``metrics`` contributes counters (``<prefix>_<name>_total``), pushed
    and pulled gauges, and histogram summaries (quantile-labelled samples
    + ``_count``, with the retention cap's evictions surfaced as an
    explicit ``_dropped_total`` counter — no silent truncation on the
    scrape surface either).  ``slo`` contributes the health state gauge,
    per-objective burn-rate gauges, and good/bad event totals.  Terminated
    by ``# EOF`` per the spec; :func:`validate_openmetrics` checks the
    result structurally.
    """
    lines: list[str] = []
    seen: set[str] = set()

    def family(name: str, kind: str) -> str | None:
        # one family per name: the SLO monitor's burn gauges also live in
        # the metrics registry (series columns), so skip re-declaration
        if name in seen:
            return None
        seen.add(name)
        lines.append(f"# TYPE {name} {kind}")
        return name

    if metrics is not None:
        for raw, v in sorted(metrics.counters.items()):
            base = _metric_name(f"{prefix}_{raw}")
            base = base[:-6] if base.endswith("_total") else base
            if family(base, "counter"):
                lines.append(f"{base}_total {_fmt(v)}")
        gauges = dict(metrics.gauges)
        for raw, fn in metrics._sources.items():
            gauges[raw] = fn()                  # pulled at scrape time
        for raw, v in sorted(gauges.items()):
            name = family(_metric_name(f"{prefix}_{raw}"), "gauge")
            if name:
                lines.append(f"{name} {_fmt(v)}")
        for raw in sorted(metrics.hists):
            name = family(_metric_name(f"{prefix}_{raw}"), "summary")
            if not name:
                continue
            pct = metrics.percentiles(raw, qs=(50, 90, 99))
            for q in (50, 90, 99):
                if f"p{q}" in pct:
                    lines.append(f'{name}{{quantile="{q / 100}"}} '
                                 f"{_fmt(pct[f'p{q}'])}")
            lines.append(f"{name}_count {_fmt(pct['n'])}")
            if family(name + "_dropped", "counter"):
                lines.append(f"{name}_dropped_total "
                             f"{_fmt(pct['n_dropped'])}")
    if slo is not None:
        from repro.serve.obs.slo import STATES
        name = family(f"{prefix}_slo_state", "gauge")
        if name:
            lines.append(f"{name} {_fmt(STATES.index(slo.state))}")
        for obj, burn in sorted(slo.last_burns.items()):
            # same family the monitor pushes as a metrics gauge — when both
            # surfaces are scraped the declaration above wins
            name = family(_metric_name(f"{prefix}_burn_{obj}"), "gauge")
            if name:
                lines.append(f"{name} {_fmt(burn)}")
        rep = slo.report()
        for obj, st in sorted(rep["objectives"].items()):
            base = _metric_name(f"{prefix}_slo_{obj}_bad")
            if family(base, "counter"):
                lines.append(f"{base}_total {_fmt(st['bad'])}")
            base = _metric_name(f"{prefix}_slo_{obj}_events")
            if family(base, "counter"):
                lines.append(f"{base}_total {_fmt(st['good'] + st['bad'])}")
        name = family(f"{prefix}_slo_transitions", "counter")
        if name:
            lines.append(f"{name}_total {_fmt(len(rep['transitions']))}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def write_openmetrics(path: str, metrics=None, slo=None,
                      prefix: str = "repro",
                      require: list[str] | None = None) -> str:
    """Render + write; validated before it hits disk, like the trace.
    ``require`` names metric families the exposition must declare —
    the disaggregated gateway passes its per-role families so a scrape
    missing them fails here rather than in the dashboard."""
    text = openmetrics_text(metrics, slo, prefix)
    errs = validate_openmetrics(text, require=require)
    if errs:
        raise AssertionError("refusing to write invalid OpenMetrics: "
                             + "; ".join(errs[:5]))
    with open(path, "w") as f:
        f.write(text)
    return text


def validate_openmetrics(text, require: list[str] | None = None
                         ) -> list[str]:
    """Structural check of an OpenMetrics text exposition.  Verifies the
    ``# EOF`` terminator, comment/sample line grammar, metric-name
    charset, numeric sample values, that every sample's family was
    declared by a preceding ``# TYPE`` line, and that counter samples use
    the ``_total`` suffix.  ``require`` lists family names that must be
    declared (each missing one is a violation).  Returns the (possibly
    empty) violation list.
    """
    if not isinstance(text, str):
        return ["exposition is not a string"]
    errs: list[str] = []
    lines = text.split("\n")
    if lines and lines[-1] == "":
        lines = lines[:-1]
    else:
        errs.append("exposition must end with a newline")
    if not lines or lines[-1] != "# EOF":
        errs.append("missing '# EOF' terminator")
    types: dict[str, str] = {}
    for i, line in enumerate(lines):
        where = f"line {i + 1}"
        if line == "# EOF":
            if i != len(lines) - 1:
                errs.append(f"{where}: '# EOF' before end of exposition")
            continue
        if line.startswith("#"):
            parts = line.split(" ")
            if len(parts) >= 4 and parts[1] == "TYPE":
                name, kind = parts[2], parts[3]
                if not _NAME_OK.match(name):
                    errs.append(f"{where}: bad metric name {name!r}")
                if kind not in ("counter", "gauge", "summary", "histogram",
                                "info", "unknown"):
                    errs.append(f"{where}: unknown metric type {kind!r}")
                if name in types:
                    errs.append(f"{where}: duplicate family {name!r}")
                types[name] = kind
            elif len(parts) >= 3 and parts[1] in ("HELP", "UNIT"):
                pass
            else:
                errs.append(f"{where}: malformed comment {line!r}")
            continue
        m = _SAMPLE.match(line)
        if not m:
            errs.append(f"{where}: malformed sample {line!r}")
            continue
        name = m.group(1)
        base = name
        for suffix in ("_total", "_count", "_sum", "_bucket", "_created"):
            if name.endswith(suffix) and name[:-len(suffix)] in types:
                base = name[:-len(suffix)]
                break
        if base not in types:
            errs.append(f"{where}: sample {name!r} has no '# TYPE' family")
        elif types[base] == "counter" and not name.endswith(
                ("_total", "_created")):
            errs.append(f"{where}: counter sample {name!r} must end "
                        f"in '_total'")
    for name in require or ():
        if name not in types:
            errs.append(f"required family {name!r} not declared")
    return errs
