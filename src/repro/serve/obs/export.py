"""Exporters: Chrome trace-event JSON (Perfetto-loadable) + metrics JSONL.

The trace format is the Chrome trace-event *JSON object format*
(``{"traceEvents": [...]}``) with complete-duration events (``ph: "X"``),
instants (``"i"``), counters (``"C"``), and process-name metadata
(``"M"``) — the subset Perfetto's legacy-trace importer accepts, so
``chrome://tracing`` and https://ui.perfetto.dev open the file directly.
Timestamps convert from the tracer's sim-clock seconds to the format's
microseconds.

:func:`validate_chrome_trace` is the schema gate CI runs over exported
traces: structural errors (missing fields, bad phases, negative durations,
non-numeric timestamps) are returned as a list so the pipeline fails
loudly instead of shipping a trace Perfetto would silently drop events
from.
"""
from __future__ import annotations

import json

_VALID_PHASES = {"X", "i", "C", "M"}


def chrome_trace(tracer, metrics=None,
                 process_names: dict[int, str] | None = None) -> dict:
    """Assemble the Chrome trace-event object from a finished tracer
    (and, optionally, a metrics registry whose interval snapshots become
    counter tracks — occupancy curves right inside the trace UI)."""
    evs: list[dict] = []
    pids = set()
    for e in tracer.events:
        ev = {"name": e["name"], "ph": e["ph"], "pid": e["pid"],
              "tid": e["tid"], "ts": e["ts"] * 1e6, "args": e["args"]}
        if e["ph"] == "X":
            ev["dur"] = e["dur"] * 1e6
        if e["ph"] == "i":
            ev["s"] = e.get("s", "t")
        evs.append(ev)
        pids.add(e["pid"])
    if metrics is not None:
        for snap in metrics.samples:
            args = {k: v for k, v in snap.items() if k != "t"}
            if args:
                evs.append({"name": "metrics", "ph": "C", "pid": 1,
                            "tid": 0, "ts": snap["t"] * 1e6, "args": args})
                pids.add(1)
    names = {0: "requests", 1: "engine"}
    if process_names:
        names.update(process_names)
    for pid in sorted(pids):
        evs.append({"name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                    "ts": 0.0,
                    "args": {"name": names.get(pid, f"slice{pid - 1}")}})
    return {"traceEvents": evs, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, tracer, metrics=None,
                       process_names: dict[int, str] | None = None) -> dict:
    """Export + write; returns the trace object (already validated —
    writing an invalid trace is a bug, not an artifact)."""
    obj = chrome_trace(tracer, metrics, process_names)
    errs = validate_chrome_trace(obj)
    if errs:
        raise AssertionError("refusing to write invalid trace: "
                             + "; ".join(errs[:5]))
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


def validate_chrome_trace(obj) -> list[str]:
    """Structural schema check for the trace-event object format.
    Returns the (possibly empty) list of violations."""
    errs: list[str] = []
    if not isinstance(obj, dict):
        return ["trace is not a JSON object"]
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        return ["missing/invalid 'traceEvents' array"]
    if not evs:
        errs.append("empty traceEvents")
    for i, e in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            errs.append(f"{where}: not an object")
            continue
        for field in ("name", "ph", "pid", "tid", "ts"):
            if field not in e:
                errs.append(f"{where}: missing '{field}'")
        ph = e.get("ph")
        if ph not in _VALID_PHASES:
            errs.append(f"{where}: unknown phase {ph!r}")
        if not isinstance(e.get("ts"), (int, float)) or \
                isinstance(e.get("ts"), bool):
            errs.append(f"{where}: non-numeric ts {e.get('ts')!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or isinstance(dur, bool):
                errs.append(f"{where}: X event missing numeric dur")
            elif dur < 0:
                errs.append(f"{where}: negative dur {dur}")
        if ph == "C" and not isinstance(e.get("args"), dict):
            errs.append(f"{where}: counter event without args dict")
        if "args" in e and not isinstance(e["args"], dict):
            errs.append(f"{where}: args is not an object")
    return errs


def write_metrics_jsonl(path: str, registry) -> int:
    """One JSON line per interval snapshot (benchmarks/ consume this).
    Returns the number of lines written."""
    with open(path, "w") as f:
        for snap in registry.samples:
            f.write(json.dumps(snap) + "\n")
    return len(registry.samples)
