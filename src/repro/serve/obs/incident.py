"""Incident forensics: auto-captured, schema-validated debug bundles.

When the gateway degrades — the SLO engine crosses ``warn -> critical``, a
drop burst hits the admission door, the recompile detector sees a shape
leak, the energy ledger stops conserving, or an operator calls
``gateway.capture_incident(reason=...)`` — :class:`IncidentCapture`
snapshots everything a post-hoc debugger needs into one size-bounded JSON
**incident bundle**:

  flight        the :class:`~repro.serve.obs.flight.FlightRecorder` ring
                (recent spans/instants/counters/metric samples, with loss
                accounting), shrunk as needed to fit ``max_bytes``.
  slo           the burn-rate engine's full report: state, transition log,
                burn snapshot, per-objective totals, pressure events.
  state         the gateway's ``debug_state()``: resolved ServeSpec,
                pool/radix snapshots (stats, shared-chain summary,
                protected set), per-slice routing/handoff/cascade
                counters, jit-cache sizes, queue/slot occupancy.
  recompile     the detector's per-executable report, when one is armed.

Writes go through :func:`validate_incident_bundle` and **refuse on
invalid** — the same stance as the Chrome trace exporter: a malformed
bundle on disk is worse than a loud failure at capture time.

``python -m repro.serve.obs.incident inspect|diff|critpath <bundle>``
inspects a bundle without the live process (summary, two-bundle diff, or a
critical-path ranking over the captured spans — see
:mod:`repro.serve.obs.critpath`).
"""
from __future__ import annotations

import dataclasses
import json
import numbers
import pathlib
from collections import deque

from repro.serve.obs import critpath
from repro.serve.obs.export import _validate_event
from repro.serve.obs.flight import FlightRecorder
from repro.serve.obs.tracer import _bump

SCHEMA = "repro.incident.v1"

# automatic triggers (the explicit ``gateway.capture_incident(reason=...)``
# path may pass any other reason string)
TRIGGERS = ("slo_critical", "drop_burst", "recompile_leak",
            "energy_mismatch")


def _jsonify(obj):
    """Best-effort JSON coercion for bundle leaves: dataclasses (ServeSpec,
    PressureEvent), numpy scalars, sets, and anything else by repr."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return dataclasses.asdict(obj)
    if isinstance(obj, (set, frozenset)):
        return sorted(map(_jsonify, obj))
    if isinstance(obj, numbers.Integral):
        return int(obj)
    if isinstance(obj, numbers.Real):
        return float(obj)
    return repr(obj)


class IncidentCapture:
    """Trigger -> bundle pipeline.  Attach via ``ServeSpec(incident_dir=)``
    or construct directly and pass to a gateway (``incident=``).

    Parameters
    ----------
    out_dir:       bundle directory (created on first capture).
    flight:        FlightRecorder whose ring each bundle embeds.
    slo:           SLOMonitor; subscribing to its pressure signal arms the
                   ``warn -> critical`` trigger.  Because the signal fires
                   *synchronously inside* ``slo.evaluate`` — which the
                   serving loops run before the next admission pass — the
                   bundle exists before the first shed drop lands.
    detector:      RecompileDetector (snapshot taken); ``poll`` arms the
                   shape-leak trigger.
    drop_burst / drop_window_s:
                   >= drop_burst drops inside a drop_window_s sim-time
                   window trips the burst trigger.
    cooldown_s:    minimum sim time between *automatic* captures (explicit
                   captures always fire).
    max_bytes:     bundle size bound; the flight section is halved until
                   the serialized bundle fits.
    tag:           filename tag, for multiple capture pipelines sharing a
                   directory.
    """

    def __init__(self, out_dir: str = ".", *, flight: FlightRecorder | None
                 = None, slo=None, metrics=None, detector=None,
                 drop_burst: int = 8, drop_window_s: float = 0.25,
                 cooldown_s: float = 0.5, max_bytes: int = 256 * 1024,
                 tag: str = ""):
        self.out_dir = pathlib.Path(out_dir)
        self.flight = flight
        self.slo = slo
        self.metrics = metrics
        self.detector = detector
        self.drop_burst = drop_burst
        self.drop_window_s = drop_window_s
        self.cooldown_s = cooldown_s
        self.max_bytes = max_bytes
        self.tag = tag
        self.captures: list[dict] = []     # {"path", "reason", "t", "seq"}
        self.context_fn = None             # gateway.debug_state, when wired
        self._drops: deque = deque()
        self._recompiles_seen = 0
        self._last_auto_t: float | None = None
        self._t = 0.0                      # latest sim time observed
        if slo is not None:
            slo.pressure.subscribe(self._on_pressure)

    # -- triggers -----------------------------------------------------------

    def _on_pressure(self, event) -> None:
        self._t = max(self._t, event.t)
        if event.state == "critical":
            self._capture_auto("slo_critical", event.t,
                               extra={"from": event.prev,
                                      "objective": event.worst})

    def observe_drop(self, t: float) -> None:
        """One admission drop at sim time ``t`` (the serving loops call
        this next to ``Telemetry.drop``)."""
        _bump()
        self._t = max(self._t, t)
        self._drops.append(t)
        while self._drops and self._drops[0] < t - self.drop_window_s:
            self._drops.popleft()
        if len(self._drops) >= self.drop_burst:
            if self._capture_auto("drop_burst", t,
                                  extra={"drops_in_window":
                                         len(self._drops),
                                         "window_s": self.drop_window_s}):
                self._drops.clear()

    def poll(self, t: float) -> None:
        """Per-tick trigger check: recompile leaks (when a snapshot-armed
        detector is attached)."""
        _bump()
        self._t = max(self._t, t)
        if self.detector is None or self.detector._baseline is None:
            return
        cur = self.detector.steady_state_recompiles()
        if cur > self._recompiles_seen:
            leaked = self._capture_auto(
                "recompile_leak", t,
                extra={"recompiles": cur,
                       "by_fn": {k: v for k, v in
                                 self.detector.deltas().items() if v > 0}})
            if leaked:
                self._recompiles_seen = cur

    def check_energy(self, telemetry, t: float | None = None) -> bool:
        """End-of-run conservation check: a ledger that no longer folds to
        the fleet total captures an ``energy_mismatch`` bundle.  Returns
        True when conservation held."""
        _bump()
        try:
            telemetry.assert_conserved()
            return True
        except AssertionError as e:
            self._capture_auto("energy_mismatch",
                               self._t if t is None else t,
                               extra={"error": str(e)})
            return False

    # -- capture ------------------------------------------------------------

    def _capture_auto(self, reason: str, t: float, extra=None) -> bool:
        if self._last_auto_t is not None and \
                t < self._last_auto_t + self.cooldown_s:
            return False
        self.capture(reason, t=t, extra=extra)
        self._last_auto_t = t
        return True

    def capture(self, reason: str, *, t: float | None = None,
                extra=None) -> str:
        """Snapshot everything into a validated bundle file; returns its
        path.  Explicit captures bypass the cooldown."""
        _bump()
        t = self._t if t is None else t
        bundle = {
            "schema": SCHEMA,
            "reason": reason,
            "t": t,
            "seq": len(self.captures),
            "trigger_detail": extra or {},
            "flight": self.flight.snapshot()
            if self.flight is not None else None,
            "slo": self.slo.report() if self.slo is not None else None,
            "state": self.context_fn() if self.context_fn is not None
            else {},
            "recompile": self.detector.report()
            if self.detector is not None
            and self.detector._baseline is not None else None,
        }
        self.out_dir.mkdir(parents=True, exist_ok=True)
        name = f"incident_{self.tag + '_' if self.tag else ''}" \
               f"{bundle['seq']:03d}_{reason}.json"
        path = self.out_dir / name
        write_incident_bundle(str(path), bundle,
                              max_bytes=self.max_bytes)
        self.captures.append({"path": str(path), "reason": reason,
                              "t": t, "seq": bundle["seq"]})
        return str(path)


# ==========================================================================
# Bundle schema + refuse-on-invalid writer (the Chrome-exporter stance).
# ==========================================================================

_TOP_FIELDS = {"schema": str, "reason": str, "t": numbers.Real,
               "seq": numbers.Integral, "trigger_detail": dict,
               "state": dict}
_FLIGHT_LISTS = ("spans", "instants", "counters", "meta", "samples")
_ACCT_PAIRS = (("spans_seen", "spans_kept"),
               ("instants_seen", "instants_kept"),
               ("counters_seen", "counters_kept"),
               ("samples_seen", "samples_kept"))


def validate_incident_bundle(bundle) -> list[str]:
    """Structural schema check; [] means valid.  Mirrors
    ``validate_chrome_trace``: every violation is named, and the writer
    refuses to put an invalid bundle on disk."""
    errs: list[str] = []
    if not isinstance(bundle, dict):
        return ["bundle: not an object"]
    for name, typ in _TOP_FIELDS.items():
        if name not in bundle:
            errs.append(f"bundle: missing field '{name}'")
        elif not isinstance(bundle[name], typ) or \
                isinstance(bundle[name], bool):
            errs.append(f"bundle: field '{name}' is "
                        f"{type(bundle[name]).__name__}")
    if "schema" in bundle and bundle["schema"] != SCHEMA:
        errs.append(f"bundle: schema {bundle.get('schema')!r} != {SCHEMA!r}")
    if not bundle.get("reason"):
        errs.append("bundle: empty reason")
    for key in ("flight", "slo", "recompile"):
        if key not in bundle:
            errs.append(f"bundle: missing field '{key}' (may be null)")
    fl = bundle.get("flight")
    if fl is not None:
        if not isinstance(fl, dict):
            errs.append("flight: not an object")
        else:
            for key in _FLIGHT_LISTS:
                if not isinstance(fl.get(key), list):
                    errs.append(f"flight: '{key}' missing or not a list")
            acct = fl.get("accounting")
            if not isinstance(acct, dict):
                errs.append("flight: missing accounting")
            else:
                for seen, kept in _ACCT_PAIRS:
                    if not isinstance(acct.get(seen), numbers.Integral) or \
                            not isinstance(acct.get(kept),
                                           numbers.Integral):
                        errs.append(f"flight: accounting {seen}/{kept} "
                                    f"missing or non-integral")
                    elif acct[seen] < acct[kept]:
                        errs.append(f"flight: accounting {seen} "
                                    f"({acct[seen]}) < {kept} "
                                    f"({acct[kept]})")
            for stream in ("spans", "instants", "counters"):
                for i, e in enumerate(fl.get(stream) or []):
                    errs += _validate_event(e, f"flight.{stream}[{i}]")
    slo = bundle.get("slo")
    if slo is not None:
        if not isinstance(slo, dict) or "state" not in slo \
                or "transitions" not in slo:
            errs.append("slo: missing state/transitions")
    return errs


def write_incident_bundle(path: str, bundle: dict, *,
                          max_bytes: int | None = None) -> int:
    """Validate, size-bound (shrinking the flight section), and write.
    Raises ``ValueError`` on an invalid bundle — never writes one.
    Returns the byte size written."""
    errs = validate_incident_bundle(bundle)
    if errs:
        raise ValueError(
            f"refusing to write invalid incident bundle {path}: "
            + "; ".join(errs[:5]))
    text = json.dumps(bundle, indent=1, default=_jsonify)
    if max_bytes is not None:
        while len(text) > max_bytes and bundle.get("flight") is not None:
            fl = bundle["flight"]
            shrunk = FlightRecorder.shrink(fl)
            if sum(len(shrunk[k]) for k in _FLIGHT_LISTS) == \
                    sum(len(fl[k]) for k in _FLIGHT_LISTS):
                # nothing left to halve: drop the ring, keep accounting
                shrunk = {"accounting": fl["accounting"],
                          "config": fl.get("config", {}),
                          **{k: [] for k in _FLIGHT_LISTS}}
                bundle = {**bundle, "flight": shrunk}
                text = json.dumps(bundle, indent=1, default=_jsonify)
                break
            bundle = {**bundle, "flight": shrunk}
            text = json.dumps(bundle, indent=1, default=_jsonify)
        if len(text) > max_bytes:
            raise ValueError(
                f"incident bundle {path} cannot fit max_bytes="
                f"{max_bytes} even with an empty flight ring "
                f"({len(text)} bytes)")
    errs = validate_incident_bundle(json.loads(text))
    if errs:
        raise ValueError(
            f"refusing to write invalid incident bundle {path}: "
            + "; ".join(errs[:5]))
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def load_incident_bundle(path: str) -> dict:
    """Read + validate a bundle; raises ``ValueError`` (with the schema
    errors, or the JSON parse failure for a truncated file) on anything
    invalid."""
    try:
        with open(path) as f:
            bundle = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise ValueError(f"{path}: unreadable incident bundle ({e})")
    errs = validate_incident_bundle(bundle)
    if errs:
        raise ValueError(f"{path}: invalid incident bundle: "
                         + "; ".join(errs[:8]))
    return bundle


# ==========================================================================
# CLI inspector: debug a bundle without the live process.
# ==========================================================================

def _fmt_acct(acct: dict) -> str:
    return ", ".join(f"{seen.split('_')[0]} {acct[kept]}/{acct[seen]}"
                     for seen, kept in _ACCT_PAIRS)


def _inspect(bundle: dict) -> None:
    print(f"incident: reason={bundle['reason']}  t={bundle['t']:.3f}s  "
          f"seq={bundle['seq']}  schema={bundle['schema']}")
    if bundle.get("trigger_detail"):
        print(f"  trigger: {bundle['trigger_detail']}")
    fl = bundle.get("flight")
    if fl:
        print(f"  flight: {_fmt_acct(fl['accounting'])} (kept/seen)")
        for e in fl["instants"][-5:]:
            print(f"    instant t={e['ts']:.4f} {e['name']} "
                  f"{e.get('args', {})}")
    slo = bundle.get("slo")
    if slo:
        burns = "  ".join(f"burn_{k}={v:.2f}"
                          for k, v in sorted(slo.get("burns", {}).items()))
        print(f"  slo: state={slo['state']}  "
              f"transitions={len(slo['transitions'])}  {burns}")
        for tr in slo["transitions"]:
            print(f"    t={tr['t']:.3f}s {tr['from']} -> {tr['to']} "
                  f"(worst: {tr['objective']})")
    rc = bundle.get("recompile")
    if rc:
        print(f"  recompile: {rc['steady_state_recompiles']} steady-state "
              f"over {rc['tracked_executables']} executables"
              + (f"  leaks={rc['recompiles_by_fn']}"
                 if rc.get("recompiles_by_fn") else ""))
    state = bundle.get("state") or {}
    for key in sorted(state):
        v = state[key]
        if isinstance(v, dict):
            flat = {k: v[k] for k in sorted(v)
                    if isinstance(v[k], (int, float, str, bool))}
            print(f"  state.{key}: {flat}" if flat
                  else f"  state.{key}: [{len(v)} entries]")
        else:
            print(f"  state.{key}: {v}")


def _num_leaves(obj, prefix="") -> dict[str, float]:
    out: dict[str, float] = {}
    if isinstance(obj, dict):
        for k in obj:
            out.update(_num_leaves(obj[k], f"{prefix}.{k}" if prefix
                                   else str(k)))
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        out[prefix] = float(obj)
    return out


def _diff(a: dict, b: dict) -> None:
    print(f"A: reason={a['reason']} t={a['t']:.3f}s   "
          f"B: reason={b['reason']} t={b['t']:.3f}s")
    sa = (a.get("slo") or {}).get("state")
    sb = (b.get("slo") or {}).get("state")
    if sa != sb:
        print(f"  slo.state: {sa} -> {sb}")
    la = _num_leaves({"state": a.get("state"),
                      "flight": (a.get("flight") or {}).get("accounting")})
    lb = _num_leaves({"state": b.get("state"),
                      "flight": (b.get("flight") or {}).get("accounting")})
    changed = sorted(k for k in la.keys() | lb.keys()
                     if la.get(k) != lb.get(k))
    for k in changed:
        print(f"  {k}: {la.get(k)} -> {lb.get(k)}")
    if not changed and sa == sb:
        print("  no numeric differences")


def _critpath(bundle: dict) -> None:
    fl = bundle.get("flight") or {}
    cps = critpath.analyze(fl.get("spans") or [])
    roles = bool((bundle.get("state") or {}).get("roles"))
    agg = critpath.aggregate(cps, roles=roles)
    print(f"critical path over {agg['requests']} captured request(s) "
          f"(exact re-fold: {agg['exact']})")
    for stage in agg.get("ranking", []):
        rec = agg["stages"][stage]
        print(f"  {stage:14s} {rec['share']:6.1%}  "
              f"{rec['total_s'] * 1e3:9.3f} ms  "
              f"dominates {rec['requests_dominated']} request(s)")
    if agg["requests"]:
        print(f"  p{int(agg['p'] * 100)} tail ({agg['p_dur'] * 1e3:.3f} ms)"
              f" dominated by: {agg['p_dominant']}")
    for role, rec in sorted(agg.get("by_role", {}).items()):
        print(f"  role {role:9s} {rec['share']:6.1%}  "
              f"stages: {', '.join(rec['stages'])}")


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve.obs.incident",
        description="Inspect incident bundles without the live process.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p_ins = sub.add_parser("inspect", help="summarize one bundle")
    p_ins.add_argument("bundle")
    p_diff = sub.add_parser("diff", help="numeric diff of two bundles")
    p_diff.add_argument("bundle_a")
    p_diff.add_argument("bundle_b")
    p_cp = sub.add_parser("critpath",
                          help="critical-path ranking over captured spans")
    p_cp.add_argument("bundle")
    args = ap.parse_args(argv)
    try:
        if args.cmd == "inspect":
            _inspect(load_incident_bundle(args.bundle))
        elif args.cmd == "diff":
            _diff(load_incident_bundle(args.bundle_a),
                  load_incident_bundle(args.bundle_b))
        else:
            _critpath(load_incident_bundle(args.bundle))
    except ValueError as e:
        print(f"ERROR: {e}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
