"""Per-stage cost/roofline attribution from XLA cost analysis + spans.

Joins two sources the stack already exposes:

  static   every serving component's ``cost_args()`` registry — the same
           jitted entry points ``jit_fns()`` tracks for recompiles, paired
           with representative steady-state-shaped arguments — lowered
           through ``fn.lower(*args).compile().cost_analysis()`` for the
           executable's FLOPs and bytes accessed;
  dynamic  the tracer's measured span durations for the stage's serving
           span (decode ``tick``, ``prefill_chunk`` folds, frame ``batch``
           steps, ``migrate`` copies).

Per stage the attributor reports arithmetic intensity (FLOPs/byte),
achieved FLOP/s and B/s over the measured spans, and a roofline verdict:
**compute-bound** when intensity clears the ridge point, **memory-bound**
below it.  The default ridge (:data:`DEFAULT_RIDGE`) sits between the two
regimes this stack actually exhibits — the in-place paged decode tick
streams the whole live KV arena for a (1-token × batch) matmul and lands
well under it; the chunked-prefill fold amortizes the weight traffic over
a full block of tokens and lands well over it.  That verdict is exactly
the classification the disaggregated prefill/decode split wants, and the
known hard axis for SC datapaths, where stream length multiplies both
terms at once.

Cost analysis is best-effort by contract: under ``REPRO_KERNELS_INTERPRET``
or non-XLA backends, ``cost_analysis()`` may be empty, partial, or raise.
:func:`analyze` returns what it can and the attributor degrades per stage —
``source`` is ``"xla"`` (both terms), ``"bytes-only"`` (no FLOP count;
verdict from traffic alone), or ``"measured-only"`` (no analysis at all;
span timings still attributed, verdict ``"unknown"``) — never an obs-path
crash.

The energy cross-check (:func:`stage_energy`) re-folds the request spans'
``energy_parts`` into per-stage nJ totals; the grand total reproduces the
telemetry ledger's conserved ``fleet_energy_nj`` bitwise, because the span
stream carries the ledger's own addends in fold order.
"""
from __future__ import annotations

import math

from repro.serve.obs.tracer import _bump

# roofline ridge point (FLOPs/byte) separating this stack's two regimes:
# bench-config in-place decode ticks measure ~0.36 F/B, chunked prefill
# folds ~1.0+ F/B, so 0.6 classifies both with ~1.7x margin
DEFAULT_RIDGE = 0.6

# stage base name -> the traced serving span whose measured durations the
# stage's cost attributes over (stages without one are static-only)
STAGE_SPANS = (
    ("decode", "tick"),
    ("chunk_fold", "prefill_chunk"),
    ("prefill", "prefill"),
    ("copy", "migrate"),
    ("sensor", "batch"),
    ("gateway", "batch"),
)


def span_for(stage: str) -> str | None:
    """Serving span name for a ``cost_args()`` stage key (slice prefixes
    ``sliceN.`` and bucket suffixes ``_b8`` stripped)."""
    base = stage.rsplit(".", 1)[-1]
    for key, span in STAGE_SPANS:
        if base == key or base.startswith(key + "_"):
            return span
    return None


def analyze(fn, args) -> dict | None:
    """FLOPs + bytes accessed for one jitted entry point via AOT lowering,
    or None when the backend offers no analysis (interpret mode, non-XLA
    paths) — callers degrade, they never crash.

    Normalizes the per-version shape drift: ``cost_analysis()`` returns a
    dict on newer jax, a one-element list of dicts on older, and empty /
    None / key-less dicts where the backend has nothing to say.
    """
    try:
        ca = fn.lower(*args).compile().cost_analysis()
    except Exception:
        return None
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    if not isinstance(ca, dict):
        return None
    flops = float(ca.get("flops", 0.0) or 0.0)
    nbytes = float(ca.get("bytes accessed", 0.0) or 0.0)
    if flops <= 0.0 and nbytes <= 0.0:
        return None
    return {"flops": flops, "bytes": nbytes}


def attribute(stages: dict, tracer=None, *, ridge: float = DEFAULT_RIDGE,
              telemetry=None) -> dict:
    """Roofline-attribute every stage of a ``cost_args()`` registry.

    ``stages`` maps stage name -> ``(jitted_fn, args)``.  Returns
    ``{"stages": {name: entry}, "ridge_flops_per_byte": ...,
    "energy": ...}`` where each entry carries the static cost (per call),
    the measured span aggregate (count, seconds), the achieved rates, and
    the verdict + its provenance (``source``).  With a ``telemetry``
    ledger attached, the per-stage energy re-fold rides along.
    """
    _bump()
    out: dict = {"ridge_flops_per_byte": ridge, "stages": {}}
    for name, (fn, args) in stages.items():
        cost = analyze(fn, args)
        span = span_for(name)
        spans = tracer.spans(span) if tracer is not None and span else []
        calls = len(spans)
        measured_s = math.fsum(s["dur"] for s in spans)
        entry = {"span": span, "calls": calls, "measured_s": measured_s}
        if cost is None:
            entry.update(source="measured-only", flops=None, bytes=None,
                         intensity=None, verdict="unknown")
        else:
            flops, nbytes = cost["flops"], cost["bytes"]
            if flops > 0.0 and nbytes > 0.0:
                intensity = flops / nbytes
                entry.update(source="xla", intensity=intensity,
                             verdict="compute-bound" if intensity >= ridge
                             else "memory-bound")
            else:
                # a byte count with no FLOP count still classifies: pure
                # traffic sits at intensity 0, under any ridge
                entry.update(source="bytes-only", intensity=0.0,
                             verdict="memory-bound")
            entry.update(flops=flops, bytes=nbytes)
            if measured_s > 0.0:
                entry["achieved_flops_per_s"] = flops * calls / measured_s
                entry["achieved_bytes_per_s"] = nbytes * calls / measured_s
        out["stages"][name] = entry
    if telemetry is not None and tracer is not None:
        out["energy"] = stage_energy(tracer, telemetry)
    return out


def stage_energy(tracer, telemetry=None) -> dict:
    """Per-stage nJ re-fold of the span stream's ``energy_parts``.

    Stage totals (``fsum`` per part key) answer "where did the energy
    go"; ``total_nj`` left-folds each request's parts in ledger order, so
    when a ``telemetry`` ledger is passed, ``conserved`` asserts the
    cross-check **bitwise** against ``fleet_energy_nj`` — per-stage
    attribution that doesn't re-fold to the conserved ledger means a path
    charged energy the ledger never saw.
    """
    _bump()
    parts_all: dict[str, list[float]] = {}
    total = 0.0
    n = 0
    for e in tracer.events:             # append order == ledger record order
        if e["ph"] != "X" or e["name"] != "request":
            continue
        parts = e["args"].get("energy_parts") or {}
        span_e = 0.0
        for k, v in parts.items():      # ledger fold order per request
            parts_all.setdefault(k, []).append(v)
            span_e += v
        total += span_e
        n += 1
    out = {"stages_nj": {k: math.fsum(v) for k, v in parts_all.items()},
           "total_nj": total, "n_requests": n}
    if telemetry is not None:
        out["fleet_energy_nj"] = telemetry.fleet_energy_nj
        out["conserved"] = total == telemetry.fleet_energy_nj
    return out
