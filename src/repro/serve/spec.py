"""Declarative gateway construction: one ``ServeSpec``, one factory.

Eight PRs of growth accreted gateway assembly into call-site folklore:
every example/bench hand-chained ``make_adapter`` -> ``ContinuousBatcher``
-> ``PromptGateway`` (or ``build_slices`` -> ``ShardedPromptGateway``),
each spelling the paged/chunked/backend/mesh/roles/obs knobs a little
differently.  ``ServeSpec`` names that configuration once as a frozen
dataclass and ``make_gateway`` is the single constructor: it validates the
knob combinations that used to fail deep inside the stack (or not at
all), then builds the colocated, sharded, or disaggregated gateway the
spec describes.  docs/serving.md has the migration notes.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Everything that shapes a serving gateway, in one value.

    Slot/cache geometry: ``n_slots`` decode lanes of ``max_len`` tokens;
    ``paged`` swaps dense per-slot KV for the block-pool adapter
    (``block_size`` tokens/block, ``num_blocks`` total — None sizes the
    pool dense-equivalent); ``chunked`` prefills through the block-size
    chunk fold so prefix hits skip recompute.

    ``backend`` picks the decode tick's attention dataflow
    ("gather" | "xla" | "pallas" | "cascade"; None probes the platform —
    see ``serve.backend``); paged only.

    Topology: ``mesh`` (a serving mesh or explicit sub-mesh list) builds
    the sharded gateway, one slice per sub-mesh; ``roles`` (a
    ``shard.RolePlan``) partitions those slices into prefill/decode for
    disaggregated serving.  Both paged-only; both None = the single-
    adapter colocated gateway.

    Scheduling/SLO: ``max_new_tokens``, ``bytes_per_token``,
    ``max_queue``, ``shed_factor`` and the observability attachments
    (``tracer``/``metrics``/``slo``, all optional) pass straight through
    to the gateway; ``energy_spec`` prices tokens for the energy ledger.

    Forensics: ``flight`` attaches an always-on bounded flight recorder
    (``True`` builds a default ``obs.FlightRecorder``; or pass one
    explicitly); ``incident_dir`` arms auto-capture — an
    ``obs.IncidentCapture`` wired to ``slo``/``flight`` writes
    schema-validated debug bundles into that directory on SLO
    warn->critical, drop bursts, recompile leaks, energy-conservation
    breaks, or ``gateway.capture_incident(reason)``.
    """
    n_slots: int = 4
    max_len: int = 128
    paged: bool = False
    block_size: int = 16
    num_blocks: int | None = None
    chunked: bool = True
    backend: str | None = None
    mesh: object | None = None
    roles: object | None = None
    max_new_tokens: int = 16
    bytes_per_token: int = 4
    max_queue: int = 64
    energy_spec: object | None = None
    tracer: object = None
    metrics: object = None
    slo: object = None
    shed_factor: int = 4
    auto_rebalance: bool = True
    flight: object = None
    incident_dir: str | None = None

    def replace(self, **kw) -> "ServeSpec":
        return dataclasses.replace(self, **kw)


def make_gateway(cfg, params, spec: ServeSpec | None = None, *,
                 extras=None, **overrides):
    """Build the gateway ``spec`` describes (plus field ``overrides``).

    Returns a ``PromptGateway`` (colocated: one adapter, one batcher), or
    a ``ShardedPromptGateway`` when ``spec.mesh`` is set (one slice per
    sub-mesh; ``spec.roles`` further disaggregates them into
    prefill/decode).  ``extras`` is the per-family modality-stub callable
    ``make_adapter`` already takes (encdec/vlm prefill inputs).

    Knob validation happens here, before any arena is allocated:
    ``backend``/``mesh``/``roles`` are paged-tick concepts and require
    ``paged=True`` (and a non-rwkv family); ``roles`` requires ``mesh``.
    """
    from repro.serve.gateway.gateway import PromptGateway
    from repro.serve.gateway.slots import ContinuousBatcher, make_adapter

    spec = spec or ServeSpec()
    if overrides:
        spec = spec.replace(**overrides)
    paged = spec.paged and cfg.family != "rwkv"
    if spec.backend is not None and not paged:
        raise ValueError(
            f"backend={spec.backend!r} selects the paged decode tick's "
            f"dataflow; it requires paged=True and a non-rwkv family "
            f"(got paged={spec.paged}, family={cfg.family})")
    if spec.roles is not None and spec.mesh is None:
        raise ValueError("roles (disaggregated serving) partitions mesh "
                         "slices; set mesh as well")
    # forensics attachments: flight=True builds the default bounded ring;
    # incident_dir arms the auto-capture pipeline against slo + flight
    # (the gateway constructor hangs its debug_state off context_fn)
    flight = spec.flight
    if flight is True:
        from repro.serve.obs import FlightRecorder
        flight = FlightRecorder()
    incident = None
    if spec.incident_dir is not None:
        from repro.serve.obs import IncidentCapture
        incident = IncidentCapture(spec.incident_dir, flight=flight,
                                   slo=spec.slo, metrics=spec.metrics)
    if spec.mesh is not None:
        if not paged:
            raise ValueError("mesh (sharded serving) requires paged=True "
                             f"and a non-rwkv family (got "
                             f"paged={spec.paged}, family={cfg.family})")
        from repro.serve.shard.router import (ShardedPromptGateway,
                                              build_slices)
        slices = build_slices(
            cfg, params, spec.mesh, n_slots=spec.n_slots,
            max_len=spec.max_len, block_size=spec.block_size,
            num_blocks=spec.num_blocks, extras=extras,
            chunked=spec.chunked, backend=spec.backend)
        return ShardedPromptGateway(
            slices, max_new_tokens=spec.max_new_tokens,
            bytes_per_token=spec.bytes_per_token, max_queue=spec.max_queue,
            energy_spec=spec.energy_spec,
            auto_rebalance=spec.auto_rebalance, roles=spec.roles,
            tracer=spec.tracer, metrics=spec.metrics, slo=spec.slo,
            shed_factor=spec.shed_factor, flight=flight, incident=incident)
    adapter = make_adapter(
        cfg, params, n_slots=spec.n_slots, max_len=spec.max_len,
        extras=extras, paged=paged, block_size=spec.block_size,
        num_blocks=spec.num_blocks, chunked=spec.chunked,
        backend=spec.backend)
    return PromptGateway(
        ContinuousBatcher(adapter), max_new_tokens=spec.max_new_tokens,
        bytes_per_token=spec.bytes_per_token, max_queue=spec.max_queue,
        energy_spec=spec.energy_spec, tracer=spec.tracer,
        metrics=spec.metrics, slo=spec.slo, shed_factor=spec.shed_factor,
        flight=flight, incident=incident)
