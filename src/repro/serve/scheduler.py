"""Continuous batching: a slot-based request scheduler over the decode
engine (vLLM-style admission, without leaving decode idle while prompts
queue).

The reference adapter targets the RWKV family, where a request's entire
context is an O(1) state pytree — slot admission is a single state insert
and there are no per-slot position/length alignment concerns (one of the
operational payoffs of state-space serving that the long_500k cells
exercise).  Attention-cache adapters additionally need per-slot lengths
threaded through `attend_decode` (left as the documented extension).

Flow per step():
  1. admit: for each free slot, pop a pending request, prefill it (B=1) and
     scatter its state into the batched slot arrays;
  2. decode: one batched decode_step over all slots;
  3. retire: slots whose request hit max_new_tokens (or EOS) free up.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.serve import engine


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    generated: list = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.generated and \
                self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens


class RwkvContinuousBatcher:
    """Continuous batching for the rwkv family (state-slot engine)."""

    def __init__(self, cfg: lm.LMConfig, params, n_slots: int = 4):
        assert cfg.family == "rwkv"
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.pending: deque[Request] = deque()
        self.active: list[Request | None] = [None] * n_slots
        self.state = engine.init_cache(cfg, n_slots, 1)   # batched slots
        self.last_token = jnp.zeros((n_slots, 1), jnp.int32)
        self._prefill = jax.jit(lambda p, b: engine.prefill(cfg, p, b))
        self._decode = jax.jit(
            lambda p, c, t: engine.decode_step(cfg, p, c, t))

    def submit(self, req: Request):
        self.pending.append(req)

    # -- internal ----------------------------------------------------------
    def _insert_slot(self, slot: int, req: Request):
        cache1, logits = self._prefill(
            self.params, {"tokens": jnp.asarray(req.prompt[None])})
        for key in ("wkv", "shift1", "shift2"):
            self.state[key] = self.state[key].at[:, slot].set(
                cache1[key][:, 0])
        tok = int(jnp.argmax(logits[0]))
        req.generated.append(tok)
        self.last_token = self.last_token.at[slot, 0].set(tok)
        self.active[slot] = req

    def step(self) -> list[Request]:
        """Admit + one decode tick.  Returns requests completed this tick."""
        for slot in range(self.n_slots):
            if self.active[slot] is None and self.pending:
                self._insert_slot(slot, self.pending.popleft())
        if not any(r is not None for r in self.active):
            return []
        new_cache, logits = self._decode(self.params, self.state,
                                         self.last_token)
        for key in ("wkv", "shift1", "shift2"):
            self.state[key] = new_cache[key]
        toks = np.asarray(jnp.argmax(logits, -1))
        finished = []
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(toks[slot])
            req.generated.append(tok)
            self.last_token = self.last_token.at[slot, 0].set(tok)
            if req.done:
                finished.append(req)
                self.active[slot] = None   # slot freed; state overwritten
                                           # on next admission
        return finished

    def run(self) -> list[Request]:
        """Drain the queue; returns all completed requests."""
        done: list[Request] = []
        while self.pending or any(r is not None for r in self.active):
            done.extend(self.step())
        return done
