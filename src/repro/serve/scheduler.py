"""Continuous batching front (compatibility module).

The scheduler now lives in :mod:`repro.serve.gateway.slots` as a
family-generic loop over slot adapters: state-slot for the RWKV family
(O(1) state, single scatter on admission) and per-slot-length KV slots for
the attention families (decoder/moe/hybrid/encdec) via a vmapped
``engine.decode_step``.  The rwkv-only restriction this module used to
carry — and its "attention adapters left as the documented extension"
note — is gone; ``RwkvContinuousBatcher`` remains as the established
entry point for the rwkv family.

Retired slots are masked: decode-state writes for freed slots are
suppressed and the adapter clears the slot (zeroed state for rwkv,
length-0 for KV caches), so a slot no longer keeps decoding stale context
between retirement and the next admission, and EOS is honored even when
the prefill-produced token is already the EOS token.
"""
from __future__ import annotations

from repro.models import lm
from repro.serve.gateway.slots import (ContinuousBatcher, KVSlotAdapter,
                                       Request, StateSlotAdapter,
                                       make_adapter)
from repro.serve.kvcache import BlockPool, PagedKVSlotAdapter

__all__ = ["BlockPool", "ContinuousBatcher", "KVSlotAdapter",
           "PagedKVSlotAdapter", "Request", "RwkvContinuousBatcher",
           "StateSlotAdapter", "make_adapter"]


class RwkvContinuousBatcher(ContinuousBatcher):
    """Continuous batching for the rwkv family (state-slot engine)."""

    def __init__(self, cfg: lm.LMConfig, params, n_slots: int = 4):
        assert cfg.family == "rwkv"
        super().__init__(StateSlotAdapter(cfg, params, n_slots))
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
