"""Paged KV cache: refcounted block pool + block-table slot adapter.

``BlockPool`` owns block identity (refcounts, radix prefix index, LRU
eviction); ``PagedKVSlotAdapter`` owns block contents (device arenas,
gather/scatter decode, copy-on-write) and plugs into the gateway's
``ContinuousBatcher`` next to the dense ``KVSlotAdapter`` it replaces.
See docs/kvcache.md.
"""
from repro.serve.kvcache.paged import PagedKVSlotAdapter
from repro.serve.kvcache.pool import (TRASH_BLOCK, BlockPool, PoolExhausted,
                                      chain_keys)

__all__ = ["BlockPool", "PagedKVSlotAdapter", "PoolExhausted", "TRASH_BLOCK",
           "chain_keys"]
