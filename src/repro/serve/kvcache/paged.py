"""Block-table-backed KV slots: the paged counterpart of ``KVSlotAdapter``.

Layout
    One preallocated device arena per sequence-axis cache key —
    ``arena[key]: (num_blocks,) + B=1 block shape`` from
    :func:`engine.init_paged_arena` — shared by every slot.  Each slot holds
    a block table (row of ``(n_slots, nb_max)`` int32) mapping logical block
    j to an arena block id; non-sequence state (rwkv-style taps, ssm/conv,
    encoder cross K/V, ``len``) stays densely slot-stacked exactly as in the
    dense adapter.

Decode tick (one jitted call, fixed shapes)
    The default tick is **in place**: :func:`engine.decode_step_paged`
    threads ``(tables, lens, arena)`` down into the attention layers, which
    read K/V straight out of the block arena (``attend_decode_paged`` in
    XLA, or the ``kernels/paged_attn.py`` scalar-prefetch kernel with
    ``kernel=True``) and write back exactly one row per layer — the new
    token's position.  No dense per-slot cache is ever materialized and no
    block is rescattered.  Inactive lanes write into the reserved trash
    block 0, so the call never changes shape.  Because every position a
    lane can read (< len) holds the same bits in both layouts and
    everything else is masked at NEG_INF before the softmax, in-place
    paged decode is *bitwise* identical to dense decode — pinned per
    family in tests/test_paged_decode.py.

    The PR 2 gather tick (gather each chain into the dense layout ->
    vmapped :func:`engine.decode_step` -> scatter one block back) is kept
    as ``inplace=False``: it is the parity oracle the in-place path is
    asserted bitwise against.  Since PR 8 the in-place tick covers every
    paged family — vlm's grouped cache (two leading layer axes) rides it
    too, so decode slices of the disaggregated mesh never need the gather
    path.  The int8 ``kv_quant`` layout rides the in-place tick as well:
    the new row is quantized post-RoPE and written as one int8 row + one
    f32 scale row per layer, and the attention read dequantizes the
    gathered view — bitwise against the gather-tick oracle.

Sharing / copy-on-write
    Admission walks the pool's radix index: full prompt blocks that match an
    earlier request's chain are referenced instead of written (their prefill
    values are discarded).  A trailing partial prompt block can be shared
    too when the whole chain plus the partial chunk matches; since decode
    extends partial blocks in place, every holder of a shared partial block
    carries a pre-allocated *spare* and copies into it before its first
    write (copy-on-write) — the sibling keeps the original, bit-for-bit.

Chunked prefill (prefix-hit compute skipping)
    Admission prefills a prompt as a *fold* of fixed block-size chunks
    through :func:`engine.prefill_chunked` — chunk j extends the KV prefix
    of j*bs positions by one block.  A radix prefix hit of H blocks gathers
    those blocks from the arena and resumes the fold at chunk H: the shared
    prompt's transformer work is skipped, not just its storage.  Chunk j's
    compiled graph has the same static shapes whether the fold started at
    0 or resumed at H, so a resumed prefill is *bitwise* identical to the
    cold one — same logits, same written blocks.  Hybrid (SSM) resumption
    additionally needs the recurrent state at the boundary; the fold
    snapshots it per indexed chain key (dropped when the pool unindexes the
    key), and falls back to an earlier boundary (or a cold fold) when the
    snapshot is gone.  ``chunked=False`` keeps the one-shot prefill path of
    PR 2 (share storage, recompute everything; lazy copy-on-write).

Admission control
    ``can_admit`` prices a request at its worst case,
    ``ceil((P + max_new) / bs)`` blocks minus full-prefix hits, and admits
    only when the pool's free + evictable supply covers it — the batcher
    queues the request otherwise instead of letting an allocation fail
    mid-flight.  The boundary (partial) block is priced once: under the
    chunked fold the slot recomputes it into its own spare (already inside
    ``n_total - hits``) without ever referencing the shared partial, while
    the legacy path additionally holds the shared partial (its LRU revival
    consumes supply) and may oblige existing holders to take copy-on-write
    spares — see ``_admission_demand``.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LMConfig
from repro.serve import engine
from repro.serve.backend import resolve_backend
from repro.serve.kvcache.pool import (TRASH_BLOCK, BlockPool, PoolExhausted)


def _pad_seq(a: jax.Array, target: int) -> jax.Array:
    """Zero-pad a cache array's sequence axis (-3) to ``target``."""
    pad = [(0, 0)] * a.ndim
    pad[-3] = (0, target - a.shape[-3])
    return jnp.pad(a, pad)


# Process-wide chunked-prefill fold executables, one per LMConfig (frozen,
# hashable).  jit buckets specialize per (q_offset, chunk/prefix shape) —
# the *same* fixed bucket set for every adapter of a config, so spinning up
# a second adapter (a second gateway slice, a test fixture, an A/B config)
# reuses the first one's compilations instead of re-tracing them all.
# tests/test_chunked_prefill.py asserts no steady-state recompiles across
# two adapters of one config.
_CHUNK_FOLDS: dict[LMConfig, Callable] = {}


def chunk_fold_fn(cfg: LMConfig) -> Callable:
    """The shared jitted ``engine.prefill_chunked`` step for ``cfg``."""
    fn = _CHUNK_FOLDS.get(cfg)
    if fn is None:
        fn = jax.jit(
            lambda p, batch, cache, q: engine.prefill_chunked(
                cfg, p, batch, cache, q),
            static_argnums=(3,))
        _CHUNK_FOLDS[cfg] = fn
    return fn


class PagedKVSlotAdapter:
    """Paged KV slots for the attention families (decoder/moe/hybrid/
    encdec/vlm).

    Drop-in for ``KVSlotAdapter`` in :class:`ContinuousBatcher` (same
    ``insert`` / ``decode`` / ``clear`` surface), plus the paging hooks the
    batcher discovers by presence: ``can_admit``, ``validate_request``,
    ``slot_stats``, ``pool_stats``.
    """

    def __init__(self, cfg: LMConfig, params, n_slots: int, max_len: int,
                 *, block_size: int = 16, num_blocks: int | None = None,
                 extras: Callable[[], dict] | None = None,
                 chunked: bool = True, inplace: bool | None = None,
                 kernel: bool | None = None, mesh=None,
                 backend: str | None = None):
        assert cfg.family != "rwkv", "rwkv has O(1) state; nothing to page"
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.bs = block_size
        self.nb_max = -(-max_len // block_size)
        self.max_len = self.nb_max * block_size
        self.extras = extras
        # chunked prefill needs the pre-quantization KV the int8 cache no
        # longer holds, and a family prefill_chunked implements
        self.chunked = (chunked and not cfg.kv_quant and cfg.family in
                        ("decoder", "moe", "hybrid", "encdec"))
        # one backend enum ("gather" | "xla" | "pallas" | "cascade", see
        # repro.serve.backend) replaces the inplace=/kernel= booleans,
        # which survive as deprecated aliases (warned once, here).  The
        # in-place tick covers every paged family — incl. the int8
        # kv_quant layout (quantized one-row write + dequantize-in-tick)
        # and, since PR 8, vlm's grouped cache; the PR 2 gather tick
        # stays available purely as the parity oracle.  The Pallas kernel
        # and the cascade grouping do NOT cover kv_quant or vlm: the
        # platform auto-selection quietly falls back to XLA there, but an
        # *explicit* backend choice is a contract ("forces the path") and
        # must fail loudly rather than measure the wrong one.
        explicit = backend is not None or kernel
        self.backend = resolve_backend(backend, inplace=inplace,
                                       kernel=kernel, warn=True)
        if self.backend in ("pallas", "cascade") and \
                (cfg.kv_quant or cfg.family == "vlm"):
            layout = "int8 kv_quant" if cfg.kv_quant else "vlm grouped"
            if explicit:
                raise ValueError(
                    f"backend={self.backend!r} does not support the "
                    f"{layout} layout; use backend=\"xla\"")
            self.backend = "xla"
        self.inplace = self.backend != "gather"
        self.kernel = self.backend == "pallas"
        if num_blocks is None:
            # dense-equivalent capacity + the reserved trash block
            num_blocks = n_slots * self.nb_max + 1
        self.pool = BlockPool(num_blocks, block_size)
        self.arena = engine.init_paged_arena(cfg, num_blocks, block_size)
        self.seq_keys = tuple(self.arena)
        self._bax = {key: engine.arena_block_axis(a)
                     for key, a in self.arena.items()}
        # hybrid: recurrent (conv/ssm) state at each indexed block boundary,
        # keyed by the boundary's chain key — what lets an SSM stream resume
        # mid-prompt; invalidated with the index entry itself.  Entries are
        # naturally bounded by the indexed-key count (<= pool capacity), and
        # an explicit LRU cap keeps the side cache's bytes proportional to
        # the arena budget even so (evicting one only costs a longer
        # re-fold); pool_stats reports the bytes it holds.
        self._boundary_states: "OrderedDict[bytes, dict]" = OrderedDict()
        self._max_boundary_states = self.pool.capacity
        self.pool.on_unindex = \
            lambda bid, key: self._boundary_states.pop(key, None)
        # compute-skip telemetry (prefill_tokens_* in pool_stats)
        self.prefill_tokens_total = 0
        self.prefill_tokens_skipped_total = 0
        # obs span recorder, wired by the prompt gateways for a run's
        # duration; every use is guarded so a bare adapter makes zero
        # obs calls.  The batcher points the tracer's lane context at the
        # admitting request before insert, so chunk spans land on it.
        self.tracer = None

        # densely slot-stacked non-sequence state (incl. the scalar "len")
        cache0 = engine.init_cache(cfg, 1, self.max_len)
        self.cache = {
            key: jnp.zeros((n_slots,) + np.shape(a), jnp.asarray(a).dtype)
            for key, a in cache0.items() if key not in self.arena}

        # host-side paging state
        self.tables = np.zeros((n_slots, self.nb_max), np.int32)
        self.lens = np.zeros(n_slots, np.int64)
        self.slot_bids: list[list[int]] = [[] for _ in range(n_slots)]
        self.cow_blk: list[int | None] = [None] * n_slots
        self.cow_spare: list[int | None] = [None] * n_slots
        self.partial_reg: list[tuple[int, int] | None] = [None] * n_slots
        self._stats: list[dict] = [{} for _ in range(n_slots)]
        # per-token arena bytes (for the bytes-saved-vs-dense telemetry)
        self._token_bytes = sum(
            a.dtype.itemsize * (int(np.prod(a.shape)) // num_blocks)
            // block_size for a in self.arena.values())
        # peak occupancy: a drained pool always reads 0 blocks in use, so
        # the memory-savings evidence is tracked at its high-water mark
        self.peak_blocks_in_use = 0
        self.peak_bytes_saved = 0

        # mesh-partitioned placement (serve/shard/): commit the arena to
        # the slice mesh with engine.arena_specs (KV heads over "model"
        # when divisible — the same rule cache_specs applies to the dense
        # layout) and replicate params + the slot-stacked state across the
        # slice's devices.  Every jit below then compiles *sharded* —
        # GSPMD partitions the tick/fold over the slice — while a
        # single-device slice runs the exact unsharded executable (the
        # bitwise-parity contract tests/test_sharded.py pins).
        self.mesh = mesh
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.dist.sharding import mesh_shape_dict
            specs = engine.arena_specs(cfg, mesh_shape_dict(mesh))
            self.arena = {
                key: jax.device_put(a, NamedSharding(mesh, specs[key]))
                for key, a in self.arena.items()}
            rep = NamedSharding(mesh, P())
            self.cache = {key: jax.device_put(a, rep)
                          for key, a in self.cache.items()}
            self.params = jax.device_put(params, rep)

        self._prefill = jax.jit(lambda p, b: engine.prefill(cfg, p, b))
        # the chunked-prefill fold: one step per prompt block.  jit
        # specializes per (q_offset, chunk/prefix shape) — a fixed bucket
        # set in the steady state, shared by cold and resumed folds (that
        # sharing is what makes a resume bitwise: same executable) and
        # shared *process-wide* across every adapter of this config
        # (chunk_fold_fn), so a second gateway slice pays zero retraces
        self._chunk_fn = chunk_fold_fn(cfg)
        self._gather_prefix = jax.jit(self._gather_prefix_impl)
        if cfg.family == "encdec":
            self._encode = jax.jit(lambda p, e: engine.encode_cross(cfg, p, e))
        # donate the arena (and dense cache) through every call that rebinds
        # it, so the .at[].set updates alias in place instead of holding a
        # second full arena copy — the whole point of the fixed byte budget.
        # CPU XLA cannot donate (it would only warn), so gate on backend.
        dn = jax.default_backend() != "cpu"
        self._scatter = jax.jit(self._scatter_impl,
                                donate_argnums=(0,) if dn else ())
        self._copy = jax.jit(self._copy_impl,
                             donate_argnums=(0,) if dn else ())
        self._write_block = jax.jit(self._write_block_impl,
                                    donate_argnums=(0,) if dn else ())
        tick = self._tick_inplace_impl if self.inplace else self._tick_impl
        self._decode = jax.jit(tick, donate_argnums=(1, 2) if dn else ())
        # the cascade tick sits NEXT TO the flat one, not instead of it:
        # a tick on which no chain is shared by >= 2 lanes degrades to
        # self._decode — the *same* executable, hence bitwise.  jit
        # specializes per metadata bucket shape (next-pow-2 padded group /
        # chain / lane / suffix counts), a fixed set in the steady state.
        if self.backend == "cascade":
            self._decode_cascade = jax.jit(
                self._tick_cascade_impl, donate_argnums=(1, 2) if dn else ())

    # -- jitted bodies ------------------------------------------------------

    def _scatter_impl(self, arena, padded, wbids):
        """Write a prompt's blocks: ``padded[key]`` is the B=1 cache padded
        to max_len; ``wbids[j]`` is the arena slot for logical block j (the
        trash block for shared/unused blocks, whose values are discarded)."""
        out = {}
        for key in self.seq_keys:
            a = padded[key]
            ax = a.ndim - 3                     # the sequence axis
            b = a.reshape(a.shape[:ax] + (self.nb_max, self.bs)
                          + a.shape[ax + 1:])
            b = jnp.moveaxis(b, ax, ax - 1)     # block axis just before B
            idx = (slice(None),) * (ax - 1) + (wbids,)
            out[key] = arena[key].at[idx].set(b)
        return out

    def _copy_impl(self, arena, dst, src):
        """Copy block ``src`` onto block ``dst`` for every key (CoW)."""
        out = {}
        for key, a in arena.items():
            ax = self._bax[key]
            idx = (slice(None),) * ax + (dst,)
            out[key] = a.at[idx].set(jnp.take(a, src, axis=ax))
        return out

    def _write_block_impl(self, arena, dst, contents):
        """Land externally-sourced block contents (cross-slice migration)
        at block id ``dst``: ``contents[key]`` is one block in the
        :meth:`arena_block` layout (the block axis squeezed out)."""
        out = dict(arena)
        for key, blk in contents.items():
            ax = self._bax[key]
            idx = (slice(None),) * ax + (dst,)
            out[key] = arena[key].at[idx].set(blk)
        return out

    def _gather_prefix_impl(self, arena, bids):
        """Gather an H-block chain into the dense prefix layout that
        :func:`engine.prefill_chunked` consumes: per sequence key,
        block axis ``bids`` -> ``(..., B, nb*bs, *post)`` (B=1 row)."""
        out = {}
        for key in self.seq_keys:
            g = jnp.take(arena[key], bids, axis=self._bax[key])
            g = jnp.moveaxis(g, self._bax[key], g.ndim - 4)  # behind B
            out[key] = g.reshape(g.shape[:g.ndim - 4]
                                 + (bids.shape[0] * self.bs,) + g.shape[-2:])
        return out

    # -- the chunked-prefill fold -------------------------------------------

    def _prefix_cache(self, n_blocks: int, bids=None, state=None):
        """Prefix cache for a fold starting at block ``n_blocks``: gathered
        arena blocks (or zero-length arrays for a cold fold), the hybrid
        boundary state, and the encdec cross K/V."""
        q0 = n_blocks * self.bs
        if n_blocks:
            cache = dict(self._gather_prefix(self.arena,
                                             jnp.asarray(bids, jnp.int32)))
        else:
            empty = engine.init_cache(self.cfg, 1, 0, abstract=True)
            cache = {key: jnp.zeros(empty[key].shape, empty[key].dtype)
                     for key in self.seq_keys if key in empty}
        cache["len"] = jnp.int32(q0)
        if self.cfg.family == "hybrid":
            if state is None:
                L = self.cfg.n_layers
                state = {
                    "conv": jnp.zeros((L, 1, self.cfg.conv_k - 1,
                                       self.cfg.inner), self.cfg.dtype),
                    "ssm": jnp.zeros((L, 1, self.cfg.inner,
                                      self.cfg.ssm_state), jnp.float32)}
            cache.update(state)
        if self.cfg.family == "encdec":
            batch = self.extras() if self.extras is not None else {}
            cache["xk"], cache["xv"] = self._encode(self.params,
                                                    batch["enc_embed"])
        return cache

    def _fold_prefill(self, prompt: np.ndarray, q0: int, cache,
                      keys: list[bytes]):
        """Run the chunk fold over ``prompt[q0:]``.  Returns (final cache,
        last-token logits, boundary-state snapshots to commit on success)."""
        P = len(prompt)
        n_full = P // self.bs
        snapshots: list[tuple[bytes, dict]] = []
        q, logits = q0, None
        while q < P:
            c = min(self.bs, P - q)
            batch = {"tokens": jnp.asarray(
                np.asarray(prompt[q:q + c], np.int32)[None])}
            if self.tracer is not None:
                self.tracer.begin("prefill_chunk")
            cache, logits = self._chunk_fn(self.params, batch, cache, q)
            if self.tracer is not None:
                self.tracer.end("prefill_chunk",
                                args={"q0": q, "tokens": c,
                                      "prefix_hit": False})
            q += c
            if (self.cfg.family == "hybrid" and q % self.bs == 0
                    and q // self.bs <= n_full):
                key = keys[q // self.bs - 1]
                if key not in self._boundary_states:
                    snapshots.append((key, {"conv": cache["conv"],
                                            "ssm": cache["ssm"]}))
        return cache, logits, snapshots

    def _tick_impl(self, p, arena, dense, tables, tokens, mask, wbids):
        """Legacy gather tick (PR 2; ``inplace=False``): gather -> vmapped
        decode_step -> scatter the written blocks.  Kept as the parity
        oracle for the in-place tick and as the fallback for the layouts it
        does not cover (vlm, kv_quant)."""
        cache = dict(dense)
        for key in self.seq_keys:
            ax = self._bax[key]
            g = jnp.take(arena[key], tables, axis=ax)
            g = jnp.moveaxis(g, ax, 0)          # slot lanes leading
            g = jnp.moveaxis(g, ax + 1, ax + 2)  # block axis behind B
            cache[key] = g.reshape(
                g.shape[:g.ndim - 4] + (self.nb_max * self.bs,)
                + g.shape[-2:])
        new_cache, logits = jax.vmap(
            lambda c, t: engine.decode_step(self.cfg, p, c, t),
            in_axes=(0, 0))(cache, tokens[:, None])
        sel = lambda new, old: jnp.where(
            mask.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)
        new_dense = {key: sel(new_cache[key], dense[key]) for key in dense}
        # each slot wrote exactly one position (pre-increment len), hence
        # exactly one block; inactive lanes target the trash block.  A lane
        # whose len is out of range (at capacity, or any host-side
        # accounting drift) is routed to the trash block HERE as well as in
        # decode(): the pre-fix clamp (min(start, max_len - bs)) silently
        # aliased such lanes onto the final — possibly *shared* — block.
        oor = dense["len"] >= self.max_len
        start = jnp.where(oor, 0, (dense["len"] // self.bs) * self.bs)
        wbids = jnp.where(oor, TRASH_BLOCK, wbids)
        new_arena = {}
        for key in self.seq_keys:
            ax = self._bax[key]
            blk = jax.vmap(
                lambda a, s: jax.lax.dynamic_slice_in_dim(
                    a, s, self.bs, axis=a.ndim - 3))(new_cache[key], start)
            blk = jnp.moveaxis(blk, 0, ax)
            idx = (slice(None),) * ax + (wbids,)
            new_arena[key] = arena[key].at[idx].set(blk)
        return new_arena, new_dense, logits[:, 0]

    def _tick_inplace_impl(self, p, arena, dense, tables, tokens, mask,
                           wbids):
        """The gather-free tick: :func:`engine.decode_step_paged` reads K/V
        through the block tables inside every attention layer and writes
        back one row per layer — no dense per-slot cache, no block
        rescatter.  Non-sequence state is masked exactly like the gather
        tick, so inactive lanes keep the state ``clear`` left them."""
        # same out-of-range defense as the gather tick: a lane whose len
        # escaped the table (at capacity / accounting drift) must write the
        # trash block, never a real — possibly shared — one
        wbids = jnp.where(dense["len"] >= self.max_len, TRASH_BLOCK, wbids)
        new_arena, new_cache, logits = engine.decode_step_paged(
            self.cfg, p, dense, tokens, tables=tables, lens=dense["len"],
            arena=arena, wbids=wbids,
            backend="pallas" if self.kernel else "xla")
        sel = lambda new, old: jnp.where(
            mask.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)
        new_dense = {key: sel(new_cache[key], dense[key]) for key in dense}
        return new_arena, new_dense, logits

    def _tick_cascade_impl(self, p, arena, dense, tables, tokens, mask,
                           wbids, cascade):
        """The in-place tick with shared-prefix cascade attention: every
        attention layer reads each shared radix chain *once per group*
        (multi-query pass, prefix KV gathered once), each divergent suffix
        per lane, and merges the partial softmax states by log-sum-exp
        (:func:`nn.attention.attend_decode_cascade`).  ``cascade`` is the
        host-built group metadata from :meth:`_cascade_meta`; the write
        epilogue is identical to the flat tick."""
        wbids = jnp.where(dense["len"] >= self.max_len, TRASH_BLOCK, wbids)
        new_arena, new_cache, logits = engine.decode_step_paged(
            self.cfg, p, dense, tokens, tables=tables, lens=dense["len"],
            arena=arena, wbids=wbids, backend="cascade", cascade=cascade)
        sel = lambda new, old: jnp.where(
            mask.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)
        new_dense = {key: sel(new_cache[key], dense[key]) for key in dense}
        return new_arena, new_dense, logits

    # -- admission ----------------------------------------------------------

    def _block_demand(self, prompt_len: int, max_new: int) -> int:
        return -(-(prompt_len + max_new) // self.bs)

    def validate_request(self, prompt_len: int, max_new: int) -> None:
        n_total = self._block_demand(prompt_len, max_new)
        if n_total > self.pool.capacity:
            raise ValueError(
                f"request needs {n_total} blocks worst-case; pool holds "
                f"{self.pool.capacity} (block_size={self.bs})")

    def _arming_demand(self, partial_hit: int | None) -> int:
        """Spares newly required by existing holders of a shared partial."""
        if partial_hit is None:
            return 0
        return sum(1 for s in range(self.n_slots)
                   if self.partial_reg[s]
                   and self.partial_reg[s][1] == partial_hit
                   and self.cow_spare[s] is None)

    def _admission_demand(self, prompt: np.ndarray, max_new: int) -> int:
        """Exact worst-case supply (free + evictable) an ``insert`` of this
        request consumes — asserted against the measured delta in
        tests/test_chunked_prefill.py.

        Every path: ``ceil((P + max_new)/bs)`` chain blocks minus full-
        prefix hits (referenced, not allocated), plus one unit per hit
        currently parked in the LRU (revival removes an evictable block
        without an allocation — ignoring it would overcommit exactly in the
        prefix-cache-warm steady state).

        The boundary (partial) block differs by path and must be priced
        once, not twice.  Chunked fold: the slot recomputes the boundary
        chunk into its own fresh block — already inside ``n_total - hits``
        — and never references the shared partial, so a partial hit adds
        nothing.  Legacy one-shot path: the shared partial is additionally
        held for the slot's lifetime (its LRU revival consumes supply) and
        newly-shared status obliges existing holders to take copy-on-write
        spares (``_arming_demand``).
        """
        pool = self.pool
        n_total = self._block_demand(len(prompt), max_new)
        hits, partial_hit, _, _ = pool.match_prefix(
            np.asarray(prompt, np.int32), count=False)
        revived = sum(1 for b in hits if pool.refcount[b] == 0)
        demand = n_total - len(hits) + revived
        if not self.chunked:
            if partial_hit is not None and pool.refcount[partial_hit] == 0:
                demand += 1
            demand += self._arming_demand(partial_hit)
        return demand

    def can_admit(self, prompt: np.ndarray, max_new: int) -> bool:
        """Worst-case block demand vs free + evictable supply; the batcher
        queues the request when it does not fit (never fails mid-flight)."""
        return self._admission_demand(prompt, max_new) <= \
            self.pool.available()

    # -- slot lifecycle ------------------------------------------------------

    def insert(self, slot: int, prompt: np.ndarray,
               max_new: int | None = None) -> int:
        P = len(prompt)
        if max_new is None:
            max_new = max(1, self.max_len - P)
        if P + max_new > self.max_len:
            raise ValueError(f"prompt {P} + {max_new} new tokens exceeds "
                             f"slot capacity {self.max_len}")
        prompt = np.asarray(prompt, np.int32)
        n_total = self._block_demand(P, max_new)
        n_full = P // self.bs
        hits, partial_hit, keys, pkey = self.pool.match_prefix(prompt)
        if self.chunked:
            return self._insert_chunked(slot, prompt, n_total, n_full,
                                        hits, partial_hit, keys, pkey)
        return self._insert_oneshot(slot, prompt, n_total, n_full,
                                    hits, partial_hit, keys, pkey)

    def _resume_blocks(self, P: int, hits: list[int],
                       keys: list[bytes]) -> int:
        """How many prefix blocks the fold can skip: the hit chain, capped
        so at least one prompt token remains (the fold must produce the
        last-token logits), and for hybrid capped at the deepest boundary
        whose recurrent-state snapshot is still cached."""
        H = len(hits)
        if self.cfg.family == "hybrid":
            while H > 0 and keys[H - 1] not in self._boundary_states:
                H -= 1
        while H > 0 and H * self.bs >= P:
            H -= 1
        return H

    def _insert_chunked(self, slot: int, prompt: np.ndarray, n_total: int,
                        n_full: int, hits, partial_hit, keys, pkey) -> int:
        """Chunk-fold admission: reference every full-block hit (storage
        sharing), resume the prefill fold past the deepest usable boundary
        (compute skipping), and recompute the trailing partial chunk into a
        private block — the shared partial is never referenced, so no
        copy-on-write arming and nothing to disarm on rollback."""
        P = len(prompt)
        pool = self.pool
        # take references on every hit before allocating (allocation may
        # evict from the LRU the hits are parked in); on exhaustion release
        # everything this insert took so a failed admission leaks nothing
        bids: list[int] = []
        fresh: list[tuple[int, bytes | None, int]] = []  # (blk_idx, key, bid)
        try:
            bids.extend(pool.acquire(b) for b in hits)
            for j in range(len(hits), n_full):
                b = pool.alloc()
                fresh.append((j, keys[j], b))
                bids.append(b)
            if n_full * self.bs < P:                   # partial prompt block
                b = pool.alloc()
                # register only when the chunk is not already indexed by a
                # sibling (first registration wins anyway); the block is
                # private either way — decode writes it in place
                fresh.append((n_full, None if partial_hit is not None
                              else pkey, b))
                bids.append(b)
            while len(bids) < n_total:                 # generation blocks
                bids.append(pool.alloc())
        except PoolExhausted:
            for b in bids:
                pool.release(b)
            raise

        H = self._resume_blocks(P, hits, keys)
        q0 = H * self.bs
        if H and self.tracer is not None:
            # the H prefix-hit chunks are *skipped*, not folded — mark the
            # resume point so the trace shows where compute was saved
            self.tracer.instant("prefix_resume",
                                args={"blocks": H, "tokens_skipped": q0,
                                      "prefix_hit": True})
        state = None
        if H and self.cfg.family == "hybrid":
            state = self._boundary_states[keys[H - 1]]
            self._boundary_states.move_to_end(keys[H - 1])   # LRU recency
        cache = self._prefix_cache(H, bids[:H] if H else None, state)
        cache, logits, snapshots = self._fold_prefill(prompt, q0, cache,
                                                      keys)
        cache = dict(cache)
        padded = {key: _pad_seq(cache.pop(key), self.max_len)
                  for key in self.seq_keys}
        wbids = np.zeros(self.nb_max, np.int32)
        for j, key, b in fresh:
            wbids[j] = b
        self.arena = self._scatter(self.arena, padded, jnp.asarray(wbids))
        # index only after the contents exist (a failed insert must never
        # leave a key pointing at an unwritten block)
        for j, key, b in fresh:
            if key is not None:
                pool.register(key, b, partial=j >= n_full)
                if j >= n_full:
                    self.partial_reg[slot] = (j, b)
        for key, st in snapshots:
            self._boundary_states.setdefault(key, st)
            self._boundary_states.move_to_end(key)
        while len(self._boundary_states) > self._max_boundary_states:
            self._boundary_states.popitem(last=False)
        for key in self.cache:
            if key == "len":
                continue
            self.cache[key] = self.cache[key].at[slot].set(cache[key])
        self.cache["len"] = self.cache["len"].at[slot].set(P)

        self.tables[slot, :] = TRASH_BLOCK
        self.tables[slot, :len(bids)] = bids
        self.lens[slot] = P
        self.slot_bids[slot] = bids
        self.prefill_tokens_total += P
        self.prefill_tokens_skipped_total += q0
        self._stats[slot] = {
            "kv_blocks": n_total,
            "prefix_hit_blocks": len(hits)
            + (1 if partial_hit is not None else 0),
            "prefill_tokens_skipped": q0}
        self._update_peaks()
        return int(jnp.argmax(logits[0]))

    def _insert_oneshot(self, slot: int, prompt: np.ndarray, n_total: int,
                        n_full: int, hits, partial_hit, keys, pkey) -> int:
        """Legacy (PR 2) path: one-shot prefill over the whole prompt —
        storage is shared (hit blocks are referenced, their recomputed
        values discarded) but no compute is skipped; a shared partial block
        is held read-only with lazy copy-on-write."""
        P = len(prompt)
        pool = self.pool
        # take references on every hit before allocating (allocation may
        # evict from the LRU the hits are parked in); on exhaustion release
        # everything this insert took — including the spares it armed other
        # holders with — so a failed admission leaks nothing
        bids = []
        fresh: list[tuple[int, bytes, int]] = []       # (blk_idx, key, bid)
        armed: list[tuple[int, tuple[int, int]]] = []  # (slot, partial_reg)
        try:
            bids.extend(pool.acquire(b) for b in hits)
            for j in range(len(hits), n_full):
                b = pool.alloc()
                fresh.append((j, keys[j], b))
                bids.append(b)
            if n_full * self.bs < P:                   # partial prompt block
                if partial_hit is not None:
                    # share it; every holder copies before its first write
                    self._arm_holders(partial_hit, armed)
                    pool.acquire(partial_hit)
                    bids.append(partial_hit)
                    self.cow_blk[slot] = n_full
                    self.cow_spare[slot] = pool.alloc()
                else:
                    b = pool.alloc()
                    fresh.append((n_full, pkey, b))
                    bids.append(b)
            while len(bids) < n_total:                 # generation blocks
                bids.append(pool.alloc())
        except PoolExhausted:
            for b in bids:
                pool.release(b)
            if self.cow_spare[slot] is not None:
                pool.release(self.cow_spare[slot])
            self.cow_blk[slot] = self.cow_spare[slot] = None
            self.partial_reg[slot] = None
            for s, prev in armed:                      # disarm: un-leak the
                pool.release(self.cow_spare[s])        # holders' spares
                self.cow_blk[s] = self.cow_spare[s] = None
                self.partial_reg[s] = prev
            raise

        # prefill and write the freshly-owned prompt blocks into the arena;
        # shared blocks keep the sibling's (bit-identical) values
        batch = {"tokens": jnp.asarray(prompt[None])}
        if self.extras is not None:
            batch.update(self.extras())
        cache1, logits = self._prefill(self.params, batch)
        cache1 = dict(cache1)
        padded = {key: _pad_seq(cache1.pop(key), self.max_len)
                  for key in self.seq_keys}
        wbids = np.zeros(self.nb_max, np.int32)
        for j, key, b in fresh:
            wbids[j] = b
        self.arena = self._scatter(self.arena, padded,
                                   jnp.asarray(wbids))
        # index only after the contents exist (a failed insert must never
        # leave a key pointing at an unwritten block)
        for j, key, b in fresh:
            pool.register(key, b, partial=j >= n_full)
            if j >= n_full:
                self.partial_reg[slot] = (j, b)
        for key in self.cache:
            if key == "len":
                continue
            self.cache[key] = self.cache[key].at[slot].set(cache1[key])
        self.cache["len"] = self.cache["len"].at[slot].set(P)

        self.tables[slot, :] = TRASH_BLOCK
        self.tables[slot, :len(bids)] = bids
        self.lens[slot] = P
        self.slot_bids[slot] = bids
        self.prefill_tokens_total += P
        self._stats[slot] = {
            "kv_blocks": n_total,
            "prefix_hit_blocks": len(hits)
            + (1 if partial_hit is not None else 0),
            "prefill_tokens_skipped": 0}
        self._update_peaks()
        return int(jnp.argmax(logits[0]))

    def _update_peaks(self) -> None:
        in_use = self.pool.blocks_in_use()
        live = sum(1 for b in self.slot_bids if b)
        saved = (live * self.max_len - in_use * self.bs) * self._token_bytes
        self.peak_blocks_in_use = max(self.peak_blocks_in_use, in_use)
        self.peak_bytes_saved = max(self.peak_bytes_saved, saved)

    def _arm_holders(self, bid: int,
                     armed: list[tuple[int, tuple[int, int]]]) -> None:
        """Give every live holder of a newly-shared partial block a spare.

        Each successfully armed holder is appended to ``armed`` (with its
        prior ``partial_reg`` entry) *before* the next allocation can
        raise, so the caller's rollback can disarm exactly the holders this
        insert armed — spares must not leak on a failed admission."""
        for s in range(self.n_slots):
            if (self.partial_reg[s] and self.partial_reg[s][1] == bid
                    and self.cow_spare[s] is None):
                prev = self.partial_reg[s]
                spare = self.pool.alloc()
                self.cow_blk[s] = prev[0]
                self.cow_spare[s] = spare
                self.partial_reg[s] = None
                armed.append((s, prev))

    def clear(self, slot: int) -> None:
        for bid in self.slot_bids[slot]:
            self.pool.release(bid)
        if self.cow_spare[slot] is not None:
            self.pool.release(self.cow_spare[slot])
        self.cow_blk[slot] = self.cow_spare[slot] = None
        self.partial_reg[slot] = None
        self.slot_bids[slot] = []
        self.tables[slot, :] = TRASH_BLOCK
        self.lens[slot] = 0
        self.cache["len"] = self.cache["len"].at[slot].set(0)

    # -- decode --------------------------------------------------------------

    def at_capacity(self, slot: int) -> bool:
        """A slot whose context has filled every block cannot take another
        token: its next write has no block to land in.  The batcher
        discovers this hook and retires such a request as finished."""
        return bool(self.slot_bids[slot]) and \
            int(self.lens[slot]) >= self.max_len

    # -- cascade grouping (backend="cascade") --------------------------------

    def _cascade_plan(self, lanes):
        """Shared-chain groups over the given lanes (host side).

        Feeds :meth:`BlockPool.shared_chains` each lane's *full* blocks
        only (the partially-filled tail is trimmed — only positions every
        sharer holds identically may enter a group pass) plus a skip set
        of blocks armed for copy-on-write, so a group never reads a block
        another lane is about to rewrite; the pool additionally excludes
        partial, unshared, and protected-for-handoff blocks.
        """
        skip = set()
        for s in range(self.n_slots):
            if self.cow_blk[s] is not None:
                skip.add(int(self.tables[s, self.cow_blk[s]]))
            if self.cow_spare[s] is not None:
                skip.add(int(self.cow_spare[s]))
        chains = {int(s): [int(b) for b in
                           self.tables[s, :int(self.lens[s]) // self.bs]]
                  for s in lanes}
        return self.pool.shared_chains(chains, skip=skip)

    @staticmethod
    def _pow2(n: int) -> int:
        return 1 if n <= 1 else 1 << (n - 1).bit_length()

    def _cascade_meta(self, groups) -> dict:
        """Device metadata for :func:`nn.attention.attend_decode_cascade`,
        padded to next-pow-2 bucket shapes so steady-state ticks reuse a
        fixed jit bucket set (the no-recompile pin in test_cascade.py)."""
        G = self._pow2(len(groups))
        npre = self._pow2(max(len(c) for c, _ in groups))
        lc = self._pow2(max(len(ls) for _, ls in groups))
        gt = np.full((G, npre), TRASH_BLOCK, np.int32)
        gl = np.zeros(G, np.int32)
        lanes = np.zeros((G, lc), np.int32)
        gmask = np.zeros((G, lc), bool)
        q0 = np.zeros(self.n_slots, np.int32)
        q0b = np.zeros(self.n_slots, np.int64)
        for g, (chain, ls) in enumerate(groups):
            gt[g, :len(chain)] = chain
            gl[g] = len(chain) * self.bs
            lanes[g, :len(ls)] = ls
            gmask[g, :len(ls)] = True
            for s in ls:
                q0[s] = len(chain) * self.bs
                q0b[s] = len(chain)
        # suffix tables must cover [q0 blocks, blocks holding cache_len)
        # for every lane — an ungrouped lane's suffix is its whole chain
        need = [max(1, -(-(int(self.lens[s]) + 1) // self.bs) - int(q0b[s]))
                for s in range(self.n_slots)]
        nsuf = self._pow2(max(need))
        st = np.full((self.n_slots, nsuf), TRASH_BLOCK, np.int32)
        for s in range(self.n_slots):
            row = self.tables[s, int(q0b[s]):int(q0b[s]) + nsuf]
            st[s, :len(row)] = row
        return {"group_tables": jnp.asarray(gt),
                "group_len": jnp.asarray(gl),
                "group_lanes": jnp.asarray(lanes),
                "group_mask": jnp.asarray(gmask),
                "lane_q0": jnp.asarray(q0),
                "suffix_tables": jnp.asarray(st)}

    def cascade_stats(self) -> dict:
        """Host-side grouping snapshot over the current live lanes
        (benchmarks/kvcache_bench.py --cascade): the groups the next tick
        would form, and the per-layer prefix rows attended once per
        *group* vs once per *lane* — the O(prefix) vs O(lanes x prefix)
        traffic claim the BENCH_cascade gate checks."""
        lanes = [s for s in range(self.n_slots)
                 if self.slot_bids[s] and not self.at_capacity(s)]
        groups = self._cascade_plan(lanes)
        shapes = [(len(c), len(ls)) for c, ls in groups]
        return {
            "groups": len(groups),
            "grouped_lanes": sum(n for _, n in shapes),
            "prefix_rows": sum(c * self.bs for c, _ in shapes),
            "prefix_rows_flat": sum(c * self.bs * n for c, n in shapes),
        }

    def decode(self, tokens: np.ndarray, active: np.ndarray) -> np.ndarray:
        active = np.asarray(active, bool).copy()
        wbids = np.full(self.n_slots, TRASH_BLOCK, np.int32)
        for slot in np.nonzero(active)[0]:
            if self.at_capacity(slot):
                # a full slot must not scatter: len // bs indexes past the
                # table and the pre-fix clamp silently overwrote the final
                # block — which may be a *shared* prefix block.  Route the
                # lane to the trash block and keep its state frozen.
                active[slot] = False
                continue
            blk = int(self.lens[slot]) // self.bs
            bid = int(self.tables[slot, blk])
            if self.cow_blk[slot] is not None and blk == self.cow_blk[slot]:
                spare = self.cow_spare[slot]
                self.arena = self._copy(self.arena, spare, bid)
                self.pool.cow_copies += 1
                self.pool.release(bid)
                self.tables[slot, blk] = spare
                self.slot_bids[slot][blk] = spare
                self.cow_blk[slot] = self.cow_spare[slot] = None
                bid = spare
            elif self.pool.is_partial(bid):
                # sole owner writes in place: the cached chunk changes, so
                # the index entry must go before the write lands
                self.pool.drop_partial(bid)
                self.partial_reg[slot] = None
            wbids[slot] = bid
        meta = None
        if self.backend == "cascade":
            # grouping runs AFTER the CoW/write-target loop above so a
            # block resolved this tick can never be both read by a group
            # pass and rewritten by its owner
            groups = self._cascade_plan(np.nonzero(active)[0])
            self.last_groups = len(groups)
            if groups:
                meta = self._cascade_meta(groups)
        if meta is None:
            # no chain shared by >= 2 lanes: degrade to the flat in-place
            # tick — the *same* jitted executable, hence bitwise-equal
            self.arena, self.cache, logits = self._decode(
                self.params, self.arena, self.cache, jnp.asarray(self.tables),
                jnp.asarray(tokens, jnp.int32)[:, None],
                jnp.asarray(active, bool), jnp.asarray(wbids))
        else:
            self.arena, self.cache, logits = self._decode_cascade(
                self.params, self.arena, self.cache, jnp.asarray(self.tables),
                jnp.asarray(tokens, jnp.int32)[:, None],
                jnp.asarray(active, bool), jnp.asarray(wbids), meta)
        self.lens[active] += 1
        self.last_logits = logits           # (n_slots, vocab) — parity tests
        return np.asarray(jnp.argmax(logits, -1))

    # -- telemetry -----------------------------------------------------------

    def arena_block(self, key: str, bid: int):
        """One arena block's contents for ``key``: the B=1 cache slice of
        ``block_size`` positions (layout-agnostic accessor for tests)."""
        return jnp.take(self.arena[key], bid, axis=self._bax[key])

    def tick_bytes_proxy(self) -> dict:
        """Analytic arena bytes one decode tick moves under each dataflow.

        A model of the traffic each tick's *dataflow* implies (what the
        TPU kernel's per-block DMA would actually stream), not a measured
        counter — benchmarks/kvcache_bench.py reports it alongside wall
        time.  The gather tick reads every lane's full ``nb_max`` chain,
        materializes + rewrites the dense per-slot cache, and scatters one
        block back; the in-place tick reads only the blocks live chains
        own and writes a single row per lane.
        """
        token = self._token_bytes
        n, ml, bs = self.n_slots, self.max_len, self.bs
        gather = n * ml * token * 2 + n * bs * token
        live_rows = sum(-(-(int(ln) + 1) // bs) * bs
                        for ln, b in zip(self.lens, self.slot_bids) if b)
        inplace = live_rows * token + n * token
        # cascade: each shared chain's prefix rows stream once per *group*
        # instead of once per lane; suffixes stream per lane as before
        groups = self._cascade_plan(
            [s for s in range(n) if self.slot_bids[s]])
        q0b = {s: len(c) for c, ls in groups for s in ls}
        prefix_rows = sum(len(c) * bs for c, _ in groups)
        suffix_rows = sum((-(-(int(ln) + 1) // bs) - q0b.get(s, 0)) * bs
                          for s, (ln, b) in
                          enumerate(zip(self.lens, self.slot_bids)) if b)
        cascade = (prefix_rows + suffix_rows) * token + n * token
        return {"gather": gather, "inplace": inplace, "cascade": cascade}

    def slot_stats(self, slot: int) -> dict:
        return dict(self._stats[slot])

    def jit_fns(self) -> dict[str, object]:
        """Named jitted entry points, for obs.RecompileDetector.track.
        The chunk fold is process-wide (shared across adapters of one
        config), so its bucket count reflects every adapter's folds."""
        fns = {"prefill": self._prefill, "chunk_fold": self._chunk_fn,
               "gather_prefix": self._gather_prefix,
               "scatter": self._scatter, "copy": self._copy,
               "write_block": self._write_block, "decode": self._decode}
        if self.backend == "cascade":
            fns["decode_cascade"] = self._decode_cascade
            # the cascade tick's inner executables are module-level jits in
            # kernels/paged_attn.py (process-wide, like chunk_fold): the
            # grouped-prefix pass, the per-lane suffix pass, and the
            # softmax-state merge.  Tracking them separately catches a leak
            # the outer decode_cascade bucket count can hide — a pow2
            # cascade-meta bucket crossing recompiles all three
            from repro.kernels import paged_attn as pk
            fns["cascade_prefix"] = pk.cascade_prefix_attention
            fns["cascade_suffix"] = pk.paged_decode_attention_with_state
            fns["cascade_merge"] = pk.merge_attn_states
        if self.cfg.family == "encdec":
            fns["encode"] = self._encode
        return fns

    def cost_args(self) -> dict[str, tuple]:
        """The serving-relevant stages of :meth:`jit_fns` paired with
        representative steady-state arguments, for obs.costmodel roofline
        attribution (``fn.lower(*args)`` — shapes only, nothing executes):
        the in-place decode tick against the live arena, one cold
        chunk-fold step (the block-size bucket every fold passes through),
        a one-block prefill, and the CoW/migration block copy."""
        batch = {"tokens": jnp.zeros((1, self.bs), jnp.int32)}
        if self.extras is not None:
            batch.update(self.extras())
        tokens = jnp.zeros((self.n_slots, 1), jnp.int32)
        mask = jnp.ones((self.n_slots,), bool)
        wbids = jnp.zeros((self.n_slots,), jnp.int32)
        return {
            "prefill": (self._prefill, (self.params, batch)),
            "chunk_fold": (self._chunk_fn,
                           (self.params, batch, self._prefix_cache(0), 0)),
            "decode": (self._decode,
                       (self.params, self.arena, self.cache,
                        jnp.asarray(self.tables), tokens, mask, wbids)),
            "copy": (self._copy, (self.arena, jnp.int32(0), jnp.int32(1))),
        }

    def pool_stats(self) -> dict:
        st = self.pool.stats()
        live = sum(1 for b in self.slot_bids if b)
        st["bytes_dense_equiv"] = live * self.max_len * self._token_bytes
        st["bytes_paged"] = st["blocks_in_use"] * self.bs * self._token_bytes
        st["bytes_saved_vs_dense"] = (st["bytes_dense_equiv"]
                                      - st["bytes_paged"])
        st["peak_blocks_in_use"] = self.peak_blocks_in_use
        st["peak_bytes_saved_vs_dense"] = self.peak_bytes_saved
        st["prefill_tokens_total"] = self.prefill_tokens_total
        st["prefill_tokens_skipped"] = self.prefill_tokens_skipped_total
        st["boundary_state_bytes"] = sum(
            a.nbytes for state in self._boundary_states.values()
            for a in state.values())
        return st
