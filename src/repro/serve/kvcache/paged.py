"""Block-table-backed KV slots: the paged counterpart of ``KVSlotAdapter``.

Layout
    One preallocated device arena per sequence-axis cache key —
    ``arena[key]: (num_blocks,) + B=1 block shape`` from
    :func:`engine.init_paged_arena` — shared by every slot.  Each slot holds
    a block table (row of ``(n_slots, nb_max)`` int32) mapping logical block
    j to an arena block id; non-sequence state (rwkv-style taps, ssm/conv,
    encoder cross K/V, ``len``) stays densely slot-stacked exactly as in the
    dense adapter.

Decode tick (one jitted call, fixed shapes)
    gather each slot's chain (``jnp.take`` over the tables) into the dense
    per-slot layout -> the same vmapped :func:`engine.decode_step` the dense
    adapter runs -> scatter back only the one block each slot wrote
    (position ``len`` lives in exactly one block).  Inactive lanes scatter
    into the reserved trash block 0, so the call never changes shape.
    Because the gathered view agrees with the dense cache at every position
    the model can read (< len; everything else is masked at NEG_INF before
    the softmax), paged decode is *bitwise* identical to dense decode.

Sharing / copy-on-write
    Admission walks the pool's radix index: full prompt blocks that match an
    earlier request's chain are referenced instead of written (their prefill
    values are discarded).  A trailing partial prompt block can be shared
    too when the whole chain plus the partial chunk matches; since decode
    extends partial blocks in place, every holder of a shared partial block
    carries a pre-allocated *spare* and copies into it before its first
    write (copy-on-write) — the sibling keeps the original, bit-for-bit.

Admission control
    ``can_admit`` prices a request at its worst case,
    ``ceil((P + max_new) / bs)`` blocks minus full-prefix hits (a partial
    hit is net zero: the spare takes its place), and admits only when the
    pool's free + evictable supply covers it — the batcher queues the
    request otherwise instead of letting an allocation fail mid-flight.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LMConfig
from repro.serve import engine
from repro.serve.kvcache.pool import (TRASH_BLOCK, BlockPool, PoolExhausted)


def _pad_seq(a: jax.Array, target: int) -> jax.Array:
    """Zero-pad a cache array's sequence axis (-3) to ``target``."""
    pad = [(0, 0)] * a.ndim
    pad[-3] = (0, target - a.shape[-3])
    return jnp.pad(a, pad)


class PagedKVSlotAdapter:
    """Paged KV slots for the attention families (decoder/moe/hybrid/encdec).

    Drop-in for ``KVSlotAdapter`` in :class:`ContinuousBatcher` (same
    ``insert`` / ``decode`` / ``clear`` surface), plus the paging hooks the
    batcher discovers by presence: ``can_admit``, ``validate_request``,
    ``slot_stats``, ``pool_stats``.
    """

    def __init__(self, cfg: LMConfig, params, n_slots: int, max_len: int,
                 *, block_size: int = 16, num_blocks: int | None = None,
                 extras: Callable[[], dict] | None = None):
        assert cfg.family != "rwkv", "rwkv has O(1) state; nothing to page"
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.bs = block_size
        self.nb_max = -(-max_len // block_size)
        self.max_len = self.nb_max * block_size
        self.extras = extras
        if num_blocks is None:
            # dense-equivalent capacity + the reserved trash block
            num_blocks = n_slots * self.nb_max + 1
        self.pool = BlockPool(num_blocks, block_size)
        self.arena = engine.init_paged_arena(cfg, num_blocks, block_size)
        self.seq_keys = tuple(self.arena)

        # densely slot-stacked non-sequence state (incl. the scalar "len")
        cache0 = engine.init_cache(cfg, 1, self.max_len)
        self.cache = {
            key: jnp.zeros((n_slots,) + np.shape(a), jnp.asarray(a).dtype)
            for key, a in cache0.items() if key not in self.arena}

        # host-side paging state
        self.tables = np.zeros((n_slots, self.nb_max), np.int32)
        self.lens = np.zeros(n_slots, np.int64)
        self.slot_bids: list[list[int]] = [[] for _ in range(n_slots)]
        self.cow_blk: list[int | None] = [None] * n_slots
        self.cow_spare: list[int | None] = [None] * n_slots
        self.partial_reg: list[tuple[int, int] | None] = [None] * n_slots
        self._stats: list[dict] = [{} for _ in range(n_slots)]
        # per-token arena bytes (for the bytes-saved-vs-dense telemetry)
        self._token_bytes = sum(
            a.dtype.itemsize * int(np.prod(a.shape[1:])) // block_size
            for a in self.arena.values())
        # peak occupancy: a drained pool always reads 0 blocks in use, so
        # the memory-savings evidence is tracked at its high-water mark
        self.peak_blocks_in_use = 0
        self.peak_bytes_saved = 0

        self._prefill = jax.jit(lambda p, b: engine.prefill(cfg, p, b))
        # donate the arena (and dense cache) through every call that rebinds
        # it, so the .at[].set updates alias in place instead of holding a
        # second full arena copy — the whole point of the fixed byte budget.
        # CPU XLA cannot donate (it would only warn), so gate on backend.
        dn = jax.default_backend() != "cpu"
        self._scatter = jax.jit(self._scatter_impl,
                                donate_argnums=(0,) if dn else ())
        self._copy = jax.jit(
            lambda arena, dst, src: {
                key: a.at[dst].set(a[src]) for key, a in arena.items()},
            donate_argnums=(0,) if dn else ())
        self._decode = jax.jit(self._tick_impl,
                               donate_argnums=(1, 2) if dn else ())

    # -- jitted bodies ------------------------------------------------------

    def _scatter_impl(self, arena, padded, wbids):
        """Write a prompt's blocks: ``padded[key]`` is the B=1 cache padded
        to max_len; ``wbids[j]`` is the arena slot for logical block j (the
        trash block for shared/unused blocks, whose values are discarded)."""
        out = {}
        for key in self.seq_keys:
            a = padded[key]
            ax = a.ndim - 3
            b = a.reshape(a.shape[:ax] + (self.nb_max, self.bs)
                          + a.shape[ax + 1:])
            b = jnp.moveaxis(b, ax, 0)          # (nb_max, *pre, bs, *post)
            out[key] = arena[key].at[wbids].set(b)
        return out

    def _tick_impl(self, p, arena, dense, tables, tokens, mask, wbids):
        """gather -> vmapped decode_step -> scatter the written blocks."""
        cache = dict(dense)
        for key in self.seq_keys:
            g = jnp.take(arena[key], tables, axis=0)
            g = jnp.moveaxis(g, 1, g.ndim - 4)  # (slots, *pre, nb, bs, *post)
            cache[key] = g.reshape(
                g.shape[:g.ndim - 4] + (self.nb_max * self.bs,)
                + g.shape[-2:])
        new_cache, logits = jax.vmap(
            lambda c, t: engine.decode_step(self.cfg, p, c, t),
            in_axes=(0, 0))(cache, tokens)
        sel = lambda new, old: jnp.where(
            mask.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)
        new_dense = {key: sel(new_cache[key], dense[key]) for key in dense}
        # each slot wrote exactly one position (pre-increment len), hence
        # exactly one block; inactive lanes target the trash block
        start = jnp.minimum((dense["len"] // self.bs) * self.bs,
                            self.max_len - self.bs)
        new_arena = {}
        for key in self.seq_keys:
            blk = jax.vmap(
                lambda a, s: jax.lax.dynamic_slice_in_dim(
                    a, s, self.bs, axis=a.ndim - 3))(new_cache[key], start)
            new_arena[key] = arena[key].at[wbids].set(blk)
        return new_arena, new_dense, logits

    # -- admission ----------------------------------------------------------

    def _block_demand(self, prompt_len: int, max_new: int) -> int:
        return -(-(prompt_len + max_new) // self.bs)

    def validate_request(self, prompt_len: int, max_new: int) -> None:
        n_total = self._block_demand(prompt_len, max_new)
        if n_total > self.pool.capacity:
            raise ValueError(
                f"request needs {n_total} blocks worst-case; pool holds "
                f"{self.pool.capacity} (block_size={self.bs})")

    def _arming_demand(self, partial_hit: int | None) -> int:
        """Spares newly required by existing holders of a shared partial."""
        if partial_hit is None:
            return 0
        return sum(1 for s in range(self.n_slots)
                   if self.partial_reg[s]
                   and self.partial_reg[s][1] == partial_hit
                   and self.cow_spare[s] is None)

    def can_admit(self, prompt: np.ndarray, max_new: int) -> bool:
        """Worst-case block demand vs free + evictable supply.

        Full-prefix hits reduce *allocations* one-for-one; a partial hit is
        net zero (its copy-on-write spare replaces the fresh partial block
        it would otherwise allocate), but may oblige existing holders to
        take spares of their own (``_arming_demand``).  A hit currently
        parked in the LRU still consumes supply when revived — it leaves the
        evictable pool without an allocation — so it counts toward demand;
        otherwise admission would overcommit exactly in the prefix-cache-
        warm steady state and ``insert`` would raise mid-flight.
        """
        pool = self.pool
        n_total = self._block_demand(len(prompt), max_new)
        hits, partial_hit, _, _ = pool.match_prefix(
            np.asarray(prompt, np.int32), count=False)
        revived = sum(1 for b in hits if pool.refcount[b] == 0)
        if partial_hit is not None and pool.refcount[partial_hit] == 0:
            revived += 1
        demand = n_total - len(hits) + revived \
            + self._arming_demand(partial_hit)
        return demand <= pool.available()

    # -- slot lifecycle ------------------------------------------------------

    def insert(self, slot: int, prompt: np.ndarray,
               max_new: int | None = None) -> int:
        P = len(prompt)
        if max_new is None:
            max_new = max(1, self.max_len - P)
        if P + max_new > self.max_len:
            raise ValueError(f"prompt {P} + {max_new} new tokens exceeds "
                             f"slot capacity {self.max_len}")
        pool = self.pool
        n_total = self._block_demand(P, max_new)
        n_full = P // self.bs
        hits, partial_hit, keys, pkey = pool.match_prefix(
            np.asarray(prompt, np.int32))

        # take references on every hit before allocating (allocation may
        # evict from the LRU the hits are parked in); on exhaustion release
        # everything this insert took so a failed admission leaks nothing
        bids = []
        fresh: list[tuple[int, bytes, int]] = []       # (blk_idx, key, bid)
        try:
            bids.extend(pool.acquire(b) for b in hits)
            for j in range(len(hits), n_full):
                b = pool.alloc()
                fresh.append((j, keys[j], b))
                bids.append(b)
            if n_full * self.bs < P:                   # partial prompt block
                if partial_hit is not None:
                    # share it; every holder copies before its first write
                    self._arm_holders(partial_hit)
                    pool.acquire(partial_hit)
                    bids.append(partial_hit)
                    self.cow_blk[slot] = n_full
                    self.cow_spare[slot] = pool.alloc()
                else:
                    b = pool.alloc()
                    fresh.append((n_full, pkey, b))
                    bids.append(b)
            while len(bids) < n_total:                 # generation blocks
                bids.append(pool.alloc())
        except PoolExhausted:
            for b in bids:
                pool.release(b)
            if self.cow_spare[slot] is not None:
                pool.release(self.cow_spare[slot])
            self.cow_blk[slot] = self.cow_spare[slot] = None
            self.partial_reg[slot] = None
            raise

        # prefill and write the freshly-owned prompt blocks into the arena;
        # shared blocks keep the sibling's (bit-identical) values
        batch = {"tokens": jnp.asarray(np.asarray(prompt, np.int32)[None])}
        if self.extras is not None:
            batch.update(self.extras())
        cache1, logits = self._prefill(self.params, batch)
        cache1 = dict(cache1)
        padded = {key: _pad_seq(cache1.pop(key), self.max_len)
                  for key in self.seq_keys}
        wbids = np.zeros(self.nb_max, np.int32)
        for j, key, b in fresh:
            wbids[j] = b
        self.arena = self._scatter(self.arena, padded,
                                   jnp.asarray(wbids))
        # index only after the contents exist (a failed insert must never
        # leave a key pointing at an unwritten block)
        for j, key, b in fresh:
            pool.register(key, b, partial=j >= n_full)
            if j >= n_full:
                self.partial_reg[slot] = (j, b)
        for key in self.cache:
            if key == "len":
                continue
            self.cache[key] = self.cache[key].at[slot].set(cache1[key])
        self.cache["len"] = self.cache["len"].at[slot].set(P)

        self.tables[slot, :] = TRASH_BLOCK
        self.tables[slot, :len(bids)] = bids
        self.lens[slot] = P
        self.slot_bids[slot] = bids
        self._stats[slot] = {
            "kv_blocks": n_total,
            "prefix_hit_blocks": len(hits)
            + (1 if partial_hit is not None else 0)}
        self._update_peaks()
        return int(jnp.argmax(logits[0]))

    def _update_peaks(self) -> None:
        in_use = self.pool.blocks_in_use()
        live = sum(1 for b in self.slot_bids if b)
        saved = (live * self.max_len - in_use * self.bs) * self._token_bytes
        self.peak_blocks_in_use = max(self.peak_blocks_in_use, in_use)
        self.peak_bytes_saved = max(self.peak_bytes_saved, saved)

    def _arm_holders(self, bid: int) -> None:
        """Give every live holder of a newly-shared partial block a spare."""
        for s in range(self.n_slots):
            if (self.partial_reg[s] and self.partial_reg[s][1] == bid
                    and self.cow_spare[s] is None):
                self.cow_blk[s] = self.partial_reg[s][0]
                self.cow_spare[s] = self.pool.alloc()
                self.partial_reg[s] = None

    def clear(self, slot: int) -> None:
        for bid in self.slot_bids[slot]:
            self.pool.release(bid)
        if self.cow_spare[slot] is not None:
            self.pool.release(self.cow_spare[slot])
        self.cow_blk[slot] = self.cow_spare[slot] = None
        self.partial_reg[slot] = None
        self.slot_bids[slot] = []
        self.tables[slot, :] = TRASH_BLOCK
        self.lens[slot] = 0
        self.cache["len"] = self.cache["len"].at[slot].set(0)

    # -- decode --------------------------------------------------------------

    def decode(self, tokens: np.ndarray, active: np.ndarray) -> np.ndarray:
        active = np.asarray(active, bool)
        wbids = np.full(self.n_slots, TRASH_BLOCK, np.int32)
        for slot in np.nonzero(active)[0]:
            blk = int(self.lens[slot]) // self.bs
            bid = int(self.tables[slot, blk])
            if self.cow_blk[slot] is not None and blk == self.cow_blk[slot]:
                spare = self.cow_spare[slot]
                self.arena = self._copy(self.arena, spare, bid)
                self.pool.cow_copies += 1
                self.pool.release(bid)
                self.tables[slot, blk] = spare
                self.slot_bids[slot][blk] = spare
                self.cow_blk[slot] = self.cow_spare[slot] = None
                bid = spare
            elif self.pool.is_partial(bid):
                # sole owner writes in place: the cached chunk changes, so
                # the index entry must go before the write lands
                self.pool.drop_partial(bid)
                self.partial_reg[slot] = None
            wbids[slot] = bid
        self.arena, self.cache, logits = self._decode(
            self.params, self.arena, self.cache, jnp.asarray(self.tables),
            jnp.asarray(tokens, jnp.int32)[:, None, None],
            jnp.asarray(active, bool), jnp.asarray(wbids))
        self.lens[active] += 1
        self.last_logits = logits[:, 0]     # (n_slots, vocab) — parity tests
        return np.asarray(jnp.argmax(logits[:, 0], -1))

    # -- telemetry -----------------------------------------------------------

    def slot_stats(self, slot: int) -> dict:
        return dict(self._stats[slot])

    def pool_stats(self) -> dict:
        st = self.pool.stats()
        live = sum(1 for b in self.slot_bids if b)
        st["bytes_dense_equiv"] = live * self.max_len * self._token_bytes
        st["bytes_paged"] = st["blocks_in_use"] * self.bs * self._token_bytes
        st["bytes_saved_vs_dense"] = (st["bytes_dense_equiv"]
                                      - st["bytes_paged"])
        st["peak_blocks_in_use"] = self.peak_blocks_in_use
        st["peak_bytes_saved_vs_dense"] = self.peak_bytes_saved
        return st
