"""Refcounted block-pool allocator with radix prefix sharing + LRU eviction.

Host-side bookkeeping for the paged KV cache: the *contents* of blocks live
in a device arena (see :mod:`repro.serve.kvcache.paged`); this module owns
which blocks exist, who references them, and which token prefixes they hold.

Design (vLLM-style, adapted to the slot batcher):

  blocks     fixed-size spans of ``block_size`` token positions.  Block 0 is
             reserved as the *trash* block — inactive decode lanes scatter
             their (masked, garbage) writes there so the batched decode stays
             one fixed-shape call.
  refcounts  every live request holds one reference per block in its table.
             Shared prefix blocks carry refcount > 1 and are read-only; a
             write to a shared block must copy first (copy-on-write, handled
             by the adapter with a spare block reserved at admission).
  radix map  a chain-hash index over *full* prompt blocks:
             ``key_j = H(key_{j-1} || tokens[j*bs:(j+1)*bs])``, so a lookup
             walks the prompt left-to-right and stops at the first miss —
             exactly a radix-tree descent, stored flat.  A trailing partial
             prompt chunk gets a separate ``H(chain || chunk || '#p')`` entry
             that is dropped the moment any write lands on its block (decode
             extends partial blocks in place; full blocks are never written
             again, so their entries are permanent until evicted).
  LRU        a block whose refcount drops to zero but is still indexed is not
             freed — it parks in an LRU so a later request with the same
             prefix can revive it.  Allocation pops the free list first, then
             evicts from the cold end of the LRU (unindexing the key).
  protected  chain keys marked hot by the owner (the disaggregated gateway
             protects a handed-off prompt chain on its owning decode slice).
             Eviction scans the LRU cold-to-hot for the first *unprotected*
             block; only when every parked block is protected does it fall
             back to plain cold-end eviction (allocation never fails because
             of protection — it is a preference, not a pin).

Admission math: a request needs ``ceil((P + max_new) / bs)`` blocks worst
case; every *full*-block prefix hit removes one from that demand (a partial
hit does not — its copy-on-write spare takes the place of the block it
shares).  ``BlockPool.available()`` counts free + evictable blocks, so the
adapter's ``can_admit`` is exact, not heuristic.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict, deque

import numpy as np

TRASH_BLOCK = 0


class PoolExhausted(RuntimeError):
    """Raised when an allocation cannot be served even after eviction."""


def chain_keys(tokens: np.ndarray, block_size: int
               ) -> tuple[list[bytes], bytes | None]:
    """(full-block chain keys, partial-chunk key or None) for a prompt."""
    tokens = np.asarray(tokens, np.int32)
    n_full = len(tokens) // block_size
    keys: list[bytes] = []
    h = b"root"
    for j in range(n_full):
        chunk = tokens[j * block_size:(j + 1) * block_size]
        h = hashlib.sha1(h + chunk.tobytes()).digest()
        keys.append(h)
    rest = tokens[n_full * block_size:]
    partial = None
    if len(rest):
        partial = hashlib.sha1(h + rest.tobytes() + b"#p").digest()
    return keys, partial


class BlockPool:
    """Refcounted fixed-size block allocator with a prefix index + LRU."""

    def __init__(self, num_blocks: int, block_size: int):
        assert num_blocks >= 2, "need at least the trash block + one real one"
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.free: deque[int] = deque(range(1, num_blocks))
        self.refcount = np.zeros(num_blocks, np.int64)
        self.index: dict[bytes, int] = {}        # chain/partial key -> block
        self.block_key: dict[int, bytes] = {}    # inverse (for eviction)
        self.partial_blocks: set[int] = set()    # indexed-partial block ids
        self.lru: OrderedDict[int, None] = OrderedDict()  # evictable blocks
        self.protected: set[bytes] = set()       # eviction-deprioritized keys
        # observer: called as on_unindex(bid, key) whenever a key leaves the
        # index (eviction / partial invalidation) — the paged adapter hangs
        # its per-boundary recurrent-state side cache off this, so that
        # cache can never outlive the blocks it describes
        self.on_unindex = None
        # counters (surfaced through gateway telemetry)
        self.evictions = 0
        self.protected_evictions = 0
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.cow_copies = 0

    # -- capacity ----------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Usable blocks (excludes the reserved trash block)."""
        return self.num_blocks - 1

    def available(self) -> int:
        """Blocks an allocation burst could obtain: free + evictable."""
        return len(self.free) + len(self.lru)

    def blocks_in_use(self) -> int:
        """Blocks referenced by live requests (excludes parked LRU blocks)."""
        return self.capacity - self.available()

    # -- allocation / refcounting ------------------------------------------
    def alloc(self) -> int:
        """Allocate a fresh block (refcount 1), evicting LRU if needed.

        Eviction is affinity-aware: the coldest *unprotected* block goes
        first, so hot shared prefix chains a decode slice owns stay
        resident under allocation pressure.  With every parked block
        protected, the cold end goes anyway — protection never turns an
        otherwise-satisfiable allocation into :class:`PoolExhausted`."""
        if self.free:
            bid = self.free.popleft()
        elif self.lru:
            bid = next((c for c in self.lru                # cold -> hot
                        if self.block_key.get(c) not in self.protected),
                       None)
            if bid is None:                                # all protected
                bid = next(iter(self.lru))
                self.protected_evictions += 1
            self.lru.pop(bid)
            self._unindex(bid)
            self.evictions += 1
        else:
            raise PoolExhausted(
                f"no free or evictable blocks (capacity {self.capacity})")
        self.refcount[bid] = 1
        return bid

    def acquire(self, bid: int) -> int:
        """Take a reference on an existing block (prefix hit / fork)."""
        if self.refcount[bid] == 0:            # revive from the LRU
            self.lru.pop(bid, None)
        self.refcount[bid] += 1
        return bid

    def release(self, bid: int) -> None:
        if bid == TRASH_BLOCK:
            return
        assert self.refcount[bid] > 0, f"double free of block {bid}"
        self.refcount[bid] -= 1
        if self.refcount[bid] == 0:
            if bid in self.block_key:
                self.lru[bid] = None           # evictable, contents cached
                self.lru.move_to_end(bid)
            else:
                self.free.append(bid)

    # -- prefix index ------------------------------------------------------
    def lookup(self, key: bytes, count: bool = True) -> int | None:
        """Index probe; ``count=False`` keeps admission pre-checks out of
        the hit-rate telemetry (only real admissions are queries)."""
        bid = self.index.get(key)
        if count:
            self.prefix_queries += 1
            if bid is not None:
                self.prefix_hits += 1
        return bid

    def register(self, key: bytes, bid: int, *, partial: bool = False) -> None:
        """Make a freshly-written prompt block findable by later requests."""
        if key in self.index:                  # racing identical prompts:
            return                             # keep the first registration
        self.index[key] = bid
        self.block_key[bid] = key
        if partial:
            self.partial_blocks.add(bid)

    def is_partial(self, bid: int) -> bool:
        return bid in self.partial_blocks

    def drop_partial(self, bid: int) -> None:
        """Invalidate a partial entry before its block is written in place."""
        if bid in self.partial_blocks:
            self._unindex(bid)

    def _unindex(self, bid: int) -> None:
        key = self.block_key.pop(bid, None)
        if key is not None:
            self.index.pop(key, None)
            self.protected.discard(key)
            if self.on_unindex is not None:
                self.on_unindex(bid, key)
        self.partial_blocks.discard(bid)

    # -- eviction protection -----------------------------------------------
    def protect(self, keys) -> None:
        """Mark chain keys hot: their blocks are evicted last (see
        :meth:`alloc`).  Keys not (or no longer) indexed are skipped —
        protection tracks residency, it does not create it."""
        for key in keys:
            if key in self.index:
                self.protected.add(key)

    def unprotect(self, keys) -> None:
        for key in keys:
            self.protected.discard(key)

    # -- prefix matching ---------------------------------------------------
    def probe_chain(self, keys: list[bytes], pkey: bytes | None = None,
                    count: bool = True) -> tuple[list[int], int | None]:
        """Walk precomputed chain keys (see :func:`chain_keys`).

        Returns (full-block hits in prefix order, partial hit or None).
        Pure probe, no references taken.  The sharded gateway router hashes
        a prompt once and probes every slice's pool with the same keys —
        radix-prefix affinity routing without re-hashing per slice.
        """
        hits: list[int] = []
        for key in keys:
            bid = self.lookup(key, count=count)
            if bid is None:
                break
            hits.append(bid)
        partial_hit = None
        if pkey is not None and len(hits) == len(keys):
            partial_hit = self.lookup(pkey, count=count)
        return hits, partial_hit

    def match_prefix(self, tokens: np.ndarray, count: bool = True
                     ) -> tuple[list[int], int | None, list[bytes],
                                bytes | None]:
        """Walk the radix chain for ``tokens``.

        Returns (full-block hits in prefix order, partial-block hit or None,
        all full-block chain keys, partial key or None).  Pure probe: takes
        no references — the caller acquires on admission.
        """
        keys, pkey = chain_keys(tokens, self.block_size)
        hits, partial_hit = self.probe_chain(keys, pkey, count=count)
        return hits, partial_hit, keys, pkey

    def shared_chains(self, lane_chains: dict, *, min_lanes: int = 2,
                      skip=()) -> list[tuple[tuple[int, ...], list]]:
        """Group decode lanes by their longest shared indexed prefix chain.

        ``lane_chains`` maps a lane id to that lane's *full*-block ids in
        prefix order (the caller trims the partially-filled tail block —
        only positions every sharer can read may enter a cascade group).
        A block is cascade-eligible iff it is indexed as a full block
        (partials are rewritten in place by their sole owner), actually
        shared (refcount >= 2 — a private chain gains nothing from a group
        pass), not ``protected`` (a handed-off chain may still be mid-
        migration rewrite on this slice), and not in ``skip`` (the adapter
        passes blocks armed for copy-on-write).  Each lane contributes its
        longest eligible prefix; lanes with the *identical* chain tuple
        form a group.  Returns ``[(chain, [lane, ...]), ...]`` for groups
        of at least ``min_lanes`` lanes, deterministic in lane order.
        """
        skip = set(skip)

        def eligible(bid: int) -> bool:
            if bid == TRASH_BLOCK or bid in skip:
                return False
            key = self.block_key.get(bid)
            if key is None or bid in self.partial_blocks:
                return False
            return self.refcount[bid] >= 2 and key not in self.protected

        by_chain: dict[tuple[int, ...], list] = {}
        for lane, chain in lane_chains.items():
            shared = []
            for bid in chain:
                if not eligible(bid):
                    break
                shared.append(int(bid))
            if shared:
                by_chain.setdefault(tuple(shared), []).append(lane)
        return [(chain, lanes) for chain, lanes in by_chain.items()
                if len(lanes) >= min_lanes]

    # -- telemetry ---------------------------------------------------------
    def gauges(self) -> dict:
        """Instantaneous occupancy gauges for pull-mode interval sampling
        (serve/obs ``MetricsRegistry.register``) — the cheap subset of
        :meth:`stats`, read once per snapshot tick."""
        q = self.prefix_queries
        return {
            "pool_blocks_in_use": int(self.blocks_in_use()),
            "pool_blocks_cached": len(self.lru),
            "prefix_hit_rate": (self.prefix_hits / q) if q else 0.0,
        }

    def stats(self) -> dict:
        q = self.prefix_queries
        return {
            "num_blocks": self.capacity,
            "block_size": self.block_size,
            "blocks_in_use": int(self.blocks_in_use()),
            "blocks_cached": len(self.lru),
            "blocks_free": len(self.free),
            "prefix_queries": q,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_rate": (self.prefix_hits / q) if q else 0.0,
            "evictions": self.evictions,
            "protected_keys": len(self.protected),
            "protected_evictions": self.protected_evictions,
            "cow_copies": self.cow_copies,
        }

    def debug_snapshot(self) -> dict:
        """Forensic pool state for incident bundles (serve/obs/incident.py):
        :meth:`stats` plus index/LRU/partial sizes and the refcount shape —
        aggregate counts only, never block contents, so bundles stay small
        and free of request payload data."""
        snap = self.stats()
        rc = self.refcount[1:]                   # trash block excluded
        snap.update({
            "index_keys": len(self.index),
            "lru_parked": len(self.lru),
            "partial_blocks": len(self.partial_blocks),
            "free_blocks": len(self.free),
            "max_refcount": int(rc.max()) if rc.size else 0,
            "referenced_blocks": int((rc > 0).sum()),
        })
        return snap
