"""int8 KV-cache quantization (beyond-paper §Perf extension).

The paper's thesis — cut precision where a cheap domain tolerates it and let
the high-precision remainder absorb the error — applied to serving: K/V
cache entries are stored int8 with one f32 scale per (token, head); the
dequantize fuses into the attention reads.  Halves cache residency (the
decode cells' dominant per-device memory) at <0.5% logit error (tests).

Per-token-per-head absmax scaling, post-RoPE (KIVI-style per-channel
pre-RoPE K scaling is a further refinement; noted, not implemented).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array):
    """x: (..., D) -> (int8 (..., D), f32 scale (..., 1))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale).astype(dtype)
