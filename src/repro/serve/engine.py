"""Serving: batched prefill + single-token decode with family-specific caches.

Cache layouts (leading axis = layers, consumed/produced by lax.scan):
  attention : k/v (L, B, Smax, Hkv, Dh) + scalar "len"
  rwkv      : wkv state (L, B, H, Dh, Dh) f32 + token-shift states (L, B, d)
  hybrid    : k/v + mamba conv state (L, B, K-1, di) + ssm state (L, B, di, N)
  encdec/vlm: self k/v + precomputed cross K/V from encoder/vision tokens

KV cache sharding (``cache_specs``): batch over the DP axes; KV heads over
"model" when divisible, otherwise the *sequence* axis shards over "model"
(split-KV decode — softmax renormalization turns into an all-reduce, which
XLA inserts automatically).  That is how llama-405B's 8 KV heads decode on a
16-wide TP axis without replicating a terabyte of cache.

The decode step is O(1)-state for rwkv/hybrid-SSM paths — the reason the
long_500k cells are only assigned to those families.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (axis_if_divisible, batch_spec_axis)
from repro.models import lm
from repro.models.lm import (LMConfig, _attn_apply, _causal_conv, _maybe_remat,
                             _mlp_apply, _norm_apply, _proj, _sinusoidal,
                             _token_shift, layer_window)
from repro.nn import attention, rope, ssm
from repro.serve import kvquant


# ==========================================================================
# Cache construction (+ specs).
# ==========================================================================

def init_cache(cfg: LMConfig, batch: int, max_len: int, abstract: bool = False,
               extras: dict | None = None):
    """Abstract mode returns ShapeDtypeStructs (dry-run decode inputs)."""
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
         (lambda s, d: jnp.zeros(s, d))
    L, B = cfg.n_layers, batch
    Hkv, Dh, d = cfg.n_kv_heads, cfg.d_head, cfg.d_model
    fam = cfg.family
    kv_dtype = jnp.int8 if cfg.kv_quant else cfg.dtype
    cache: dict = {"len": mk((), jnp.int32)}
    if fam in ("decoder", "moe", "hybrid"):
        cache["k"] = mk((L, B, max_len, Hkv, Dh), kv_dtype)
        cache["v"] = mk((L, B, max_len, Hkv, Dh), kv_dtype)
        if cfg.kv_quant:
            cache["k_scale"] = mk((L, B, max_len, Hkv, 1), jnp.float32)
            cache["v_scale"] = mk((L, B, max_len, Hkv, 1), jnp.float32)
        if fam == "hybrid":
            cache["conv"] = mk((L, B, cfg.conv_k - 1, cfg.inner), cfg.dtype)
            cache["ssm"] = mk((L, B, cfg.inner, cfg.ssm_state), jnp.float32)
    elif fam == "rwkv":
        cache["wkv"] = mk((L, B, cfg.n_heads, Dh, Dh), jnp.float32)
        cache["shift1"] = mk((L, B, d), cfg.dtype)
        cache["shift2"] = mk((L, B, d), cfg.dtype)
    elif fam == "encdec":
        cache["k"] = mk((L, B, max_len, Hkv, Dh), cfg.dtype)
        cache["v"] = mk((L, B, max_len, Hkv, Dh), cfg.dtype)
        cache["xk"] = mk((L, B, cfg.enc_len, Hkv, Dh), cfg.dtype)
        cache["xv"] = mk((L, B, cfg.enc_len, Hkv, Dh), cfg.dtype)
    elif fam == "vlm":
        k = cfg.cross_every
        G = cfg.n_layers // k
        cache["k"] = mk((G, k - 1, B, max_len, Hkv, Dh), cfg.dtype)
        cache["v"] = mk((G, k - 1, B, max_len, Hkv, Dh), cfg.dtype)
        cache["kx_self"] = mk((G, B, max_len, Hkv, Dh), cfg.dtype)
        cache["vx_self"] = mk((G, B, max_len, Hkv, Dh), cfg.dtype)
        cache["xk"] = mk((G, B, cfg.n_vision_tokens, Hkv, Dh), cfg.dtype)
        cache["xv"] = mk((G, B, cfg.n_vision_tokens, Hkv, Dh), cfg.dtype)
    else:
        raise ValueError(fam)
    return cache


# Cache keys whose axis -3 is the (paged) sequence axis.  Everything else —
# rwkv/ssm states, conv taps, encoder/vision cross K/V — is O(1) per slot and
# stays densely slot-stacked even under the paged layout.
PAGED_SEQ_KEYS = ("k", "v", "k_scale", "v_scale", "kx_self", "vx_self")


def init_paged_arena(cfg: LMConfig, num_blocks: int, block_size: int,
                     abstract: bool = False) -> dict:
    """Block arenas for the paged KV cache (serve/kvcache/).

    Per sequence-axis cache key, the B=1 cache of ``max_len=block_size``
    with a ``num_blocks`` axis spliced in just before the batch axis —
    layer-leading, so ``arena[key][..., bid, :1, :bs]`` (via
    :func:`arena_block_axis`) is exactly one block of that key and block
    granularity / cache layout can never drift apart: both come from
    :func:`init_cache`.  The layer axis stays leading (rather than the
    block axis, as in PR 2) so the in-place decode tick can scan layers
    over per-layer ``(num_blocks, 1, bs, ...)`` slices — and so the arena
    shards over a mesh with the same leading-axes PartitionSpec shape as
    ``cache_specs`` gives the dense layout (the next ROADMAP item).
    """
    blk = init_cache(cfg, 1, block_size, abstract=True)
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
         (lambda s, d: jnp.zeros(s, d))
    out = {}
    for key in PAGED_SEQ_KEYS:
        if key not in blk:
            continue
        s = blk[key].shape                       # (*layers, 1, bs, *post)
        ax = len(s) - 4                          # just before the B axis
        out[key] = mk(s[:ax] + (num_blocks,) + s[ax:], blk[key].dtype)
    return out


def arena_block_axis(a) -> int:
    """Block-id axis of an :func:`init_paged_arena` array.

    Every paged key's block shape ends ``(B=1, bs, heads-ish, feat)`` with
    the block axis spliced in just before B, so it always sits 5 axes from
    the end whatever the leading layer axes look like (one for decoder
    k/v, two for the vlm grouped layout)."""
    return a.ndim - 5


def arena_specs(cfg: LMConfig, mesh_shape: dict[str, int]):
    """PartitionSpec tree matching :func:`init_paged_arena`.

    Derived from :func:`cache_specs` the same way the arena layout is
    derived from the cache layout: the dense B=1 spec with a replicated
    block axis spliced in just before the batch axis.  KV heads shard over
    "model" when divisible (the split-KV fallback then shards the
    *block-size* axis instead, mirroring the dense sequence-axis
    fallback); the block axis itself is never sharded — slices of the
    serving mesh partition the arena by *pool*, not by splitting one
    pool's blocks (see serve/shard/)."""
    dense = cache_specs(cfg, mesh_shape, batch=1)
    out = {}
    for key in PAGED_SEQ_KEYS:
        if key not in dense:
            continue
        sp = tuple(dense[key])
        ax = len(sp) - 4                         # just before the B axis
        out[key] = P(*sp[:ax], None, *sp[ax:])
    return out


def cache_specs(cfg: LMConfig, mesh_shape: dict[str, int], batch: int):
    """PartitionSpec tree matching init_cache."""
    b = batch_spec_axis(mesh_shape, batch)
    kv_heads = axis_if_divisible("model", cfg.n_kv_heads, mesh_shape)
    seq = None if kv_heads else "model"      # split-KV fallback
    fam = cfg.family
    specs: dict = {"len": P()}
    kv = P(None, b, seq, kv_heads, None)
    if fam in ("decoder", "moe", "hybrid"):
        specs["k"] = kv
        specs["v"] = kv
        if cfg.kv_quant:
            specs["k_scale"] = kv
            specs["v_scale"] = kv
        if fam == "hybrid":
            di = axis_if_divisible("model", cfg.inner, mesh_shape)
            specs["conv"] = P(None, b, None, di)
            specs["ssm"] = P(None, b, di, None)
    elif fam == "rwkv":
        h = axis_if_divisible("model", cfg.n_heads, mesh_shape)
        specs["wkv"] = P(None, b, h, None, None)
        specs["shift1"] = P(None, b, None)
        specs["shift2"] = P(None, b, None)
    elif fam == "encdec":
        specs.update(k=kv, v=kv, xk=kv, xv=kv)
    elif fam == "vlm":
        kv5 = P(None, None, b, seq, kv_heads, None)
        kv4 = P(None, b, seq, kv_heads, None)
        specs.update(k=kv5, v=kv5, kx_self=kv4, vx_self=kv4, xk=kv4, xv=kv4)
    return specs


# ==========================================================================
# Prefill.
# ==========================================================================

def prefill(cfg: LMConfig, params, batch):
    """Process a full prompt; returns (cache, last-token logits).

    batch: {"tokens": (B, S)} + family extras (enc_embed / vision_embed).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = lm.embed_tokens(cfg, params, tokens)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    fam = cfg.family
    cache = {"len": jnp.int32(S)}

    if fam in ("decoder", "moe"):
        if fam == "moe":
            p0 = jax.tree.map(lambda a: a[0], params["dense0"])
            x, kv0, _ = lm.decoder_block(cfg, p0, x, positions)

        def body(lp, x, idx):
            x, kv, _ = lm.decoder_block(
                cfg, lp, x, positions, window=layer_window(cfg, idx),
                moe_layer=(fam == "moe"),
                moe_dropless=cfg.moe_dropless_prefill)
            return x, kv
        L = cfg.n_layers - (1 if fam == "moe" else 0)
        x, kvs = lm._stack_scan(cfg, params["blocks"], body, x,
                                jnp.arange(L, dtype=jnp.int32))
        k, v = kvs
        if fam == "moe":
            k = jnp.concatenate([kv0[0][None], k], 0)
            v = jnp.concatenate([kv0[1][None], v], 0)
        cache["k"], cache["v"] = k, v

    elif fam == "rwkv":
        def body(lp, x, _):
            st = {"wkv": jnp.zeros((B, cfg.n_heads, cfg.d_head, cfg.d_head),
                                   jnp.float32),
                  "shift1": jnp.zeros((B, cfg.d_model), x.dtype),
                  "shift2": jnp.zeros((B, cfg.d_model), x.dtype)}
            x, st = lm.rwkv_block(cfg, lp, x, st)
            return x, st
        x, states = lm._stack_scan(cfg, params["blocks"], body, x)
        cache.update(states)

    elif fam == "hybrid":
        def fresh_state():
            return {"conv": jnp.zeros((B, cfg.conv_k - 1, cfg.inner),
                                      x.dtype),
                    "ssm": jnp.zeros((B, cfg.inner, cfg.ssm_state),
                                     jnp.float32)}

        if lm.hybrid_grouped(cfg):
            G, ge = cfg.n_layers // cfg.global_every, cfg.global_every
            grouped = jax.tree.map(
                lambda a: a.reshape((G, ge) + a.shape[1:]), params["blocks"])

            def group_body(gp, x, _):
                g0 = jax.tree.map(lambda a: a[0], gp)
                rest = jax.tree.map(lambda a: a[1:], gp)
                x, kv0, st0 = lm.hymba_block(cfg, g0, x, positions,
                                             fresh_state(), window=0)

                def inner(lp, x, __):
                    x, kv, st = lm.hymba_block(cfg, lp, x, positions,
                                               fresh_state(),
                                               window=cfg.window)
                    return x, (kv, st)
                x, (kvs, sts) = lm._stack_scan(cfg, rest, inner, x)
                # interleave group-local outputs back to layer order
                kv_all = jax.tree.map(
                    lambda a0, a: jnp.concatenate([a0[None], a], 0),
                    kv0, kvs)
                st_all = jax.tree.map(
                    lambda a0, a: jnp.concatenate([a0[None], a], 0),
                    st0, sts)
                return x, (kv_all, st_all)

            def outer(carry, gp):
                return lm._maybe_remat(cfg, group_body)(gp, carry, None)
            x, (kvs, states) = jax.lax.scan(outer, x, grouped)
            kvs = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), kvs)
            states = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), states)
        else:
            def body(lp, x, idx):
                x, kv, st = lm.hymba_block(cfg, lp, x, positions,
                                           fresh_state(),
                                           window=layer_window(cfg, idx))
                return x, (kv, st)
            x, (kvs, states) = lm._stack_scan(
                cfg, params["blocks"], body, x,
                jnp.arange(cfg.n_layers, dtype=jnp.int32))
        cache["k"], cache["v"] = kvs
        cache.update(states)

    elif fam == "encdec":
        # one encoder pass + per-layer cross-K/V via encode_cross — the
        # same function the chunked-prefill fold consumes, so the one-shot
        # and folded admission paths cannot drift apart
        xk, xv = encode_cross(cfg, params, batch["enc_embed"])

        def dec_body(lp, x, inp):
            kx, vx = inp
            x, kv = lm.cross_block(cfg, lp, x, positions,
                                   (kx.astype(x.dtype), vx.astype(x.dtype)))
            return x, kv
        x, kvs = lm._stack_scan(cfg, params["dec_blocks"], dec_body, x,
                                (xk, xv))
        cache["k"], cache["v"] = kvs
        cache["xk"], cache["xv"] = xk, xv

    elif fam == "vlm":
        vis = batch["vision_embed"].astype(x.dtype)
        k_ = cfg.cross_every
        G = cfg.n_layers // k_
        self_pp = jax.tree.map(
            lambda a: a.reshape((G, k_ - 1) + a.shape[1:]), params["blocks"])

        def group_body(gp, x, _):
            self_p, cross_p = gp

            def inner(lp, x, __):
                x, kv, _ = lm.decoder_block(cfg, lp, x, positions)
                return x, kv
            x, kvs = lm._stack_scan(cfg, self_p, inner, x)
            kx = _proj(vis, cross_p["xattn"]["wk"]).reshape(
                B, -1, cfg.n_kv_heads, cfg.d_head)
            vx = _proj(vis, cross_p["xattn"]["wv"]).reshape(
                B, -1, cfg.n_kv_heads, cfg.d_head)
            x, kv_self = lm.cross_block(cfg, cross_p, x, positions, (kx, vx))
            return x, (kvs, kv_self, (kx, vx))

        def outer(carry, inp):
            return _maybe_remat(cfg, group_body)(inp, carry, None)
        x, (kvs, kv_self, xkvs) = jax.lax.scan(
            outer, x, (self_pp, params["cross_blocks"]))
        cache["k"], cache["v"] = kvs
        cache["kx_self"], cache["vx_self"] = kv_self
        cache["xk"], cache["xv"] = xkvs
    else:
        raise ValueError(fam)

    if cfg.kv_quant and fam in ("decoder", "moe", "hybrid"):
        cache["k"], cache["k_scale"] = kvquant.quantize(cache["k"])
        cache["v"], cache["v_scale"] = kvquant.quantize(cache["v"])

    x = _norm_apply(cfg, params["final_norm"], x[:, -1:])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    return cache, logits[:, 0]


# ==========================================================================
# Chunked (suffix-only) prefill: resume from an existing KV prefix.
# ==========================================================================

def encode_cross(cfg: LMConfig, params, enc_embed):
    """Encoder pass + per-decoder-layer cross K/V projections (encdec).

    Returns (xk, xv): (L, B, enc_len, Hkv, Dh).  Factored out of
    :func:`prefill` so a chunked prefill fold runs the encoder exactly once
    per request — every chunk (and every resumed fold) then consumes the
    same arrays, keeping the fold's cross-attention bit-stable.
    """
    assert cfg.family == "encdec", cfg.family
    B = enc_embed.shape[0]
    enc = enc_embed.astype(cfg.dtype)
    enc = enc + _sinusoidal(enc.shape[1], cfg.d_model).astype(enc.dtype)
    enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1]), (B, enc.shape[1]))

    def enc_body(lp, h, _):
        h, _, _ = lm.decoder_block(cfg, lp, h, enc_pos, causal=False)
        return h, jnp.float32(0.0)
    enc, _ = lm._stack_scan(cfg, params["enc_blocks"], enc_body, enc)
    enc = _norm_apply(cfg, params["enc_norm"], enc)

    def proj_body(lp, h, _):
        kx = _proj(enc, lp["xattn"]["wk"]).reshape(
            B, -1, cfg.n_kv_heads, cfg.d_head)
        vx = _proj(enc, lp["xattn"]["wv"], lp["xattn"].get("bv")).reshape(
            B, -1, cfg.n_kv_heads, cfg.d_head)
        return h, (kx, vx)
    _, (xk, xv) = lm._stack_scan(cfg, params["dec_blocks"], proj_body,
                                 jnp.float32(0.0))
    return xk, xv


def prefill_chunked(cfg: LMConfig, params, batch, cache, q_offset: int):
    """Process one prompt chunk against an existing KV prefix.

    batch: {"tokens": (B, S_chunk)} — ONLY the tokens past the prefix.
    ``cache``: the prefix context — k/v of exactly ``q_offset`` positions
    on the sequence axis (zero-length arrays for a from-scratch fold), the
    conv taps / SSM state at the boundary for the hybrid family, and the
    precomputed :func:`encode_cross` xk/xv for encdec.  Returns (cache
    covering prefix+chunk, last-chunk-token logits).

    This is the step function of the serving **prefill fold**: a prompt is
    prefilled as a sequence of fixed-size chunks, and a radix prefix hit of
    H blocks resumes the fold at chunk H with the prefix gathered from the
    block arena.  Bit-exactness of the resume is structural: chunk j has
    the same static shapes whether the fold started at 0 or at H <= j, so
    XLA compiles the identical executable and the resumed fold reproduces
    the cold fold's K/V and logits bit-for-bit (tests/test_chunked_prefill
    asserts exactly this).  ``cfg.kv_quant`` is unsupported (the int8 cache
    no longer holds the pre-quantization values prefill attends over).
    """
    assert not cfg.kv_quant, "chunked prefill: int8 KV prefix unsupported"
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = lm.embed_tokens(cfg, params, tokens, pos_offset=q_offset)
    positions = jnp.broadcast_to(jnp.arange(q_offset, q_offset + S), (B, S))
    fam = cfg.family
    new_cache = {"len": jnp.int32(q_offset + S)}
    assert fam in ("decoder", "moe", "hybrid", "encdec"), fam
    pk_all, pv_all = cache["k"], cache["v"]     # (L, B, q_offset, Hkv, Dh)
    assert pk_all.shape[-3] == q_offset, (pk_all.shape, q_offset)

    if fam in ("decoder", "moe"):
        if fam == "moe":
            p0 = jax.tree.map(lambda a: a[0], params["dense0"])
            x, kv0, _ = lm.decoder_block(cfg, p0, x, positions,
                                         q_offset=q_offset,
                                         kv_prefix=(pk_all[0], pv_all[0]))

        def body(lp, x, inp):
            pk, pv, idx = inp
            x, kv, _ = lm.decoder_block(cfg, lp, x, positions,
                                        window=layer_window(cfg, idx),
                                        moe_layer=(fam == "moe"),
                                        moe_dropless=cfg.moe_dropless_prefill,
                                        q_offset=q_offset,
                                        kv_prefix=(pk, pv))
            return x, kv
        L = cfg.n_layers - (1 if fam == "moe" else 0)
        off = 1 if fam == "moe" else 0
        x, kvs = lm._stack_scan(cfg, params["blocks"], body, x,
                                (pk_all[off:], pv_all[off:],
                                 jnp.arange(L, dtype=jnp.int32)))
        k, v = kvs
        if fam == "moe":
            k = jnp.concatenate([kv0[0][None], k], 0)
            v = jnp.concatenate([kv0[1][None], v], 0)
        new_cache["k"], new_cache["v"] = k, v

    elif fam == "hybrid":
        if lm.hybrid_grouped(cfg):
            G, ge = cfg.n_layers // cfg.global_every, cfg.global_every
            regroup = lambda a: a.reshape((G, ge) + a.shape[1:])
            grouped = jax.tree.map(regroup, params["blocks"])
            xs = (grouped, regroup(pk_all), regroup(pv_all),
                  regroup(cache["conv"]), regroup(cache["ssm"]))

            def group_body(inp, x, _):
                gp, pk, pv, conv, ssm_st = inp
                g0 = jax.tree.map(lambda a: a[0], gp)
                rest = jax.tree.map(lambda a: a[1:], gp)
                x, kv0, st0 = lm.hymba_block(
                    cfg, g0, x, positions,
                    {"conv": conv[0], "ssm": ssm_st[0]}, window=0,
                    q_offset=q_offset, kv_prefix=(pk[0], pv[0]))

                def inner(lp, x, einp):
                    ipk, ipv, iconv, issm = einp
                    x, kv, st = lm.hymba_block(
                        cfg, lp, x, positions,
                        {"conv": iconv, "ssm": issm}, window=cfg.window,
                        q_offset=q_offset, kv_prefix=(ipk, ipv))
                    return x, (kv, st)
                x, (kvs, sts) = lm._stack_scan(
                    cfg, rest, inner, x,
                    (pk[1:], pv[1:], conv[1:], ssm_st[1:]))
                kv_all = jax.tree.map(
                    lambda a0, a: jnp.concatenate([a0[None], a], 0),
                    kv0, kvs)
                st_all = jax.tree.map(
                    lambda a0, a: jnp.concatenate([a0[None], a], 0),
                    st0, sts)
                return x, (kv_all, st_all)

            def outer(carry, inp):
                return lm._maybe_remat(cfg, group_body)(inp, carry, None)
            x, (kvs, states) = jax.lax.scan(outer, x, xs)
            kvs = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), kvs)
            states = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), states)
        else:
            def body(lp, x, inp):
                pk, pv, conv, ssm_st, idx = inp
                x, kv, st = lm.hymba_block(
                    cfg, lp, x, positions, {"conv": conv, "ssm": ssm_st},
                    window=layer_window(cfg, idx), q_offset=q_offset,
                    kv_prefix=(pk, pv))
                return x, (kv, st)
            x, (kvs, states) = lm._stack_scan(
                cfg, params["blocks"], body, x,
                (pk_all, pv_all, cache["conv"], cache["ssm"],
                 jnp.arange(cfg.n_layers, dtype=jnp.int32)))
        new_cache["k"], new_cache["v"] = kvs
        new_cache.update(states)

    elif fam == "encdec":
        # cross K/V come precomputed from encode_cross — the whole fold
        # (every chunk, cold or resumed) consumes the same arrays
        def dec_body(lp, x, inp):
            pk, pv, kx, vx = inp
            x, kv = lm.cross_block(cfg, lp, x, positions,
                                   (kx.astype(x.dtype), vx.astype(x.dtype)),
                                   q_offset=q_offset, kv_prefix=(pk, pv))
            return x, kv
        x, kvs = lm._stack_scan(cfg, params["dec_blocks"], dec_body, x,
                                (pk_all, pv_all, cache["xk"], cache["xv"]))
        new_cache["k"], new_cache["v"] = kvs
        new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]

    x = _norm_apply(cfg, params["final_norm"], x[:, -1:])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    return new_cache, logits[:, 0]


# ==========================================================================
# Decode (one token).
# ==========================================================================

def _decode_attn(cfg, p, x1, cache_k, cache_v, pos, *, window=0,
                 scales=None):
    """x1: (B,1,d).  Updates cache at ``pos`` and attends.

    ``scales``: (k_scale, v_scale) when the cache is int8-quantized
    (cfg.kv_quant) — writes quantize, reads dequantize (fused into the
    attention einsum's input).  Returns (out, new_k, new_v, new_scales).
    """
    B = x1.shape[0]
    q = _proj(x1, p["wq"], p.get("bq")).reshape(B, 1, cfg.n_heads, cfg.d_head)
    k1 = _proj(x1, p["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.d_head)
    v1 = _proj(x1, p["wv"], p.get("bv")).reshape(B, 1, cfg.n_kv_heads,
                                                 cfg.d_head)
    if cfg.pos_embedding == "rope":
        posb = jnp.broadcast_to(pos[None], (B, 1))
        q = rope.apply_rope(q, posb, cfg.rope_theta)
        k1 = rope.apply_rope(k1, posb, cfg.rope_theta)
    if scales is not None:
        ks, vs = scales
        k1q, k1s = kvquant.quantize(k1)
        v1q, v1s = kvquant.quantize(v1)
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k1q, pos, 1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v1q, pos, 1)
        ks = jax.lax.dynamic_update_slice_in_dim(ks, k1s, pos, 1)
        vs = jax.lax.dynamic_update_slice_in_dim(vs, v1s, pos, 1)
        k_full = kvquant.dequantize(cache_k, ks, cfg.dtype)
        v_full = kvquant.dequantize(cache_v, vs, cfg.dtype)
        o = attention.attend_decode(q, k_full, v_full, pos + 1,
                                    window=window)
        out = _proj(o.reshape(B, 1, cfg.n_heads * cfg.d_head), p["wo"],
                    p.get("bo"))
        return out, cache_k, cache_v, (ks, vs)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k1, pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v1, pos, axis=1)
    o = attention.attend_decode(q, cache_k, cache_v, pos + 1, window=window)
    out = _proj(o.reshape(B, 1, cfg.n_heads * cfg.d_head), p["wo"],
                p.get("bo"))
    return out, cache_k, cache_v, None


def decode_step(cfg: LMConfig, params, cache, tokens):
    """tokens: (B, 1).  Returns (new_cache, logits (B, vocab_padded))."""
    B = tokens.shape[0]
    pos = cache["len"]
    x = params["embed"][tokens]
    if cfg.pos_embedding == "sinusoidal":
        i = jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)
        ang = pos.astype(jnp.float32) / jnp.power(10000.0, i / cfg.d_model)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]
        x = x + pe.astype(x.dtype)
    fam = cfg.family
    new_cache = dict(cache)
    new_cache["len"] = pos + 1

    if fam in ("decoder", "moe"):
        blocks = params["blocks"]
        L = cfg.n_layers - (1 if fam == "moe" else 0)
        quant = cfg.kv_quant

        def layer_caches(sl):
            out = [cache["k"][sl], cache["v"][sl]]
            if quant:
                out += [cache["k_scale"][sl], cache["v_scale"][sl]]
            return out

        if fam == "moe":
            p0 = jax.tree.map(lambda a: a[0], params["dense0"])
            c0 = layer_caches(0)
            h, k0, v0, sc0 = _decode_attn(
                cfg, p0["attn"], _norm_apply(cfg, p0["ln1"], x),
                c0[0], c0[1], pos,
                scales=tuple(c0[2:]) if quant else None)
            x = x + h
            x = x + _mlp_apply(cfg, p0["mlp"], _norm_apply(cfg, p0["ln2"], x))

        def body(x, inp):
            lp, caches, idx = inp
            ck, cv = caches[0], caches[1]
            h, ck, cv, sc = _decode_attn(
                cfg, lp["attn"], _norm_apply(cfg, lp["ln1"], x), ck, cv,
                pos, window=layer_window(cfg, idx),
                scales=(caches[2], caches[3]) if quant else None)
            x = x + h
            z = _norm_apply(cfg, lp["ln2"], x)
            if fam == "moe":
                y, _ = lm.moe_ffn_decode(cfg, lp["moe"], z)
            else:
                y = _mlp_apply(cfg, lp["mlp"], z)
            outc = (ck, cv) + (sc if quant else ())
            return x + y, outc

        off = slice(1, None) if fam == "moe" else slice(None)
        x, outs = jax.lax.scan(
            body, x, (blocks, tuple(layer_caches(off)),
                      jnp.arange(L, dtype=jnp.int32)))
        ks, vs = outs[0], outs[1]
        if fam == "moe":
            ks = jnp.concatenate([k0[None], ks], 0)
            vs = jnp.concatenate([v0[None], vs], 0)
        new_cache["k"], new_cache["v"] = ks, vs
        if quant:
            kss, vss = outs[2], outs[3]
            if fam == "moe":
                kss = jnp.concatenate([sc0[0][None], kss], 0)
                vss = jnp.concatenate([sc0[1][None], vss], 0)
            new_cache["k_scale"], new_cache["v_scale"] = kss, vss

    elif fam == "rwkv":
        def body(x, inp):
            lp, wkv_st, sh1, sh2 = inp
            st = {"wkv": wkv_st, "shift1": sh1, "shift2": sh2}
            x, st = lm.rwkv_block(cfg, lp, x, st)
            return x, (st["wkv"], st["shift1"], st["shift2"])
        x, (wkv, s1, s2) = jax.lax.scan(
            body, x, (params["blocks"], cache["wkv"], cache["shift1"],
                      cache["shift2"]))
        new_cache.update(wkv=wkv, shift1=s1, shift2=s2)

    elif fam == "hybrid":
        quant = cfg.kv_quant

        def body(x, inp):
            if quant:
                lp, ck, cv, ks_, vs_, conv_st, ssm_st, idx = inp
                sc_in = (ks_, vs_)
            else:
                lp, ck, cv, conv_st, ssm_st, idx = inp
                sc_in = None
            z = _norm_apply(cfg, lp["ln1"], x)
            att, ck, cv, sc = _decode_attn(cfg, lp["attn"], z, ck, cv, pos,
                                           window=layer_window(cfg, idx),
                                           scales=sc_in)
            xz = _proj(z, lp["in_proj"])
            xm, gate = jnp.split(xz, 2, axis=-1)
            xm, conv_st = _causal_conv(xm, lp["conv_w"], conv_st)
            xm = jax.nn.silu(xm.astype(jnp.float32)).astype(x.dtype)
            dtr = lp["dt_proj"].shape[0]
            dbc = _proj(xm, lp["x_proj"])
            dt = jax.nn.softplus(
                _proj(dbc[..., :dtr], lp["dt_proj"]).astype(jnp.float32)
                + lp["dt_bias"].astype(jnp.float32))
            N = cfg.ssm_state
            y1, ssm_st = ssm.selective_step(
                xm[:, 0], dt[:, 0].astype(x.dtype), lp["A_log"],
                dbc[:, 0, dtr:dtr + N], dbc[:, 0, dtr + N:], lp["D_skip"],
                ssm_st)
            y = (y1[:, None] * jax.nn.silu(gate.astype(jnp.float32)
                                           ).astype(x.dtype))
            y = _proj(y, lp["ssm_out"])
            beta = lp["beta"].astype(jnp.float32)
            mixed = (beta[0] * _norm_apply(cfg, lp["norm_attn"], att
                                           ).astype(jnp.float32)
                     + beta[1] * _norm_apply(cfg, lp["norm_ssm"], y
                                             ).astype(jnp.float32)) * 0.5
            x = x + mixed.astype(x.dtype)
            x = x + _mlp_apply(cfg, lp["mlp"], _norm_apply(cfg, lp["ln2"], x))
            outc = (ck, cv) + (sc if quant else ()) + (conv_st, ssm_st)
            return x, outc

        xs_in = (params["blocks"], cache["k"], cache["v"])
        if quant:
            xs_in += (cache["k_scale"], cache["v_scale"])
        xs_in += (cache["conv"], cache["ssm"],
                  jnp.arange(cfg.n_layers, dtype=jnp.int32))
        x, outs = jax.lax.scan(body, x, xs_in)
        if quant:
            ks, vs, kss, vss, conv, ssm_s = outs
            new_cache.update(k=ks, v=vs, k_scale=kss, v_scale=vss,
                             conv=conv, ssm=ssm_s)
        else:
            ks, vs, conv, ssm_s = outs
            new_cache.update(k=ks, v=vs, conv=conv, ssm=ssm_s)

    elif fam == "encdec":
        def body(x, inp):
            lp, ck, cv, xk, xv = inp
            h, ck, cv, _ = _decode_attn(cfg, lp["attn"],
                                        _norm_apply(cfg, lp["ln1"], x),
                                        ck, cv, pos)
            x = x + h
            q = _proj(_norm_apply(cfg, lp["ln_x"], x), lp["xattn"]["wq"],
                      lp["xattn"].get("bq")).reshape(
                x.shape[0], 1, cfg.n_heads, cfg.d_head)
            o = attention.attend_decode(q, xk, xv, xk.shape[1])
            hx = _proj(o.reshape(x.shape[0], 1, -1), lp["xattn"]["wo"],
                       lp["xattn"].get("bo"))
            gate = jnp.tanh(lp["gate_attn"].astype(jnp.float32)).astype(x.dtype)
            x = x + gate * hx
            x = x + _mlp_apply(cfg, lp["mlp"], _norm_apply(cfg, lp["ln2"], x))
            return x, (ck, cv)
        x, (ks, vs) = jax.lax.scan(
            body, x, (params["dec_blocks"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        new_cache.update(k=ks, v=vs)

    elif fam == "vlm":
        k_ = cfg.cross_every
        G = cfg.n_layers // k_
        self_pp = jax.tree.map(
            lambda a: a.reshape((G, k_ - 1) + a.shape[1:]), params["blocks"])

        def group(x, inp):
            self_p, cross_p, ck, cv, ckx, cvx, xk, xv = inp

            def inner(x, sinp):
                lp, ck_i, cv_i = sinp
                h, ck_i, cv_i, _ = _decode_attn(
                    cfg, lp["attn"], _norm_apply(cfg, lp["ln1"], x),
                    ck_i, cv_i, pos)
                x = x + h
                x = x + _mlp_apply(cfg, lp["mlp"],
                                   _norm_apply(cfg, lp["ln2"], x))
                return x, (ck_i, cv_i)
            x, (ck, cv) = jax.lax.scan(inner, x, (self_p, ck, cv))
            h, ckx, cvx, _ = _decode_attn(cfg, cross_p["attn"],
                                          _norm_apply(cfg, cross_p["ln1"], x),
                                          ckx, cvx, pos)
            x = x + h
            q = _proj(_norm_apply(cfg, cross_p["ln_x"], x),
                      cross_p["xattn"]["wq"]).reshape(
                x.shape[0], 1, cfg.n_heads, cfg.d_head)
            o = attention.attend_decode(q, xk, xv, xk.shape[1])
            hx = _proj(o.reshape(x.shape[0], 1, -1), cross_p["xattn"]["wo"])
            gate = jnp.tanh(cross_p["gate_attn"].astype(jnp.float32)
                            ).astype(x.dtype)
            x = x + gate * hx
            x = x + _mlp_apply(cfg, cross_p["mlp"],
                               _norm_apply(cfg, cross_p["ln2"], x))
            return x, (ck, cv, ckx, cvx)

        x, (ks, vs, kxs, vxs) = jax.lax.scan(
            group, x, (self_pp, params["cross_blocks"], cache["k"],
                       cache["v"], cache["kx_self"], cache["vx_self"],
                       cache["xk"], cache["xv"]))
        new_cache.update(k=ks, v=vs, kx_self=kxs, vx_self=vxs)
    else:
        raise ValueError(fam)

    x = _norm_apply(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    return new_cache, logits[:, 0]


# ==========================================================================
# Decode (one token), in place against the paged block arena.
# ==========================================================================

def decode_step_paged(cfg: LMConfig, params, cache, tokens, *, tables, lens,
                      arena, wbids=None, kernel=None, interpret=None,
                      backend=None, cascade=None):
    """One batched decode tick reading K/V **in place** from the block arena.

    The gather-free counterpart of ``vmap(decode_step)`` over slot lanes:
    instead of materializing every lane's chain as a dense ``max_len``
    cache, each attention layer reads its K/V through the lane's block
    table (``lm.attn_decode_paged`` → ``attend_decode_paged`` in XLA, or
    the ``kernels/paged_attn.py`` scalar-prefetch kernel with
    ``kernel=True``), and the only persistent sequence-axis write is the
    new token's single row per layer, scattered once after the layer scan.

    cache   slot-stacked non-sequence state, exactly the paged adapter's
            dense dict: "len" (S,) plus hybrid conv/ssm and encdec/vlm
            xk/xv (leading axis = slot lanes).
    tokens  (S, 1) int32.
    tables  (S, nb) int32 arena block ids (trash-padded past each chain).
    lens    (S,) int32 per-lane lengths (== cache["len"]; the new token
            lands at position ``lens``).
    arena   :func:`init_paged_arena` dict (layer-leading block axis).
    wbids   (S,) int32 arena block each lane's new row lands in — the
            caller routes lanes that must not write (inactive, at capacity,
            pre-copy-on-write) to the trash block.  ``None`` derives the
            block from the table, routing out-of-range lanes to block 0
            (the pool's reserved trash block).

    Returns (new_arena, new_cache, logits (S, vocab_padded)).  With
    ``kernel=False`` the logits are bitwise-identical to the gather tick /
    dense-adapter oracle (pinned per family in tests/test_paged_decode.py):
    every position a lane can read holds the same bits in both layouts and
    everything else is masked to NEG_INF before the softmax.

    Maintenance note: the per-family layer bodies below deliberately
    mirror :func:`decode_step` (only the cache plumbing differs — scan xs
    are arena slices instead of per-layer dense caches, and the write is a
    row instead of a buffer).  Any numeric change to a family's decode
    body must land in BOTH functions; the bitwise parity suite exists to
    catch exactly that drift, so a paged-parity failure after touching
    :func:`decode_step` means this copy is stale, not that paging broke.
    """
    fam = cfg.family
    assert fam in ("decoder", "moe", "hybrid", "encdec", "vlm"), \
        f"in-place paged decode: unsupported family {fam}"
    # backend= is the per-layer read-path enum ("xla" | "pallas" |
    # "cascade", see repro.serve.backend); kernel= survives as its
    # deprecated boolean alias (True -> "pallas")
    if backend is None:
        backend = "pallas" if kernel else "xla"
    assert backend in ("xla", "pallas", "cascade"), \
        f"in-place paged decode: unknown backend {backend!r}"
    assert backend != "cascade" or cascade is not None, \
        "backend=\"cascade\" needs the group metadata in cascade="
    # encdec/vlm cache full-dtype (init_cache ignores kv_quant there)
    quant = cfg.kv_quant and fam not in ("encdec", "vlm")
    assert not (quant and backend != "xla"), \
        "in-place paged decode: only the XLA reference covers kv_quant"
    assert not (fam == "vlm" and backend != "xla"), \
        "in-place paged decode: only the XLA reference covers the vlm " \
        "grouped layout"
    S = tokens.shape[0]
    bs = arena["k"].shape[-3]
    nb = tables.shape[1]
    pos = jnp.asarray(lens, jnp.int32)
    offs = pos % bs
    if wbids is None:
        blk = jnp.take_along_axis(tables, jnp.minimum(pos // bs, nb - 1)
                                  [:, None], axis=1)[:, 0]
        wbids = jnp.where(pos >= nb * bs, 0, blk)    # 0 = trash block
    x = params["embed"][tokens]                       # (S, 1, d)
    if cfg.pos_embedding == "sinusoidal":
        i = jnp.arange(0, cfg.d_model, 2, dtype=jnp.float32)
        ang = pos[:, None].astype(jnp.float32) / \
            jnp.power(10000.0, i / cfg.d_model)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)[:, None, :]
        x = x + pe.astype(x.dtype)
    new_cache = dict(cache)
    new_cache["len"] = pos + 1

    def attn(lp, z, kb, vb, window=0, scales=None):
        """Returns (out, *rows): rows are the sequence-axis writes this
        layer owes the arena — (k1, v1) plain, + (k1_scale, v1_scale)
        under the int8 kv_quant layout."""
        out = lm.attn_decode_paged(cfg, lp, z, kb, vb, tables, pos,
                                   window=window, backend=backend,
                                   cascade=cascade, interpret=interpret,
                                   scales=scales)
        return out[0], out[1:]

    def layer_arenas(sl):
        out = (arena["k"][sl], arena["v"][sl])
        if quant:
            out += (arena["k_scale"][sl], arena["v_scale"][sl])
        return out

    def split_sc(arenas):
        return (arenas[:2], arenas[2:] if quant else None)

    if fam in ("decoder", "moe"):
        L = cfg.n_layers - (1 if fam == "moe" else 0)

        def body(x, inp):
            lp, idx = inp[0], inp[-1]
            (kb, vb), sc = split_sc(inp[1:-1])
            h, rows = attn(lp["attn"], _norm_apply(cfg, lp["ln1"], x),
                           kb, vb, window=layer_window(cfg, idx), scales=sc)
            x = x + h
            z = _norm_apply(cfg, lp["ln2"], x)
            if fam == "moe":
                # per-lane dispatch groups of one token, exactly the
                # vmapped dense tick's routing (a lane's output must not
                # depend on which other lanes share its decode batch)
                y = jax.vmap(lambda zi: lm.moe_ffn_decode(
                    cfg, lp["moe"], zi[None])[0][0])(z)
            else:
                y = _mlp_apply(cfg, lp["mlp"], z)
            return x + y, rows

        if fam == "moe":
            p0 = jax.tree.map(lambda a: a[0], params["dense0"])
            (kb0, vb0), sc0 = split_sc(layer_arenas(0))
            h, rows0 = attn(p0["attn"], _norm_apply(cfg, p0["ln1"], x),
                            kb0, vb0, scales=sc0)
            x = x + h
            x = x + _mlp_apply(cfg, p0["mlp"], _norm_apply(cfg, p0["ln2"], x))
        off = slice(1, None) if fam == "moe" else slice(None)
        x, rows = jax.lax.scan(
            body, x, (params["blocks"],) + layer_arenas(off)
            + (jnp.arange(L, dtype=jnp.int32),))
        if fam == "moe":
            rows = tuple(jnp.concatenate([r0[None], r], 0)
                         for r0, r in zip(rows0, rows))

    elif fam == "hybrid":
        def body(x, inp):
            lp, conv_st, ssm_st, idx = inp[0], inp[-3], inp[-2], inp[-1]
            (kb, vb), sc = split_sc(inp[1:-3])
            z = _norm_apply(cfg, lp["ln1"], x)
            att, kv_rows = attn(lp["attn"], z, kb, vb,
                                window=layer_window(cfg, idx), scales=sc)
            xz = _proj(z, lp["in_proj"])
            xm, gate = jnp.split(xz, 2, axis=-1)
            xm, conv_st = _causal_conv(xm, lp["conv_w"], conv_st)
            xm = jax.nn.silu(xm.astype(jnp.float32)).astype(x.dtype)
            dtr = lp["dt_proj"].shape[0]
            dbc = _proj(xm, lp["x_proj"])
            dt = jax.nn.softplus(
                _proj(dbc[..., :dtr], lp["dt_proj"]).astype(jnp.float32)
                + lp["dt_bias"].astype(jnp.float32))
            N = cfg.ssm_state
            y1, ssm_st = ssm.selective_step(
                xm[:, 0], dt[:, 0].astype(x.dtype), lp["A_log"],
                dbc[:, 0, dtr:dtr + N], dbc[:, 0, dtr + N:], lp["D_skip"],
                ssm_st)
            y = (y1[:, None] * jax.nn.silu(gate.astype(jnp.float32)
                                           ).astype(x.dtype))
            y = _proj(y, lp["ssm_out"])
            beta = lp["beta"].astype(jnp.float32)
            mixed = (beta[0] * _norm_apply(cfg, lp["norm_attn"], att
                                           ).astype(jnp.float32)
                     + beta[1] * _norm_apply(cfg, lp["norm_ssm"], y
                                             ).astype(jnp.float32)) * 0.5
            x = x + mixed.astype(x.dtype)
            x = x + _mlp_apply(cfg, lp["mlp"], _norm_apply(cfg, lp["ln2"], x))
            return x, kv_rows + (conv_st, ssm_st)

        x, outs = jax.lax.scan(
            body, x, (params["blocks"],) + layer_arenas(slice(None))
            + (jnp.moveaxis(cache["conv"], 1, 0)[:, :, 0],
               jnp.moveaxis(cache["ssm"], 1, 0)[:, :, 0],
               jnp.arange(cfg.n_layers, dtype=jnp.int32)))
        rows, (conv, ssm_s) = outs[:-2], outs[-2:]
        new_cache["conv"] = jnp.moveaxis(conv, 1, 0)[:, :, None]
        new_cache["ssm"] = jnp.moveaxis(ssm_s, 1, 0)[:, :, None]

    elif fam == "encdec":
        def body(x, inp):
            lp, kb, vb, xk, xv = inp
            h, rows = attn(lp["attn"], _norm_apply(cfg, lp["ln1"], x),
                           kb, vb)
            x = x + h
            q = _proj(_norm_apply(cfg, lp["ln_x"], x), lp["xattn"]["wq"],
                      lp["xattn"].get("bq")).reshape(
                S, 1, cfg.n_heads, cfg.d_head)
            o = attention.attend_decode(q, xk, xv, xk.shape[1])
            hx = _proj(o.reshape(S, 1, -1), lp["xattn"]["wo"],
                       lp["xattn"].get("bo"))
            gate = jnp.tanh(lp["gate_attn"].astype(jnp.float32)
                            ).astype(x.dtype)
            x = x + gate * hx
            x = x + _mlp_apply(cfg, lp["mlp"], _norm_apply(cfg, lp["ln2"], x))
            return x, rows

        x, rows = jax.lax.scan(
            body, x, (params["dec_blocks"], arena["k"], arena["v"],
                      jnp.moveaxis(cache["xk"], 1, 0)[:, :, 0],
                      jnp.moveaxis(cache["xv"], 1, 0)[:, :, 0]))

    elif fam == "vlm":
        k_ = cfg.cross_every
        G = cfg.n_layers // k_
        self_pp = jax.tree.map(
            lambda a: a.reshape((G, k_ - 1) + a.shape[1:]), params["blocks"])

        def group(x, inp):
            self_p, cross_p, kb_g, vb_g, kxb, vxb, xk, xv = inp

            def inner(x, sinp):
                lp, kb, vb = sinp
                h, rows_i = attn(lp["attn"], _norm_apply(cfg, lp["ln1"], x),
                                 kb, vb)
                x = x + h
                x = x + _mlp_apply(cfg, lp["mlp"],
                                   _norm_apply(cfg, lp["ln2"], x))
                return x, rows_i
            x, self_rows = jax.lax.scan(inner, x, (self_p, kb_g, vb_g))
            h, x_rows = attn(cross_p["attn"],
                             _norm_apply(cfg, cross_p["ln1"], x), kxb, vxb)
            x = x + h
            q = _proj(_norm_apply(cfg, cross_p["ln_x"], x),
                      cross_p["xattn"]["wq"]).reshape(
                S, 1, cfg.n_heads, cfg.d_head)
            o = attention.attend_decode(q, xk, xv, xk.shape[1])
            hx = _proj(o.reshape(S, 1, -1), cross_p["xattn"]["wo"])
            gate = jnp.tanh(cross_p["gate_attn"].astype(jnp.float32)
                            ).astype(x.dtype)
            x = x + gate * hx
            x = x + _mlp_apply(cfg, cross_p["mlp"],
                               _norm_apply(cfg, cross_p["ln2"], x))
            return x, (self_rows, x_rows)

        x, (self_rows, x_rows) = jax.lax.scan(
            group, x, (self_pp, params["cross_blocks"], arena["k"],
                       arena["v"], arena["kx_self"], arena["vx_self"],
                       jnp.moveaxis(cache["xk"], 1, 0)[:, :, 0],
                       jnp.moveaxis(cache["xv"], 1, 0)[:, :, 0]))
        # grouped rows: self k/v (G, k-1, S, Hkv, Dh), cross-layer self
        # k/v (G, S, Hkv, Dh) — ranks the generalized write below absorbs
        rows = (self_rows[0], self_rows[1], x_rows[0], x_rows[1])

    # the tick's only sequence-axis write: one (S, Hkv, Dh) row per layer
    # (+ the f32 scale rows under kv_quant), landed at (block, offset) per
    # lane — trash-routed lanes are absorbed by the reserved block 0.  The
    # kernel leg scatters through kernels.paged_attn.scatter_kv_rows,
    # whose input_output_aliases update the arena buffers in place instead
    # of functionally rebuilding every layer slice (XLA donation already
    # covers the .at[].set reference leg).
    new_arena = dict(arena)
    if fam == "vlm":
        row_keys = ("k", "v", "kx_self", "vx_self")
    else:
        row_keys = ("k", "v", "k_scale", "v_scale") if quant else ("k", "v")
    if backend == "pallas":
        from repro.kernels.paged_attn import scatter_kv_rows
        new_arena["k"], new_arena["v"] = scatter_kv_rows(
            arena["k"], arena["v"], rows[0], rows[1], wbids, offs,
            interpret=interpret)
    else:
        for key, r in zip(row_keys, rows):
            # leading layer axes vary per key (one for decoder k/v, two
            # for vlm's grouped self k/v, one for its cross-layer self
            # k/v); the (block, B=1, offset) triple always sits 5 axes
            # from the end — see arena_block_axis
            idx = (slice(None),) * (arena[key].ndim - 5) + (wbids, 0, offs)
            new_arena[key] = arena[key].at[idx].set(r)

    x = _norm_apply(cfg, params["final_norm"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    return new_arena, new_cache, logits[:, 0]
