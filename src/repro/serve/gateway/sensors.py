"""Sensor-fleet workload: deterministic Poisson/bursty arrival streams.

Each endpoint is an independent Markov-modulated Poisson source: it
alternates exponentially-distributed OFF (baseline rate) and ON
(``burst_factor`` x rate) phases, which produces the heavy-tailed arrival
clumps that make micro-batching interesting (a plain Poisson fleet barely
exercises the deadline/backpressure paths).  Everything is a pure function
of ``(seed, endpoint)``, so a trace is exactly reproducible and two runs
with different gateway configs see the *same* offered load.

Two endpoint kinds:
  frame  — 28x28 u8 sensor frames (synthetic digit set), the hybrid LeNet
           path;
  prompt — int32 token prompts for the LM path, lengths drawn from a small
           fixed set so slot-batcher prefill compiles stay bounded.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data import mnist_synth


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    n_endpoints: int = 64
    frame_rate_hz: float = 4.0        # mean per-endpoint baseline rate
    burst_factor: float = 4.0         # ON-phase rate multiplier
    burst_on_s: float = 0.5           # mean ON duration
    burst_off_s: float = 2.0          # mean OFF duration; <=0 disables bursts
    prompt_fraction: float = 0.0      # fraction of endpoints emitting prompts
    prompt_lens: tuple[int, ...] = (8, 12, 16)
    prompt_vocab: int = 256
    image_pool: int = 256             # synthetic frames to cycle through
    seed: int = 0

    @property
    def bursty(self) -> bool:
        return self.burst_off_s > 0 and self.burst_factor > 1


@dataclasses.dataclass(frozen=True)
class Arrival:
    uid: int
    t: float                          # seconds since trace start
    endpoint: int
    kind: str                         # "frame" | "prompt"
    payload: np.ndarray               # (28,28,1) u8 | (S,) int32
    label: int = -1                   # ground-truth digit for frames


def _endpoint_times(rng: np.random.Generator, cfg: FleetConfig,
                    duration: float) -> list[float]:
    ts: list[float] = []
    t, on = 0.0, False
    phase_end = (rng.exponential(cfg.burst_off_s) if cfg.bursty
                 else float("inf"))
    while t < duration:
        rate = cfg.frame_rate_hz * (cfg.burst_factor if on else 1.0)
        dt = rng.exponential(1.0 / rate)
        if t + dt > phase_end:
            t = phase_end
            on = not on
            phase_end = t + rng.exponential(
                cfg.burst_on_s if on else cfg.burst_off_s)
            continue
        t += dt
        if t < duration:
            ts.append(t)
    return ts


class SensorFleet:
    """Generates the merged, time-sorted arrival trace for the fleet."""

    def __init__(self, cfg: FleetConfig = FleetConfig()):
        self.cfg = cfg
        xtr, ytr, _, _ = mnist_synth.dataset(cfg.image_pool, 16, seed=1)
        self._frames = xtr               # (pool, 28, 28, 1) u8
        self._labels = ytr
        n_prompt = int(round(cfg.n_endpoints * cfg.prompt_fraction))
        self._prompt_endpoints = set(range(n_prompt))   # first N are textual

    def events(self, duration: float) -> list[Arrival]:
        cfg = self.cfg
        out: list[Arrival] = []
        for ep in range(cfg.n_endpoints):
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, ep]))
            for t in _endpoint_times(rng, cfg, duration):
                if ep in self._prompt_endpoints:
                    n = int(rng.choice(cfg.prompt_lens))
                    payload = rng.integers(0, cfg.prompt_vocab, size=n,
                                           dtype=np.int32)
                    out.append(Arrival(0, t, ep, "prompt", payload))
                else:
                    i = int(rng.integers(len(self._frames)))
                    out.append(Arrival(0, t, ep, "frame", self._frames[i],
                                       int(self._labels[i])))
        out.sort(key=lambda a: a.t)
        return [dataclasses.replace(a, uid=i) for i, a in enumerate(out)]

    def offered_load_hz(self) -> float:
        """Mean fleet arrival rate implied by the config (for reports)."""
        cfg = self.cfg
        if not cfg.bursty:
            return cfg.n_endpoints * cfg.frame_rate_hz
        on = cfg.burst_on_s / (cfg.burst_on_s + cfg.burst_off_s)
        rate = cfg.frame_rate_hz * ((1 - on) + on * cfg.burst_factor)
        return cfg.n_endpoints * rate
