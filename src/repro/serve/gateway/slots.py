"""Family-generic continuous batching: slot adapters + one scheduler loop.

The scheduler (admit / decode / retire) is family-agnostic; what differs
between model families is only how a slot's context is stored:

  StateSlotAdapter — O(1)-state families (rwkv): a request's entire context
    is a state pytree, so admission is a single scatter into the batched
    slot arrays and there are no position-alignment concerns.

  KVSlotAdapter — attention-cache families (decoder/moe/hybrid/encdec): each
    slot owns a B=1 cache (k/v padded to ``max_len``) with its *own* length,
    stacked on a leading slot axis.  The batched decode is a vmapped
    ``engine.decode_step``, which threads the per-slot lengths through
    ``attend_decode`` automatically — slots at different positions decode
    together in one fixed-shape compiled call.

  PagedKVSlotAdapter (serve/kvcache/, ``make_adapter(..., paged=True)``) —
    same families, same batcher surface, but slots hold block tables into a
    shared refcounted block pool instead of dense ``max_len`` buffers:
    prefix sharing, copy-on-write, LRU eviction, and block-granular
    admission.  The dense KVSlotAdapter remains the reference oracle the
    paged path is parity-tested against (tests/test_kvcache.py).

The batcher discovers paging hooks by presence: ``can_admit`` (queue while
the pool cannot cover a request's worst-case block demand),
``validate_request`` (reject at submit what could never fit), and
``slot_stats`` (per-request block accounting stamped onto the Request).

Both adapters mask state writes with the active-slot mask inside the
batched decode, so a freed (or never-admitted) slot keeps exactly the
state ``clear`` left it instead of decoding stale context forward between
retirement and the next admission.  ``clear`` semantics differ by adapter:
StateSlotAdapter zeroes the slot's state arrays; KVSlotAdapter resets the
slot's length to 0 — its k/v contents are stale but inert (nothing reads
past ``len``) and are fully overwritten by the next admission's padded
prefill.  Code that reads raw cache contents must consult ``len``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import LMConfig
from repro.serve import engine
from repro.serve.kvcache.pool import PoolExhausted


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    generated: list = dataclasses.field(default_factory=list)
    # paged-adapter accounting, stamped at retire (0 under dense slots)
    kv_blocks: int = 0
    prefix_hit_blocks: int = 0
    # prompt tokens whose prefill was skipped via a prefix-cache resume
    prefill_tokens_skipped: int = 0
    # cross-slice migration accounting (sharded gateway, serve/shard/)
    migrations: int = 0
    migration_bytes: int = 0
    # serving SLO timestamps (virtual clock; -1 = untracked): when the
    # request left the pending queue for a slot, and when its prefill
    # produced the first token — stamped by the batcher when it has a
    # clock, copied onto the RequestRecord at completion
    t_dequeue: float = -1.0
    t_admit: float = -1.0

    @property
    def done(self) -> bool:
        if self.eos_id is not None and self.generated and \
                self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new_tokens


# ==========================================================================
# Adapters.
# ==========================================================================

class StateSlotAdapter:
    """State-slot engine for the rwkv family (batched decode over slots)."""

    STATE_KEYS = ("wkv", "shift1", "shift2")

    def __init__(self, cfg: LMConfig, params, n_slots: int):
        assert cfg.family == "rwkv", cfg.family
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = None                      # O(1) state: no length cap
        self.state = engine.init_cache(cfg, n_slots, 1)
        self._prefill = jax.jit(lambda p, b: engine.prefill(cfg, p, b))

        def _step(p, state, tokens, mask):
            new_cache, logits = engine.decode_step(cfg, p, state, tokens)
            masked = {"len": state["len"]}
            for key in self.STATE_KEYS:
                m = mask.reshape((1, -1) + (1,) * (new_cache[key].ndim - 2))
                masked[key] = jnp.where(m, new_cache[key], state[key])
            return masked, logits
        self._decode = jax.jit(_step)

    def insert(self, slot: int, prompt: np.ndarray,
               max_new: int | None = None) -> int:
        cache1, logits = self._prefill(
            self.params, {"tokens": jnp.asarray(prompt[None])})
        for key in self.STATE_KEYS:
            self.state[key] = self.state[key].at[:, slot].set(
                cache1[key][:, 0])
        return int(jnp.argmax(logits[0]))

    def clear(self, slot: int) -> None:
        for key in self.STATE_KEYS:
            self.state[key] = self.state[key].at[:, slot].set(0)

    def decode(self, tokens: np.ndarray, active: np.ndarray) -> np.ndarray:
        self.state, logits = self._decode(
            self.params, self.state, jnp.asarray(tokens, jnp.int32)[:, None],
            jnp.asarray(active, bool))
        return np.asarray(jnp.argmax(logits, -1))

    def jit_fns(self) -> dict[str, object]:
        """Named jitted entry points, for obs.RecompileDetector.track."""
        return {"prefill": self._prefill, "decode": self._decode}

    def cost_args(self, prompt_len: int = 8) -> dict[str, tuple]:
        """``jit_fns`` paired with representative steady-state arguments,
        for obs.costmodel roofline attribution (``fn.lower(*args)`` —
        shapes only, nothing executes)."""
        batch = {"tokens": jnp.zeros((1, prompt_len), jnp.int32)}
        tokens = jnp.zeros((self.n_slots, 1), jnp.int32)
        mask = jnp.ones((self.n_slots,), bool)
        return {"prefill": (self._prefill, (self.params, batch)),
                "decode": (self._decode,
                           (self.params, self.state, tokens, mask))}


class KVSlotAdapter:
    """KV-slot engine for attention-cache families, per-slot lengths.

    The stacked cache holds one B=1 cache per slot (leading axis =
    ``n_slots``); ``cache["len"]`` is a (n_slots,) vector.  Decode is one
    jitted vmap of :func:`engine.decode_step` — fixed shapes, one
    compilation, any mix of slot positions.
    """

    # cache keys whose axis -3 is the sequence axis (padded to max_len);
    # cross-attention keys (xk/xv) are fixed-length and never padded.
    SEQ_KEYS = ("k", "v", "k_scale", "v_scale", "kx_self", "vx_self")

    def __init__(self, cfg: LMConfig, params, n_slots: int, max_len: int,
                 extras: Callable[[], dict] | None = None):
        assert cfg.family != "rwkv", "use StateSlotAdapter for rwkv"
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.extras = extras
        cache0 = engine.init_cache(cfg, 1, max_len)
        self.cache = jax.tree.map(
            lambda a: jnp.zeros((n_slots,) + a.shape, a.dtype), cache0)
        self._prefill = jax.jit(lambda p, b: engine.prefill(cfg, p, b))

        def _step(p, cache, tokens, mask):
            new_cache, logits = jax.vmap(
                lambda c, t: engine.decode_step(cfg, p, c, t),
                in_axes=(0, 0))(cache, tokens)
            sel = lambda new, old: jnp.where(
                mask.reshape((-1,) + (1,) * (new.ndim - 1)), new, old)
            return jax.tree.map(sel, new_cache, cache), logits
        self._decode = jax.jit(_step)

    def insert(self, slot: int, prompt: np.ndarray,
               max_new: int | None = None) -> int:
        if len(prompt) > self.max_len:
            raise ValueError(f"prompt length {len(prompt)} exceeds slot "
                             f"capacity {self.max_len}")
        batch = {"tokens": jnp.asarray(prompt[None])}
        if self.extras is not None:
            batch.update(self.extras())
        cache1, logits = self._prefill(self.params, batch)
        cache1 = dict(cache1)
        for key in self.SEQ_KEYS:
            if key in cache1:
                a = cache1[key]
                pad = [(0, 0)] * a.ndim
                pad[-3] = (0, self.max_len - a.shape[-3])
                cache1[key] = jnp.pad(a, pad)
        self.cache = jax.tree.map(lambda sl, c1: sl.at[slot].set(c1),
                                  self.cache, cache1)
        return int(jnp.argmax(logits[0]))

    def clear(self, slot: int) -> None:
        # length 0 masks the slot: its (garbage) decodes write at pos 0 and
        # never walk the cache forward; admission overwrites everything.
        self.cache["len"] = self.cache["len"].at[slot].set(0)

    def decode(self, tokens: np.ndarray, active: np.ndarray) -> np.ndarray:
        t = jnp.asarray(tokens, jnp.int32)[:, None, None]    # (slots, 1, 1)
        self.cache, logits = self._decode(self.params, self.cache, t,
                                          jnp.asarray(active, bool))
        self.last_logits = logits[:, 0]     # (n_slots, vocab) — parity tests
        return np.asarray(jnp.argmax(logits[:, 0], -1))

    def jit_fns(self) -> dict[str, object]:
        """Named jitted entry points, for obs.RecompileDetector.track."""
        return {"prefill": self._prefill, "decode": self._decode}

    def cost_args(self, prompt_len: int = 8) -> dict[str, tuple]:
        """``jit_fns`` paired with representative steady-state arguments,
        for obs.costmodel roofline attribution (``fn.lower(*args)`` —
        shapes only, nothing executes)."""
        batch = {"tokens": jnp.zeros((1, prompt_len), jnp.int32)}
        if self.extras is not None:
            batch.update(self.extras())
        tokens = jnp.zeros((self.n_slots, 1, 1), jnp.int32)
        mask = jnp.ones((self.n_slots,), bool)
        return {"prefill": (self._prefill, (self.params, batch)),
                "decode": (self._decode,
                           (self.params, self.cache, tokens, mask))}


def make_adapter(cfg: LMConfig, params, n_slots: int, max_len: int = 128,
                 extras: Callable[[], dict] | None = None, *,
                 paged: bool = False, block_size: int = 16,
                 num_blocks: int | None = None, chunked: bool = True,
                 inplace: bool | None = None, kernel: bool | None = None,
                 mesh=None, backend: str | None = None):
    """Family dispatch: state slots for rwkv, KV slots for everything else.

    ``paged=True`` swaps the dense per-slot KV buffers for the block-pool
    adapter (``serve/kvcache/``): same batcher surface, shared-prefix blocks,
    and admission priced in blocks instead of whole slots.  ``chunked``
    (paged only) prefills via the block-size chunk fold so prefix hits skip
    recomputing the shared prompt; ``chunked=False`` keeps the one-shot
    prefill with storage-only sharing.  ``backend`` (paged only) picks the
    decode tick's attention dataflow — ``"gather"`` (the PR 2
    gather->decode->scatter parity oracle), ``"xla"`` (in-place tick, XLA
    reference read), ``"pallas"`` (in-place tick, Pallas paged-attention
    kernel), ``"cascade"`` (in-place tick with shared-prefix cascade
    grouping); None probes the platform (``serve.backend.auto_backend``).
    The old ``inplace``/``kernel`` booleans are deprecated aliases mapped
    by ``serve.backend.resolve_backend`` (with a ``DeprecationWarning``).
    ``mesh`` (paged only) commits the adapter's arena/params to a
    serving-mesh slice with ``engine.arena_specs`` placement — the
    sharded-serving entry point (serve/shard/; a single-device slice stays
    bitwise-identical to the unsharded adapter).  rwkv has O(1) state, so
    ``paged`` is a no-op for it.
    """
    if mesh is not None and (not paged or cfg.family == "rwkv"):
        # silently returning an unplaced adapter would defeat the sharding
        # without any signal — only the paged attention families commit
        # their state to a mesh slice
        raise ValueError("mesh placement requires paged=True and a "
                         f"non-rwkv family (got paged={paged}, "
                         f"family={cfg.family})")
    if cfg.family == "rwkv":
        return StateSlotAdapter(cfg, params, n_slots)
    if paged:
        from repro.serve.kvcache import PagedKVSlotAdapter
        return PagedKVSlotAdapter(cfg, params, n_slots, max_len,
                                  block_size=block_size,
                                  num_blocks=num_blocks, extras=extras,
                                  chunked=chunked, inplace=inplace,
                                  kernel=kernel, mesh=mesh, backend=backend)
    return KVSlotAdapter(cfg, params, n_slots, max_len, extras)


# ==========================================================================
# The scheduler loop (family-agnostic).
# ==========================================================================

class ContinuousBatcher:
    """vLLM-style continuous batching over any slot adapter.

    Flow per step():
      1. admit: for each free slot, pop a pending request, prefill (B=1) and
         scatter its context into the slot; a request whose prefill token
         already finishes it (EOS or a 1-token budget) retires immediately
         without occupying the slot;
      2. decode: one batched decode over all slots;
      3. retire: finished requests free their slot and the adapter zeroes
         the slot's state so it cannot keep evolving between admissions.
    """

    def __init__(self, adapter):
        self.adapter = adapter
        self.n_slots = adapter.n_slots
        self.pending: deque[Request] = deque()
        self.active: list[Request | None] = [None] * self.n_slots
        self.last_token = np.zeros((self.n_slots,), np.int32)
        self.peak_active = 0            # max concurrent slots ever decoded
        self.last_active = 0            # slots decoding in the latest step
        # observability hooks (serve/obs/), wired by the prompt gateways
        # for the duration of a run; all None by default and every use is
        # guarded, so a bare batcher makes zero obs calls
        self.clock = None               # SimClock for t_dequeue/t_admit
        self.tracer = None              # span recorder
        self.trace_pid = 1              # engine track (1 + slice_idx)

    def submit(self, req: Request):
        if self.adapter.max_len is not None and \
                len(req.prompt) + req.max_new_tokens > self.adapter.max_len:
            raise ValueError(
                f"request {req.uid}: prompt {len(req.prompt)} + "
                f"{req.max_new_tokens} new tokens exceeds slot capacity "
                f"{self.adapter.max_len}")
        validate = getattr(self.adapter, "validate_request", None)
        if validate is not None:        # paged: whole-pool capacity bound
            validate(len(req.prompt), req.max_new_tokens)
        self.pending.append(req)

    def _admissible(self, req: Request) -> bool:
        can = getattr(self.adapter, "can_admit", None)
        return can is None or can(req.prompt, req.max_new_tokens)

    def _stamp_stats(self, slot: int, req: Request) -> None:
        stats = getattr(self.adapter, "slot_stats", None)
        if stats is not None:
            st = stats(slot)
            req.kv_blocks = st.get("kv_blocks", 0)
            req.prefix_hit_blocks = st.get("prefix_hit_blocks", 0)
            req.prefill_tokens_skipped = st.get("prefill_tokens_skipped", 0)

    @property
    def busy(self) -> bool:
        return bool(self.pending) or any(r is not None for r in self.active)

    def debug_state(self) -> dict:
        """Occupancy snapshot for incident bundles (serve/obs/incident.py):
        queue depth and per-slot uids only — never prompt or token payloads,
        so a bundle can leave the machine."""
        return {
            "n_slots": self.n_slots,
            "pending": len(self.pending),
            "pending_uids": [r.uid for r in list(self.pending)[:16]],
            "active_uids": [None if r is None else r.uid
                            for r in self.active],
            "last_active": self.last_active,
            "peak_active": self.peak_active,
        }

    def _now(self) -> float:
        """Virtual time for SLO stamps: the tracer's (possibly
        wall-interpolated) clock when tracing, the bare clock when only SLO
        stamping is on, -1 (untracked) for a bare batcher."""
        if self.tracer is not None:
            return self.tracer.now()
        if self.clock is not None:
            return self.clock.t
        return -1.0

    def _retire_trace(self, req: Request, reason: str) -> None:
        # the guard heals requests that went active before the tracer was
        # wired (no decode span to close)
        if self.tracer is not None and \
                self.tracer.innermost(tid=req.uid) == "decode":
            self.tracer.end("decode", tid=req.uid,
                            args={"tokens": len(req.generated),
                                  "retire": reason})

    def step(self, decode: bool = True) -> list[Request]:
        """Admit + one decode tick.  Returns requests completed this tick.

        ``decode=False`` is the prefill-role mode of the disaggregated
        gateway (serve/shard/): admit pending requests (chunked prefill)
        and retire at-capacity / EOS-at-prefill lanes, but skip the
        batched decode — admitted lanes keep their prefill token staged in
        ``last_token`` and wait for the router to hand them off to a
        decode slice.  The default path is untouched."""
        tr = self.tracer
        if tr is not None:
            tr.begin("tick", pid=self.trace_pid, tid=0)
        finished: list[Request] = []
        stalled = False                 # FIFO: head can't admit -> stop
        for slot in range(self.n_slots):
            while self.active[slot] is None and self.pending and not stalled:
                if not self._admissible(self.pending[0]):
                    stalled = True      # blocks free up as requests retire
                    break
                req = self.pending.popleft()
                req.t_dequeue = self._now()
                if tr is not None:
                    if tr.innermost(tid=req.uid) != "queue_wait":
                        # submitted before the tracer was wired (direct
                        # batcher submit, pre-run queueing): open the
                        # lifecycle late so the rest of it is traced
                        tr.begin("request", tid=req.uid,
                                 args={"late_open": True})
                        tr.begin("queue_wait", tid=req.uid)
                    tr.end("queue_wait", tid=req.uid)
                    tr.begin("prefill", tid=req.uid,
                             args={"prompt_len": len(req.prompt)})
                    # chunk spans from the paged adapter's fold land on
                    # this request's track without threading uids through
                    tr.set_ctx(req.uid)
                try:
                    tok = self.adapter.insert(
                        slot, np.asarray(req.prompt, np.int32),
                        max_new=req.max_new_tokens)
                except PoolExhausted:
                    # insert rolled its allocations back; requeue at the
                    # head and let retirements free blocks (can_admit makes
                    # this unreachable, but admission must degrade to
                    # queueing, never to a crashed serving loop)
                    self.pending.appendleft(req)
                    stalled = True
                    if tr is not None:
                        tr.end("prefill", tid=req.uid,
                               args={"admitted": False})
                        tr.begin("queue_wait", tid=req.uid)
                    break
                req.t_admit = self._now()
                if tr is not None:
                    tr.end("prefill", tid=req.uid,
                           args={"slot": slot})
                req.generated.append(tok)
                if req.done:            # EOS fired on the prefill token
                    self._stamp_stats(slot, req)
                    self.adapter.clear(slot)
                    finished.append(req)
                    continue
                if tr is not None:
                    tr.begin("decode", tid=req.uid)
                self.active[slot] = req
                self.last_token[slot] = tok
        # a slot whose context filled every KV block cannot take another
        # token — surface it as finished instead of letting its next write
        # be silently clamped onto the final (possibly shared) block
        cap = getattr(self.adapter, "at_capacity", None)
        if cap is not None:
            for slot, req in enumerate(self.active):
                if req is not None and cap(slot):
                    self._stamp_stats(slot, req)
                    self._retire_trace(req, "at_capacity")
                    finished.append(req)
                    self.active[slot] = None
                    self.adapter.clear(slot)
                    self.last_token[slot] = 0
        active = np.asarray([r is not None for r in self.active])
        self.last_active = int(active.sum())
        self.peak_active = max(self.peak_active, self.last_active)
        if not active.any():
            if tr is not None:
                tr.end("tick", pid=self.trace_pid, tid=0,
                       args={"active": 0, "finished": len(finished)})
            return finished
        if not decode:
            if tr is not None:
                tr.end("tick", pid=self.trace_pid, tid=0,
                       args={"active": self.last_active,
                             "finished": len(finished), "decode": False})
            return finished
        toks = self.adapter.decode(self.last_token, active)
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            tok = int(toks[slot])
            req.generated.append(tok)
            self.last_token[slot] = tok
            if req.done:
                self._stamp_stats(slot, req)
                self._retire_trace(req, "done")
                finished.append(req)
                self.active[slot] = None
                self.adapter.clear(slot)
                self.last_token[slot] = 0
        if tr is not None:
            tr.end("tick", pid=self.trace_pid, tid=0,
                   args={"active": self.last_active,
                         "finished": len(finished)})
        return finished

    def run(self) -> list[Request]:
        """Drain the queue; returns all completed requests."""
        done: list[Request] = []
        while self.busy:
            done.extend(self.step())
        return done
