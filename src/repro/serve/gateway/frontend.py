"""The separable at-sensor stage and its link-payload accounting.

Two partitions of the hybrid LeNet pipeline across the sensor->host link:

  sc      — the paper's design point.  The SC engine's power envelope
            (~33 mW flat across precisions, Table 3) fits at the sensor, so
            conv1 (+ the trivial 2x2 sign max-pool) runs there and the link
            carries ternary features packed at 2 bits/value as int8 words.
  binary  — the conventional baseline.  The k-bit MAC datapath's power
            (325 mW at 4 bits) does not fit the sensor envelope, so raw
            8-bit pixels cross the link and conv1 runs host-side.

Both partitions compute the *same* function (sign conv1 -> pool -> binary
tail), so accuracy is comparable and the measured difference is exactly
what the paper claims: energy and bytes moved.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import energy
from repro.core.sc_layer import SCConfig
from repro.models import lenet
from repro.models.lenet import LeNetConfig


@dataclasses.dataclass(frozen=True)
class FrontendSpec:
    mode: str = "sc"                 # "sc" | "binary"
    bits: int = 4                    # stream length 2**bits / MAC width
    sc_impl: str = "table"
    # near-sensor engine geometry: 8 first-layer kernels keep the packed
    # feature payload (2 bits x 14x14x8 = 392 B) under the raw-pixel payload
    # (784 B) that the binary partition must move.
    lenet: LeNetConfig = LeNetConfig(conv1_filters=8, conv2_filters=16,
                                     dense=64)

    @property
    def sc_cfg(self) -> SCConfig:
        return SCConfig(bits=self.bits, adder="tff")


# --------------------------------------------------------------------------
# Link payload accounting.
# --------------------------------------------------------------------------

def link_bytes_per_frame(spec: FrontendSpec) -> int:
    """Bytes/frame crossing the sensor->host link."""
    c = spec.lenet
    if spec.mode == "sc":
        n_values = (c.image_size // 2) ** 2 * c.conv1_filters
        return -(-2 * n_values // 8)          # 2-bit ternary, packed
    if spec.mode == "binary":
        return c.image_size ** 2 * c.channels  # raw 8-bit pixels
    raise ValueError(spec.mode)


def link_energy_nj(n_bytes: int) -> float:
    """Energy to move ``n_bytes`` over the sensor->host link — the exact
    expression the telemetry ledger charges, factored out so the tracer's
    per-stage energy attribution (serve/obs/) prices link bytes with the
    same floats the ledger folds (bitwise conservation, not tolerance)."""
    from repro.serve.gateway.telemetry import E_LINK_PJ_PER_BYTE
    return n_bytes * E_LINK_PJ_PER_BYTE * 1e-3


def frame_energy_nj(spec: FrontendSpec) -> float:
    """First-layer compute energy/frame from the calibrated Table-3 model,
    projected onto this spec's layer geometry."""
    c = spec.lenet
    r = energy.scaled_report(
        spec.bits,
        k_window=c.ksize * c.ksize * c.channels,
        n_units=c.image_size ** 2,
        n_kernels=c.conv1_filters)
    return r.sc_energy_nj if spec.mode == "sc" else r.bin_energy_nj


def lm_token_energy_nj(spec: FrontendSpec, d_model: int) -> float:
    """Per-token first-projection energy for the LM path.

    The near-sensor frontend of a prompt endpoint is the embedding-row
    projection: one ``d_model``-wide dot-product window per token (one
    "unit", ``n_kernels`` weight passes), run through the same calibrated
    Table-3 model (``energy.scaled_report``) the frame path charges —
    so frame and LM requests land in the ledger in the same joules.
    """
    r = energy.scaled_report(spec.bits, k_window=d_model, n_units=1,
                             n_kernels=spec.lenet.conv1_filters)
    return r.sc_energy_nj if spec.mode == "sc" else r.bin_energy_nj


def migration_energy_nj(spec: FrontendSpec, n_bytes: int) -> float:
    """Energy charged for moving ``n_bytes`` of KV blocks between gateway
    slices (serve/shard/ block migration).

    Each migrated byte is priced as one 8-bit window pass through the
    calibrated k-bit binary datapath (``energy.scaled_report`` with
    ``k_window=8, n_units=1, n_kernels=1`` — migration always rides the
    binary partition; there is no stochastic re-encode on a host-to-host
    move) plus the per-byte link cost.  Charged onto the migrated
    request's ledger entry, so the fleet total stays conserved.
    """
    from repro.serve.gateway.telemetry import E_LINK_PJ_PER_BYTE
    r = energy.scaled_report(spec.bits, k_window=8, n_units=1, n_kernels=1)
    return n_bytes * (r.bin_energy_nj + E_LINK_PJ_PER_BYTE * 1e-3)


def sensor_latency_s(spec: FrontendSpec) -> float:
    """At-sensor processing latency before the payload hits the link: the SC
    engine streams 2**bits cycles/frame; the binary partition transmits
    immediately (its compute cost lands host-side in the service time)."""
    if spec.mode != "sc":
        return 0.0
    c = spec.lenet
    passes = c.conv1_filters / energy.N_KERNELS
    return energy.frame_time_us(spec.bits) * passes * 1e-6


# --------------------------------------------------------------------------
# The two pipeline stages (pure functions of (params, batch)).
# --------------------------------------------------------------------------

def pack_ternary(h: jax.Array) -> jax.Array:
    """(B, ...) values in {-1,0,1} -> (B, ceil(n/4)) uint8, 2 bits/value.
    This IS the wire format: payload.nbytes matches link_bytes_per_frame."""
    B = h.shape[0]
    q = (h + 1.0).astype(jnp.uint8).reshape(B, -1)    # {0,1,2}
    pad = (-q.shape[1]) % 4
    q = jnp.pad(q, ((0, 0), (0, pad))).reshape(B, -1, 4)
    return (q[..., 0] | (q[..., 1] << 2) | (q[..., 2] << 4)
            | (q[..., 3] << 6)).astype(jnp.uint8)


def unpack_ternary(packed: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`pack_ternary` -> float32 values in {-1,0,1}."""
    B = packed.shape[0]
    shifts = jnp.asarray([0, 2, 4, 6], jnp.uint8)
    vals = (packed[..., None] >> shifts) & jnp.uint8(3)   # (B, n/4, 4)
    n = 1
    for d in shape:
        n *= d
    return vals.reshape(B, -1)[:, :n].astype(jnp.float32).reshape(
        (B,) + shape) - 1.0


def _pooled_shape(cfg: LeNetConfig) -> tuple[int, int, int]:
    return (cfg.image_size // 2, cfg.image_size // 2, cfg.conv1_filters)


def sensor_stage(params, frames_u8: jax.Array, spec: FrontendSpec):
    """At-sensor compute.  frames_u8: (B, 28, 28, 1) uint8.

    Returns the link payload: 2-bit-packed pooled ternary features for
    "sc", the untouched frames for "binary" (sensor is a pass-through)."""
    if spec.mode == "binary":
        return frames_u8
    x01 = frames_u8.astype(jnp.float32) / 255.0
    h1 = lenet.first_layer(params, x01, mode="sc", sc_cfg=spec.sc_cfg,
                           sc_impl=spec.sc_impl)      # (B,28,28,C) {-1,0,1}
    return pack_ternary(lenet._maxpool(h1))           # (B, 2*14*14*C/8) u8


def gateway_stage(params, payload: jax.Array, spec: FrontendSpec):
    """Host-side compute: the binary-domain remainder (plus conv1 for the
    binary partition).  Returns class logits (B, classes)."""
    cfg = spec.lenet
    if spec.mode == "binary":
        x01 = payload.astype(jnp.float32) / 255.0
        h1 = lenet.first_layer(params, x01, mode="binary", bits=spec.bits)
        h = lenet._maxpool(h1)
    else:
        h = unpack_ternary(payload, _pooled_shape(cfg))
    h = jax.nn.relu(lenet._conv(h, params["conv2"]["w"],
                                params["conv2"]["b"]))
    h = lenet._maxpool(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["dense1"]["w"] + params["dense1"]["b"])
    return h @ params["dense2"]["w"] + params["dense2"]["b"]
