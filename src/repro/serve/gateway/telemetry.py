"""Per-request energy/bandwidth/latency accounting for the gateway.

Every completed request is charged:
  - frontend energy — the calibrated gate-level model of ``core.energy``
    projected onto the serving layer's geometry (``scaled_report``): SC
    streams for the sc frontend, the k-bit MAC datapath for binary;
  - link energy — bytes crossing the sensor->host link at a nominal
    near-sensor serial-link cost (``E_LINK_PJ_PER_BYTE``).

The ledger keeps an independent running fleet total next to the per-request
records; ``assert_conserved`` checks they agree exactly (no energy is
created or dropped by the aggregation), which the tier-1 suite exercises.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# ~10 pJ/bit: MIPI-class near-sensor serial link at 65nm (order-of-magnitude
# constant; what matters for the paper's claim is bytes, reported alongside).
E_LINK_PJ_PER_BYTE = 80.0


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    uid: int
    endpoint: int
    kind: str                    # "frame" | "prompt"
    t_arrival: float
    t_done: float
    energy_nj: float             # frontend + link
    link_bytes: int
    output: int = -1             # predicted class / last token
    kv_blocks: int = 0           # paged KV blocks reserved (0 = dense slots)
    prefix_hit_blocks: int = 0   # of those, satisfied from the radix index
    # prompt tokens never prefilled (prefix-cache resume); energy_nj covers
    # only the tokens actually processed, energy_saved_nj is the frontend
    # energy those skipped tokens would have cost (scaled_report pricing)
    prefill_tokens_skipped: int = 0
    energy_saved_nj: float = 0.0
    # cross-slice KV-block migration (sharded gateway): bytes this request's
    # context moved between slices; the move's energy is already inside
    # energy_nj (frontend.migration_energy_nj), keeping the ledger conserved
    migration_bytes: int = 0
    migrations: int = 0
    # serving SLO timestamps (virtual clock; -1 = not tracked): when the
    # request left the queue for its slot, and when its first token existed
    # (prefill done) — TTFT/TPOT and the queue-wait breakdown in report()
    t_dequeue: float = -1.0
    t_admit: float = -1.0
    tokens_out: int = 0          # generated tokens (TPOT denominator)

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrival


class Telemetry:
    """Append-only request ledger + conserved fleet totals."""

    def __init__(self):
        # (uid, kind, reason, t) rejections; indices 0/1 keep the legacy
        # (uid, kind) tuple shape for existing consumers
        self.records: list[RequestRecord] = []
        self.dropped: list[tuple[int, str, str, float]] = []
        self._fleet_energy_nj = 0.0
        self._fleet_link_bytes = 0
        self.pool: dict = {}          # paged KV pool snapshot (LM path)
        self.pools: dict = {}         # per-slice snapshots (sharded gateway)
        self.routing: dict = {}       # cross-slice routing/migration counts
        self.series: list[dict] = []  # interval metric snapshots (serve/obs)

    # -- charging ----------------------------------------------------------
    def record(self, rec: RequestRecord) -> None:
        self.records.append(rec)
        self._fleet_energy_nj += rec.energy_nj
        self._fleet_link_bytes += rec.link_bytes

    def drop(self, uid: int, kind: str, reason: str = "unspecified",
             t: float = 0.0) -> None:
        """Rejection accounting: *why* (queue-full / capacity / deadline /
        pool-exhausted) and *when* (virtual clock), not just who.  The old
        2-tuple call shape still works — reason/t default."""
        self.dropped.append((uid, kind, reason, t))

    def record_pool(self, stats: dict, slice_idx: int | None = None) -> None:
        """Snapshot the paged KV pool's counters (blocks in use, prefix-hit
        rate, bytes saved vs dense, evictions) into the ledger.  The
        sharded gateway passes ``slice_idx`` to keep one snapshot per mesh
        slice (``pools``); ``pool`` then aggregates the additive counters
        across slices."""
        if slice_idx is None:
            self.pool = dict(stats)
            return
        self.pools[slice_idx] = dict(stats)
        agg: dict = {}
        for st in self.pools.values():
            for k, v in st.items():
                if k == "block_size" or isinstance(v, bool) or \
                        not isinstance(v, (int, float)):
                    agg[k] = v                   # per-slice constant
                elif k == "prefix_hit_rate":
                    agg[k] = agg.get(k, 0.0)     # re-derived below
                elif k.startswith("peak_"):
                    # per-slice high-water marks are asynchronous: their
                    # sum overstates any fleet-simultaneous peak.  Max is
                    # the defensible aggregate (a lower bound on the true
                    # fleet peak); the per-slice marks stay in ``pools``
                    agg[k] = max(agg.get(k, 0), v)
                else:
                    agg[k] = agg.get(k, 0) + v   # additive counter
        # the fleet hit rate comes from the summed raw counters, not a
        # mean of per-slice rates (a busy cold slice would otherwise be
        # averaged 1:1 against an idle warm one)
        q = agg.get("prefix_queries", 0)
        agg["prefix_hit_rate"] = (agg.get("prefix_hits", 0) / q) if q \
            else 0.0
        agg["n_slices"] = len(self.pools)
        self.pool = agg

    def record_routing(self, counts: dict) -> None:
        """Cross-slice routing decisions + migration totals (sharded
        gateway): affinity vs load routes, spills, migrations, bytes."""
        self.routing = dict(counts)

    def record_series(self, samples: list[dict]) -> None:
        """Attach the interval metric snapshots a run sampled
        (serve/obs.MetricsRegistry): occupancy/queue-depth curves ride in
        ``report()`` next to the end-of-run aggregates."""
        self.series = list(samples)

    # -- aggregation -------------------------------------------------------
    @property
    def fleet_energy_nj(self) -> float:
        return self._fleet_energy_nj

    @property
    def fleet_link_bytes(self) -> int:
        return self._fleet_link_bytes

    def assert_conserved(self) -> None:
        per_req = sum(r.energy_nj for r in self.records)
        if not np.isclose(per_req, self._fleet_energy_nj, rtol=0, atol=1e-9):
            raise AssertionError(
                f"energy ledger leak: sum(per-request)={per_req} != "
                f"fleet total={self._fleet_energy_nj}")
        if sum(r.link_bytes for r in self.records) != self._fleet_link_bytes:
            raise AssertionError("link-byte ledger leak")

    def report(self, duration_s: float, kind: str | None = None) -> dict:
        recs = [r for r in self.records
                if kind is None or r.kind == kind]
        dropped = [d for d in self.dropped
                   if kind is None or d[1] == kind]
        out = {
            "completed": len(recs),
            "dropped": len(dropped),
            # n_samples rides along so downstream gates (check_bench) can
            # refuse percentile claims built on tiny samples
            "n_samples": len(recs),
            "throughput_hz": len(recs) / duration_s if duration_s > 0
            else 0.0,
        }
        if dropped:
            by_reason: dict[str, int] = {}
            for d in dropped:
                r = d[2] if len(d) > 2 else "unspecified"
                by_reason[r] = by_reason.get(r, 0) + 1
            out["dropped_by_reason"] = by_reason
        if recs:
            lat = np.asarray([r.latency_s for r in recs])
            energy = np.asarray([r.energy_nj for r in recs])
            link = np.asarray([r.link_bytes for r in recs])
            out.update(
                p50_latency_ms=float(np.percentile(lat, 50) * 1e3),
                p99_latency_ms=float(np.percentile(lat, 99) * 1e3),
                mean_energy_nj=float(energy.mean()),
                j_per_inference=float(energy.mean() * 1e-9),
                link_bytes_per_req=float(link.mean()),
            )
            kv = sum(r.kv_blocks for r in recs)
            if kv:
                out["kv_blocks_per_req"] = kv / len(recs)
                out["kv_prefix_hit_blocks_per_req"] = \
                    sum(r.prefix_hit_blocks for r in recs) / len(recs)
                out["prefill_tokens_skipped_per_req"] = \
                    sum(r.prefill_tokens_skipped for r in recs) / len(recs)
                out["prefill_energy_saved_nj"] = \
                    float(sum(r.energy_saved_nj for r in recs))
            mig = sum(r.migrations for r in recs)
            if mig:
                out["migrations"] = mig
                out["migration_bytes_total"] = \
                    int(sum(r.migration_bytes for r in recs))
            # serving SLO stats, from requests that tracked the admission
            # timestamps (LM paths; frame requests have no queue/prefill
            # split so they simply don't contribute)
            slo = [r for r in recs if r.t_admit >= 0]
            if slo:
                ttft = np.asarray([r.t_admit - r.t_arrival for r in slo])
                tpot = np.asarray([(r.t_done - r.t_admit)
                                   / max(1, r.tokens_out - 1) for r in slo])
                out.update(
                    slo_n_samples=len(slo),
                    ttft_p50_ms=float(np.percentile(ttft, 50) * 1e3),
                    ttft_p99_ms=float(np.percentile(ttft, 99) * 1e3),
                    tpot_p50_ms=float(np.percentile(tpot, 50) * 1e3),
                    tpot_p99_ms=float(np.percentile(tpot, 99) * 1e3),
                )
                qw = [r for r in slo if r.t_dequeue >= 0]
                if qw:
                    w = np.asarray([r.t_dequeue - r.t_arrival for r in qw])
                    out["queue_wait_p50_ms"] = \
                        float(np.percentile(w, 50) * 1e3)
                    out["queue_wait_p99_ms"] = \
                        float(np.percentile(w, 99) * 1e3)
        if self.pool and kind in (None, "prompt"):
            out["pool"] = dict(self.pool)
        if self.pools and kind in (None, "prompt"):
            out["pools"] = {i: dict(st) for i, st in self.pools.items()}
        if self.routing and kind in (None, "prompt"):
            out["routing"] = dict(self.routing)
        if self.series:
            out["series"] = list(self.series)
        return out
