"""Near-sensor serving gateway.

The paper's premise is that the stochastic first layer is cheap enough to
live *at the sensor*, so that reduced features — not raw pixels — cross the
link to the host.  This package models the serving side of that story:

  sensors.py   — a fleet of sensor endpoints emitting Poisson/bursty streams
                 of frames (and token prompts for the LM path)
  gateway.py   — the async micro-batching front door: fixed bucket shapes
                 (so jit never recompiles), per-bucket deadlines, admission
                 control and backpressure
  frontend.py  — the separable at-sensor stage (SC vs binary first layer)
                 and its link-payload accounting
  telemetry.py — per-request energy (core.energy's calibrated model) + link
                 bytes, aggregated into p50/p99 latency, throughput and
                 J/inference
  slots.py     — the family-generic slot batcher (state-slot for rwkv,
                 per-slot-length KV slots for attention families) behind one
                 adapter interface
"""
from repro.serve.gateway.slots import (ContinuousBatcher, KVSlotAdapter,
                                       Request, StateSlotAdapter,
                                       make_adapter)

__all__ = ["ContinuousBatcher", "KVSlotAdapter", "Request",
           "StateSlotAdapter", "make_adapter"]
