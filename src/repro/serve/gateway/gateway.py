"""Async micro-batching front door over virtual time.

JetStream/SHARK-style serving shape: a small fixed set of padded batch
shapes ("buckets"), one pre-compiled entry point per bucket, so the steady
state never recompiles regardless of how ragged the arrival process is.
The loop is a discrete-event simulation over virtual time — deterministic
given a trace, while service times can still be *measured* from the real
jitted computation (``service_model="measured"``) or pinned
(``service_model="fixed"``) for tests.

Per tick the gateway:
  1. admits arrivals into a bounded queue (admission control: beyond
     ``max_queue`` the request is rejected and counted — backpressure is
     explicit, not an OOM);
  2. flushes a batch when the largest bucket fills OR the oldest queued
     request hits its ``max_delay_s`` deadline, padding up to the smallest
     bucket that fits;
  3. runs the two pipeline stages (at-sensor stage feeds the link; the
     host stage occupies the server) and charges per-request telemetry.

The LM path (``PromptGateway``) fronts the family-generic slot batcher the
same way: arrivals admit into slots as they free up, one batched decode
tick per virtual-time step.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lenet
from repro.serve.gateway import frontend as fe
from repro.serve.gateway.sensors import Arrival
from repro.serve.gateway.slots import ContinuousBatcher, Request
from repro.serve.gateway.telemetry import (E_LINK_PJ_PER_BYTE, RequestRecord,
                                           Telemetry)


@dataclasses.dataclass(frozen=True)
class GatewayConfig:
    bucket_sizes: tuple[int, ...] = (1, 2, 4, 8, 16, 32)
    max_queue: int = 128             # admission control bound
    max_delay_s: float = 0.02        # oldest-request flush deadline
    link_mbps: float = 32.0          # sensor->host link bandwidth (Mbit/s)
    service_model: str = "measured"  # "measured" | "fixed"
    fixed_service_s: float = 0.0     # per-batch service time for "fixed"

    def __post_init__(self):
        assert tuple(sorted(self.bucket_sizes)) == tuple(self.bucket_sizes)


class MicroBatchGateway:
    """The frame path: sensor fleet -> buckets -> frontend offload -> tail."""

    def __init__(self, cfg: GatewayConfig, spec: fe.FrontendSpec,
                 params=None, seed: int = 0):
        self.cfg = cfg
        self.spec = spec
        self.params = params if params is not None else \
            lenet.init(jax.random.key(seed), spec.lenet)
        # one fixed-shape entry point per bucket (never recompiles)
        self._sensor_fns = {
            bs: jax.jit(lambda p, x, _s=spec: fe.sensor_stage(p, x, _s))
            for bs in cfg.bucket_sizes}
        self._gateway_fns = {
            bs: jax.jit(lambda p, x, _s=spec: fe.gateway_stage(p, x, _s))
            for bs in cfg.bucket_sizes}
        self._frame_energy_nj = fe.frame_energy_nj(spec)
        self._link_bytes = fe.link_bytes_per_frame(spec)
        self._sensor_lat = fe.sensor_latency_s(spec)
        self._link_lat = self._link_bytes * 8 / (cfg.link_mbps * 1e6)

    # -- compile management -------------------------------------------------
    def warmup(self) -> None:
        """Compile every bucket up front (steady state then never compiles)."""
        for bs in self.cfg.bucket_sizes:
            x = jnp.zeros((bs, self.spec.lenet.image_size,
                           self.spec.lenet.image_size,
                           self.spec.lenet.channels), jnp.uint8)
            payload = self._sensor_fns[bs](self.params, x)
            jax.block_until_ready(self._gateway_fns[bs](self.params, payload))

    def compile_counts(self) -> dict[int, int]:
        """jit-cache sizes per bucket (tests assert these stay at 1)."""
        return {bs: self._sensor_fns[bs]._cache_size()
                + self._gateway_fns[bs]._cache_size()
                for bs in self.cfg.bucket_sizes}

    # -- one batch ----------------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        for bs in self.cfg.bucket_sizes:
            if bs >= n:
                return bs
        return self.cfg.bucket_sizes[-1]

    def _serve_batch(self, frames: np.ndarray, bs: int):
        """Returns (predictions, host_service_seconds)."""
        x = jnp.asarray(frames)
        payload = jax.block_until_ready(
            self._sensor_fns[bs](self.params, x))   # at-sensor (not server time)
        t0 = time.perf_counter()
        logits = jax.block_until_ready(
            self._gateway_fns[bs](self.params, payload))
        svc = time.perf_counter() - t0
        if self.cfg.service_model == "fixed":
            svc = self.cfg.fixed_service_s
        return np.asarray(jnp.argmax(logits, -1)), svc

    # -- the event loop -----------------------------------------------------
    def run(self, arrivals: list[Arrival],
            telemetry: Telemetry | None = None) -> Telemetry:
        tel = telemetry if telemetry is not None else Telemetry()
        arrivals = [a for a in arrivals if a.kind == "frame"]
        # payload hits the gateway queue after at-sensor compute + link time
        offset = self._sensor_lat + self._link_lat
        queue: deque[Arrival] = deque()
        max_bs = self.cfg.bucket_sizes[-1]
        now, i, n = 0.0, 0, len(arrivals)

        def admit_until(t: float):
            nonlocal i
            while i < n and arrivals[i].t + offset <= t:
                a = arrivals[i]
                i += 1
                if len(queue) >= self.cfg.max_queue:
                    tel.drop(a.uid, "frame")      # backpressure: reject
                else:
                    queue.append(a)

        while i < n or queue:
            if not queue:
                now = max(now, arrivals[i].t + offset)
            admit_until(now)
            if not queue:
                continue
            # wait (in virtual time) for a full bucket or the deadline
            deadline = queue[0].t + offset + self.cfg.max_delay_s
            while len(queue) < max_bs and i < n and \
                    arrivals[i].t + offset <= deadline:
                now = max(now, arrivals[i].t + offset)
                admit_until(now)
            if len(queue) < max_bs:
                now = max(now, deadline)
            batch = [queue.popleft()
                     for _ in range(min(len(queue), max_bs))]
            bs = self._bucket_for(len(batch))
            frames = np.zeros((bs,) + batch[0].payload.shape, np.uint8)
            for j, a in enumerate(batch):
                frames[j] = a.payload
            preds, svc = self._serve_batch(frames, bs)
            now += svc
            energy_nj = self._frame_energy_nj \
                + self._link_bytes * E_LINK_PJ_PER_BYTE * 1e-3
            for j, a in enumerate(batch):
                tel.record(RequestRecord(
                    uid=a.uid, endpoint=a.endpoint, kind="frame",
                    t_arrival=a.t, t_done=now, energy_nj=energy_nj,
                    link_bytes=self._link_bytes, output=int(preds[j])))
        return tel


def drive_prompt_loop(arrivals, tel: Telemetry, *, busy, queue_depth,
                      max_queue: int, submit, step, record) -> None:
    """The virtual-time event loop shared by the one-slice
    :class:`PromptGateway` and the sharded router (serve/shard/): drain
    arrivals into ``submit`` as virtual time reaches them (dropping, with
    accounting, beyond ``max_queue``), charge each ``step``'s measured
    wall time to the virtual clock, and ``record(req, now)`` every
    completion.  One driver means drop policy and clock accounting cannot
    drift between the two front doors.
    """
    now, i, n = 0.0, 0, len(arrivals)
    while i < n or busy():
        if not busy():
            now = max(now, arrivals[i].t)
        while i < n and arrivals[i].t <= now:
            a = arrivals[i]
            i += 1
            if queue_depth() >= max_queue:
                tel.drop(a.uid, "prompt")
                continue
            submit(a)
        t0 = time.perf_counter()
        finished = step()
        now += time.perf_counter() - t0
        for req in finished:
            record(req, now)


def record_prompt_completion(tel: Telemetry, req, now: float,
                             t_arrival: float, endpoint: int,
                             token_energy_nj: float, bytes_per_token: int,
                             energy_spec: "fe.FrontendSpec | None" = None
                             ) -> None:
    """Charge one finished LM request into the ledger — the single pricing
    path shared by :class:`PromptGateway` and the sharded router
    (serve/shard/router.py), so the energy model cannot drift between the
    one-slice and multi-slice front doors.

    Prefix-cache resumes skip the frontend compute for the shared prompt
    tokens (the link still carries every token); cross-slice migration
    bytes, when present on the request, are priced through
    :func:`frontend.migration_energy_nj`.
    """
    n_tokens = len(req.prompt) + len(req.generated)
    processed = n_tokens - req.prefill_tokens_skipped
    link = bytes_per_token * n_tokens
    energy_nj = token_energy_nj * processed \
        + link * E_LINK_PJ_PER_BYTE * 1e-3
    migration_bytes = getattr(req, "migration_bytes", 0)
    if migration_bytes and energy_spec is not None:
        energy_nj += fe.migration_energy_nj(energy_spec, migration_bytes)
    tel.record(RequestRecord(
        uid=req.uid, endpoint=endpoint, kind="prompt",
        t_arrival=t_arrival, t_done=now, energy_nj=energy_nj,
        link_bytes=link, output=req.generated[-1],
        kv_blocks=req.kv_blocks,
        prefix_hit_blocks=req.prefix_hit_blocks,
        prefill_tokens_skipped=req.prefill_tokens_skipped,
        energy_saved_nj=token_energy_nj * req.prefill_tokens_skipped,
        migration_bytes=migration_bytes,
        migrations=getattr(req, "migrations", 0)))


class PromptGateway:
    """The LM path: arrivals -> family-generic slot batcher, virtual time.

    Same contracts as the frame path: ``warmup`` pre-compiles prefill (per
    prompt length) and the batched decode so one-time XLA compilation never
    lands in the virtual clock, and admission is bounded by ``max_queue``
    (excess prompts are rejected and counted, not queued without bound).

    LM requests are charged energy the same way frames are: per processed
    token, the calibrated Table-3 model projected onto the embedding-row
    geometry (``frontend.lm_token_energy_nj``), plus link energy on the
    token bytes — so every request in the ledger, frame or prompt, carries
    a J/inference figure.  When the batcher runs over the paged KV adapter,
    the pool's counters are snapshotted into the telemetry at drain.
    """

    def __init__(self, batcher: ContinuousBatcher, max_new_tokens: int = 16,
                 bytes_per_token: int = 4, max_queue: int = 64,
                 energy_spec: fe.FrontendSpec | None = None):
        self.batcher = batcher
        self.max_new_tokens = max_new_tokens
        self.bytes_per_token = bytes_per_token
        self.max_queue = max_queue
        if energy_spec is None:
            energy_spec = fe.FrontendSpec()
        self.energy_spec = energy_spec
        self._token_energy_nj = fe.lm_token_energy_nj(
            energy_spec, batcher.adapter.cfg.d_model)

    def warmup(self, prompt_lens: tuple[int, ...], vocab: int = 2) -> None:
        """Drain one dummy request per prompt length through the batcher
        (compiles prefill for each length + the batched decode); adapters
        clear slot state on retire, so real traffic is unaffected.
        max_new_tokens=2 forces at least one decode tick — a 1-token budget
        would retire at admission and leave decode uncompiled."""
        for j, n in enumerate(prompt_lens):
            self.batcher.submit(Request(
                uid=-1 - j, prompt=np.zeros((n,), np.int32),
                max_new_tokens=2))
        self.batcher.run()

    def run(self, arrivals: list[Arrival],
            telemetry: Telemetry | None = None) -> Telemetry:
        tel = telemetry if telemetry is not None else Telemetry()
        arrivals = [a for a in arrivals if a.kind == "prompt"]
        arr_t = {a.uid: a.t for a in arrivals}
        arr_ep = {a.uid: a.endpoint for a in arrivals}
        drive_prompt_loop(
            arrivals, tel,
            busy=lambda: self.batcher.busy,
            queue_depth=lambda: len(self.batcher.pending),
            max_queue=self.max_queue,
            submit=lambda a: self.batcher.submit(Request(
                uid=a.uid, prompt=np.asarray(a.payload, np.int32),
                max_new_tokens=self.max_new_tokens)),
            step=self.batcher.step,
            record=lambda req, now: record_prompt_completion(
                tel, req, now, arr_t[req.uid], arr_ep[req.uid],
                self._token_energy_nj, self.bytes_per_token,
                self.energy_spec))
        pool_stats = getattr(self.batcher.adapter, "pool_stats", None)
        if pool_stats is not None:
            tel.record_pool(pool_stats())
        return tel
