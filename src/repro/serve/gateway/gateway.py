"""Async micro-batching front door over virtual time.

JetStream/SHARK-style serving shape: a small fixed set of padded batch
shapes ("buckets"), one pre-compiled entry point per bucket, so the steady
state never recompiles regardless of how ragged the arrival process is.
The loop is a discrete-event simulation over virtual time — deterministic
given a trace, while service times can still be *measured* from the real
jitted computation (``service_model="measured"``) or pinned
(``service_model="fixed"``) for tests.

Per tick the gateway:
  1. admits arrivals into a bounded queue (admission control: beyond
     ``max_queue`` the request is rejected and counted — backpressure is
     explicit, not an OOM);
  2. flushes a batch when the largest bucket fills OR the oldest queued
     request hits its ``max_delay_s`` deadline, padding up to the smallest
     bucket that fits;
  3. runs the two pipeline stages (at-sensor stage feeds the link; the
     host stage occupies the server) and charges per-request telemetry.

The LM path (``PromptGateway``) fronts the family-generic slot batcher the
same way: arrivals admit into slots as they free up, one batched decode
tick per virtual-time step.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lenet
from repro.serve.gateway import frontend as fe
from repro.serve.gateway.sensors import Arrival
from repro.serve.gateway.slots import ContinuousBatcher, Request
from repro.serve.gateway.telemetry import RequestRecord, Telemetry


@dataclasses.dataclass(frozen=True)
class GatewayConfig:
    bucket_sizes: tuple[int, ...] = (1, 2, 4, 8, 16, 32)
    max_queue: int = 128             # admission control bound
    max_delay_s: float = 0.02        # oldest-request flush deadline
    link_mbps: float = 32.0          # sensor->host link bandwidth (Mbit/s)
    service_model: str = "measured"  # "measured" | "fixed"
    fixed_service_s: float = 0.0     # per-batch service time for "fixed"

    def __post_init__(self):
        assert tuple(sorted(self.bucket_sizes)) == tuple(self.bucket_sizes)


class MicroBatchGateway:
    """The frame path: sensor fleet -> buckets -> frontend offload -> tail."""

    def __init__(self, cfg: GatewayConfig, spec: fe.FrontendSpec,
                 params=None, seed: int = 0):
        self.cfg = cfg
        self.spec = spec
        self.params = params if params is not None else \
            lenet.init(jax.random.key(seed), spec.lenet)
        # one fixed-shape entry point per bucket (never recompiles)
        self._sensor_fns = {
            bs: jax.jit(lambda p, x, _s=spec: fe.sensor_stage(p, x, _s))
            for bs in cfg.bucket_sizes}
        self._gateway_fns = {
            bs: jax.jit(lambda p, x, _s=spec: fe.gateway_stage(p, x, _s))
            for bs in cfg.bucket_sizes}
        self._frame_energy_nj = fe.frame_energy_nj(spec)
        self._link_bytes = fe.link_bytes_per_frame(spec)
        self._sensor_lat = fe.sensor_latency_s(spec)
        self._link_lat = self._link_bytes * 8 / (cfg.link_mbps * 1e6)

    # -- compile management -------------------------------------------------
    def warmup(self) -> None:
        """Compile every bucket up front (steady state then never compiles)."""
        for bs in self.cfg.bucket_sizes:
            x = jnp.zeros((bs, self.spec.lenet.image_size,
                           self.spec.lenet.image_size,
                           self.spec.lenet.channels), jnp.uint8)
            payload = self._sensor_fns[bs](self.params, x)
            jax.block_until_ready(self._gateway_fns[bs](self.params, payload))

    def compile_counts(self) -> dict[int, int]:
        """jit-cache sizes per bucket (tests assert these stay at 1)."""
        return {bs: self._sensor_fns[bs]._cache_size()
                + self._gateway_fns[bs]._cache_size()
                for bs in self.cfg.bucket_sizes}

    def jit_fns(self) -> dict[str, object]:
        """Named jitted entry points, for obs.RecompileDetector.track."""
        fns: dict[str, object] = {}
        for bs in self.cfg.bucket_sizes:
            fns[f"sensor_b{bs}"] = self._sensor_fns[bs]
            fns[f"gateway_b{bs}"] = self._gateway_fns[bs]
        return fns

    def cost_args(self) -> dict[str, tuple]:
        """``jit_fns`` paired with representative abstract arguments, for
        obs.costmodel roofline attribution (``fn.lower(*args)`` — shapes
        only, nothing executes)."""
        out: dict[str, tuple] = {}
        ln = self.spec.lenet
        for bs in self.cfg.bucket_sizes:
            x = jax.ShapeDtypeStruct(
                (bs, ln.image_size, ln.image_size, ln.channels), jnp.uint8)
            out[f"sensor_b{bs}"] = (self._sensor_fns[bs], (self.params, x))
            payload = jax.eval_shape(self._sensor_fns[bs], self.params, x)
            out[f"gateway_b{bs}"] = (self._gateway_fns[bs],
                                     (self.params, payload))
        return out

    # -- one batch ----------------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        for bs in self.cfg.bucket_sizes:
            if bs >= n:
                return bs
        return self.cfg.bucket_sizes[-1]

    def _serve_batch(self, frames: np.ndarray, bs: int):
        """Returns (predictions, host_service_seconds)."""
        x = jnp.asarray(frames)
        payload = jax.block_until_ready(
            self._sensor_fns[bs](self.params, x))   # at-sensor (not server time)
        t0 = time.perf_counter()
        logits = jax.block_until_ready(
            self._gateway_fns[bs](self.params, payload))
        svc = time.perf_counter() - t0
        if self.cfg.service_model == "fixed":
            svc = self.cfg.fixed_service_s
        return np.asarray(jnp.argmax(logits, -1)), svc

    # -- the event loop -----------------------------------------------------
    def run(self, arrivals: list[Arrival],
            telemetry: Telemetry | None = None, *,
            tracer=None, metrics=None, slo=None, flight=None,
            incident=None) -> Telemetry:
        # always-on flight mode: with a FlightRecorder but no tracer, spans
        # still flow — through a retention-free tracer whose only sink is
        # the bounded ring (nothing grows with run length)
        if flight is not None:
            from repro.serve.obs import Tracer
            if tracer is None:
                tracer = Tracer(retain=False, sink=flight)
            elif tracer.sink is None:
                tracer.sink = flight
            if metrics is not None and metrics.sink is None:
                metrics.sink = flight.observe_sample
        if incident is not None and incident.context_fn is None:
            incident.context_fn = self.debug_state
        tel = telemetry if telemetry is not None else Telemetry()
        arrivals = [a for a in arrivals if a.kind == "frame"]
        # payload hits the gateway queue after at-sensor compute + link time
        offset = self._sensor_lat + self._link_lat
        queue: deque[Arrival] = deque()
        max_bs = self.cfg.bucket_sizes[-1]
        now, i, n = 0.0, 0, len(arrivals)
        if metrics is not None:
            metrics.register("queue_depth", lambda: len(queue))
        # per-request energy attribution: the same addends, folded in the
        # same order, that land in each record's energy_nj — request spans
        # carry this dict so obs can check conservation bitwise
        parts = {"frontend_nj": self._frame_energy_nj,
                 "link_nj": fe.link_energy_nj(self._link_bytes)}
        energy_nj = 0.0
        for v in parts.values():
            energy_nj += v

        def admit_until(t: float):
            nonlocal i
            while i < n and arrivals[i].t + offset <= t:
                a = arrivals[i]
                i += 1
                rejected = len(queue) >= self.cfg.max_queue
                if rejected:
                    tel.drop(a.uid, "frame", "queue_full",
                             a.t + offset)    # backpressure: reject
                    if tracer is not None:
                        tracer.instant("drop", tid=a.uid, t=a.t + offset,
                                       args={"reason": "queue_full"})
                    if incident is not None:
                        incident.observe_drop(a.t + offset)
                else:
                    queue.append(a)
                if slo is not None:
                    # every admission decision is a drop_rate event; the
                    # burn engine sees rejections as budget burn
                    slo.observe_event("drop_rate", a.t + offset, rejected)

        while i < n or queue:
            if not queue:
                now = max(now, arrivals[i].t + offset)
            admit_until(now)
            if not queue:
                continue
            # wait (in virtual time) for a full bucket or the deadline
            deadline = queue[0].t + offset + self.cfg.max_delay_s
            while len(queue) < max_bs and i < n and \
                    arrivals[i].t + offset <= deadline:
                now = max(now, arrivals[i].t + offset)
                admit_until(now)
            if len(queue) < max_bs:
                now = max(now, deadline)
            batch = [queue.popleft()
                     for _ in range(min(len(queue), max_bs))]
            bs = self._bucket_for(len(batch))
            frames = np.zeros((bs,) + batch[0].payload.shape, np.uint8)
            for j, a in enumerate(batch):
                frames[j] = a.payload
            t_serve = now
            preds, svc = self._serve_batch(frames, bs)
            now += svc
            if tracer is not None:
                tracer.clock.advance(now)
                tracer.begin("batch", pid=1, tid=0, t=t_serve,
                             args={"bucket": bs, "n": len(batch)})
                tracer.end("batch", pid=1, tid=0, t=now)
            for j, a in enumerate(batch):
                if tracer is not None:
                    # the loop is virtual time, so the lifecycle is traced
                    # retroactively at completion with exact stamps
                    tracer.begin("request", tid=a.uid, t=a.t,
                                 args={"endpoint": a.endpoint})
                    tracer.begin("sensor_link", tid=a.uid, t=a.t)
                    tracer.end("sensor_link", tid=a.uid, t=a.t + offset)
                    tracer.begin("queue_wait", tid=a.uid, t=a.t + offset)
                    tracer.end("queue_wait", tid=a.uid, t=t_serve)
                    tracer.begin("serve", tid=a.uid, t=t_serve)
                    tracer.end("serve", tid=a.uid, t=now)
                    tracer.end("request", tid=a.uid, t=now,
                               args={"energy_parts": parts,
                                     "energy_nj": energy_nj})
                rec = RequestRecord(
                    uid=a.uid, endpoint=a.endpoint, kind="frame",
                    t_arrival=a.t, t_done=now, energy_nj=energy_nj,
                    link_bytes=self._link_bytes, output=int(preds[j]))
                tel.record(rec)
                if slo is not None:
                    slo.observe_record(rec)
            if slo is not None:
                slo.evaluate(now)
            if incident is not None:
                incident.poll(now)
            if metrics is not None:
                metrics.inc("frames_completed", len(batch))
                metrics.maybe_sample(now)
        if metrics is not None and metrics.samples:
            tel.record_series(metrics.samples)
        if incident is not None:
            incident.check_energy(tel, now)
        return tel

    def debug_state(self) -> dict:
        """Incident-bundle context: configuration + jit surface sizes (the
        frame path keeps no cross-run queue state)."""
        return {
            "kind": "frame_gateway",
            "config": dataclasses.asdict(self.cfg),
            "frontend": {"mode": self.spec.mode, "bits": self.spec.bits},
            "jit_cache_sizes": {name: fn._cache_size()
                                for name, fn in self.jit_fns().items()},
        }


def drive_prompt_loop(arrivals, tel: Telemetry, *, busy, queue_depth,
                      max_queue, submit, step, record,
                      clock=None, tracer=None, metrics=None,
                      slo=None, step_cost=None, incident=None) -> None:
    """The virtual-time event loop shared by the one-slice
    :class:`PromptGateway` and the sharded router (serve/shard/): drain
    arrivals into ``submit`` as virtual time reaches them (dropping, with
    accounting, beyond ``max_queue``), charge each ``step``'s measured
    wall time to the virtual clock, and ``record(req, now)`` every
    completion.  One driver means drop policy and clock accounting cannot
    drift between the two front doors.

    ``max_queue`` may be a callable returning the current bound — the
    SLO-driven backpressure path shrinks it under critical burn, so the
    gateway sheds early at admission instead of queueing work it already
    knows will miss its deadline.

    Observability (serve/obs/) rides on four optional hooks: ``clock``
    (a SimClock the loop advances, so the batcher can stamp dequeue/admit
    times), ``tracer`` (request/queue_wait spans open at submit; each
    ``step`` runs inside an ``anchor``/``release`` window so sub-tick
    spans interpolate between the tick's virtual endpoints), ``metrics``
    (interval snapshots after every tick), and ``slo`` (admission
    decisions feed the drop_rate objective; the burn engine evaluates
    once per tick, next to the metrics sampler).  All default to None,
    and the loop makes zero observability calls then.

    ``incident`` (an obs.IncidentCapture) observes every admission drop
    (the drop-burst trigger) and is polled once per tick for recompile
    leaks.  Its SLO ``warn -> critical`` trigger needs no loop hook: the
    pressure signal fires synchronously inside ``slo.evaluate`` below —
    *before* the next admission pass — so the bundle is on disk before the
    first pressure-shed drop is even decided.

    ``step_cost`` (optional, ``fn(wall_seconds) -> virtual_seconds``)
    re-prices a tick before it is charged to the clock.  The sharded
    router uses it to charge *concurrent-slice* time — slices are
    disjoint device groups that tick simultaneously in a real fleet, so
    a round costs the slowest slice's tick plus the router's serial
    overhead, not the sum a single-host simulation measures.  Mutually
    exclusive with ``tracer``: sub-tick spans interpolate real wall
    offsets inside each tick, which only stay inside the tick's virtual
    window under wall accounting (callers pass one or the other).
    """
    assert step_cost is None or tracer is None, \
        "step_cost re-pricing and wall-anchored tracing are exclusive"
    if tracer is not None and clock is None:
        clock = tracer.clock
    now, i, n = 0.0, 0, len(arrivals)
    while i < n or busy():
        if not busy():
            now = max(now, arrivals[i].t)
            if clock is not None:
                clock.advance(now)
        while i < n and arrivals[i].t <= now:
            a = arrivals[i]
            i += 1
            mq = max_queue() if callable(max_queue) else max_queue
            rejected = queue_depth() >= mq
            if slo is not None:
                slo.observe_event("drop_rate", now, rejected)
            if rejected:
                tel.drop(a.uid, "prompt", "queue_full", now)
                if tracer is not None:
                    tracer.instant("drop", tid=a.uid, t=now,
                                   args={"reason": "queue_full"})
                if incident is not None:
                    incident.observe_drop(now)
                continue
            if tracer is not None:
                # lifecycle span opens at *arrival* (the request waited
                # from a.t even if the loop reached it later)
                tracer.begin("request", tid=a.uid, t=a.t,
                             args={"endpoint": a.endpoint})
                tracer.begin("queue_wait", tid=a.uid, t=a.t)
            submit(a)
        if tracer is not None:
            tracer.anchor()
        t0 = time.perf_counter()
        finished = step()
        dt = time.perf_counter() - t0
        if step_cost is not None:
            dt = step_cost(dt)
        now += dt
        if clock is not None:
            clock.advance(now)
        if tracer is not None:
            tracer.release()
        for req in finished:
            record(req, now)
        # evaluate before sampling so the burn/state gauges the evaluation
        # pushes land in this tick's snapshot, not the next one
        if slo is not None:
            slo.evaluate(now)
        if incident is not None:
            incident.poll(now)
        if metrics is not None:
            metrics.maybe_sample(now)


def record_prompt_completion(tel: Telemetry, req, now: float,
                             t_arrival: float, endpoint: int,
                             token_energy_nj: float, bytes_per_token: int,
                             energy_spec: "fe.FrontendSpec | None" = None,
                             tracer=None, slo=None) -> None:
    """Charge one finished LM request into the ledger — the single pricing
    path shared by :class:`PromptGateway` and the sharded router
    (serve/shard/router.py), so the energy model cannot drift between the
    one-slice and multi-slice front doors.

    Prefix-cache resumes skip the frontend compute for the shared prompt
    tokens (the link still carries every token); cross-slice migration
    bytes, when present on the request, are priced through
    :func:`frontend.migration_energy_nj`.

    The stage-attributed parts (frontend prefill / frontend decode / link /
    migration — each an independent product, so the split itself introduces
    no rounding) are folded left-to-right into ``energy_nj`` and — when a
    ``tracer`` is attached — stamped onto the closing request span, so the
    span stream's energy sum reproduces the ledger total bitwise
    (``obs.Tracer.assert_energy_conserved``) and obs.costmodel can join
    per-stage nJ against the roofline stages.  An attached ``slo`` monitor
    observes the completion (TTFT / TPOT / queue-wait) as it is recorded.
    """
    n_tokens = len(req.prompt) + len(req.generated)
    processed = n_tokens - req.prefill_tokens_skipped
    link = bytes_per_token * n_tokens
    # tokens the batched decode tick produced vs tokens the prefill pass
    # processed (the first generated token comes out of prefill)
    decode_tok = max(0, len(req.generated) - 1)
    parts = {"frontend_prefill_nj": token_energy_nj
             * (processed - decode_tok),
             "frontend_decode_nj": token_energy_nj * decode_tok,
             "link_nj": fe.link_energy_nj(link)}
    migration_bytes = getattr(req, "migration_bytes", 0)
    if migration_bytes and energy_spec is not None:
        parts["migration_nj"] = fe.migration_energy_nj(energy_spec,
                                                       migration_bytes)
    energy_nj = 0.0
    for v in parts.values():
        energy_nj += v
    rec = RequestRecord(
        uid=req.uid, endpoint=endpoint, kind="prompt",
        t_arrival=t_arrival, t_done=now, energy_nj=energy_nj,
        link_bytes=link, output=req.generated[-1],
        kv_blocks=req.kv_blocks,
        prefix_hit_blocks=req.prefix_hit_blocks,
        prefill_tokens_skipped=req.prefill_tokens_skipped,
        energy_saved_nj=token_energy_nj * req.prefill_tokens_skipped,
        migration_bytes=migration_bytes,
        migrations=getattr(req, "migrations", 0),
        t_dequeue=getattr(req, "t_dequeue", -1.0),
        t_admit=getattr(req, "t_admit", -1.0),
        tokens_out=len(req.generated))
    tel.record(rec)
    if slo is not None:
        slo.observe_record(rec)
    if tracer is not None:
        if tracer.innermost(tid=req.uid) != "request":
            # the request's whole active life predates the tracer wiring
            # (direct submit + step before run): open its span late, at
            # arrival, so every completed uid still carries a request span
            # with conserved energy parts
            tracer.begin("request", tid=req.uid, t=t_arrival,
                         args={"late_open": True})
        tracer.end("request", tid=req.uid, t=now,
                   args={"energy_parts": parts, "energy_nj": energy_nj,
                         "tokens_out": len(req.generated)})


class PromptGateway:
    """The LM path: arrivals -> family-generic slot batcher, virtual time.

    Same contracts as the frame path: ``warmup`` pre-compiles prefill (per
    prompt length) and the batched decode so one-time XLA compilation never
    lands in the virtual clock, and admission is bounded by ``max_queue``
    (excess prompts are rejected and counted, not queued without bound).

    LM requests are charged energy the same way frames are: per processed
    token, the calibrated Table-3 model projected onto the embedding-row
    geometry (``frontend.lm_token_energy_nj``), plus link energy on the
    token bytes — so every request in the ledger, frame or prompt, carries
    a J/inference figure.  When the batcher runs over the paged KV adapter,
    the pool's counters are snapshotted into the telemetry at drain.
    """

    def __init__(self, batcher: ContinuousBatcher, max_new_tokens: int = 16,
                 bytes_per_token: int = 4, max_queue: int = 64,
                 energy_spec: fe.FrontendSpec | None = None,
                 tracer=None, metrics=None, slo=None,
                 shed_factor: int = 4, flight=None, incident=None):
        self.batcher = batcher
        self.max_new_tokens = max_new_tokens
        self.bytes_per_token = bytes_per_token
        self.max_queue = max_queue
        if energy_spec is None:
            energy_spec = fe.FrontendSpec()
        self.energy_spec = energy_spec
        self._token_energy_nj = fe.lm_token_energy_nj(
            energy_spec, batcher.adapter.cfg.d_model)
        # observability (serve/obs/): all default None and are wired into
        # the batcher only for the duration of run() — warmup stays
        # untraced and a gateway without a tracer makes zero obs calls
        self.tracer = tracer
        self.metrics = metrics
        self.slo = slo
        # flight recorder + incident forensics (serve/obs/flight.py,
        # incident.py): with a FlightRecorder but no tracer, run() creates
        # a retention-free tracer whose only sink is the bounded ring —
        # always-on span capture without an unbounded event list; an
        # IncidentCapture snapshots the ring (plus debug_state) on its
        # triggers, and capture_incident() does so on demand
        self.flight = flight
        self.incident = incident
        if incident is not None and incident.context_fn is None:
            incident.context_fn = self.debug_state
        # SLO-driven backpressure: subscribe to the monitor's pressure
        # signal; under critical burn the admission bound shrinks by
        # shed_factor, so overload sheds at the door (cheap, counted)
        # instead of queueing work that will blow its deadline anyway.
        # The same hook is where the planned closed-loop bit-width
        # degradation controller will step endpoints down the stream-length
        # ladder (ROADMAP).
        self.shed_factor = shed_factor
        self._shedding = False
        if slo is not None:
            slo.pressure.subscribe(self._on_pressure)

    def _on_pressure(self, event) -> None:
        self._shedding = event.state == "critical"

    def _admit_bound(self) -> int:
        if self._shedding:
            return max(1, self.max_queue // self.shed_factor)
        return self.max_queue

    def jit_fns(self) -> dict[str, object]:
        """Named jitted entry points, for obs.RecompileDetector.track."""
        fns = getattr(self.batcher.adapter, "jit_fns", None)
        return fns() if fns is not None else {}

    def cost_args(self) -> dict[str, tuple]:
        """Adapter stages + representative args, for obs.costmodel
        roofline attribution (see the adapters' ``cost_args``)."""
        fns = getattr(self.batcher.adapter, "cost_args", None)
        return fns() if fns is not None else {}

    def warmup(self, prompt_lens: tuple[int, ...], vocab: int = 2) -> None:
        """Drain one dummy request per prompt length through the batcher
        (compiles prefill for each length + the batched decode); adapters
        clear slot state on retire, so real traffic is unaffected.
        max_new_tokens=2 forces at least one decode tick — a 1-token budget
        would retire at admission and leave decode uncompiled."""
        for j, n in enumerate(prompt_lens):
            self.batcher.submit(Request(
                uid=-1 - j, prompt=np.zeros((n,), np.int32),
                max_new_tokens=2))
        self.batcher.run()

    def run(self, arrivals: list[Arrival],
            telemetry: Telemetry | None = None) -> Telemetry:
        tel = telemetry if telemetry is not None else Telemetry()
        arrivals = [a for a in arrivals if a.kind == "prompt"]
        arr_t = {a.uid: a.t for a in arrivals}
        arr_ep = {a.uid: a.endpoint for a in arrivals}
        pool_stats = getattr(self.batcher.adapter, "pool_stats", None)
        if self.flight is not None:
            from repro.serve.obs import Tracer
            if self.tracer is None:
                # always-on mode: the bounded ring is the only retention
                self.tracer = Tracer(retain=False, sink=self.flight)
            elif self.tracer.sink is None:
                self.tracer.sink = self.flight
            if self.metrics is not None and self.metrics.sink is None:
                self.metrics.sink = self.flight.observe_sample
        # SLO timestamps (t_dequeue/t_admit) need a shared virtual clock
        # even when no tracer is attached
        from repro.serve.obs import SimClock
        clock = self.tracer.clock if self.tracer is not None else SimClock()
        if self.metrics is not None:
            m = self.metrics
            m.register("queue_depth", lambda: len(self.batcher.pending))
            m.register("active_slots", lambda: self.batcher.last_active)
            pool = getattr(self.batcher.adapter, "pool", None)
            if pool is not None:
                for name in pool.gauges():
                    m.register(name, lambda n=name: pool.gauges()[n])
            cascade = getattr(self.batcher.adapter, "cascade_stats", None)
            if cascade is not None and \
                    getattr(self.batcher.adapter, "backend", None) \
                    == "cascade":
                # cascade_* gauges -> repro_cascade_* OpenMetrics families
                for key in ("groups", "grouped_lanes", "prefix_rows",
                            "prefix_rows_flat"):
                    m.register(f"cascade_{key}",
                               lambda k=key: cascade()[k])
        self.batcher.clock = clock
        self.batcher.tracer = self.tracer
        self.batcher.adapter.tracer = self.tracer
        try:
            drive_prompt_loop(
                arrivals, tel,
                busy=lambda: self.batcher.busy,
                queue_depth=lambda: len(self.batcher.pending),
                max_queue=self._admit_bound,
                submit=lambda a: self.batcher.submit(Request(
                    uid=a.uid, prompt=np.asarray(a.payload, np.int32),
                    max_new_tokens=self.max_new_tokens)),
                step=self.batcher.step,
                record=lambda req, now: record_prompt_completion(
                    tel, req, now, arr_t[req.uid], arr_ep[req.uid],
                    self._token_energy_nj, self.bytes_per_token,
                    self.energy_spec, tracer=self.tracer, slo=self.slo),
                clock=clock, tracer=self.tracer, metrics=self.metrics,
                slo=self.slo, incident=self.incident)
        finally:
            self.batcher.clock = None
            self.batcher.tracer = None
            self.batcher.adapter.tracer = None
        if pool_stats is not None:
            tel.record_pool(pool_stats())
        if self.metrics is not None and self.metrics.samples:
            tel.record_series(self.metrics.samples)
        if self.incident is not None:
            self.incident.check_energy(tel, clock.t)
        return tel

    def debug_state(self) -> dict:
        """Forensic gateway state for incident bundles: batcher occupancy,
        pool + radix debug snapshot, cascade grouping, jit-cache sizes —
        aggregate state only, no request payloads."""
        ad = self.batcher.adapter
        state: dict = {
            "kind": "prompt_gateway",
            "max_new_tokens": self.max_new_tokens,
            "max_queue": self.max_queue,
            "admit_bound": self._admit_bound(),
            "shedding": self._shedding,
            "batcher": self.batcher.debug_state(),
            "jit_cache_sizes": {name: fn._cache_size()
                                for name, fn in self.jit_fns().items()},
        }
        pool = getattr(ad, "pool", None)
        if pool is not None:
            state["pool"] = pool.debug_snapshot()
        if getattr(ad, "backend", None) is not None:
            state["backend"] = ad.backend
        if getattr(ad, "backend", None) == "cascade":
            state["cascade"] = ad.cascade_stats()
        return state

    def capture_incident(self, reason: str, *, extra: dict | None = None):
        """Explicit forensic capture: snapshot the flight ring + debug
        state into a bundle right now (trigger ``explicit``).  Requires an
        IncidentCapture attached at construction."""
        if self.incident is None:
            raise RuntimeError(
                "capture_incident() needs an IncidentCapture attached "
                "(PromptGateway(..., incident=...) or "
                "ServeSpec(incident_dir=...))")
        return self.incident.capture(reason, extra=extra)
