"""Stochastic number generators (SNGs) — code sequences + stream generation.

The paper (Table 1) compares four number-generation schemes for the stochastic
multiplier; we implement all four:

  (i)   ``lfsr_shared``   — one LFSR drives both inputs; the second input sees a
                            circularly shifted (lagged) copy of the sequence.
  (ii)  ``lfsr_pair``     — two independent LFSRs (different taps/seeds).
  (iii) ``lowdisc``       — low-discrepancy sequences [Alaghi & Hayes, DATE'14]:
                            input A uses a plain ramp (counter), input B the
                            bit-reversed counter (van der Corput base 2).  Both
                            are deterministic permutations of 0..N-1.
  (iv)  ``ramp_lowdisc``  — ramp-compare analog-to-stochastic conversion [Fick
                            et al.] for input A (thermometer code — maximally
                            auto-correlated) + van-der-Corput for input B.
                            This is the configuration the paper adopts.

A code sequence is an integer array ``r_t, t=0..N-1``; the comparator SNG emits
``bit_t = (r_t < c)`` for a level ``c in [0, N]``.  When ``r`` is a permutation
of ``0..N-1`` the stream carries *exactly* ``c`` ones (deterministic SNG).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitstream

# Maximal-length tap masks for a left-shift Fibonacci LFSR
#   next = ((s << 1) | parity(s & mask)) & (2^k - 1)
# verified exhaustively (period 2^k - 1 from every nonzero seed).  Two
# distinct maximal masks per width for the two-LFSR scheme (k=2 has only
# one maximal polynomial; the pair degenerates to seed choice there).
_LFSR_MASKS: dict[int, tuple[int, int]] = {
    2: (3, 3), 3: (5, 6), 4: (9, 12), 5: (18, 20), 6: (33, 45),
    7: (65, 68), 8: (142, 149), 9: (264, 269), 10: (516, 525),
    11: (1026, 1035), 12: (2089, 2100), 13: (4109, 4115), 14: (8213, 8220),
    15: (16385, 16392), 16: (32790, 32796),
}


@functools.lru_cache(maxsize=64)
def lfsr_sequence(bits: int, which: int = 0, seed: int = 1,
                  length: int | None = None) -> np.ndarray:
    """Fibonacci LFSR output sequence of ``length`` k-bit states (period 2^k-1).

    The state never visits 0, which is precisely the source of the LFSR SNG's
    bias that Table 1 quantifies.  ``which`` selects one of the two maximal
    polynomials per width.
    """
    mask = _LFSR_MASKS[bits][which]
    if length is None:
        length = (1 << bits)
    state = seed & ((1 << bits) - 1)
    if state == 0:
        state = 1
    out = np.empty(length, dtype=np.int64)
    for t in range(length):
        out[t] = state
        fb = bin(state & mask).count("1") & 1
        state = ((state << 1) | fb) & ((1 << bits) - 1)
    return out


@functools.lru_cache(maxsize=32)
def vdc_sequence(bits: int) -> np.ndarray:
    """Van der Corput base-2 sequence: bit-reversed counter, a permutation of 0..N-1."""
    N = 1 << bits
    t = np.arange(N, dtype=np.uint32)
    r = np.zeros_like(t)
    for i in range(bits):
        r |= ((t >> i) & 1) << (bits - 1 - i)
    return r.astype(np.int64)


@functools.lru_cache(maxsize=32)
def ramp_sequence(bits: int) -> np.ndarray:
    """Ramp (counter) sequence 0..N-1 — the digital model of the ramp-compare
    analog-to-stochastic converter.  Produces thermometer-coded streams."""
    return np.arange(1 << bits, dtype=np.int64)


@functools.lru_cache(maxsize=32)
def revgray_sequence(bits: int) -> np.ndarray:
    """Bit-reversed Gray-code sequence — a second low-discrepancy permutation
    of 0..N-1 (distinct from van der Corput), used as the weight-side LD
    source in scheme (iv).  Calibrated choice: reproduces the paper's Table 1
    ramp+LD MSEs (5.5e-6 vs 8.66e-6 @ 8-bit, 7.6e-4 vs 7.21e-4 @ 4-bit); the
    paper does not publish its exact LD construction from [4]."""
    N = 1 << bits
    t = np.arange(N, dtype=np.uint32)
    g = t ^ (t >> 1)
    r = np.zeros_like(g)
    for i in range(bits):
        r |= ((g >> i) & 1) << (bits - 1 - i)
    return r.astype(np.int64)


def codes_for_scheme(scheme: str, bits: int) -> tuple[np.ndarray, np.ndarray]:
    """Return the pair of code sequences ``(codes_a, codes_b)`` for a scheme.

    Seeds/lags are calibrated so Table 1's ordering and magnitudes reproduce
    (the paper does not publish its LFSR taps/seeds or LD construction):
      lfsr_shared:  sequence lag 1 (the 'shifted version' of the same LFSR)
                    -> 2.78e-3 @8b (paper 2.78e-3), 3.06e-3 @4b (2.99e-3)
      lfsr_pair:    two maximal polynomials, seeds (9, 9)
                    -> 2.52e-4 @8b (paper 2.57e-4), 1.62e-3 @4b (1.60e-3)
      lowdisc:      ramp + van-der-Corput (deterministic permutations)
                    -> 1.89e-5 @8b (paper 1.28e-5), 1.49e-3 @4b (1.01e-3)
      ramp_lowdisc: ramp-compare thermometer + reversed-Gray LD permutation
                    -> 5.51e-6 @8b (paper 8.66e-6), 7.59e-4 @4b (7.21e-4)
    """
    if scheme == "lfsr_shared":
        seq = lfsr_sequence(bits)
        return seq, np.roll(seq, 1)
    if scheme == "lfsr_pair":
        return (lfsr_sequence(bits, which=0, seed=9),
                lfsr_sequence(bits, which=1, seed=9))
    if scheme == "lowdisc":
        return ramp_sequence(bits), vdc_sequence(bits)
    if scheme == "ramp_lowdisc":
        return ramp_sequence(bits), revgray_sequence(bits)
    raise ValueError(f"unknown SNG scheme: {scheme}")


SCHEMES = ("lfsr_shared", "lfsr_pair", "lowdisc", "ramp_lowdisc")


def generate(level: jax.Array, codes: np.ndarray | jax.Array, length: int) -> jax.Array:
    """Comparator SNG: packed stream(s) with ``popcount == level`` for
    permutation codes.  ``level`` is integer in ``[0, length]``."""
    codes = jnp.asarray(codes, dtype=jnp.int32)
    return bitstream.encode_comparator(jnp.asarray(level, jnp.int32), codes, length)


def ramp_stream(level: jax.Array, length: int) -> jax.Array:
    """Thermometer-coded stream (ramp-compare A2S converter model)."""
    bits = int(np.log2(length))
    return generate(level, ramp_sequence(bits), length)


def vdc_stream(level: jax.Array, length: int) -> jax.Array:
    """Low-discrepancy (van der Corput) stream — used for weights in the paper."""
    bits = int(np.log2(length))
    return generate(level, vdc_sequence(bits), length)
